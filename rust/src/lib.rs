//! PaCA: Partial Connection Adaptation for Efficient Fine-Tuning
//! (Woo et al., ICLR 2025) — a three-layer rust + JAX + Pallas
//! reproduction.
//!
//! Layering (DESIGN.md §2):
//!   * L1 (python/compile/kernels): Pallas kernels for PaCA's ∇P
//!     hot-spot, NF4 dequant, and the LoRA baseline.
//!   * L2 (python/compile): JAX transformer/ViT with pluggable PEFT
//!     parameterizations, lowered ONCE to HLO text.
//!   * L3 (this crate): the fine-tuning coordinator — config, data
//!     pipeline, PJRT runtime, training orchestration, device cost
//!     model, memory accountant, the paper's benchmark harness, and
//!     the multi-tenant adapter-serving subsystem (serve/).
//!
//! Python never runs on the training path: after `make artifacts` the
//! `paca` binary is self-contained.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod exps;
pub mod init;
pub mod manifest;
pub mod memory;
pub mod metrics;
pub mod nf4;
pub mod peft;
pub mod runtime;
pub mod serve;
pub mod simulator;
pub mod tensor;
pub mod util;

/// Locate the artifacts directory: $PACA_ARTIFACTS, else walk up from
/// the cwd looking for artifacts/manifest.json (tests and benches run
/// from nested target dirs).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PACA_ARTIFACTS") {
        return p.into();
    }
    let mut here = std::env::current_dir().unwrap_or_default();
    loop {
        let cand = here.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !here.pop() {
            return "artifacts".into();
        }
    }
}
