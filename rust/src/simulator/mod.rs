//! Analytic device cost model (substrate for the paper's A100 / Gaudi2
//! testbeds, which this environment does not have — DESIGN.md §4).
//!
//! Per-iteration training time = Σ over kernels of
//!     max(flops / (peak·eff), bytes / bw) + launch_overhead
//! with kernel counts that encode the paper's central systems argument:
//! LoRA-family adapters run as *extra serialized kernels* after each
//! frozen GEMM (they add launch + small-GEMM overhead out of proportion
//! to their FLOPs), while PaCA's forward/backward kernels are exactly
//! the frozen model's, plus one tiny ∇P GEMM per target in backward.
//!
//! Calibration targets (see EXPERIMENTS.md): Fig 2 (LoRA fwd +33% over
//! Full-FT at equal FLOPs; PaCA −19% total vs LoRA), Table 1 timing
//! ratios, Fig 3 throughput curves on both device profiles.

use crate::manifest::ModelInfo;
use crate::memory;

#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Peak bf16 matmul throughput, FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// HBM capacity, bytes.
    pub capacity: f64,
    /// Effective per-kernel dispatch overhead, seconds (launch + small-
    /// GEMM underutilization; the quantity behind the paper's Fig 2).
    pub launch_s: f64,
    /// Achievable fraction of peak for well-shaped GEMMs.
    pub gemm_eff: f64,
    /// Per-adapter-target serialized-path overhead: framework dispatch +
    /// unfused dropout/scale/add elementwise around the two adapter
    /// GEMMs. Calibrated so LoRA's forward lands ~+33% over Full-FT at
    /// the paper's Fig-2 operating point.
    pub adapter_overhead_s: f64,
}

pub const A100_80G: DeviceProfile = DeviceProfile {
    name: "A100-80GB",
    peak_flops: 312e12,
    mem_bw: 2.039e12,
    capacity: 80e9,
    launch_s: 10e-6,
    gemm_eff: 0.45,
    adapter_overhead_s: 130e-6,
};

pub const GAUDI2: DeviceProfile = DeviceProfile {
    name: "Gaudi2",
    peak_flops: 432e12,
    mem_bw: 2.45e12,
    capacity: 96e9,
    launch_s: 8e-6,
    gemm_eff: 0.40,
    adapter_overhead_s: 110e-6,
};

pub fn profile(name: &str) -> Option<&'static DeviceProfile> {
    match name {
        "a100" | "A100" | "A100-80GB" => Some(&A100_80G),
        "gaudi2" | "Gaudi2" => Some(&GAUDI2),
        _ => None,
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTime {
    pub forward_s: f64,
    pub backward_s: f64,
    pub optimizer_s: f64,
}

impl PhaseTime {
    pub fn total_s(&self) -> f64 {
        self.forward_s + self.backward_s + self.optimizer_s
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct FlopCount {
    pub forward: f64,
    pub backward: f64,
}

impl FlopCount {
    pub fn total(&self) -> f64 {
        self.forward + self.backward
    }
}

/// One GEMM's wall time on the roofline + dispatch overhead.
/// (pub(crate): the serving cost model in serve::cost builds its
/// forward-only path from the same primitives.)
pub(crate) fn gemm_time(dev: &DeviceProfile, m: f64, k: f64, n: f64) -> f64 {
    let flops = 2.0 * m * k * n;
    let bytes = 2.0 * (m * k + k * n + m * n);
    (flops / (dev.peak_flops * dev.gemm_eff)).max(bytes / dev.mem_bw)
        + dev.launch_s
}

/// Elementwise / bandwidth-bound pass over `bytes`.
pub(crate) fn bw_time(dev: &DeviceProfile, bytes: f64) -> f64 {
    bytes / dev.mem_bw + dev.launch_s
}

/// FLOPs per training iteration (paper Fig 2a).
pub fn iteration_flops(m: &ModelInfo, method: &str, rank: usize,
                       batch: usize, seq: usize) -> FlopCount {
    let t = (batch * seq) as f64;
    let d = m.d_model as f64;
    let hd = d / m.n_heads as f64;
    let s = seq as f64;
    let r = rank as f64;
    let layers = m.n_layers as f64;

    let target_sum = memory::target_params_per_layer(m);
    let gemm_fwd = 2.0 * t * target_sum;
    let attn_fwd = 2.0 * 2.0 * (batch as f64) * (m.n_heads as f64)
        * s * s * hd;
    let head_fwd = 2.0 * t * d * m.vocab as f64;
    let embed_norm = t * d * 12.0 * layers;
    let fwd_core = layers * (gemm_fwd + attn_fwd) + head_fwd + embed_norm;

    // Adapter forward FLOPs (LoRA family only).
    let adapter_fwd = match method {
        "lora" | "qlora" | "dora" => {
            layers * m.linear_shapes().iter()
                .map(|(_, i, o)| 2.0 * t * r * (*i as f64 + *o as f64))
                .sum::<f64>()
        }
        "moslora" => {
            layers * m.linear_shapes().iter()
                .map(|(_, i, o)| 2.0 * t * r
                     * (*i as f64 + *o as f64 + r))
                .sum::<f64>()
        }
        _ => 0.0,
    };

    // Backward: dX everywhere (≈ forward cost); dW only where trained.
    let dx = fwd_core;
    let dw = match method {
        "full" => layers * gemm_fwd + head_fwd,
        // adapters: dA + dB per target ≈ adapter_fwd again; plus dX
        // through the adapters.
        "lora" | "qlora" | "dora" | "moslora" => 2.0 * adapter_fwd,
        // PaCA: one (r × T)·(T × d_out) GEMM per target (Eq. 9).
        "paca" | "qpaca" => layers * m.linear_shapes().iter()
            .map(|(_, _i, o)| 2.0 * t * r * (*o as f64)).sum::<f64>(),
        _ => 0.0,
    };

    FlopCount { forward: fwd_core + adapter_fwd, backward: dx + dw }
}

/// Per-iteration wall time (paper Fig 2b / Table 1 Time columns).
pub fn iteration_time(dev: &DeviceProfile, m: &ModelInfo, method: &str,
                      rank: usize, batch: usize, seq: usize) -> PhaseTime {
    let t = (batch * seq) as f64;
    let d = m.d_model as f64;
    let s = seq as f64;
    let r = rank as f64;
    let b = batch as f64;
    let h = m.n_heads as f64;
    let hd = d / h;
    let layers = m.n_layers as usize;

    let mut fwd = 0.0;
    let mut bwd = 0.0;

    for _ in 0..layers {
        for (_, din, dout) in m.linear_shapes() {
            let (din, dout) = (din as f64, dout as f64);
            // frozen GEMM fwd + its dX in bwd
            fwd += gemm_time(dev, t, din, dout);
            bwd += gemm_time(dev, t, dout, din);
            match method {
                "full" => {
                    bwd += gemm_time(dev, din, t, dout); // dW
                }
                "lora" | "qlora" | "dora" | "moslora" => {
                    // two serialized adapter GEMMs in fwd, plus the
                    // framework overhead of the serialized path …
                    fwd += gemm_time(dev, t, din, r)
                        + gemm_time(dev, t, r, dout)
                        + dev.adapter_overhead_s;
                    if method == "moslora" {
                        fwd += gemm_time(dev, t, r, r);
                    }
                    // … and four GEMMs + 2× overhead in bwd
                    // (dX_mid, dX, dB, dA).
                    bwd += gemm_time(dev, t, dout, r)
                        + gemm_time(dev, t, r, din)
                        + gemm_time(dev, r, t, dout)
                        + gemm_time(dev, din, t, r)
                        + 2.0 * dev.adapter_overhead_s;
                    if method == "dora" {
                        // DoRA differentiates through the weight-norm
                        // decomposition: it must materialize the FULL
                        // dW_dir = Xᵀ dY (a Full-FT-sized GEMM) before
                        // projecting onto dA/dB/dm — the reason DoRA is
                        // ~2× LoRA's step time in Table 1.
                        fwd += gemm_time(dev, din, r, dout)  // B·A
                            + bw_time(dev, 2.0 * din * dout * 2.0);
                        bwd += gemm_time(dev, din, t, dout)  // dW_dir
                            + gemm_time(dev, din, dout, r)   // →dA
                            + gemm_time(dev, r, din, dout)   // →dB
                            + bw_time(dev, 4.0 * din * dout * 2.0);
                    }
                }
                "paca" | "qpaca" => {
                    // the ONLY extra op: ∇P, serialized after dX (§3.1)
                    bwd += gemm_time(dev, r, t, dout);
                }
                _ => {}
            }
            if method == "qlora" || method == "qpaca" {
                // NF4 dequant of the frozen weight in fwd and bwd.
                let wbytes = din * dout * 0.5625;
                fwd += bw_time(dev, wbytes + din * dout * 2.0);
                bwd += bw_time(dev, wbytes + din * dout * 2.0);
            }
        }
        // attention: QKᵀ and PV fwd, ×2 in bwd, plus softmax/rope
        // elementwise traffic.
        let attn = 2.0 * (gemm_time(dev, b * h * s, hd, s)
                          + gemm_time(dev, b * h * s, s, hd));
        fwd += attn / 2.0;
        bwd += attn;
        fwd += bw_time(dev, t * d * 12.0);
        bwd += bw_time(dev, t * d * 24.0);
    }
    // LM head + embedding.
    fwd += gemm_time(dev, t, d, m.vocab as f64);
    bwd += gemm_time(dev, t, m.vocab as f64, d)
        + if method == "full" {
            gemm_time(dev, d, t, m.vocab as f64)
        } else {
            0.0
        };

    // Optimizer: read grad + m + v, write p + m + v (fp32 moments).
    let trainable = memory::trainable_params(m, method, rank);
    let optimizer = bw_time(dev, trainable * 20.0) + 50.0 * dev.launch_s;

    PhaseTime { forward_s: fwd, backward_s: bwd, optimizer_s: optimizer }
}

/// Training throughput in sequences/s at (batch, seq) — Fig 3's y-axis.
pub fn throughput_seq_per_s(dev: &DeviceProfile, m: &ModelInfo,
                            method: &str, rank: usize, batch: usize,
                            seq: usize) -> f64 {
    batch as f64
        / iteration_time(dev, m, method, rank, batch, seq).total_s()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llama3_8b() -> ModelInfo {
        ModelInfo { name: "llama3-8b".into(), vocab: 128256,
                    d_model: 4096, n_layers: 32, n_heads: 32,
                    d_ff: 14336, max_seq: 8192, profile_only: true }
    }

    #[test]
    fn fig2a_flops_lora_below_full() {
        // Paper: LoRA ≈ 33% fewer FLOPs than Full-FT per iteration.
        let m = llama3_8b();
        let full = iteration_flops(&m, "full", 8, 2, 512).total();
        let lora = iteration_flops(&m, "lora", 8, 2, 512).total();
        let paca = iteration_flops(&m, "paca", 8, 2, 512).total();
        assert!(lora < 0.85 * full, "lora/full = {}", lora / full);
        assert!(paca <= lora);
        // fwd FLOPs nearly equal across methods
        let ff = iteration_flops(&m, "full", 8, 2, 512).forward;
        let pf = iteration_flops(&m, "paca", 8, 2, 512).forward;
        assert!((pf / ff - 1.0).abs() < 0.02);
    }

    #[test]
    fn fig2b_lora_fwd_overhead_but_equal_flops() {
        // Paper: LoRA forward ~33% slower than Full-FT despite ~equal
        // forward FLOPs (serialized adapter kernels).
        let m = llama3_8b();
        let full = iteration_time(&A100_80G, &m, "full", 8, 2, 512);
        let lora = iteration_time(&A100_80G, &m, "lora", 8, 2, 512);
        let ratio = lora.forward_s / full.forward_s;
        assert!(ratio > 1.15 && ratio < 1.6, "fwd ratio {ratio}");
    }

    #[test]
    fn fig2b_paca_faster_than_lora() {
        // Paper: PaCA total −19% vs LoRA (fwd −18%, bwd −20%).
        let m = llama3_8b();
        let lora = iteration_time(&A100_80G, &m, "lora", 8, 2, 512);
        let paca = iteration_time(&A100_80G, &m, "paca", 8, 2, 512);
        let total = paca.total_s() / lora.total_s();
        assert!(total < 0.92 && total > 0.65, "paca/lora {total}");
        assert!(paca.forward_s < lora.forward_s);
        assert!(paca.backward_s < lora.backward_s);
    }

    #[test]
    fn paca_bwd_slower_than_fwd() {
        // Paper §3.1 observation: PaCA backward ≈ +17% over forward
        // (dX and ∇P are serialized).
        let m = llama3_8b();
        let p = iteration_time(&A100_80G, &m, "paca", 8, 2, 512);
        assert!(p.backward_s > p.forward_s);
    }

    #[test]
    fn dora_much_slower() {
        // Paper Table 1: DoRA ~2x LoRA time.
        let m = llama3_8b();
        let lora = iteration_time(&A100_80G, &m, "lora", 8, 8, 512);
        let dora = iteration_time(&A100_80G, &m, "dora", 8, 8, 512);
        assert!(dora.total_s() > 1.3 * lora.total_s());
    }

    #[test]
    fn quant_methods_pay_dequant_overhead() {
        // Paper §4.3: Q-variants slower than fp16 counterparts; QPaCA
        // still faster than QLoRA.
        let m = llama3_8b();
        let lora = iteration_time(&A100_80G, &m, "lora", 64, 16, 768);
        let qlora = iteration_time(&A100_80G, &m, "qlora", 64, 16, 768);
        let qpaca = iteration_time(&A100_80G, &m, "qpaca", 64, 16, 768);
        assert!(qlora.total_s() > lora.total_s());
        assert!(qpaca.total_s() < qlora.total_s());
    }

    #[test]
    fn gaudi2_faster_at_same_workload() {
        // Paper Fig 3: Gaudi2 reaches higher sentences/s than A100.
        let m = llama3_8b();
        let a = throughput_seq_per_s(&A100_80G, &m, "paca", 8, 8, 512);
        let g = throughput_seq_per_s(&GAUDI2, &m, "paca", 8, 8, 512);
        assert!(g > a);
    }

    #[test]
    fn throughput_increases_with_batch() {
        let m = llama3_8b();
        let t4 = throughput_seq_per_s(&A100_80G, &m, "paca", 8, 4, 512);
        let t16 = throughput_seq_per_s(&A100_80G, &m, "paca", 8, 16, 512);
        assert!(t16 > t4);
    }

    #[test]
    fn paca_throughput_beats_lora_at_same_batch() {
        let m = llama3_8b();
        let l = throughput_seq_per_s(&A100_80G, &m, "lora", 8, 8, 512);
        let p = throughput_seq_per_s(&A100_80G, &m, "paca", 8, 8, 512);
        assert!(p > l, "paca {p} !> lora {l}");
    }
}
