//! Experiment drivers — one per table/figure in the paper's evaluation
//! (DESIGN.md §5 maps each to its modules). Every driver prints (a) the
//! paper's reported numbers, (b) our measured results on the CPU-PJRT
//! testbed (tiny/small presets), and (c) the device-cost-model
//! projection at the paper's own model/hardware scale.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::config::{preset, TrainConfig};
use crate::coordinator::Trainer;
use crate::data::{ImageGen, MTBENCH_CATEGORIES};
use crate::memory;
use crate::metrics::{fmt_gb, fmt_params, Table};
use crate::peft::{self, Selection};
use crate::runtime::Runtime;
use crate::simulator::{self, A100_80G, GAUDI2};
use crate::tensor::HostTensor;

pub const EXPERIMENTS: [&str; 10] = [
    "fig2", "table1", "table2", "table3", "table4", "fig3", "table5",
    "table6", "table7", "serve",
];

pub fn run_experiment(rt: &Runtime, name: &str,
                      quick: bool) -> Result<String> {
    match name {
        "fig2" => fig2(rt, quick),
        "table1" => table1(rt, quick),
        "table2" => table2(rt, quick),
        "table3" => table3(rt, quick),
        "table4" => table4(rt),
        "fig3" => fig3(rt, quick),
        "table5" => table5(rt, quick),
        "table6" => table6(rt, quick),
        "table7" => table7(rt, quick),
        "serve" => serve_exp(rt, quick),
        other => Err(anyhow!("unknown experiment {other:?}; \
                              available: {EXPERIMENTS:?}")),
    }
}

fn steps(quick: bool, full: usize) -> usize {
    if quick { full.min(8) } else { full }
}

/// Measured seconds/step over `n` steps of an artifact (after warmup).
fn measure_step_time(rt: &Runtime, artifact: &str,
                     n: usize) -> Result<(f64, Trainer)> {
    let mut cfg = TrainConfig::default();
    cfg.artifact = artifact.into();
    cfg.steps = 0;
    cfg.warmup_steps = 1;
    let mut tr = Trainer::new(rt, cfg)?;
    tr.train_step()?; // warmup (first dispatch may fault pages)
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        tr.train_step()?;
    }
    Ok((t0.elapsed().as_secs_f64() / n as f64, tr))
}

// ------------------------------------------------------------------ fig2

/// Fig 2: operation count (TFLOPs) and per-iteration time, Full-FT vs
/// LoRA vs PaCA — measured on tiny-lm + projected on LLaMA3-8B/A100.
pub fn fig2(rt: &Runtime, quick: bool) -> Result<String> {
    let mut out = String::from(
        "## Fig 2 — FLOPs and per-iteration time (fwd/bwd)\n\n\
         Paper (LLaMA3-8B, bs 2, seq 512, A100): LoRA ~33% fewer FLOPs \
         than Full-FT yet only 0.6% faster; LoRA fwd +33% vs Full-FT; \
         PaCA total -19% vs LoRA (fwd -18%, bwd -20%).\n\n");
    let m = rt.manifest.model("llama3-8b")?;

    let mut t = Table::new(&["Method", "fwd TFLOPs", "bwd TFLOPs",
                             "fwd ms", "bwd ms", "total ms",
                             "vs LoRA"]);
    let lora_total = simulator::iteration_time(&A100_80G, m, "lora", 8,
                                               2, 512).total_s();
    for method in ["full", "lora", "paca"] {
        let fl = simulator::iteration_flops(m, method, 8, 2, 512);
        let ti = simulator::iteration_time(&A100_80G, m, method, 8, 2,
                                           512);
        t.row(&[method.to_string(),
                format!("{:.2}", fl.forward / 1e12),
                format!("{:.2}", fl.backward / 1e12),
                format!("{:.1}", ti.forward_s * 1e3),
                format!("{:.1}", ti.backward_s * 1e3),
                format!("{:.1}", ti.total_s() * 1e3),
                format!("{:+.1}%",
                        (ti.total_s() / lora_total - 1.0) * 100.0)]);
    }
    out.push_str("Projected (LLaMA3-8B profile, A100 cost model):\n\n");
    out.push_str(&t.render());

    // Measured on the real CPU-PJRT testbed.
    let n = steps(quick, 12);
    let mut t2 = Table::new(&["Method", "s/step (tiny-lm, CPU PJRT)",
                              "vs LoRA"]);
    let (lora_s, _) = measure_step_time(rt, "train_lora_tiny", n)?;
    for (method, art) in [("full", "train_full_tiny"),
                          ("lora", "train_lora_tiny"),
                          ("paca", "train_paca_tiny")] {
        let (s, _) = measure_step_time(rt, art, n)?;
        t2.row(&[method.to_string(), format!("{:.4}", s),
                 format!("{:+.1}%", (s / lora_s - 1.0) * 100.0)]);
    }
    out.push_str("\nMeasured (tiny-lm artifacts, this machine):\n\n");
    out.push_str(&t2.render());
    Ok(out)
}

// ---------------------------------------------------------------- table1

/// Table 1: fine-tuning on the MMLU-analog task — Param/Mem/Time +
/// per-subject accuracy for LoRA/DoRA/MosLoRA/PaCA(r8,r16).
pub fn table1(rt: &Runtime, quick: bool) -> Result<String> {
    let mut out = String::from(
        "## Table 1 — task fine-tuning (MMLU-analog)\n\n\
         Paper (LLaMA2-7B): LoRA 20M/23G/4.1h acc 50.6 | DoRA 21M/29G/\
         8.7h 51.3 | MosLoRA 20M/23G/4.3h 51.1 | PaCA r8 11M/20G/3.2h \
         50.4 | PaCA r16 22M/20G/3.2h 51.2.\n\n");

    // (a) projections at the paper's scale.
    let mut proj = Table::new(&["Model", "Method", "Rank", "Param",
                                "Mem", "Time/iter"]);
    for model in ["llama2-7b", "llama2-13b", "llama3-8b"] {
        let m = rt.manifest.model(model)?;
        for (method, rank) in [("lora", 8), ("dora", 8), ("moslora", 8),
                               ("paca", 8), ("paca", 16)] {
            let mem = memory::breakdown(m, method, rank, 8, 512, true);
            let ti = simulator::iteration_time(&A100_80G, m, method,
                                               rank, 8, 512);
            proj.row(&[model.into(), method.into(), rank.to_string(),
                       fmt_params(peft::trainable_params(m, method,
                                                         rank) as f64),
                       fmt_gb(mem.total()),
                       format!("{:.0}ms", ti.total_s() * 1e3)]);
        }
    }
    out.push_str("Projected at paper scale (A100 cost model):\n\n");
    out.push_str(&proj.render());

    // (b) measured fine-tuning runs on tiny-lm.
    let n_steps = steps(quick, 150);
    let mut meas = Table::new(&["Method", "Rank", "Param", "s/step",
                                "Hums.", "STEM", "Social.", "Other",
                                "Avg acc"]);
    for (method, art, rank) in [
        ("lora", "train_lora_tiny", 8),
        ("dora", "train_dora_tiny", 8),
        ("moslora", "train_moslora_tiny", 8),
        ("paca", "train_paca_tiny", 8),
        ("paca", "train_paca_tiny_r16", 16),
    ] {
        let mut cfg = preset("mmlu")?;
        cfg.artifact = art.into();
        cfg.steps = n_steps;
        cfg.warmup_steps = (n_steps / 10).max(1);
        let mut tr = Trainer::new(rt, cfg)?;
        let t0 = std::time::Instant::now();
        tr.run(false)?;
        let per_step = t0.elapsed().as_secs_f64() / n_steps as f64;
        let ev = tr.evaluate(if quick { 2 } else { 8 })?;
        meas.row(&[method.into(), rank.to_string(),
                   fmt_params(tr.info().trainable_params as f64),
                   format!("{:.3}", per_step),
                   format!("{:.3}", ev.acc[0]),
                   format!("{:.3}", ev.acc[1]),
                   format!("{:.3}", ev.acc[2]),
                   format!("{:.3}", ev.acc[3]),
                   format!("{:.3}", ev.mean_acc())]);
    }
    out.push_str("\nMeasured (tiny-lm, MMLU-analog synthetic task, \
                  CPU PJRT):\n\n");
    out.push_str(&meas.render());
    Ok(out)
}

// ---------------------------------------------------------------- table2

/// Table 2: instruction tuning + MT-Bench-analog per-category scores.
pub fn table2(rt: &Runtime, quick: bool) -> Result<String> {
    let mut out = String::from(
        "## Table 2 — instruction tuning (Oasst1/MT-Bench analog)\n\n\
         Paper (LLaMA3-8B, r64): LoRA 56G/26m score 5.12 | DoRA 65G/50m \
         5.28 | MosLoRA 56G/27m 5.15 | PaCA r64 47G/21m 5.23 | \
         r128 51G/21m 5.26.\n\n");

    let m = rt.manifest.model("llama3-8b")?;
    let mut proj = Table::new(&["Method", "Rank", "Mem", "Time/iter"]);
    for (method, rank) in [("lora", 64), ("dora", 64), ("moslora", 64),
                           ("paca", 64), ("paca", 128)] {
        let mem = memory::breakdown(m, method, rank, 16, 768, true);
        let ti = simulator::iteration_time(&A100_80G, m, method, rank,
                                           16, 768);
        proj.row(&[method.into(), rank.to_string(),
                   fmt_gb(mem.total()),
                   format!("{:.0}ms", ti.total_s() * 1e3)]);
    }
    out.push_str("Projected at paper scale:\n\n");
    out.push_str(&proj.render());

    let n_steps = steps(quick, 150);
    let mut meas = Table::new(&["Method", "s/step", "Avg score",
                                "(per-category)"]);
    for (method, art) in [("lora", "train_lora_tiny"),
                          ("dora", "train_dora_tiny"),
                          ("moslora", "train_moslora_tiny"),
                          ("paca r8", "train_paca_tiny"),
                          ("paca r16", "train_paca_tiny_r16")] {
        let mut cfg = preset("instr")?;
        cfg.artifact = art.into();
        cfg.steps = n_steps;
        cfg.warmup_steps = (n_steps / 10).max(1);
        let mut tr = Trainer::new(rt, cfg)?;
        let t0 = std::time::Instant::now();
        tr.run(false)?;
        let per_step = t0.elapsed().as_secs_f64() / n_steps as f64;
        let ev = tr.evaluate(if quick { 1 } else { 4 })?;
        let scores = ev.scores();
        let avg = scores.iter().sum::<f64>() / scores.len() as f64;
        let per: Vec<String> = MTBENCH_CATEGORIES.iter().zip(&scores)
            .map(|(c, s)| format!("{c} {s:.1}")).collect();
        meas.row(&[method.into(), format!("{:.3}", per_step),
                   format!("{:.2}", avg), per.join(", ")]);
    }
    out.push_str("\nMeasured (tiny-lm, instruction-analog task):\n\n");
    out.push_str(&meas.render());
    Ok(out)
}

// ---------------------------------------------------------------- table3

/// Table 3: QLoRA vs QPaCA (NF4 quantized base weights).
pub fn table3(rt: &Runtime, quick: bool) -> Result<String> {
    let mut out = String::from(
        "## Table 3 — QPaCA vs QLoRA\n\n\
         Paper: 8B — QLoRA 18G/42m 5.00, QPaCA 16G/37m 5.02; \
         70B — QLoRA 80G/5.1h 6.09, QPaCA 69G/4.7h 6.08.\n\n");

    let mut proj = Table::new(&["Model", "Method", "Mem", "Time/iter"]);
    // Paper Table 11: batch 16 with grad-accum 4 (8B) / 2 (70B) —
    // per-device microbatch 4 / 8 is what bounds memory.
    for (model, mb) in [("llama3-8b", 4), ("llama3.1-70b", 8)] {
        let m = rt.manifest.model(model)?;
        for method in ["qlora", "qpaca"] {
            let mem = memory::breakdown(m, method, 64, mb, 768, true);
            let ti = simulator::iteration_time(&A100_80G, m, method, 64,
                                               mb, 768);
            proj.row(&[model.into(), method.into(),
                       fmt_gb(mem.total()),
                       format!("{:.0}ms", ti.total_s() * 1e3)]);
        }
    }
    out.push_str("Projected at paper scale:\n\n");
    out.push_str(&proj.render());

    let n_steps = steps(quick, 120);
    let mut meas = Table::new(&["Method", "s/step", "final loss",
                                "Avg score"]);
    for (method, art) in [("qlora", "train_qlora_tiny"),
                          ("qpaca", "train_qpaca_tiny")] {
        let mut cfg = preset("instr")?;
        cfg.artifact = art.into();
        cfg.steps = n_steps;
        cfg.warmup_steps = (n_steps / 10).max(1);
        let mut tr = Trainer::new(rt, cfg)?;
        let t0 = std::time::Instant::now();
        tr.run(false)?;
        let per_step = t0.elapsed().as_secs_f64() / n_steps as f64;
        let ev = tr.evaluate(if quick { 1 } else { 4 })?;
        meas.row(&[method.into(), format!("{:.3}", per_step),
                   format!("{:.3}", tr.curve.tail_mean(5)),
                   format!("{:.2}", 10.0 * ev.mean_acc())]);
    }
    out.push_str("\nMeasured (tiny-lm, NF4 path, CPU PJRT):\n\n");
    out.push_str(&meas.render());
    Ok(out)
}

// ---------------------------------------------------------------- table4

/// Table 4: max sequence length before OOM (memory accountant).
pub fn table4(rt: &Runtime) -> Result<String> {
    let mut out = String::from(
        "## Table 4 — max sequence length, LLaMA3-8B on one A100 80GB\n\n\
         Paper: LoRA 8.0K | DoRA 4.7K | MosLoRA 8.0K | PaCA 9.8K.\n\n");
    let m = rt.manifest.model("llama3-8b")?;
    let mut t = Table::new(&["Method", "Max seq", "vs LoRA"]);
    let lora = memory::max_seq_len(m, "lora", 8, A100_80G.capacity,
                                   false);
    for method in ["lora", "dora", "moslora", "paca"] {
        let s = memory::max_seq_len(m, method, 8, A100_80G.capacity,
                                    false);
        t.row(&[method.into(), format!("{:.1}K", s as f64 / 1e3),
                format!("{:+.0}%",
                        (s as f64 / lora as f64 - 1.0) * 100.0)]);
    }
    out.push_str(&t.render());
    Ok(out)
}

// ------------------------------------------------------------------ fig3

/// Fig 3: training throughput vs batch size on A100 + Gaudi2, with OOM
/// walls per method, plus a measured tiny-lm throughput point.
pub fn fig3(rt: &Runtime, quick: bool) -> Result<String> {
    let mut out = String::from(
        "## Fig 3 — throughput (sentences/s) vs batch size, seq 512\n\n\
         Paper: PaCA sustains ~33% (A100) / ~21% (Gaudi2) larger \
         batches and +16% peak throughput vs LoRA \
         (A100 peak 10.36, Gaudi2 15.5 sentences/s).\n\n");
    let m = rt.manifest.model("llama3-8b")?;
    for dev in [&A100_80G, &GAUDI2] {
        let mut t = Table::new(&["Batch", "Full-FT", "LoRA", "DoRA",
                                 "MosLoRA", "PaCA"]);
        let methods = ["full", "lora", "dora", "moslora", "paca"];
        let maxb: BTreeMap<&str, usize> = methods.iter()
            .map(|&me| (me, memory::max_batch(m, me, 8, 512,
                                              dev.capacity, false)))
            .collect();
        let top = maxb.values().copied().max().unwrap_or(8);
        let mut b = 2;
        while b <= top.max(2) {
            let cells: Vec<String> = methods.iter().map(|&me| {
                if b > maxb[me] {
                    "OOM".to_string()
                } else {
                    format!("{:.2}", simulator::throughput_seq_per_s(
                        dev, m, me, 8, b, 512))
                }
            }).collect();
            let mut row = vec![b.to_string()];
            row.extend(cells);
            t.row(&row);
            b *= 2;
        }
        out.push_str(&format!("\n{} (cost model; OOM per memory \
                               accountant):\n\n", dev.name));
        out.push_str(&t.render());
        let peak_lora = (1..=maxb["lora"].max(1)).map(|b| {
            simulator::throughput_seq_per_s(dev, m, "lora", 8, b, 512)
        }).fold(0.0, f64::max);
        let peak_paca = (1..=maxb["paca"].max(1)).map(|b| {
            simulator::throughput_seq_per_s(dev, m, "paca", 8, b, 512)
        }).fold(0.0, f64::max);
        out.push_str(&format!(
            "\npeak: LoRA {:.2} vs PaCA {:.2} sentences/s ({:+.0}%)\n",
            peak_lora, peak_paca,
            (peak_paca / peak_lora - 1.0) * 100.0));
    }

    // Measured single-point throughput on the testbed.
    let n = steps(quick, 10);
    let mut t = Table::new(&["Method", "tiny-lm seq/s (measured)"]);
    for (me, art) in [("lora", "train_lora_tiny"),
                      ("paca", "train_paca_tiny")] {
        let (s, tr) = measure_step_time(rt, art, n)?;
        let (b, _) = tr.batch_geometry();
        t.row(&[me.into(), format!("{:.2}", b as f64 / s)]);
    }
    out.push_str("\nMeasured on this machine:\n\n");
    out.push_str(&t.render());
    Ok(out)
}

// ---------------------------------------------------------------- table5

/// Table 5: connection-selection strategies (random seeds, weight-norm,
/// gradient-norm) — real training runs.
pub fn table5(rt: &Runtime, quick: bool) -> Result<String> {
    let mut out = String::from(
        "## Table 5 — PaCA selection strategies (instruction task)\n\n\
         Paper: Random #1 5.23 | Random #2 5.26 | Weight-based 5.18 | \
         Gradient-based 5.24 — i.e. selection strategy does not \
         noticeably matter.\n\n");
    let n_steps = steps(quick, 150);

    let run = |selection: Selection, seed: u64| -> Result<(f64, f64)> {
        let mut cfg = preset("instr")?;
        cfg.artifact = "train_paca_tiny".into();
        cfg.steps = n_steps;
        cfg.warmup_steps = (n_steps / 10).max(1);
        cfg.seed = seed;
        let mut tr = Trainer::with_selection(rt, cfg, selection)?;
        tr.run(false)?;
        let ev = tr.evaluate(if quick { 1 } else { 4 })?;
        Ok((10.0 * ev.mean_acc(), tr.curve.tail_mean(5)))
    };

    let mut t = Table::new(&["Strategy", "Avg score", "final loss"]);
    for (name, sel, seed) in [
        ("Random (seed #1)", Selection::Random, 42u64),
        ("Random (seed #2)", Selection::Random, 1337),
        ("Weight-based", Selection::WeightNorm, 42),
    ] {
        let (score, loss) = run(sel, seed)?;
        t.row(&[name.into(), format!("{:.2}", score),
                format!("{:.3}", loss)]);
    }
    // Gradient-based: accumulate per-row grad-norm scores with the
    // grad-probe artifact (paper: 100 probe iterations, no updates).
    match grad_scores(rt, if quick { 2 } else { 20 }) {
        Ok(scores) => {
            let (score, loss) = run(Selection::GradNorm(scores), 42)?;
            t.row(&["Gradient-based".into(), format!("{:.2}", score),
                    format!("{:.3}", loss)]);
        }
        Err(e) => {
            t.row(&["Gradient-based".into(), "n/a".into(),
                    format!("({e})")]);
        }
    }
    out.push_str(&t.render());
    Ok(out)
}

/// Accumulated per-row gradient norms from the grad_probe artifact.
pub fn grad_scores(rt: &Runtime,
                   iters: usize) -> Result<BTreeMap<String, Vec<f32>>> {
    let exe = rt.load("grad_probe_tiny")?;
    let info = exe.info.clone();
    let state = crate::init::init_state(&info, 42, &Selection::Random)?;
    let lits: Vec<xla::Literal> = state.iter().map(|t| t.to_literal())
        .collect::<Result<_>>()?;
    let model = rt.manifest.model(&info.model)?;
    let mut gen = crate::data::TokenGen::new(
        crate::data::Task::Instr, model.vocab, 42);
    let mut acc: BTreeMap<String, Vec<f32>> = BTreeMap::new();
    for _ in 0..iters {
        let batch = gen.train_batch(info.batch, info.seq);
        let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
        let blit = batch.to_literal()?;
        inputs.push(&blit);
        let outs = exe.run(&inputs)?;
        for (name, lit) in info.outputs.iter().zip(&outs) {
            let t = HostTensor::from_literal(lit)?;
            let v = t.as_f32();
            let idx_name = format!(
                "{}/idx", name.trim_start_matches("grad_sq/")
                    .trim_end_matches("/w"));
            let e = acc.entry(idx_name)
                .or_insert_with(|| vec![0.0; v.len()]);
            for (a, b) in e.iter_mut().zip(&v) {
                *a += *b;
            }
        }
    }
    Ok(acc)
}

// ----------------------------------------------------------------- serve

/// Serving-throughput comparison (beyond the paper — the north star's
/// inference side): merged-PaCA vs unmerged-LoRA serving on the
/// A100/Gaudi2 cost model, plus a measured multi-tenant run of the
/// host serving engine with FIFO vs swap-aware batching.
pub fn serve_exp(rt: &Runtime, quick: bool) -> Result<String> {
    use crate::serve::{cost, engine, registry, scheduler, trace};

    let mut out = String::from(
        "## Serve — multi-tenant adapter serving\n\n\
         PaCA's merged serving runs the bare frozen base (zero adapter \
         kernels); unmerged LoRA pays the serialized adapter path per \
         request. PaCA's only multi-tenant cost is the per-batch \
         adapter swap, which swap-aware batching amortizes.\n\n");

    // (a) projection at paper scale.
    let m8b = rt.manifest.model("llama3-8b")
        .cloned().unwrap_or_else(|_| cost::llama3_8b());
    out.push_str("Projected (serving cost model):\n");
    out.push_str(&cost::comparison_table(&m8b, 64, 512));

    out.push_str("\nLatency vs load (M/D/1 queueing on the serving \
                  cost model):\n");
    out.push_str(&cost::latency_table(&m8b, 64, 8, 512));

    out.push_str("\nIteration-level decode (TTFT/TPOT; the unmerged \
                  path pays its adapter kernels per output token):\n");
    out.push_str(&cost::decode_table(&m8b, 64, 512, 512));

    out.push_str("\nKV capacity (max concurrent sequences / max \
                  context in HBM after the frozen base — merged PaCA \
                  pins zero resident adapter bytes, so the unmerged \
                  path's adapter set comes straight out of KV):\n");
    out.push_str(&cost::kv_capacity_table(&m8b, 64, 4096, 8));

    // (b) measured on the host serving engine: the online
    // continuous-batching pipeline over a bursty SLO trace, per
    // policy, on the deterministic analytic clock.
    let spec = trace::TraceSpec {
        n_requests: if quick { 64 } else { 256 },
        n_tenants: 8,
        deadline_ms: 60.0,
        burstiness: 3.0,
        ..Default::default()
    };
    let model = engine::tiny_model();
    let mut t = Table::new(&["Policy", "Swaps", "Offline swaps",
                             "queue p50 ms", "queue p99 ms",
                             "misses", "virt req/s"]);
    for policy in scheduler::Policy::ALL {
        let tr = trace::synthesize(&spec);
        let base = engine::BaseModel::synthetic(&model, 7);
        let mut reg = registry::AdapterRegistry::new(64);
        for name in tr.pool.names() {
            reg.insert(registry::PacaAdapter::synthetic(
                name, &model, 8, 11));
        }
        let offline_swaps = scheduler::swap_count(
            &scheduler::plan(tr.requests.clone(), 8, policy));
        let n_ids = tr.pool.len();
        let mut eng = engine::ServeEngine::new(
            base, reg, Box::<engine::HostBackend>::default(),
            tr.pool);
        let mut sched = scheduler::OnlineScheduler::new(
            tr.requests, n_ids, 8, policy);
        eng.serve_online(&mut sched, engine::ClockModel::Analytic {
            swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
        })?;
        eng.finish()?; // bit-exact base restore, every policy
        let pq = |q: f64| format!(
            "{:.3}", eng.queueing.percentile("(all)", q)
                .unwrap_or(0.0) * 1e3);
        t.row(&[policy.name().to_string(),
                eng.stats.swaps.to_string(),
                offline_swaps.to_string(),
                pq(0.50),
                pq(0.99),
                format!("{}/{}", eng.stats.deadline_misses,
                        eng.stats.deadline_total),
                format!("{:.0}", eng.virtual_req_per_s())]);
    }
    out.push_str("\nMeasured (host engine, online continuous \
                  batching, bursty 8-tenant trace, 60ms deadlines, \
                  analytic clock):\n\n");
    out.push_str(&t.render());
    Ok(out)
}

// ---------------------------------------------------------------- table6

/// Table 6: ViT fine-tuning, LoRA vs PaCA on synthetic image classes.
pub fn table6(rt: &Runtime, quick: bool) -> Result<String> {
    let mut out = String::from(
        "## Table 6 — ViT fine-tuning (synthetic image classes)\n\n\
         Paper (ViT-B/16): LoRA 11.0G/45m avg acc 96.1 | PaCA 6.7G/32m \
         96.2 — same accuracy, 39% less memory, 29% less time.\n\n");
    let n_steps = steps(quick, 200);
    let mut t = Table::new(&["Method", "s/step", "train acc",
                             "held-out acc"]);
    for (method, art, lr) in [("lora", "train_lora_vit_tiny", 5e-4),
                              ("paca", "train_paca_vit_tiny", 3e-3)] {
        let (per_step, acc_train, acc_eval) =
            run_vit_lr(rt, art, n_steps, quick, lr)?;
        t.row(&[method.into(), format!("{:.3}", per_step),
                format!("{:.3}", acc_train),
                format!("{:.3}", acc_eval)]);
    }
    out.push_str("Measured (vit-tiny, CPU PJRT):\n\n");
    out.push_str(&t.render());
    Ok(out)
}

/// Train a ViT artifact on ImageGen; returns (s/step, train acc,
/// held-out acc via lr=0 dispatches).
fn run_vit(rt: &Runtime, artifact: &str, n_steps: usize,
           quick: bool) -> Result<(f64, f64, f64)> {
    run_vit_lr(rt, artifact, n_steps, quick, 3e-3)
}

/// As run_vit but with an explicit peak LR (the paper tunes LR per
/// method; LoRA's alpha/r=4 scaling needs a smaller one).
fn run_vit_lr(rt: &Runtime, artifact: &str, n_steps: usize,
              quick: bool, peak_lr: f32) -> Result<(f64, f64, f64)> {
    let exe = rt.load(artifact)?;
    let info = exe.info.clone();
    let state = crate::init::init_state(&info, 42, &Selection::Random)?;
    let mut lits: Vec<xla::Literal> = state.iter()
        .map(|t| t.to_literal()).collect::<Result<_>>()?;
    let upd = info.updated_state_indices();
    let mut gen = ImageGen::new(10, 42);
    // held-out: same class patterns, fresh pixel noise
    let mut eval_gen = ImageGen::with_seeds(10, 42, 777);
    let b = info.batch;

    let dispatch = |lits: &mut Vec<xla::Literal>,
                    imgs: &HostTensor, labels: &HostTensor, lr: f32,
                    apply: bool| -> Result<(f64, f64)> {
        let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
        let (il, ll, lrl) = (imgs.to_literal()?, labels.to_literal()?,
                             HostTensor::scalar_f32(lr).to_literal()?);
        inputs.push(&il);
        inputs.push(&ll);
        inputs.push(&lrl);
        let mut outs = exe.run(&inputs)?;
        let acc = outs.pop().unwrap().get_first_element::<f32>()
            .map_err(|e| anyhow!("{e:?}"))? as f64;
        let loss = outs.pop().unwrap().get_first_element::<f32>()
            .map_err(|e| anyhow!("{e:?}"))? as f64;
        if apply {
            for (j, lit) in outs.into_iter().enumerate() {
                lits[upd[j]] = lit;
            }
        }
        Ok((loss, acc))
    };

    let t0 = std::time::Instant::now();
    let mut accs = Vec::new();
    for i in 0..n_steps {
        let (imgs, labels) = gen.batch(b);
        let lr = peak_lr * (1.0 - i as f32 / n_steps as f32);
        let (_, acc) = dispatch(&mut lits, &imgs, &labels, lr, true)?;
        accs.push(acc);
    }
    let per_step = t0.elapsed().as_secs_f64() / n_steps as f64;
    let tail = accs.len().min(10);
    let last_acc = accs[accs.len() - tail..].iter().sum::<f64>()
        / tail as f64;

    let eval_batches = if quick { 2 } else { 8 };
    let mut acc_sum = 0.0;
    for _ in 0..eval_batches {
        let (imgs, labels) = eval_gen.batch(b);
        let (_, acc) = dispatch(&mut lits, &imgs, &labels, 0.0, false)?;
        acc_sum += acc;
    }
    Ok((per_step, last_acc, acc_sum / eval_batches as f64))
}

// ---------------------------------------------------------------- table7

/// Table 7: CNN generality — Full-FT vs PaCA on the conv substrate.
/// PaCA fine-tunes a random subset of *input channels* of each conv
/// kernel (python/compile/cnn.py), which LoRA's linear adapters cannot
/// express without un-mergeable extra layers — the paper's point.
pub fn table7(rt: &Runtime, quick: bool) -> Result<String> {
    let mut out = String::from(
        "## Table 7 — Full-FT vs PaCA on a CNN\n\n\
         Paper (EfficientNetV2-L): Full-FT 18.3G/70m avg 94.3 | PaCA \
         13.2G/59m 93.7 — PaCA applies to conv layers where LoRA's \
         linear adapters cannot merge.\n\n");
    let n_steps = steps(quick, 250);
    let mut t = Table::new(&["Method", "Trainable", "s/step",
                             "train acc", "held-out acc"]);
    for (method, art) in [("full", "train_full_cnn_tiny"),
                          ("paca", "train_paca_cnn_tiny")] {
        let exe = rt.load(art)?;
        let trainable = exe.info.trainable_params;
        let (per_step, acc_train, acc_eval) =
            run_vit(rt, art, n_steps, quick)?;
        t.row(&[method.into(), fmt_params(trainable as f64),
                format!("{:.3}", per_step),
                format!("{:.3}", acc_train),
                format!("{:.3}", acc_eval)]);
    }
    out.push_str("Measured (cnn-tiny: 3 conv stages + linear head, \
                  synthetic image classes):\n\n");
    out.push_str(&t.render());
    Ok(out)
}
