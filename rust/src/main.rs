//! `paca` — the L3 launcher CLI (hand-rolled arg parsing; the offline
//! build has no clap).
//!
//! Subcommands:
//!   info                          platform + manifest summary
//!   train  [--config f.toml] [-o key=value …]   run fine-tuning
//!   eval   --artifact NAME --checkpoint f.ckpt  evaluate a checkpoint
//!   bench  --exp fig2|table1..7|fig3|serve|all [--quick]  experiments
//!   memory --model NAME --method M [--rank R …]      memory breakdown
//!   serve  --adapters DIR --requests TRACE --batch N  multi-tenant
//!                                 adapter serving (serve/)
//!   selftest                      kernel artifacts vs rust oracles

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use paca::config::{preset, ServeConfig, TrainConfig};
use paca::coordinator::Trainer;
use paca::exps;
use paca::memory;
use paca::metrics::fmt_gb;
use paca::nf4;
use paca::runtime::Runtime;
use paca::serve::{cluster, cost, engine, events, registry, router,
                  scheduler, telemetry, trace};
use paca::simulator::A100_80G;
use paca::tensor::HostTensor;
use paca::util::rng::Rng;
use paca::util::toml::TomlDoc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Flags {
    positional: Vec<String>,
    named: std::collections::BTreeMap<String, String>,
    switches: std::collections::BTreeSet<String>,
}

/// A token that must be parsed as a flag rather than as the previous
/// flag's value: `-o`, any `--name`, or a single-dash token that is
/// not a negative number — so `--lr -0.01` stays a key/value pair
/// while `--quick -o lr=-0.01` keeps `--quick` a bare switch.
fn is_flag_token(s: &str) -> bool {
    if s == "-o" {
        return true;
    }
    if let Some(rest) = s.strip_prefix("--") {
        return !rest.is_empty();
    }
    match s.strip_prefix('-') {
        Some(rest) => !rest.chars().next()
            .is_some_and(|c| c.is_ascii_digit() || c == '.'),
        None => false,
    }
}

fn parse_flags(args: &[String]) -> Flags {
    let mut f = Flags { positional: Vec::new(),
                        named: Default::default(),
                        switches: Default::default() };
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !is_flag_token(&args[i + 1]) {
                f.named.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                f.switches.insert(name.to_string());
                i += 1;
            }
        } else if a == "-o" && i + 1 < args.len() {
            f.named.entry("override".into()).or_default();
            let cur = f.named.get_mut("override").unwrap();
            if !cur.is_empty() {
                cur.push(';');
            }
            cur.push_str(&args[i + 1]);
            i += 2;
        } else {
            f.positional.push(a.clone());
            i += 1;
        }
    }
    f
}

fn usage() -> &'static str {
    "usage: paca <info|train|eval|bench|memory|serve|selftest> [flags]\n\
     \n\
     paca train [--config run.toml] [--preset mmlu|instr|smoke] \\\n\
     \x20          [-o key=value ...]      # e.g. -o artifact=train_paca_tiny\n\
     paca bench --exp fig2|table1..table7|fig3|serve|all [--quick] \\\n\
     \x20          [--out results.md]\n\
     paca eval --artifact train_paca_tiny --checkpoint model.ckpt\n\
     paca memory --model llama3-8b --method paca --rank 8 \\\n\
     \x20          [--batch 8] [--seq 512]\n\
     paca serve [--adapters dir] [--requests trace.jsonl] [--batch 8] \\\n\
     \x20          [--policy swap-aware|fifo|slo-aware] [--tenants 8] \\\n\
     \x20          [--count 256] [--rank 8] [--capacity 64] \\\n\
     \x20          [--backend auto|host|pjrt] [--deadline-ms 0] \\\n\
     \x20          [--burstiness 1] [--req-per-s 200] \\\n\
     \x20          [--decode-tokens 0] \\\n\
     \x20          [--max-batch-tokens 0] [--service-unit step|batch] \\\n\
     \x20          [--kv-blocks 0] [--kv-block-tokens 16] \\\n\
     \x20          [--preempt true|false] [--host-max-tokens 2048] \\\n\
     \x20          [--prefix-cache on|off] [--shared-prefix-tokens 0] \\\n\
     \x20          [--report-json report.json] \\\n\
     \x20          [--trace-events events.jsonl] \\\n\
     \x20          [--trace-format jsonl|chrome] \\\n\
     \x20          [--trace-buffer-events 65536] \\\n\
     \x20          [--metrics metrics.prom] [--metrics-interval 1] \\\n\
     \x20          [--profile profile.folded] \\\n\
     \x20          [--prefill-chunk-tokens 0] [--prefetch on|off] \\\n\
     \x20          [--cache-aware on|off] [--prompt-tail 0] \\\n\
     \x20          [--chat-turns 0] \\\n\
     \x20          [--arrival-pattern steady|diurnal|flash] \\\n\
     \x20          [--replicas 1] [--router shard|least-loaded|warmth] \\\n\
     \x20          [--kill-replica R@T]\n\
     \x20          # online continuous batching over the trace's\n\
     \x20          # arrival times; missing trace/adapters are\n\
     \x20          # synthesized and saved.\n\
     \x20          # --service-unit step (default) = iteration-level\n\
     \x20          # decode batching: one token per in-flight sequence\n\
     \x20          # per step, late same-tenant arrivals join the live\n\
     \x20          # batch mid-generation, TTFT/TPOT reported;\n\
     \x20          # \"batch\" = the v2 whole-batch pipeline.\n\
     \x20          # --decode-tokens N synthesizes decode-heavy traces\n\
     \x20          # (mean N output tokens after the first);\n\
     \x20          # --max-batch-tokens caps tokens per step (0 = off)\n\
     \x20          # --kv-blocks N bounds the paged KV-cache pool (N\n\
     \x20          # blocks of --kv-block-tokens tokens; 0 = off);\n\
     \x20          # admission is capacity-gated and, with --preempt\n\
     \x20          # true, the least-urgent decoding slot is evicted\n\
     \x20          # (blocks freed, recompute-on-resume) under memory\n\
     \x20          # pressure or urgent other-tenant deadlines\n\
     \x20          # --prefix-cache on (default): same-tenant shared\n\
     \x20          # prompt prefixes (--shared-prefix-tokens N system\n\
     \x20          # prompts) reuse cached KV blocks copy-on-write\n\
     \x20          # instead of recomputing prefill; off = exact PR-4\n\
     \x20          # behaviour. --report-json writes the engine\n\
     \x20          # report as JSON alongside the text report.\n\
     \x20          # --trace-events records the step-level engine\n\
     \x20          # event stream (arrivals, dispatches, splices,\n\
     \x20          # prefill/decode steps, kv alloc/free, preempt/\n\
     \x20          # resume), audits it online against the serving\n\
     \x20          # invariants (nonzero exit on violation), and\n\
     \x20          # exports it as JSONL or, with --trace-format\n\
     \x20          # chrome, as a Chrome/Perfetto trace. Off = the\n\
     \x20          # null sink: zero cost, bit-identical output.\n\
     \x20          # jsonl export streams to disk DURING the run in\n\
     \x20          # --trace-buffer-events chunks (the in-memory\n\
     \x20          # recorder keeps the first N; overflow is counted\n\
     \x20          # as events_dropped, never silent). --metrics PATH\n\
     \x20          # scrapes a Prometheus-text metrics registry (fed\n\
     \x20          # from the event bus) every --metrics-interval\n\
     \x20          # virtual seconds; --profile PATH writes per-phase\n\
     \x20          # folded stacks (flamegraph input) from the step\n\
     \x20          # profiler. Both require --trace-events. Under\n\
     \x20          # --replicas N the registries merge under replica\n\
     \x20          # labels and the profile merges across engines.\n\
     \x20          # --prefill-chunk-tokens N splits each prompt into\n\
     \x20          # N-token chunks interleaved with decode steps so\n\
     \x20          # long prompts never stall the decoding slots (0 =\n\
     \x20          # unchunked); --prefetch on spends idle step budget\n\
     \x20          # prefilling cold shared prefixes into the radix\n\
     \x20          # cache ahead of arrival; --cache-aware on prefers\n\
     \x20          # warm-chain tenants among equally-urgent pending\n\
     \x20          # requests. --prompt-tail P / --chat-turns K shape\n\
     \x20          # synthesized traces: a lognormal heavy-tail prompt\n\
     \x20          # mix, and K-turn chat sessions that re-hit their\n\
     \x20          # own growing prefix. --arrival-pattern shapes the\n\
     \x20          # long-horizon rate (steady = historical, diurnal =\n\
     \x20          # one sinusoidal period, flash = an 8x crowd spike).\n\
     \x20          # --replicas N serves through an in-process cluster\n\
     \x20          # of N independent engines (own registry, KV pool,\n\
     \x20          # prefix cache, event stream) on ONE merged virtual\n\
     \x20          # clock, with global ingress routed by --router:\n\
     \x20          # shard = tenant-name hash affinity, least-loaded =\n\
     \x20          # min queue depth, warmth = follow the warm radix\n\
     \x20          # chain with overflow spill. --kill-replica R@T\n\
     \x20          # kills replica R at virtual time T; its work\n\
     \x20          # replays exactly-once on the least-loaded survivor\n\
     \x20          # (merged-stream audited). --replicas 1 is\n\
     \x20          # bit-for-bit the single engine.\n\
     paca selftest"
}

fn run(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1..]);
    match cmd {
        "info" => info(),
        "train" => train(&flags),
        "eval" => eval_cmd(&flags),
        "bench" => bench(&flags),
        "memory" => memory_cmd(&flags),
        "serve" => serve_cmd(&flags),
        "selftest" => selftest(),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

fn open_runtime() -> Result<Runtime> {
    let dir = paca::default_artifacts_dir();
    Runtime::new(&dir).map_err(|e| {
        anyhow!("{e:#}\nhint: run `make artifacts` first \
                 (looked in {})", dir.display())
    })
}

fn info() -> Result<()> {
    let rt = open_runtime()?;
    println!("platform: {}", rt.platform());
    println!("artifacts dir: {}", rt.manifest.dir.display());
    println!("\nmodels:");
    for m in rt.manifest.models.values() {
        println!("  {:<14} d={:<5} L={:<3} vocab={:<7} params={:>7} {}",
                 m.name, m.d_model, m.n_layers, m.vocab,
                 paca::metrics::fmt_params(m.n_params() as f64),
                 if m.profile_only { "(profile-only)" } else { "" });
    }
    println!("\nartifacts:");
    for a in rt.manifest.artifacts.values() {
        println!("  {:<24} {:<10} {:<8} rank={:<3} b={} s={} \
                  state={} pallas={}",
                 a.name, a.kind, a.method, a.rank, a.batch, a.seq,
                 a.state.len(), a.use_pallas);
    }
    Ok(())
}

fn build_config(flags: &Flags) -> Result<TrainConfig> {
    let mut cfg = if let Some(p) = flags.named.get("preset") {
        preset(p)?
    } else if let Some(path) = flags.named.get("config") {
        TrainConfig::from_toml_file(Path::new(path))?
    } else {
        TrainConfig::default()
    };
    if let Some(ov) = flags.named.get("override") {
        for kv in ov.split(';') {
            cfg.apply_override(kv)?;
        }
    }
    Ok(cfg)
}

fn train(flags: &Flags) -> Result<()> {
    let cfg = build_config(flags)?;
    let rt = open_runtime()?;
    println!("training {} for {} steps (task {}, lr {:.2e}, seed {})",
             cfg.artifact, cfg.steps, cfg.task, cfg.peak_lr, cfg.seed);
    let eval_batches = cfg.eval_batches;
    let mut tr = Trainer::new(&rt, cfg)?;
    let t0 = std::time::Instant::now();
    tr.run(true)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("\ndone: {} steps in {:.1}s ({:.3} s/step)", tr.step, dt,
             dt / tr.step.max(1) as f64);
    println!("timers: {}", tr.timers.report());
    let ev = tr.evaluate(eval_batches)?;
    println!("\nfinal eval (per category):");
    for (c, (l, a)) in ev.categories.iter()
        .zip(ev.loss.iter().zip(&ev.acc))
    {
        println!("  {:<10} loss {:.4}  acc {:.3}", c, l, a);
    }
    println!("  mean loss {:.4}  mean acc {:.3}", ev.mean_loss(),
             ev.mean_acc());
    Ok(())
}

fn eval_cmd(flags: &Flags) -> Result<()> {
    let artifact = flags.named.get("artifact")
        .ok_or_else(|| anyhow!("--artifact required"))?;
    let ckpt = flags.named.get("checkpoint")
        .ok_or_else(|| anyhow!("--checkpoint required"))?;
    let rt = open_runtime()?;
    let mut cfg = TrainConfig::default();
    cfg.artifact = artifact.clone();
    let mut tr = Trainer::new(&rt, cfg)?;
    tr.load_checkpoint(Path::new(ckpt))?;
    let ev = tr.evaluate(8)?;
    for (c, (l, a)) in ev.categories.iter()
        .zip(ev.loss.iter().zip(&ev.acc))
    {
        println!("{:<10} loss {:.4}  acc {:.3}", c, l, a);
    }
    Ok(())
}

fn bench(flags: &Flags) -> Result<()> {
    let exp = flags.named.get("exp").map(String::as_str)
        .unwrap_or("all");
    let quick = flags.switches.contains("quick");
    let rt = open_runtime()?;
    let names: Vec<&str> = if exp == "all" {
        exps::EXPERIMENTS.to_vec()
    } else {
        exp.split(',').collect()
    };
    let mut report = String::new();
    for name in names {
        println!("=== running {name} ===");
        let out = exps::run_experiment(&rt, name, quick)?;
        println!("{out}");
        report.push_str(&out);
        report.push('\n');
    }
    if let Some(path) = flags.named.get("out") {
        std::fs::write(path, &report)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn memory_cmd(flags: &Flags) -> Result<()> {
    let rt = open_runtime()?;
    let model = flags.named.get("model").map(String::as_str)
        .unwrap_or("llama3-8b");
    let method = flags.named.get("method").map(String::as_str)
        .unwrap_or("paca");
    let rank: usize = flags.named.get("rank")
        .map(|s| s.parse()).transpose()?.unwrap_or(8);
    let batch: usize = flags.named.get("batch")
        .map(|s| s.parse()).transpose()?.unwrap_or(8);
    let seq: usize = flags.named.get("seq")
        .map(|s| s.parse()).transpose()?.unwrap_or(512);
    let m = rt.manifest.model(model)?;
    let bd = memory::breakdown(m, method, rank, batch, seq, true);
    println!("{model} / {method} r={rank} b={batch} s={seq}");
    println!("  weights       {}", fmt_gb(bd.weights));
    println!("  grads+opt     {}", fmt_gb(bd.grads_opt));
    println!("  activations   {}", fmt_gb(bd.activations));
    println!("  method static {}", fmt_gb(bd.method_static));
    println!("  framework     {}", fmt_gb(bd.framework));
    println!("  TOTAL         {}", fmt_gb(bd.total()));
    let ti = paca::simulator::iteration_time(&A100_80G, m, method, rank,
                                             batch, seq);
    println!("  time/iter (A100 model): fwd {:.1}ms bwd {:.1}ms \
              opt {:.1}ms total {:.1}ms",
             ti.forward_s * 1e3, ti.backward_s * 1e3,
             ti.optimizer_s * 1e3, ti.total_s() * 1e3);
    Ok(())
}

/// Open the runtime and build the PJRT serving backend around the
/// first lowered eval artifact (compiles it, so a stub xla build
/// fails here — which "auto" catches and downgrades to host).
fn pjrt_backend(seed: u64) -> Result<(paca::manifest::ModelInfo,
                                      Box<dyn engine::ForwardBackend>)> {
    let rt = open_runtime()?;
    let eval = rt.manifest.artifacts.values()
        .find(|a| a.kind == "eval_step")
        .ok_or_else(|| anyhow!("no eval artifact in manifest"))?;
    let model = rt.manifest.model(&eval.model)?.clone();
    let fw = engine::PjrtForward::new(&rt, &model.name, seed)?;
    Ok((model, Box::new(fw)))
}

fn host_backend(max_tokens: usize) -> (paca::manifest::ModelInfo,
                                       Box<dyn engine::ForwardBackend>) {
    (engine::tiny_model(),
     Box::new(engine::HostBackend::with_cap(max_tokens)))
}

/// `paca serve`: multi-tenant adapter serving over one shared frozen
/// base (serve/), driven as an ONLINE continuous-batching pipeline —
/// requests are admitted as their trace arrival times pass, and the
/// scheduler makes incremental swap-aware (or SLO-aware) dispatch
/// decisions. The offline one-shot planner's swap counts are printed
/// as the comparison baseline. Synthesizes the trace and any missing
/// tenant adapters on first run, so it works end-to-end on a fresh
/// checkout.
fn serve_cmd(flags: &Flags) -> Result<()> {
    let mut cfg = if let Some(path) = flags.named.get("config") {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {path}: {e}"))?;
        ServeConfig::from_doc(&TomlDoc::parse(&src)
                              .map_err(|e| anyhow!("{e}"))?)?
    } else {
        ServeConfig::default()
    };
    for (k, v) in &flags.named {
        match k.as_str() {
            "config" => {}
            "override" => {
                for kv in v.split(';') {
                    cfg.apply_override(kv)?;
                }
            }
            _ => cfg.apply_override(&format!("{k}={v}"))?,
        }
    }
    cfg.validate()?;
    let policy = scheduler::Policy::parse(&cfg.policy)?;
    if cfg.batch == 0 {
        bail!("--batch must be >= 1");
    }
    if cfg.tenants == 0 {
        bail!("--tenants must be >= 1");
    }
    if cfg.rank == 0 {
        bail!("--rank must be >= 1");
    }
    if cfg.mean_tokens < 2 {
        bail!("--mean-tokens must be >= 2");
    }
    if cfg.count == 0 {
        bail!("--count must be >= 1");
    }
    if cfg.capacity == 0 {
        bail!("--capacity must be >= 1 (the registry needs room for \
               at least one resident adapter)");
    }

    // Request trace: load, or synthesize + persist for reproducibility.
    let trace_path = Path::new(&cfg.requests);
    let tr = if trace_path.exists() {
        let tr = trace::read_jsonl(trace_path)?;
        println!("loaded {} requests from {}", tr.len(),
                 trace_path.display());
        tr
    } else {
        let spec = trace::TraceSpec {
            n_requests: cfg.count,
            n_tenants: cfg.tenants,
            mean_tokens: cfg.mean_tokens,
            deadline_ms: cfg.deadline_ms,
            burstiness: cfg.burstiness,
            req_per_s: cfg.req_per_s,
            decode_tokens: cfg.decode_tokens,
            shared_prefix_tokens: cfg.shared_prefix_tokens,
            prompt_tail: cfg.prompt_tail,
            chat_turns: cfg.chat_turns,
            arrival_pattern: trace::ArrivalPattern::parse(
                &cfg.arrival_pattern).ok_or_else(|| anyhow!(
                    "unknown arrival pattern {:?}",
                    cfg.arrival_pattern))?,
            seed: cfg.seed,
            ..Default::default()
        };
        let tr = trace::synthesize(&spec);
        trace::write_jsonl(trace_path, &tr)?;
        println!("synthesized {} requests over {} tenants -> {}",
                 tr.len(), cfg.tenants, trace_path.display());
        tr
    };
    if tr.is_empty() {
        bail!("trace {} has no requests", trace_path.display());
    }
    let tenants = tr.tenant_names();

    // Backend: the PJRT eval artifact when lowered, else the host GEMM
    // reference path (always available). "auto" falls back to host on
    // ANY pjrt failure (missing artifacts, stub xla build, …).
    let artifacts_dir = paca::default_artifacts_dir();
    // `resolved_backend` records what "auto" actually picked, so the
    // cluster path can build one backend PER replica without
    // re-running (and re-printing) the fallback probe.
    let (model, backend, resolved_backend) = match cfg.backend.as_str()
    {
        "host" => {
            let (m, b) = host_backend(cfg.host_max_tokens);
            (m, b, "host")
        }
        "pjrt" => {
            let (m, b) = pjrt_backend(cfg.seed)?;
            (m, b, "pjrt")
        }
        "auto" => {
            if Runtime::artifacts_present(&artifacts_dir) {
                match pjrt_backend(cfg.seed) {
                    Ok((m, b)) => (m, b, "pjrt"),
                    Err(e) => {
                        println!("note: pjrt backend unavailable \
                                  ({e:#}); falling back to host");
                        let (m, b) = host_backend(cfg.host_max_tokens);
                        (m, b, "host")
                    }
                }
            } else {
                let (m, b) = host_backend(cfg.host_max_tokens);
                (m, b, "host")
            }
        }
        other => bail!("unknown backend {other:?} (auto|host|pjrt)"),
    };

    // Adapter store: synthesize any tenants the trace needs that have
    // no `<tenant>.paca` file yet (stand-ins for fine-tune outputs).
    let adapters_dir = Path::new(&cfg.adapters_dir);
    std::fs::create_dir_all(adapters_dir)
        .map_err(|e| anyhow!("creating {}: {e}",
                             adapters_dir.display()))?;
    let mut created = 0;
    for t in &tenants {
        let path = registry::AdapterRegistry::adapter_path(
            adapters_dir, t);
        if !path.exists() {
            registry::PacaAdapter::synthetic(t, &model, cfg.rank,
                                             cfg.seed)
                .save(&path)?;
            created += 1;
        }
    }
    if created > 0 {
        println!("synthesized {created} tenant adapters (rank {}) in {}",
                 cfg.rank, adapters_dir.display());
    }
    let reg = registry::AdapterRegistry::with_dir(adapters_dir,
                                                 cfg.capacity);

    let base = engine::BaseModel::synthetic(&model, cfg.seed);
    let decode_total: usize = tr.requests.iter()
        .map(|r| r.decode_tokens).sum();
    println!("serving {}: {} tenants over one {:.1}MB shared base \
              ({} target weights) | backend {} | batch {} | policy {} \
              | unit {} | trace span {:.2}s | {} decode \
              tokens{}{}{}{}{}{}{}{}",
             model.name, tenants.len(), base.bytes() as f64 / 1e6,
             base.weights.len(), backend.name(), cfg.batch,
             policy.name(), cfg.service_unit, tr.span_s(),
             decode_total,
             if cfg.max_batch_tokens > 0 {
                 format!(" | step budget {} tokens",
                         cfg.max_batch_tokens)
             } else {
                 String::new()
             },
             if cfg.kv_blocks > 0 {
                 format!(" | kv pool {} x {}-token blocks ({})",
                         cfg.kv_blocks, cfg.kv_block_tokens,
                         if cfg.preempt { "preempt" }
                         else { "drain-only" })
             } else {
                 String::new()
             },
             if cfg.prefix_cache {
                 ""
             } else {
                 " | prefix cache off"
             },
             if cfg.prefill_chunk_tokens > 0 {
                 format!(" | prefill chunks of {} tokens",
                         cfg.prefill_chunk_tokens)
             } else {
                 String::new()
             },
             if cfg.prefetch {
                 " | speculative prefix prefetch"
             } else {
                 ""
             },
             if cfg.cache_aware {
                 " | cache-aware dispatch"
             } else {
                 ""
             },
             if cfg.arrival_pattern != "steady" {
                 format!(" | {} arrivals", cfg.arrival_pattern)
             } else {
                 String::new()
             },
             if cfg.replicas > 1 {
                 format!(" | {} replicas (router {}{})", cfg.replicas,
                         cfg.router,
                         if cfg.kill_replica.is_empty() {
                             String::new()
                         } else {
                             format!(", kill {}", cfg.kill_replica)
                         })
             } else {
                 String::new()
             });

    // Offline baseline: what the one-shot planner would do with the
    // whole queue in hand, per policy.
    for p in scheduler::Policy::ALL {
        let plan = scheduler::plan(tr.requests.clone(), cfg.batch, p);
        println!("offline plan [{:>10}]: {} batches, {} adapter swaps",
                 p.name(), plan.len(), scheduler::swap_count(&plan));
    }

    // The online pipeline: admission by arrival time, incremental
    // dispatch, measured service times on the virtual clock —
    // iteration-level token steps by default, the v2 whole-batch loop
    // under --service-unit batch.
    let n_tenant_ids = tr.pool.len();
    if cfg.replicas > 1 {
        return serve_cluster(&cfg, tr, &model, (base, reg, backend),
                             policy, resolved_backend);
    }
    let mut eng = engine::ServeEngine::new(base, reg, backend,
                                           tr.pool);
    eng.configure_kv(cfg.kv_blocks, cfg.kv_block_tokens, cfg.preempt);
    eng.configure_prefix(cfg.prefix_cache);
    eng.configure_chunking(cfg.prefill_chunk_tokens);
    eng.configure_prefetch(cfg.prefetch);
    if !cfg.trace_events.is_empty() {
        eng.configure_events(events::Events::recording());
        if cfg.trace_format == "jsonl" {
            // Stream events to disk DURING the run: the ring flushes
            // every trace_buffer_events, and the in-memory recorder
            // is bounded to the same size (overflow counted, never
            // silent). Chrome export still needs the full buffered
            // stream for its end-of-run layout pass.
            let sink = telemetry::JsonlStreamSink::create(
                Path::new(&cfg.trace_events),
                cfg.trace_buffer_events)
                .map_err(|e| anyhow!("creating {}: {e}",
                                     cfg.trace_events))?;
            eng.events.stream_to(sink);
            eng.events.bound_recorder(cfg.trace_buffer_events);
        }
        if !cfg.metrics.is_empty() {
            let out = telemetry::TelemetryOut::create(
                Path::new(&cfg.metrics))
                .map_err(|e| anyhow!("creating {}: {e}",
                                     cfg.metrics))?;
            eng.events.configure_metrics(telemetry::MetricsFeeder::new(
                &[("policy", policy.name())], &tenants,
                cfg.metrics_interval_s, Some(out)));
        }
        if !cfg.profile.is_empty() {
            // The CLI serves on the measured clock, so wall dual
            // stamps are armed alongside the virtual attribution.
            eng.configure_profiler(true);
        }
    }
    let mut sched = scheduler::OnlineScheduler::new(
        tr.requests, n_tenant_ids, cfg.batch, policy);
    sched.max_batch_tokens = cfg.max_batch_tokens;
    sched.prefill_chunk_tokens = cfg.prefill_chunk_tokens;
    sched.cache_aware = cfg.cache_aware;
    let served = if cfg.service_unit == "batch" {
        eng.serve_online(&mut sched, engine::ClockModel::Measured)
    } else {
        eng.serve_iterative(&mut sched, engine::ClockModel::Measured)
    };
    served.map_err(|e| {
        e.context(format!(
            "serving failed — if the adapters in {} were created \
             for a different model geometry, delete that \
             directory and re-run", adapters_dir.display()))
    })?;
    eng.finish()?;
    println!("\n{}", eng.report());
    println!("shared frozen base restored bit-exactly after un-merge \
              (fingerprint verified)");
    if !cfg.report_json.is_empty() {
        let path = Path::new(&cfg.report_json);
        std::fs::write(path, eng.report_json().to_string())
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))?;
        println!("wrote engine report json -> {}", path.display());
    }
    if !cfg.trace_events.is_empty() {
        let path = Path::new(&cfg.trace_events);
        let written = if cfg.trace_format == "chrome" {
            let stream = eng.events.snapshot();
            let body = events::to_chrome_trace(&stream,
                                               eng.pool.names())
                .to_string();
            std::fs::write(path, body)
                .map_err(|e| anyhow!("writing {}: {e}",
                                     path.display()))?;
            stream.len() as u64
        } else {
            // Already streamed incrementally; finish() finalized the
            // sink (the ring remainder is on disk).
            if let Some(e) = eng.events.stream_error() {
                bail!("event stream sink failed writing {}: {e}",
                      path.display());
            }
            eng.events.stream_written()
        };
        let violations = eng.events.violation_count();
        let dropped = eng.events.events_dropped();
        println!("wrote {} engine events ({}) -> {}{} | auditor: {}",
                 written, cfg.trace_format, path.display(),
                 if dropped > 0 {
                     format!(" | {dropped} past the {}-event \
                              recorder bound (streamed to disk, \
                              not lost)", cfg.trace_buffer_events)
                 } else {
                     String::new()
                 },
                 if violations == 0 {
                     "clean".to_string()
                 } else {
                     format!("{violations} violations")
                 });
        if violations > 0 {
            for v in eng.events.violations() {
                eprintln!("auditor violation: {v}");
            }
            bail!("event auditor found {violations} invariant \
                   violations in the serve run");
        }
        if !cfg.metrics.is_empty() {
            if let Some(e) = eng.events.metrics_error() {
                bail!("metrics scrape failed writing {}: {e}",
                      cfg.metrics);
            }
            println!("wrote {} metric scrapes (every {}s virtual) \
                      -> {}", eng.events.metrics_scrapes(),
                     cfg.metrics_interval_s, cfg.metrics);
        }
        if !cfg.profile.is_empty() {
            let p = eng.profiler.as_ref()
                .expect("profiler armed when --profile is set");
            let path = Path::new(&cfg.profile);
            std::fs::write(path, p.folded())
                .map_err(|e| anyhow!("writing {}: {e}",
                                     path.display()))?;
            println!("wrote folded step profile ({} steps, {} \
                      phases) -> {}", p.steps,
                     telemetry::Phase::COUNT, path.display());
        }
    }

    println!("\nProjected at paper scale (serving cost model):");
    println!("{}", cost::comparison_table(&cost::llama3_8b(), 64, 512));
    println!("{}", cost::latency_table(&cost::llama3_8b(), 64,
                                       cfg.batch.max(1), 512));
    println!("{}", cost::decode_table(&cost::llama3_8b(), 64, 512,
                                      512));
    println!("{}", cost::kv_capacity_table(&cost::llama3_8b(), 64,
                                           4096, cfg.batch.max(1)));
    if cfg.prefix_cache {
        println!("{}", cost::prefix_hit_table(&cost::llama3_8b(), 64,
                                              cfg.batch.max(1), 512));
    }
    if cfg.prefill_chunk_tokens > 0 {
        println!("{}", cost::chunked_prefill_table(
            &cost::llama3_8b(), 64, 4096, cfg.batch.max(1), 512));
    }
    Ok(())
}

/// The multi-replica path of `paca serve` (`--replicas N`, N > 1):
/// builds N independent engines — each with its OWN registry, KV
/// pool, prefix cache and event stream, but the same synthesized
/// base — and drives them through [`cluster::Cluster`] on one merged
/// virtual clock with router-owned ingress. Replica 0 reuses the
/// base/registry/backend the shared prologue already built; the rest
/// are constructed identically.
fn serve_cluster(cfg: &ServeConfig, tr: trace::Trace,
                 model: &paca::manifest::ModelInfo,
                 first: (engine::BaseModel, registry::AdapterRegistry,
                         Box<dyn engine::ForwardBackend>),
                 policy: scheduler::Policy,
                 backend_kind: &str) -> Result<()> {
    let kill = cfg.parse_kill_replica()?;
    let rpolicy = router::RouterPolicy::parse(&cfg.router)
        .ok_or_else(|| anyhow!("unknown router {:?}", cfg.router))?;
    let adapters_dir = Path::new(&cfg.adapters_dir);
    let n_tenant_ids = tr.pool.len();
    let mut first = Some(first);
    let mut parts = Vec::with_capacity(cfg.replicas);
    for i in 0..cfg.replicas {
        let (base, reg, backend) = match first.take() {
            Some(t) => t,
            None => (
                engine::BaseModel::synthetic(model, cfg.seed),
                registry::AdapterRegistry::with_dir(adapters_dir,
                                                    cfg.capacity),
                match backend_kind {
                    "host" => host_backend(cfg.host_max_tokens).1,
                    _ => pjrt_backend(cfg.seed)?.1,
                },
            ),
        };
        let mut eng = engine::ServeEngine::new(base, reg, backend,
                                               tr.pool.clone());
        eng.configure_kv(cfg.kv_blocks, cfg.kv_block_tokens,
                         cfg.preempt);
        eng.configure_prefix(cfg.prefix_cache);
        eng.configure_chunking(cfg.prefill_chunk_tokens);
        eng.configure_prefetch(cfg.prefetch);
        if !cfg.trace_events.is_empty() {
            eng.configure_events(events::Events::recording());
            if !cfg.metrics.is_empty() {
                // Registry-only feeder (no per-replica output file):
                // the cluster scrapes the MERGED registry on the
                // merged clock, with each replica's series kept
                // apart by its base label.
                let replica = i.to_string();
                eng.events.configure_metrics(
                    telemetry::MetricsFeeder::new(
                        &[("policy", policy.name()),
                          ("replica", replica.as_str())],
                        tr.pool.names(), cfg.metrics_interval_s,
                        None));
            }
            if !cfg.profile.is_empty() {
                eng.configure_profiler(true);
            }
        }
        let mut sched = scheduler::OnlineScheduler::new(
            Vec::new(), n_tenant_ids, cfg.batch, policy);
        sched.max_batch_tokens = cfg.max_batch_tokens;
        sched.prefill_chunk_tokens = cfg.prefill_chunk_tokens;
        sched.cache_aware = cfg.cache_aware;
        parts.push((eng, sched));
    }
    let mut cl = cluster::Cluster::new(parts, tr.requests, rpolicy,
                                       cfg.batch, kill);
    if !cfg.metrics.is_empty() && !cfg.trace_events.is_empty() {
        let out = telemetry::TelemetryOut::create(
            Path::new(&cfg.metrics))
            .map_err(|e| anyhow!("creating {}: {e}", cfg.metrics))?;
        cl.configure_metrics(out, cfg.metrics_interval_s);
    }
    cl.run(engine::ClockModel::Measured).map_err(|e| {
        e.context(format!(
            "cluster serving failed — if the adapters in {} were \
             created for a different model geometry, delete that \
             directory and re-run", adapters_dir.display()))
    })?;
    println!("\n{}", cl.report());
    println!("shared frozen base restored bit-exactly after un-merge \
              on every replica (fingerprints verified)");
    if !cfg.report_json.is_empty() {
        let path = Path::new(&cfg.report_json);
        std::fs::write(path, cl.report_json().to_string())
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))?;
        println!("wrote cluster report json -> {}", path.display());
    }
    if !cfg.trace_events.is_empty() {
        let streams = cl.event_streams();
        let merged = events::merge_replica_streams(&streams);
        let path = Path::new(&cfg.trace_events);
        let body = if cfg.trace_format == "chrome" {
            events::to_chrome_trace_cluster(
                &streams, cl.replicas[0].engine.pool.names())
                .to_string()
        } else {
            events::to_jsonl_cluster(&merged)
        };
        std::fs::write(path, body)
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))?;
        let audit = cl.audit();
        let per_replica: u64 = cl.replicas.iter()
            .map(|r| r.engine.events.violation_count()).sum();
        let violations = audit.violation_count() + per_replica;
        println!("wrote {} engine events across {} replicas ({}) -> \
                  {} | auditor: {}",
                 merged.len(), cfg.replicas, cfg.trace_format,
                 path.display(),
                 if violations == 0 {
                     "clean".to_string()
                 } else {
                     format!("{violations} violations")
                 });
        if violations > 0 {
            for v in audit.violations() {
                eprintln!("cluster auditor violation: {v}");
            }
            for rep in &cl.replicas {
                for v in rep.engine.events.violations() {
                    eprintln!("replica auditor violation: {v}");
                }
            }
            bail!("event auditors found {violations} invariant \
                   violations in the cluster run");
        }
        if !cfg.metrics.is_empty() {
            if let Some(e) = cl.metrics_error() {
                bail!("merged metrics scrape failed writing {}: {e}",
                      cfg.metrics);
            }
            println!("wrote {} merged metric scrapes across {} \
                      replicas (every {}s virtual) -> {}",
                     cl.metrics_scrapes(), cfg.replicas,
                     cfg.metrics_interval_s, cfg.metrics);
        }
        if !cfg.profile.is_empty() {
            let p = cl.merged_profiler()
                .expect("profilers armed when --profile is set");
            let path = Path::new(&cfg.profile);
            std::fs::write(path, p.folded())
                .map_err(|e| anyhow!("writing {}: {e}",
                                     path.display()))?;
            println!("wrote merged folded step profile ({} steps \
                      across {} replicas) -> {}", p.steps,
                     cfg.replicas, path.display());
        }
    }

    println!("\nProjected at paper scale (serving cost model):");
    println!("{}", cost::comparison_table(&cost::llama3_8b(), 64, 512));
    println!("{}", cost::cluster_queueing_table(
        &cost::llama3_8b(), 64, cfg.batch.max(1), 512, cfg.replicas));
    Ok(())
}

/// Numeric cross-checks: run the Pallas kernel artifacts through PJRT
/// and compare against rust-side oracles.
fn selftest() -> Result<()> {
    let rt = open_runtime()?;

    // paca_grad: ∇P = xpᵀ dy.
    let exe = rt.load("kernel_paca_grad")?;
    let (t, r, dout) = (64usize, exe.info.rank, 64usize);
    let mut rng = Rng::new(7);
    let xp: Vec<f32> = (0..t * r).map(|_| rng.normal_f32(1.0)).collect();
    let dy: Vec<f32> = (0..t * dout).map(|_| rng.normal_f32(1.0))
        .collect();
    let outs = exe.run_host(&[
        HostTensor::from_f32(&[t, r], xp.clone()),
        HostTensor::from_f32(&[t, dout], dy.clone()),
    ])?;
    let got = outs[0].as_f32();
    let mut max_err = 0f32;
    for i in 0..r {
        for j in 0..dout {
            let mut want = 0f32;
            for k in 0..t {
                want += xp[k * r + i] * dy[k * dout + j];
            }
            max_err = max_err.max((got[i * dout + j] - want).abs());
        }
    }
    println!("kernel_paca_grad: max |err| = {max_err:.2e}");
    if max_err > 1e-3 {
        bail!("paca_grad kernel mismatch");
    }

    // NF4: quantize host-side (the production init path), dequantize
    // through the Pallas artifact, compare to the rust dequantizer.
    let exe = rt.load("kernel_nf4_roundtrip")?;
    let w: Vec<f32> = (0..64 * 64).map(|_| rng.normal_f32(0.05))
        .collect();
    let (codes, scales) = nf4::quantize(&w, 64);
    let outs = exe.run_host(&[
        HostTensor::from_i8(&[64, 64], codes.clone()),
        HostTensor::from_f32(&[64], scales.clone()),
    ])?;
    let got = outs[0].as_f32();
    let want = nf4::dequantize(&codes, &scales, 64);
    let mut max_err = 0f32;
    for (g, w_) in got.iter().zip(&want) {
        max_err = max_err.max((g - w_).abs());
    }
    println!("kernel_nf4_dequant: max |rust-python err| = {max_err:.2e}");
    if max_err > 1e-5 {
        bail!("nf4 kernel/rust dequantizer mismatch");
    }
    // And the roundtrip error of the host-side quantizer must respect
    // the half-code-gap bound (paper Table-3 substrate).
    let mut max_gap = 0f32;
    for i in 1..16 {
        max_gap = max_gap.max(nf4::NF4_CODEBOOK[i]
                              - nf4::NF4_CODEBOOK[i - 1]);
    }
    for (i, (orig, deq)) in w.iter().zip(&want).enumerate() {
        let bound = scales[i / 64] * max_gap / 2.0 + 1e-6;
        if (orig - deq).abs() > bound {
            bail!("nf4 roundtrip bound violated at {i}");
        }
    }
    println!("selftest OK");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(args: &[&str]) -> Flags {
        let v: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        parse_flags(&v)
    }

    #[test]
    fn switch_before_override_is_not_swallowed() {
        // The historical bug: `--quick -o lr=-0.01` parsed `--quick`
        // as taking the value `-o`, dropping the override.
        let fl = f(&["--quick", "-o", "lr=-0.01"]);
        assert!(fl.switches.contains("quick"));
        assert_eq!(fl.named.get("override").unwrap(), "lr=-0.01");
        assert!(fl.positional.is_empty());
    }

    #[test]
    fn negative_numbers_are_flag_values() {
        let fl = f(&["--lr", "-0.01", "--delta", "-.5",
                     "--steps", "-3"]);
        assert_eq!(fl.named.get("lr").unwrap(), "-0.01");
        assert_eq!(fl.named.get("delta").unwrap(), "-.5");
        assert_eq!(fl.named.get("steps").unwrap(), "-3");
        assert!(fl.switches.is_empty());
    }

    #[test]
    fn flag_followed_by_flag_is_a_switch() {
        let fl = f(&["--quick", "--out", "x.md", "--verbose"]);
        assert!(fl.switches.contains("quick"));
        assert!(fl.switches.contains("verbose"));
        assert_eq!(fl.named.get("out").unwrap(), "x.md");
    }

    #[test]
    fn overrides_accumulate() {
        let fl = f(&["-o", "a=1", "-o", "b=-2"]);
        assert_eq!(fl.named.get("override").unwrap(), "a=1;b=-2");
    }

    #[test]
    fn positionals_and_values_mix() {
        let fl = f(&["run", "--exp", "serve", "extra"]);
        assert_eq!(fl.positional, vec!["run", "extra"]);
        assert_eq!(fl.named.get("exp").unwrap(), "serve");
    }

    #[test]
    fn flag_token_classification() {
        assert!(is_flag_token("-o"));
        assert!(is_flag_token("--anything"));
        assert!(is_flag_token("-x"));
        assert!(!is_flag_token("-0.01"));
        assert!(!is_flag_token("-.5"));
        assert!(!is_flag_token("-9"));
        assert!(!is_flag_token("value"));
        assert!(!is_flag_token("a-b"));
    }
}
