//! PJRT runtime: loads AOT-lowered HLO text artifacts and executes them
//! on the CPU PJRT client. Python never runs here — the artifacts are
//! self-contained HLO modules (see python/compile/aot.py).
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::manifest::{ArtifactInfo, Manifest};
use crate::tensor::HostTensor;

/// A device-resident buffer plus the host literal it was (and may still
/// be being) copied from — see Executable::to_device.
pub struct DeviceTensor {
    pub buf: xla::PjRtBuffer,
    _src: xla::Literal,
}

impl DeviceTensor {
    pub fn read(&self) -> Result<HostTensor> {
        let lit = self.buf.to_literal_sync()
            .map_err(|e| anyhow!("d2h readback: {e:?}"))?;
        HostTensor::from_literal(&lit)
    }
}

/// One compiled executable + its manifest row.
pub struct Executable {
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Run with positional literal inputs; returns the flattened output
    /// tuple. Uploads each literal to an owned device buffer first and
    /// dispatches through `run_b` — NEVER through the crate's literal
    /// `execute`, which leaks its internal per-argument device buffers
    /// (see run_b).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self, inputs: &[L]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.info.n_inputs() {
            return Err(anyhow!(
                "{}: got {} inputs, expected {} \
                 (state {} + batch {} + extra {})",
                self.info.name, inputs.len(), self.info.n_inputs(),
                self.info.state.len(), self.info.batch_inputs.len(),
                self.info.extra_inputs.len()));
        }
        let bufs: Vec<DeviceTensor> = inputs.iter()
            .map(|l| self.to_device(l.borrow().clone()))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> =
            bufs.iter().map(|d| &d.buf).collect();
        self.run_b(&refs)
    }

    /// Run with device-resident buffer inputs (`execute_b`) — the hot
    /// path. The literal-input `execute` converts every argument to a
    /// fresh device buffer per call and never frees it (xla-rs leak:
    /// ~state-size bytes per step, OOM on long runs — EXPERIMENTS.md
    /// §Perf L3#5); buffers we own are freed on Drop, and persistent
    /// state never leaves the device between steps.
    pub fn run_b<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self, inputs: &[B]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.info.n_inputs() {
            return Err(anyhow!(
                "{}: got {} inputs, expected {}",
                self.info.name, inputs.len(), self.info.n_inputs()));
        }
        let bufs = self.exe.execute_b::<B>(inputs)
            .with_context(|| format!("executing {}", self.info.name))?;
        let lit = bufs[0][0].to_literal_sync()
            .context("fetching output tuple")?;
        let outs = lit.to_tuple().context("untupling outputs")?;
        if outs.len() != self.info.outputs.len() {
            return Err(anyhow!(
                "{}: got {} outputs, manifest says {}",
                self.info.name, outs.len(), self.info.outputs.len()));
        }
        Ok(outs)
    }

    /// Upload a host literal to a device buffer we own. TFRT-CPU's
    /// BufferFromHostLiteral fills the buffer ASYNCHRONOUSLY from the
    /// source literal, so the literal must outlive the copy — the
    /// returned DeviceTensor owns both (the source is freed with the
    /// buffer). Passing a temporary literal crashes with
    /// `literal.size_bytes() == b->size()` deep in PJRT.
    /// SAFETY CONTRACT: the returned DeviceTensor must be EXECUTED
    /// against (passed to run_b) before it is dropped — TFRT-CPU fills
    /// the buffer asynchronously and has no standalone sync API in this
    /// xla_extension version; an uploaded-but-never-used buffer leaves
    /// a pending task that can fire after free. The coordinator
    /// therefore keeps *updated* state host-side as literals (outputs
    /// are never re-uploaded) and only uploads tensors that are
    /// immediately consumed by an execution.
    pub fn to_device(&self, lit: xla::Literal) -> Result<DeviceTensor> {
        let buf = self.exe.client().buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow!("h2d upload: {e:?}"))?;
        Ok(DeviceTensor { buf, _src: lit })
    }

    /// Run with host tensors; returns host tensors (convenience path —
    /// the trainer's hot loop manages device buffers itself).
    pub fn run_host(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lits: Vec<xla::Literal> = inputs.iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        self.run(&lits)?
            .iter()
            .map(HostTensor::from_literal)
            .collect()
    }
}

/// PJRT client + compiled-executable cache. Compilation is the expensive
/// step (seconds for the larger graphs), so executables are cached by
/// artifact name for the lifetime of the runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// (artifact, compile_seconds) log for EXPERIMENTS.md §Perf.
    pub compile_log: Mutex<Vec<(String, f64)>>,
}

impl Runtime {
    /// Cheap probe used by tests, benches, and `paca serve` to pick a
    /// non-PJRT path (or skip) on checkouts without `make artifacts`.
    pub fn artifacts_present(artifacts_dir: &Path) -> bool {
        artifacts_dir.join("manifest.json").exists()
    }

    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest,
                     cache: Mutex::new(HashMap::new()),
                     compile_log: Mutex::new(Vec::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (cached) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let info = self.manifest.artifact(name)?.clone();
        let path = self.manifest.hlo_path(&info);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let secs = t0.elapsed().as_secs_f64();
        self.compile_log.lock().unwrap().push((name.to_string(), secs));
        let exe = Arc::new(Executable { info, exe });
        self.cache.lock().unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn loaded(&self) -> Vec<String> {
        self.cache.lock().unwrap().keys().cloned().collect()
    }
}
