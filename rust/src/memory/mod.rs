//! Memory accountant: per-method training-memory model at paper scale.
//!
//! Reproduces the *structure* of the paper's memory numbers (Tables 1–4,
//! Fig 3's OOM walls): weights + gradients + optimizer states +
//! activations, with the method deltas coming from exactly the
//! mechanisms the paper describes —
//!
//!   * LoRA-family stores the full input activations of every target
//!     matrix (for ∇A) plus the adapter mid activations X_mid;
//!   * PaCA stores only the r selected features per target (ᵖX_in);
//!   * DoRA adds weight-shaped direction buffers + a heavier backward;
//!   * QLoRA/QPaCA shrink frozen target weights to NF4 (4.5 bits/w).
//!
//! Two activation regimes, matching the paper's two experimental
//! settings: `ckpt = true` (Tables 1–3: HF-style partial recompute,
//! calibrated factor 0.48) and `ckpt = false` (Table 4 / Fig 3: every
//! intermediate live). Calibration targets are the paper's own reported
//! numbers for LLaMA2-7B/LLaMA3-8B; see EXPERIMENTS.md.

use crate::manifest::ModelInfo;
use crate::nf4;

/// bf16 training precision (paper: 16-bit mixed precision).
const BP: f64 = 2.0;
/// AdamW moments in fp32.
const OPT_BYTES_PER_PARAM: f64 = 8.0;
/// Activation retention under HF-style selective recompute (calibrated
/// so LoRA/LLaMA2-7B lands at the paper's 23 GB, Table 1).
const CKPT_FACTOR: f64 = 0.48;
/// ≥20B-parameter models train with FULL gradient checkpointing (the
/// only way the paper's 70B runs fit one A100); calibrated to Table 3.
const CKPT_FACTOR_HUGE: f64 = 0.12;
const HUGE_PARAMS: f64 = 20e9;

fn ckpt_factor(m: &ModelInfo, ckpt: bool) -> f64 {
    if !ckpt {
        1.0
    } else if m.n_params() as f64 > HUGE_PARAMS {
        CKPT_FACTOR_HUGE
    } else {
        CKPT_FACTOR
    }
}
/// DoRA's backward through the weight normalization roughly doubles its
/// per-token target-activation footprint (calibrated to Table 4).
const DORA_ACT_MULT: f64 = 2.1;
/// DoRA direction/magnitude weight-shaped buffers (calibrated to the
/// +6 GB Table-1 delta on LLaMA2-7B).
const DORA_STATIC_FRAC: f64 = 0.45;
/// CUDA context + allocator + framework overhead.
const FRAMEWORK_BYTES: f64 = 1.2e9;

#[derive(Debug, Clone, Copy)]
pub struct MemBreakdown {
    pub weights: f64,
    pub grads_opt: f64,
    pub activations: f64,
    pub method_static: f64,
    pub framework: f64,
}

impl MemBreakdown {
    pub fn total(&self) -> f64 {
        self.weights + self.grads_opt + self.activations
            + self.method_static + self.framework
    }

    pub fn total_gb(&self) -> f64 {
        self.total() / 1e9
    }
}

/// Σ d_in·d_out over the 7 PEFT targets, per layer.
pub fn target_params_per_layer(m: &ModelInfo) -> f64 {
    m.linear_shapes().iter()
        .map(|(_, i, o)| (*i as f64) * (*o as f64)).sum()
}

pub fn trainable_params(m: &ModelInfo, method: &str, rank: usize) -> f64 {
    crate::peft::trainable_params(m, method, rank) as f64
}

/// Model weight bytes, NF4-compressing target matrices for q-methods.
pub fn weight_bytes(m: &ModelInfo, method: &str) -> f64 {
    let total = m.n_params() as f64;
    let targets = target_params_per_layer(m) * m.n_layers as f64;
    match method {
        "qlora" | "qpaca" => {
            (total - targets) * BP
                + targets * nf4::bits_per_weight(64) / 8.0
        }
        _ => total * BP,
    }
}

/// Per-token-per-block activation bytes, split into the always-stored
/// intermediates and the method-dependent target-input stores.
fn act_bytes_per_token_block(m: &ModelInfo, method: &str, rank: usize,
                             ckpt: bool) -> f64 {
    let d = m.d_model as f64;
    let f = m.d_ff as f64;
    let r = rank as f64;
    // Intermediates no autograd formulation can avoid (attention/silu/
    // norm backward inputs). Smaller set under recompute.
    let common = if ckpt { 5.0 * d + 2.0 * f } else { 8.0 * d + 3.0 * f };
    // Inputs of the 7 target matrices: 4 distinct tensors (xn1 shared by
    // q/k/v, ctx for o, xn2 for gate/up, and the f-wide down input),
    // plus X_mid (7 adapters × r) for the LoRA family.
    let target = match method {
        "full" => 3.0 * d + f,
        "lora" | "qlora" => 3.0 * d + f + 7.0 * r,
        "moslora" => 3.0 * d + f + 14.0 * r,
        "dora" => (3.0 * d + f + 7.0 * r) * DORA_ACT_MULT,
        // The paper's claim: PaCA keeps only ᵖX_in per target.
        "paca" | "qpaca" => 7.0 * r,
        _ => 3.0 * d + f,
    };
    (common + target) * BP * ckpt_factor(m, ckpt)
}

/// Full breakdown for one training configuration.
pub fn breakdown(m: &ModelInfo, method: &str, rank: usize, batch: usize,
                 seq: usize, ckpt: bool) -> MemBreakdown {
    let tokens = (batch * seq) as f64;
    let trainable = trainable_params(m, method, rank);
    let act_tb = act_bytes_per_token_block(m, method, rank, ckpt);
    // LM-head logits dominate at long seq (bf16 logits + fp32 softmax).
    let logits = tokens * m.vocab as f64 * 6.0 * ckpt_factor(m, ckpt);
    let method_static = match method {
        "dora" => DORA_STATIC_FRAC * target_params_per_layer(m)
            * m.n_layers as f64 * BP,
        // One dequantized layer's targets live at a time.
        "qlora" | "qpaca" => target_params_per_layer(m) * BP,
        _ => 0.0,
    };
    MemBreakdown {
        weights: weight_bytes(m, method),
        grads_opt: trainable * (BP + OPT_BYTES_PER_PARAM),
        activations: tokens * m.n_layers as f64 * act_tb + logits,
        method_static,
        framework: FRAMEWORK_BYTES,
    }
}

/// Largest sequence length (batch=1) fitting in `capacity_bytes`
/// (Table 4). Linear activation growth ⇒ closed form, then clamp.
pub fn max_seq_len(m: &ModelInfo, method: &str, rank: usize,
                   capacity_bytes: f64, ckpt: bool) -> usize {
    let fixed = breakdown(m, method, rank, 1, 0, ckpt);
    let fixed_bytes = fixed.total();
    if fixed_bytes >= capacity_bytes {
        return 0;
    }
    let per_token = m.n_layers as f64
        * act_bytes_per_token_block(m, method, rank, ckpt)
        + m.vocab as f64 * 6.0 * ckpt_factor(m, ckpt);
    (((capacity_bytes - fixed_bytes) / per_token) as usize / 100) * 100
}

/// Largest batch fitting at fixed seq (Fig 3's OOM walls).
pub fn max_batch(m: &ModelInfo, method: &str, rank: usize, seq: usize,
                 capacity_bytes: f64, ckpt: bool) -> usize {
    let mut b = 0;
    loop {
        let next = b + 1;
        if breakdown(m, method, rank, next, seq, ckpt).total()
            > capacity_bytes
        {
            return b;
        }
        b = next;
        if b > 4096 {
            return b; // guard
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llama2_7b() -> ModelInfo {
        ModelInfo { name: "llama2-7b".into(), vocab: 32000,
                    d_model: 4096, n_layers: 32, n_heads: 32,
                    d_ff: 11008, max_seq: 4096, profile_only: true }
    }

    fn llama3_8b() -> ModelInfo {
        ModelInfo { name: "llama3-8b".into(), vocab: 128256,
                    d_model: 4096, n_layers: 32, n_heads: 32,
                    d_ff: 14336, max_seq: 8192, profile_only: true }
    }

    #[test]
    fn table1_absolute_calibration_llama2_7b() {
        // Paper Table 1 (batch 8, seq 512, ckpt regime):
        // LoRA 23G, PaCA 20G, DoRA 29G.
        let m = llama2_7b();
        let lora = breakdown(&m, "lora", 8, 8, 512, true).total_gb();
        let paca = breakdown(&m, "paca", 8, 8, 512, true).total_gb();
        let dora = breakdown(&m, "dora", 8, 8, 512, true).total_gb();
        assert!((lora - 23.0).abs() < 3.0, "lora={lora}");
        assert!((paca - 20.0).abs() < 3.0, "paca={paca}");
        assert!((dora - 29.0).abs() < 4.0, "dora={dora}");
        assert!(paca < lora && lora < dora);
    }

    #[test]
    fn paca_saves_activation_memory() {
        let m = llama3_8b();
        for ckpt in [true, false] {
            let l = breakdown(&m, "lora", 8, 8, 512, ckpt);
            let p = breakdown(&m, "paca", 8, 8, 512, ckpt);
            assert!(p.activations < l.activations);
            assert_eq!(p.weights, l.weights);
        }
    }

    #[test]
    fn table4_max_seq_ordering_and_ratio() {
        // Paper Table 4 (A100 80GB): LoRA 8.0K, DoRA 4.7K, PaCA 9.8K.
        let m = llama3_8b();
        let cap = 80e9;
        let lora = max_seq_len(&m, "lora", 8, cap, false);
        let dora = max_seq_len(&m, "dora", 8, cap, false);
        let paca = max_seq_len(&m, "paca", 8, cap, false);
        assert!(dora < lora && lora < paca,
                "dora={dora} lora={lora} paca={paca}");
        let ratio = paca as f64 / lora as f64;
        assert!(ratio > 1.1 && ratio < 1.7, "ratio={ratio}");
    }

    #[test]
    fn fig3_max_batch_gain() {
        // Paper: PaCA fits ~33% larger batch than LoRA at seq 512.
        let m = llama3_8b();
        let lora = max_batch(&m, "lora", 8, 512, 80e9, false);
        let paca = max_batch(&m, "paca", 8, 512, 80e9, false);
        assert!(paca as f64 >= 1.15 * lora as f64,
                "lora={lora} paca={paca}");
    }

    #[test]
    fn quantization_shrinks_weights() {
        // Paper Table 3: QLoRA 70B trains on one 80GB A100.
        let m = ModelInfo { name: "llama3.1-70b".into(), vocab: 128256,
                            d_model: 8192, n_layers: 80, n_heads: 64,
                            d_ff: 28672, max_seq: 8192,
                            profile_only: true };
        let full = weight_bytes(&m, "lora");
        let quant = weight_bytes(&m, "qlora");
        assert!(full / 1e9 > 140.0);
        assert!(quant < 0.35 * full, "quant={}", quant / 1e9);
        // Paper Table 11: batch 16 with grad-accum 2 → microbatch 8.
        let qpaca = breakdown(&m, "qpaca", 64, 8, 768, true);
        let qlora = breakdown(&m, "qlora", 64, 8, 768, true);
        assert!(qpaca.total() < qlora.total());
        assert!(qlora.total_gb() < 96.0);
    }

    #[test]
    fn monotone_in_batch_seq_rank() {
        let m = llama2_7b();
        let base = breakdown(&m, "paca", 8, 8, 512, true).total();
        assert!(breakdown(&m, "paca", 8, 16, 512, true).total() > base);
        assert!(breakdown(&m, "paca", 8, 8, 1024, true).total() > base);
        assert!(breakdown(&m, "paca", 64, 8, 512, true).total() > base);
    }

    #[test]
    fn rank_memory_delta_small_then_visible() {
        // Paper §4.2: r 8→16 barely moves memory; 64→128 adds ~4GB.
        let m = llama3_8b();
        let d_small = breakdown(&m, "paca", 16, 16, 768, true).total()
            - breakdown(&m, "paca", 8, 16, 768, true).total();
        let d_large = breakdown(&m, "paca", 128, 16, 768, true).total()
            - breakdown(&m, "paca", 64, 16, 768, true).total();
        assert!(d_large > 3.0 * d_small);
    }
}
