//! Synthetic data pipeline (substrate for the paper's MMLU / Oasst1 /
//! image workloads — see DESIGN.md §4 substitutions).
//!
//! Three token tasks over the model's vocab, all with *learnable*
//! structure so fine-tuning measurably improves loss/accuracy:
//!
//!  * `lm-zipf`    — Zipfian unigrams + deterministic bigram skeleton
//!                   (generic causal-LM corpus).
//!  * `mmlu-like`  — four "subjects" (Humanities/STEM/Social/Other) with
//!                   subject-specific transition rules and embedded
//!                   question→answer positions; per-subject eval batches
//!                   reproduce Table 1's subject columns.
//!  * `instr`      — eight instruction→response categories mirroring
//!                   MT-Bench's task mix; the response is a per-category
//!                   deterministic transform of the instruction, so
//!                   instruction-following is learnable; per-category
//!                   eval loss maps to a 0–10 score proxy.
//!
//! Plus a double-buffered prefetching loader (std::thread — the offline
//! build has no tokio) and synthetic class-conditional images for the
//! ViT experiments.

use std::sync::mpsc;
use std::thread;

use crate::tensor::HostTensor;
use crate::util::rng::{Rng, Zipf};

pub const MMLU_SUBJECTS: [&str; 4] = ["Hums.", "STEM", "Social.", "Other"];
pub const MTBENCH_CATEGORIES: [&str; 8] = [
    "Human.", "STEM", "Role.", "Extract.", "Writing", "Reason.",
    "Coding", "Math",
];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    LmZipf,
    MmluLike,
    Instr,
}

impl Task {
    pub fn parse(s: &str) -> anyhow::Result<Task> {
        Ok(match s {
            "lm-zipf" => Task::LmZipf,
            "mmlu-like" => Task::MmluLike,
            "instr" => Task::Instr,
            other => anyhow::bail!("unknown task {other:?}"),
        })
    }

    pub fn n_categories(&self) -> usize {
        match self {
            Task::LmZipf => 1,
            Task::MmluLike => MMLU_SUBJECTS.len(),
            Task::Instr => MTBENCH_CATEGORIES.len(),
        }
    }

    pub fn category_names(&self) -> &'static [&'static str] {
        match self {
            Task::LmZipf => &["LM"],
            Task::MmluLike => &MMLU_SUBJECTS,
            Task::Instr => &MTBENCH_CATEGORIES,
        }
    }
}

/// Token-stream generator. Train batches mix categories; eval batches
/// can be pinned to one category for the per-column tables.
pub struct TokenGen {
    pub task: Task,
    pub vocab: usize,
    zipf: Zipf,
    rng: Rng,
}

impl TokenGen {
    pub fn new(task: Task, vocab: usize, seed: u64) -> TokenGen {
        assert!(vocab >= 64, "vocab too small for task structure");
        TokenGen { task, vocab, zipf: Zipf::new(vocab, 1.1),
                   rng: Rng::for_tag(seed, "data") }
    }

    /// (b, s+1) training batch, categories interleaved.
    pub fn train_batch(&mut self, b: usize, s: usize) -> HostTensor {
        let mut toks = Vec::with_capacity(b * (s + 1));
        for row in 0..b {
            let cat = row % self.task.n_categories();
            self.fill_row(&mut toks, s + 1, cat);
        }
        HostTensor::from_i32(&[b, s + 1], toks)
    }

    /// (b, s+1) eval batch pinned to `category`, from a forked stream so
    /// eval data is disjoint from training data.
    pub fn eval_batch(&mut self, b: usize, s: usize, category: usize,
                      eval_seed: u64) -> HostTensor {
        let mut rng = Rng::for_tag(eval_seed ^ 0x5eed_0000,
                                   &format!("eval/{category}"));
        std::mem::swap(&mut self.rng, &mut rng);
        let mut toks = Vec::with_capacity(b * (s + 1));
        for _ in 0..b {
            self.fill_row(&mut toks, s + 1, category);
        }
        std::mem::swap(&mut self.rng, &mut rng);
        HostTensor::from_i32(&[b, s + 1], toks)
    }

    fn fill_row(&mut self, out: &mut Vec<i32>, len: usize, cat: usize) {
        match self.task {
            Task::LmZipf => self.fill_lm(out, len, 0),
            Task::MmluLike => self.fill_mmlu(out, len, cat),
            Task::Instr => self.fill_instr(out, len, cat),
        }
    }

    /// Zipf unigram with an 80%-deterministic bigram skeleton:
    /// next = (a·t + c) mod V with per-stream constants.
    fn fill_lm(&mut self, out: &mut Vec<i32>, len: usize, shift: usize) {
        let v = self.vocab;
        let mut t = self.zipf.sample(&mut self.rng);
        for _ in 0..len {
            out.push(t as i32);
            t = if self.rng.next_f64() < 0.8 {
                (t * 31 + 17 + shift) % v
            } else {
                self.zipf.sample(&mut self.rng)
            };
        }
    }

    /// [SUBJ] q q q q [ANS] a, repeated. The answer token is a
    /// deterministic function of the question tokens and the subject,
    /// so subject-conditional reasoning is learnable.
    fn fill_mmlu(&mut self, out: &mut Vec<i32>, len: usize, subj: usize) {
        let v = self.vocab;
        let subj_tok = (v - 8 + subj) as i32; // reserved subject markers
        let ans_mark = (v - 16) as i32;
        let mut row = Vec::with_capacity(len);
        row.push(subj_tok);
        while row.len() < len {
            let qlen = 4;
            let mut acc = subj * 131;
            for _ in 0..qlen {
                if row.len() >= len {
                    break;
                }
                let q = self.zipf.sample(&mut self.rng) % (v - 20);
                acc += q;
                row.push(q as i32);
            }
            if row.len() < len {
                row.push(ans_mark);
            }
            if row.len() < len {
                row.push((acc % (v - 20)) as i32);
            }
        }
        out.extend_from_slice(&row[..len]);
    }

    /// [CAT] instruction… [RESP] response…, where the response applies a
    /// per-category affine transform to the instruction tokens.
    fn fill_instr(&mut self, out: &mut Vec<i32>, len: usize, cat: usize) {
        let v = self.vocab;
        let cat_tok = (v - 32 + cat) as i32;
        let resp_mark = (v - 17) as i32;
        let mut row = Vec::with_capacity(len);
        row.push(cat_tok);
        let ilen = (len / 2).saturating_sub(2).max(1);
        let mut instr = Vec::with_capacity(ilen);
        for _ in 0..ilen {
            instr.push(self.zipf.sample(&mut self.rng) % (v - 40));
        }
        row.extend(instr.iter().map(|&t| t as i32));
        row.push(resp_mark);
        // per-category transform: t -> (a_cat * t + b_cat) mod (v-40)
        let a = 3 + 2 * cat;
        let b = 7 * (cat + 1);
        for &t in &instr {
            if row.len() >= len {
                break;
            }
            row.push(((a * t + b) % (v - 40)) as i32);
        }
        while row.len() < len {
            row.push((self.zipf.sample(&mut self.rng) % (v - 40)) as i32);
        }
        out.extend_from_slice(&row[..len]);
    }
}

/// Class-conditional synthetic images for the ViT/CNN experiments:
/// class k = a fixed random low-frequency pattern + pixel noise.
pub struct ImageGen {
    patterns: Vec<Vec<f32>>, // n_classes × (3·32·32)
    rng: Rng,
    pub n_classes: usize,
}

impl ImageGen {
    pub fn new(n_classes: usize, seed: u64) -> ImageGen {
        Self::with_seeds(n_classes, seed, seed)
    }

    /// Separate pattern/noise streams: held-out data = SAME class
    /// patterns (pattern_seed), fresh pixel noise (noise_seed).
    pub fn with_seeds(n_classes: usize, pattern_seed: u64,
                      noise_seed: u64) -> ImageGen {
        let mut patterns = Vec::with_capacity(n_classes);
        for k in 0..n_classes {
            let mut prng = Rng::for_tag(pattern_seed,
                                        &format!("img/pattern/{k}"));
            // low-frequency: sum of 3 random 2-D cosines per channel
            let mut p = vec![0f32; 3 * 32 * 32];
            for c in 0..3 {
                for _ in 0..3 {
                    let fx = prng.range(1, 5) as f32;
                    let fy = prng.range(1, 5) as f32;
                    let phase = prng.next_f32() * 6.283;
                    for y in 0..32 {
                        for x in 0..32 {
                            let v = ((fx * x as f32 / 32.0
                                      + fy * y as f32 / 32.0)
                                     * 6.283 + phase).cos();
                            p[c * 1024 + y * 32 + x] += v * 0.5;
                        }
                    }
                }
            }
            patterns.push(p);
        }
        ImageGen { patterns, rng: Rng::for_tag(noise_seed, "img/noise"),
                   n_classes }
    }

    pub fn batch(&mut self, b: usize) -> (HostTensor, HostTensor) {
        let mut imgs = Vec::with_capacity(b * 3 * 32 * 32);
        let mut labels = Vec::with_capacity(b);
        for _ in 0..b {
            let k = self.rng.below(self.n_classes);
            labels.push(k as i32);
            for &v in &self.patterns[k] {
                imgs.push(v + self.rng.normal_f32(0.3));
            }
        }
        (HostTensor::from_f32(&[b, 3, 32, 32], imgs),
         HostTensor::from_i32(&[b], labels))
    }
}

/// Background prefetcher: a worker thread keeps `depth` batches ready so
/// batch generation overlaps PJRT execution (tokio-free async substrate).
pub struct Prefetcher {
    rx: mpsc::Receiver<HostTensor>,
    _handle: thread::JoinHandle<()>,
}

impl Prefetcher {
    pub fn new(task: Task, vocab: usize, seed: u64, b: usize, s: usize,
               depth: usize) -> Prefetcher {
        let (tx, rx) = mpsc::sync_channel(depth);
        let handle = thread::spawn(move || {
            let mut gen = TokenGen::new(task, vocab, seed);
            loop {
                let batch = gen.train_batch(b, s);
                if tx.send(batch).is_err() {
                    return; // consumer dropped
                }
            }
        });
        Prefetcher { rx, _handle: handle }
    }

    pub fn next(&self) -> HostTensor {
        self.rx.recv().expect("prefetcher thread died")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_vocab_bounds() {
        for task in [Task::LmZipf, Task::MmluLike, Task::Instr] {
            let mut g = TokenGen::new(task, 512, 1);
            let b = g.train_batch(4, 32);
            assert_eq!(b.shape, vec![4, 33]);
            assert!(b.as_i32().iter().all(|&t| t >= 0 && t < 512));
        }
    }

    #[test]
    fn train_is_deterministic_per_seed() {
        let a = TokenGen::new(Task::Instr, 512, 7).train_batch(2, 16);
        let b = TokenGen::new(Task::Instr, 512, 7).train_batch(2, 16);
        let c = TokenGen::new(Task::Instr, 512, 8).train_batch(2, 16);
        assert_eq!(a.as_i32(), b.as_i32());
        assert_ne!(a.as_i32(), c.as_i32());
    }

    #[test]
    fn eval_batches_category_pinned_and_stable() {
        let mut g = TokenGen::new(Task::MmluLike, 512, 1);
        let e0 = g.eval_batch(2, 16, 0, 9);
        let e0b = g.eval_batch(2, 16, 0, 9);
        let e1 = g.eval_batch(2, 16, 1, 9);
        assert_eq!(e0.as_i32(), e0b.as_i32());
        assert_ne!(e0.as_i32(), e1.as_i32());
        // subject marker token present in row starts
        let toks = e0.as_i32();
        assert_eq!(toks[0], (512 - 8) as i32);
    }

    #[test]
    fn eval_does_not_perturb_train_stream() {
        let mut g1 = TokenGen::new(Task::LmZipf, 512, 3);
        let mut g2 = TokenGen::new(Task::LmZipf, 512, 3);
        let _ = g2.eval_batch(2, 16, 0, 1);
        assert_eq!(g1.train_batch(2, 16).as_i32(),
                   g2.train_batch(2, 16).as_i32());
    }

    #[test]
    fn instr_response_is_deterministic_transform() {
        let mut g = TokenGen::new(Task::Instr, 512, 5);
        let b = g.train_batch(1, 32);
        let toks = b.as_i32();
        let cat = (toks[0] - (512 - 32)) as usize;
        let ilen: usize = (33 / 2) - 2;
        let (a, off) = (3 + 2 * cat, 7 * (cat + 1));
        // response tokens follow the [RESP] marker at position 1+ilen
        let resp_start = 1 + ilen + 1;
        for j in 0..4 {
            let inst = toks[1 + j] as usize;
            let want = ((a * inst + off) % (512 - 40)) as i32;
            assert_eq!(toks[resp_start + j], want);
        }
    }

    #[test]
    fn images_class_separable() {
        let mut g = ImageGen::new(4, 1);
        let (imgs, labels) = g.batch(8);
        assert_eq!(imgs.shape, vec![8, 3, 32, 32]);
        assert_eq!(labels.len(), 8);
        // same-class images correlate more than cross-class ones
        let v = imgs.as_f32();
        let l = labels.as_i32();
        let row = |i: usize| &v[i * 3072..(i + 1) * 3072];
        let corr = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| x * y).sum()
        };
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..8 {
            for j in (i + 1)..8 {
                if l[i] == l[j] {
                    same.push(corr(row(i), row(j)));
                } else {
                    diff.push(corr(row(i), row(j)));
                }
            }
        }
        if !same.is_empty() && !diff.is_empty() {
            let ms = same.iter().sum::<f32>() / same.len() as f32;
            let md = diff.iter().sum::<f32>() / diff.len() as f32;
            assert!(ms > md, "same {ms} !> diff {md}");
        }
    }

    #[test]
    fn prefetcher_delivers() {
        let p = Prefetcher::new(Task::LmZipf, 512, 1, 2, 16, 2);
        for _ in 0..5 {
            let b = p.next();
            assert_eq!(b.shape, vec![2, 17]);
        }
    }
}
