//! State initialization from manifest init specs.
//!
//! The python layer never ships weights: every tensor carries a
//! declarative `Init` (normal/zeros/ones/eye/choice/col_norm/nf4_*/
//! rows_of/const) and the rust side materializes them deterministically
//! from (seed, tensor-name) RNG streams. This keeps artifacts small and
//! lets the coordinator re-seed PaCA's column selection at run time
//! (Table 5's selection-strategy ablation).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::manifest::{ArtifactInfo, EntrySpec, Init};
use crate::nf4;
use crate::peft::Selection;
use crate::tensor::{DType, HostTensor};
use crate::util::rng::Rng;

/// The "virtual" pretrained weight a quantized / rows_of init refers to:
/// N(0, std²) drawn from the stream of the *weight's layer prefix*, so
/// codes, scales, and the fp rows all see the same pretrained values.
fn virtual_weight(seed: u64, layer_prefix: &str, shape: (usize, usize),
                  std: f32) -> Vec<f32> {
    let mut rng = Rng::for_tag(seed, &format!("{layer_prefix}#virtual"));
    (0..shape.0 * shape.1).map(|_| rng.normal_f32(std)).collect()
}

fn layer_prefix(name: &str) -> &str {
    name.rsplit_once('/').map(|(p, _)| p).unwrap_or(name)
}

/// Initialize all state tensors of an artifact.
///
/// `selection` overrides the PaCA/QPaCA index initialization (random by
/// default; weight-norm / gradient-norm for the Table-5 ablation).
pub fn init_state(art: &ArtifactInfo, seed: u64,
                  selection: &Selection) -> Result<Vec<HostTensor>> {
    let mut out: BTreeMap<String, HostTensor> = BTreeMap::new();

    // Two passes: tensors without cross-references first, then the
    // dependent inits (col_norm, rows_of) which read earlier tensors.
    for pass in 0..2 {
        for e in &art.state {
            if out.contains_key(&e.name) {
                continue;
            }
            let dependent = matches!(e.init,
                                     Init::ColNorm { .. }
                                     | Init::RowsOf { .. });
            if (pass == 0) == dependent {
                continue;
            }
            let t = init_entry(e, seed, selection, &out)?;
            out.insert(e.name.clone(), t);
        }
    }

    // Preserve manifest (input) order.
    art.state.iter()
        .map(|e| out.remove(&e.name)
             .ok_or_else(|| anyhow!("uninitialized entry {}", e.name)))
        .collect()
}

fn init_entry(e: &EntrySpec, seed: u64, selection: &Selection,
              done: &BTreeMap<String, HostTensor>) -> Result<HostTensor> {
    let n: usize = e.shape.iter().product();
    Ok(match &e.init {
        Init::Normal { std } => {
            let mut rng = Rng::for_tag(seed, &e.name);
            HostTensor::from_f32(
                &e.shape, (0..n).map(|_| rng.normal_f32(*std)).collect())
        }
        Init::Zeros | Init::None => HostTensor::zeros(&e.shape, e.dtype),
        Init::Ones => HostTensor::from_f32(&e.shape, vec![1.0; n]),
        Init::Eye => {
            let r = e.shape[0];
            let mut v = vec![0f32; r * r];
            for i in 0..r {
                v[i * r + i] = 1.0;
            }
            HostTensor::from_f32(&e.shape, v)
        }
        Init::Choice { n: pool } => {
            let r = e.shape[0];
            let idx = selection.select(seed, &e.name, *pool, r, done)?;
            HostTensor::from_i32(&e.shape,
                                 idx.into_iter().map(|i| i as i32)
                                 .collect())
        }
        Init::ColNorm { of } => {
            let w = done.get(of)
                .ok_or_else(|| anyhow!("col_norm: {of} not ready"))?;
            if w.shape.len() != 2 {
                bail!("col_norm of non-matrix {of}");
            }
            let (rows, cols) = (w.shape[0], w.shape[1]);
            let mut norms = vec![0f32; cols];
            for i in 0..rows {
                for j in 0..cols {
                    let v = w.f32_at(i * cols + j);
                    norms[j] += v * v;
                }
            }
            for v in norms.iter_mut() {
                *v = v.sqrt();
            }
            HostTensor::from_f32(&e.shape, norms)
        }
        Init::Nf4Codes { of_shape, std, block } => {
            let w = virtual_weight(seed, layer_prefix(&e.name), *of_shape,
                                   *std);
            let (codes, _scales) = nf4::quantize(&w, *block);
            HostTensor::from_i8(&e.shape, codes)
        }
        Init::Nf4Scales { of_shape, std, block } => {
            let w = virtual_weight(seed, layer_prefix(&e.name), *of_shape,
                                   *std);
            let (_codes, scales) = nf4::quantize(&w, *block);
            HostTensor::from_f32(&e.shape, scales)
        }
        Init::RowsOf { of_shape, std, idx } => {
            let w = virtual_weight(seed, layer_prefix(&e.name), *of_shape,
                                   *std);
            let idx_t = done.get(idx)
                .ok_or_else(|| anyhow!("rows_of: {idx} not ready"))?;
            let cols = of_shape.1;
            let mut v = Vec::with_capacity(e.shape.iter().product());
            for &i in &idx_t.as_i32() {
                let i = i as usize;
                v.extend_from_slice(&w[i * cols..(i + 1) * cols]);
            }
            HostTensor::from_f32(&e.shape, v)
        }
        Init::ConstI32 { value } => {
            if e.dtype != DType::I32 {
                bail!("const_i32 on non-i32 {}", e.name);
            }
            HostTensor::from_i32(&e.shape, vec![*value; n.max(1)])
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{EntrySpec, Init};

    fn spec(name: &str, shape: &[usize], dtype: DType,
            init: Init) -> EntrySpec {
        EntrySpec { name: name.into(), shape: shape.to_vec(), dtype,
                    role: "frozen".into(), init, updated: false }
    }

    #[test]
    fn normal_is_deterministic_per_name() {
        let e = spec("blocks/0/q/w", &[8, 8], DType::F32,
                     Init::Normal { std: 0.02 });
        let done = BTreeMap::new();
        let a = init_entry(&e, 1, &Selection::Random, &done).unwrap();
        let b = init_entry(&e, 1, &Selection::Random, &done).unwrap();
        assert_eq!(a.data, b.data);
        let c = init_entry(&spec("blocks/1/q/w", &[8, 8], DType::F32,
                                 Init::Normal { std: 0.02 }),
                           1, &Selection::Random, &done).unwrap();
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn eye_and_ones() {
        let done = BTreeMap::new();
        let e = init_entry(&spec("m", &[3, 3], DType::F32, Init::Eye), 0,
                           &Selection::Random, &done).unwrap();
        assert_eq!(e.as_f32(), vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]);
        let o = init_entry(&spec("g", &[2], DType::F32, Init::Ones), 0,
                           &Selection::Random, &done).unwrap();
        assert_eq!(o.as_f32(), vec![1., 1.]);
    }

    #[test]
    fn choice_distinct_and_seed_dependent() {
        let done = BTreeMap::new();
        let e = spec("l/idx", &[8], DType::I32, Init::Choice { n: 64 });
        let a = init_entry(&e, 1, &Selection::Random, &done).unwrap();
        let b = init_entry(&e, 2, &Selection::Random, &done).unwrap();
        let mut av = a.as_i32();
        assert_ne!(av, b.as_i32());
        av.sort_unstable();
        av.dedup();
        assert_eq!(av.len(), 8);
        assert!(av.iter().all(|&i| i >= 0 && i < 64));
    }

    #[test]
    fn col_norm_reads_dependency() {
        let mut done = BTreeMap::new();
        done.insert("l/w".to_string(),
                    HostTensor::from_f32(&[2, 2], vec![3., 0., 4., 0.]));
        let e = spec("l/mag", &[2], DType::F32,
                     Init::ColNorm { of: "l/w".into() });
        let t = init_entry(&e, 0, &Selection::Random, &done).unwrap();
        assert_eq!(t.as_f32(), vec![5.0, 0.0]);
    }

    #[test]
    fn nf4_codes_scales_consistent_with_rows_of() {
        // codes/scales/rows all derive from the same virtual weight.
        let done = BTreeMap::new();
        let codes = init_entry(
            &spec("l/codes", &[2, 64], DType::I8,
                  Init::Nf4Codes { of_shape: (8, 16), std: 0.02,
                                   block: 64 }),
            5, &Selection::Random, &done).unwrap();
        let scales = init_entry(
            &spec("l/scales", &[2], DType::F32,
                  Init::Nf4Scales { of_shape: (8, 16), std: 0.02,
                                    block: 64 }),
            5, &Selection::Random, &done).unwrap();
        assert_eq!(codes.data.len(), 128);
        assert_eq!(scales.as_f32().len(), 2);

        let mut done2 = BTreeMap::new();
        done2.insert("l/idx".to_string(),
                     HostTensor::from_i32(&[2], vec![1, 4]));
        let rows = init_entry(
            &spec("l/p", &[2, 16], DType::F32,
                  Init::RowsOf { of_shape: (8, 16), std: 0.02,
                                 idx: "l/idx".into() }),
            5, &Selection::Random, &done2).unwrap();
        // Row values must match dequantizing nothing — they come from the
        // same virtual weight (sanity: finite, nonzero).
        assert!(rows.as_f32().iter().any(|&v| v != 0.0));
    }
}
