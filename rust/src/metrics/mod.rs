//! Training metrics: loss/accuracy curves, phase timers, throughput
//! counters, and CSV/markdown reporters used by the bench harness and
//! EXPERIMENTS.md generation.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Default)]
pub struct LossCurve {
    pub steps: Vec<usize>,
    pub loss: Vec<f64>,
    pub acc: Vec<f64>,
}

impl LossCurve {
    pub fn push(&mut self, step: usize, loss: f64, acc: f64) {
        self.steps.push(step);
        self.loss.push(loss);
        self.acc.push(acc);
    }

    pub fn last_loss(&self) -> Option<f64> {
        self.loss.last().copied()
    }

    /// Mean of the final `k` recorded losses (noise-robust endpoint).
    pub fn tail_mean(&self, k: usize) -> f64 {
        let n = self.loss.len();
        let k = k.min(n).max(1);
        self.loss[n - k..].iter().sum::<f64>() / k as f64
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,loss,acc\n");
        for i in 0..self.steps.len() {
            let _ = writeln!(out, "{},{:.6},{:.6}", self.steps[i],
                             self.loss[i], self.acc[i]);
        }
        out
    }
}

/// Accumulates wall time per training phase.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimers {
    pub data_s: f64,
    pub h2d_s: f64,
    pub execute_s: f64,
    pub d2h_s: f64,
    pub total_s: f64,
}

impl PhaseTimers {
    pub fn report(&self) -> String {
        format!(
            "data {:.3}s | h2d {:.3}s | execute {:.3}s | d2h {:.3}s | \
             total {:.3}s",
            self.data_s, self.h2d_s, self.execute_s, self.d2h_s,
            self.total_s)
    }
}

pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch(Instant::now())
    }

    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.0;
        self.0 = now;
        d
    }
}

/// THE percentile rule every consumer shares, reduced to its index
/// arithmetic: the 0-based position of the nearest-rank order
/// statistic (the ceil(q·n)th sample) among `n` ascending samples,
/// with q clamped into [0, 1]. Shared with the telemetry histogram
/// so its bucket-walk percentiles agree with [`LatencyRecorder`]
/// bitwise whenever every bucket holds one distinct sample.
pub(crate) fn nearest_rank_index(n: usize, q: f64) -> usize {
    let rank = (q.clamp(0.0, 1.0) * n as f64).ceil() as usize;
    rank.saturating_sub(1).min(n.saturating_sub(1))
}

/// Nearest-rank order statistic of an ascending-sorted slice, with
/// 0.0 for an empty slice (callers gate on emptiness for their
/// `Option` APIs; the helper stays total so no path can index out
/// of bounds).
fn nearest_rank(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[nearest_rank_index(sorted.len(), q)]
}

/// Keyed latency samples (seconds) with percentile queries — the
/// serving engine records per-tenant and aggregate request latencies
/// here and renders them as a table.
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples: BTreeMap<String, Vec<f64>>,
    /// Lazily-built ascending copy per key, reused across percentile
    /// queries. Samples only ever append, so a cached copy whose
    /// length matches the raw vec is current; anything shorter is
    /// rebuilt on the next query. (Interior mutability keeps the
    /// query API `&self`.)
    sorted: RefCell<BTreeMap<String, Vec<f64>>>,
}

impl LatencyRecorder {
    pub fn record(&mut self, key: &str, secs: f64) {
        self.samples.entry(key.to_string()).or_default().push(secs);
    }

    /// Run `f` over the key's ascending-sorted samples, sorting at
    /// most once per batch of recorded samples (`None` when the key
    /// is missing or empty).
    fn with_sorted<R>(&self, key: &str,
                      f: impl FnOnce(&[f64]) -> R) -> Option<R> {
        let raw = self.samples.get(key)?;
        if raw.is_empty() {
            return None;
        }
        let mut cache = self.sorted.borrow_mut();
        let entry = cache.entry(key.to_string()).or_default();
        if entry.len() != raw.len() {
            entry.clear();
            entry.extend_from_slice(raw);
            entry.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        Some(f(entry))
    }

    /// Merge another recorder's samples into this one (the cluster
    /// report pools per-replica recorders into merged percentiles).
    /// Appends per key, so the sorted caches invalidate themselves
    /// through the length check on the next query.
    pub fn absorb(&mut self, other: &LatencyRecorder) {
        for (key, s) in &other.samples {
            self.samples.entry(key.clone()).or_default()
                .extend_from_slice(s);
        }
    }

    pub fn keys(&self) -> Vec<&str> {
        self.samples.keys().map(String::as_str).collect()
    }

    pub fn count(&self, key: &str) -> usize {
        self.samples.get(key).map(Vec::len).unwrap_or(0)
    }

    pub fn mean(&self, key: &str) -> Option<f64> {
        let s = self.samples.get(key)?;
        if s.is_empty() {
            return None;
        }
        Some(s.iter().sum::<f64>() / s.len() as f64)
    }

    /// q in [0, 1]; nearest-rank (ceil(q·n)th order statistic) on the
    /// cached sorted copy — repeated queries (the breakdown table
    /// asks five per row) no longer re-clone and re-sort per call.
    pub fn percentile(&self, key: &str, q: f64) -> Option<f64> {
        self.with_sorted(key, |sorted| nearest_rank(sorted, q))
    }

    /// One row per key: n, mean/p50/p95/max in milliseconds, off the
    /// same per-key sorted cache the percentile queries use.
    pub fn table(&self, key_header: &str) -> Table {
        let mut t = Table::new(&[key_header, "n", "mean ms", "p50 ms",
                                 "p95 ms", "max ms"]);
        for (key, s) in &self.samples {
            if s.is_empty() {
                continue;
            }
            let mean = s.iter().sum::<f64>() / s.len() as f64;
            let ms = |v: f64| format!("{:.3}", v * 1e3);
            let row = self.with_sorted(key, |sorted| {
                [ms(nearest_rank(sorted, 0.50)),
                 ms(nearest_rank(sorted, 0.95)),
                 ms(nearest_rank(sorted, 1.0))]
            }).expect("non-empty key");
            t.row(&[key.clone(),
                    s.len().to_string(),
                    ms(mean),
                    row[0].clone(),
                    row[1].clone(),
                    row[2].clone()]);
        }
        t
    }
}

/// One row per tenant key shared by the recorders: the serving
/// latency decomposition (queueing delay vs service time vs
/// end-to-end), p50/p99 in milliseconds. Keys present in `e2e` drive
/// the row set; the other recorders contribute blanks when missing.
pub fn latency_breakdown_table(queueing: &LatencyRecorder,
                               service: &LatencyRecorder,
                               e2e: &LatencyRecorder,
                               key_header: &str) -> Table {
    let mut t = Table::new(&[key_header, "n", "queue p50", "queue p99",
                             "service p50", "e2e p50", "e2e p99"]);
    let ms = |v: Option<f64>| match v {
        Some(v) => format!("{:.3}ms", v * 1e3),
        None => "-".to_string(),
    };
    for key in e2e.keys() {
        t.row(&[key.to_string(),
                e2e.count(key).to_string(),
                ms(queueing.percentile(key, 0.50)),
                ms(queueing.percentile(key, 0.99)),
                ms(service.percentile(key, 0.50)),
                ms(e2e.percentile(key, 0.50)),
                ms(e2e.percentile(key, 0.99))]);
    }
    t
}

/// Completions binned into fixed-width wall/virtual-clock buckets —
/// the time-resolved view of serving throughput (bursts and recovery
/// are invisible in a single aggregate req/s number).
#[derive(Debug, Clone)]
pub struct ThroughputTimeline {
    bucket_s: f64,
    requests: Vec<u64>,
    tokens: Vec<u64>,
}

impl ThroughputTimeline {
    pub fn new(bucket_s: f64) -> ThroughputTimeline {
        assert!(bucket_s > 0.0);
        ThroughputTimeline { bucket_s, requests: Vec::new(),
                             tokens: Vec::new() }
    }

    pub fn bucket_s(&self) -> f64 {
        self.bucket_s
    }

    /// Record `requests`/`tokens` completing at time `t_s`.
    pub fn record(&mut self, t_s: f64, requests: u64, tokens: u64) {
        // Cap the index so one absurd timestamp cannot OOM the
        // timeline.
        let i = ((t_s.max(0.0) / self.bucket_s) as usize)
            .min(1_000_000);
        if i >= self.requests.len() {
            self.requests.resize(i + 1, 0);
            self.tokens.resize(i + 1, 0);
        }
        self.requests[i] += requests;
        self.tokens[i] += tokens;
    }

    pub fn n_buckets(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    pub fn total_requests(&self) -> u64 {
        self.requests.iter().sum()
    }

    /// Highest single-bucket completion rate, req/s.
    pub fn peak_req_per_s(&self) -> f64 {
        self.requests.iter().copied().max().unwrap_or(0) as f64
            / self.bucket_s
    }

    /// Mean completion rate over the recorded span, req/s.
    pub fn mean_req_per_s(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.total_requests() as f64
            / (self.requests.len() as f64 * self.bucket_s)
    }

    /// One row per bucket: [t0, t1), completions, req/s, tok/s.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&["window", "done", "req/s", "tok/s"]);
        for (i, (&n, &tok)) in self.requests.iter()
            .zip(&self.tokens).enumerate()
        {
            t.row(&[format!("{:.2}-{:.2}s", i as f64 * self.bucket_s,
                            (i + 1) as f64 * self.bucket_s),
                    n.to_string(),
                    format!("{:.1}", n as f64 / self.bucket_s),
                    format!("{:.0}", tok as f64 / self.bucket_s)]);
        }
        t
    }
}

/// Per-iteration batch-occupancy record of the iteration-level serving
/// engine: for every step, how many sequences were in flight and how
/// many tokens the step computed (prefill prompts + one per decoding
/// slot). The summary view of how well continuous batching keeps the
/// batch full — invisible in aggregate throughput numbers.
#[derive(Debug, Clone, Default)]
pub struct OccupancyTimeline {
    slots: Vec<u64>,
    tokens: Vec<u64>,
}

impl OccupancyTimeline {
    /// Record one engine step with `slots` in-flight sequences
    /// computing `tokens` tokens.
    pub fn record(&mut self, slots: u64, tokens: u64) {
        self.slots.push(slots);
        self.tokens.push(tokens);
    }

    pub fn n_steps(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn mean_slots(&self) -> f64 {
        if self.slots.is_empty() {
            return 0.0;
        }
        self.slots.iter().sum::<u64>() as f64
            / self.slots.len() as f64
    }

    pub fn peak_slots(&self) -> u64 {
        self.slots.iter().copied().max().unwrap_or(0)
    }

    /// Largest single-step token load — the number the
    /// `--max-batch-tokens` budget bounds.
    pub fn peak_tokens(&self) -> u64 {
        self.tokens.iter().copied().max().unwrap_or(0)
    }

    pub fn mean_tokens(&self) -> f64 {
        if self.tokens.is_empty() {
            return 0.0;
        }
        self.tokens.iter().sum::<u64>() as f64
            / self.tokens.len() as f64
    }

    /// One row per step: in-flight slots and step tokens.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&["step", "slots", "tokens"]);
        for (i, (&s, &tok)) in self.slots.iter().zip(&self.tokens)
            .enumerate()
        {
            t.row(&[i.to_string(), s.to_string(), tok.to_string()]);
        }
        t
    }
}

/// Per-step KV-cache occupancy of the paged serving allocator: after
/// each iteration step, how many pool blocks were live and how many
/// token slots were actually filled. The capacity-axis companion to
/// [`OccupancyTimeline`] (which tracks compute occupancy): peak blocks
/// is what `--kv-blocks` bounds, and the blocks-vs-tokens gap is the
/// pool's internal fragmentation over time.
#[derive(Debug, Clone, Default)]
pub struct KvOccupancyTimeline {
    blocks: Vec<u64>,
    tokens: Vec<u64>,
    /// Cache-only (reclaimable) blocks per step — live blocks held
    /// solely by the prefix cache, i.e. capacity the LRU reclaim can
    /// hand back on demand. Live minus reclaimable = pinned.
    reclaimable: Vec<u64>,
}

impl KvOccupancyTimeline {
    /// Record one engine step with `blocks` live pool blocks holding
    /// `tokens` resident tokens, `reclaimable` of the blocks held
    /// only by the prefix cache (0 without one).
    pub fn record(&mut self, blocks: u64, tokens: u64,
                  reclaimable: u64) {
        self.blocks.push(blocks);
        self.tokens.push(tokens);
        self.reclaimable.push(reclaimable);
    }

    pub fn n_steps(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    pub fn peak_blocks(&self) -> u64 {
        self.blocks.iter().copied().max().unwrap_or(0)
    }

    pub fn mean_blocks(&self) -> f64 {
        if self.blocks.is_empty() {
            return 0.0;
        }
        self.blocks.iter().sum::<u64>() as f64
            / self.blocks.len() as f64
    }

    pub fn peak_tokens(&self) -> u64 {
        self.tokens.iter().copied().max().unwrap_or(0)
    }

    pub fn mean_tokens(&self) -> f64 {
        if self.tokens.is_empty() {
            return 0.0;
        }
        self.tokens.iter().sum::<u64>() as f64
            / self.tokens.len() as f64
    }

    pub fn peak_reclaimable(&self) -> u64 {
        self.reclaimable.iter().copied().max().unwrap_or(0)
    }

    pub fn mean_reclaimable(&self) -> f64 {
        if self.reclaimable.is_empty() {
            return 0.0;
        }
        self.reclaimable.iter().sum::<u64>() as f64
            / self.reclaimable.len() as f64
    }

    /// Pinned (live minus cache-only) blocks at the recorded peak-
    /// occupancy step have no single meaning across steps; per-step
    /// pinned is simply blocks − reclaimable, so expose the mean.
    pub fn mean_pinned(&self) -> f64 {
        self.mean_blocks() - self.mean_reclaimable()
    }

    /// Mean allocated-but-unfilled fraction of live blocks of
    /// `block_tokens` tokens each — internal fragmentation averaged
    /// over the steps where anything was resident.
    pub fn mean_frag_frac(&self, block_tokens: usize) -> f64 {
        let mut frac_sum = 0.0;
        let mut n = 0usize;
        for (&b, &t) in self.blocks.iter().zip(&self.tokens) {
            let slots = b * block_tokens as u64;
            if slots == 0 {
                continue;
            }
            frac_sum += (slots - t) as f64 / slots as f64;
            n += 1;
        }
        if n == 0 { 0.0 } else { frac_sum / n as f64 }
    }

    /// One row per step: live blocks, resident tokens, cache-only
    /// blocks.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&["step", "kv blocks", "kv tokens",
                                 "cache-only"]);
        for (i, ((&b, &tok), &r)) in self.blocks.iter()
            .zip(&self.tokens).zip(&self.reclaimable).enumerate()
        {
            t.row(&[i.to_string(), b.to_string(), tok.to_string(),
                    r.to_string()]);
        }
        t
    }
}

/// Fixed-width markdown table builder for the experiment reports.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(),
                rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], out: &mut String| {
            let _ = write!(out, "|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, " {:<w$} |", c, w = widths[i]);
            }
            let _ = writeln!(out);
        };
        line(&self.header, &mut out);
        let sep: Vec<String> =
            widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &mut out);
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }
}

pub fn fmt_gb(bytes: f64) -> String {
    format!("{:.1}G", bytes / 1e9)
}

pub fn fmt_dur_h(seconds: f64) -> String {
    if seconds >= 3600.0 {
        format!("{:.1}h", seconds / 3600.0)
    } else if seconds >= 60.0 {
        format!("{:.0}m", seconds / 60.0)
    } else {
        format!("{:.1}s", seconds)
    }
}

pub fn fmt_params(n: f64) -> String {
    if n >= 1e9 {
        format!("{:.1}B", n / 1e9)
    } else if n >= 1e6 {
        format!("{:.0}M", n / 1e6)
    } else {
        format!("{:.0}K", n / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_and_csv() {
        let mut c = LossCurve::default();
        c.push(1, 2.0, 0.1);
        c.push(2, 1.0, 0.2);
        assert_eq!(c.last_loss(), Some(1.0));
        assert_eq!(c.tail_mean(2), 1.5);
        let csv = c.to_csv();
        assert!(csv.starts_with("step,loss,acc\n"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Method", "Mem"]);
        t.row(&["LoRA".into(), "23G".into()]);
        t.row(&["PaCA (Ours)".into(), "20G".into()]);
        let r = t.render();
        assert!(r.contains("| Method"));
        assert!(r.contains("| PaCA (Ours) |"));
        assert_eq!(r.lines().count(), 4);
    }

    #[test]
    fn latency_recorder_percentiles() {
        let mut r = LatencyRecorder::default();
        for i in 1..=100 {
            r.record("t0", i as f64 * 1e-3);
        }
        r.record("t1", 0.5);
        assert_eq!(r.count("t0"), 100);
        assert_eq!(r.count("nope"), 0);
        assert!((r.mean("t0").unwrap() - 0.0505).abs() < 1e-9);
        // Nearest-rank: p50 of 1..=100 ms is the 50th sample.
        assert!((r.percentile("t0", 0.5).unwrap() - 0.050).abs() < 1e-9);
        assert!((r.percentile("t0", 1.0).unwrap() - 0.100).abs() < 1e-9);
        assert!((r.percentile("t0", 0.0).unwrap() - 0.001).abs() < 1e-9);
        assert!(r.percentile("t0", 0.95).unwrap()
                >= r.percentile("t0", 0.5).unwrap());
        let tbl = r.table("tenant").render();
        assert!(tbl.contains("t0") && tbl.contains("t1"));
    }

    #[test]
    fn nearest_rank_shared_helper_edges() {
        // Empty slice is total — no caller path can index out of
        // bounds.
        assert_eq!(nearest_rank(&[], 0.5), 0.0);
        assert_eq!(nearest_rank(&[], 1.0), 0.0);
        // A single sample IS every percentile, clamping included.
        for q in [0.0, 0.5, 0.99, 1.0, -3.0, 7.0] {
            assert_eq!(nearest_rank(&[4.2], q), 4.2, "q={q}");
        }
        // q = 1.0 is the max, q = 0.0 the min, for any n.
        let s = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(nearest_rank(&s, 1.0), 5.0);
        assert_eq!(nearest_rank(&s, 0.0), 1.0);
        // Nearest rank, not interpolation: p50 of n=4 is sample 2.
        assert_eq!(nearest_rank(&[1.0, 2.0, 3.0, 4.0], 0.5), 2.0);
    }

    #[test]
    fn percentile_cache_tracks_new_samples() {
        // The sorted cache must refresh when more samples land
        // between queries — including values that land out of order.
        let mut r = LatencyRecorder::default();
        r.record("k", 0.5);
        assert_eq!(r.percentile("k", 1.0), Some(0.5));
        r.record("k", 0.1);
        assert_eq!(r.percentile("k", 0.0), Some(0.1),
                   "stale cache would still say 0.5");
        assert_eq!(r.percentile("k", 1.0), Some(0.5));
        r.record("k", 0.9);
        assert_eq!(r.percentile("k", 1.0), Some(0.9));
        assert_eq!(r.count("k"), 3);
        // Cloned recorders (the engine snapshots them) keep working.
        let c = r.clone();
        assert_eq!(c.percentile("k", 0.5), Some(0.5));
    }

    #[test]
    fn percentile_edge_cases() {
        // Degenerate recorder shapes must not skew (or panic out of)
        // the bench asserts that consume them.
        let r = LatencyRecorder::default();
        assert_eq!(r.count("missing"), 0);
        assert!(r.mean("missing").is_none(), "empty recorder");
        assert!(r.percentile("missing", 0.5).is_none());
        assert!(r.keys().is_empty());

        // A single sample IS every percentile.
        let mut r = LatencyRecorder::default();
        r.record("one", 0.042);
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(r.percentile("one", q), Some(0.042), "q={q}");
        }
        assert_eq!(r.mean("one"), Some(0.042));

        // All-equal samples: every percentile equals the value.
        let mut r = LatencyRecorder::default();
        for _ in 0..100 {
            r.record("flat", 7e-3);
        }
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(r.percentile("flat", q), Some(7e-3), "q={q}");
        }
        assert!((r.mean("flat").unwrap() - 7e-3).abs() < 1e-15);
        // Out-of-range q clamps instead of indexing out of bounds.
        assert_eq!(r.percentile("flat", -0.5), Some(7e-3));
        assert_eq!(r.percentile("flat", 2.0), Some(7e-3));
    }

    #[test]
    fn ttft_tpot_style_recorders_decompose() {
        // TTFT ≤ e2e per request, and TPOT = (e2e − ttft)/decode; the
        // recorders must preserve that ordering through percentiles.
        let mut ttft = LatencyRecorder::default();
        let mut tpot = LatencyRecorder::default();
        let mut e2e = LatencyRecorder::default();
        for i in 1..=50u32 {
            let first = i as f64 * 1e-3;
            let done = first + 10.0 * 2e-3; // 10 decode steps @ 2ms
            ttft.record("t", first);
            e2e.record("t", done);
            tpot.record("t", (done - first) / 10.0);
        }
        for q in [0.5, 0.99] {
            assert!(ttft.percentile("t", q).unwrap()
                    < e2e.percentile("t", q).unwrap());
            assert!((tpot.percentile("t", q).unwrap() - 2e-3).abs()
                    < 1e-12, "constant per-token time");
        }
    }

    #[test]
    fn occupancy_timeline_tracks_slots_and_tokens() {
        let mut oc = OccupancyTimeline::default();
        assert!(oc.is_empty());
        assert_eq!(oc.peak_slots(), 0);
        assert_eq!(oc.peak_tokens(), 0);
        assert_eq!(oc.mean_slots(), 0.0);
        assert_eq!(oc.mean_tokens(), 0.0);
        oc.record(8, 128); // prefill step: 8 prompts
        oc.record(8, 8);   // decode step: 1 token per slot
        oc.record(2, 2);   // batch draining
        assert_eq!(oc.n_steps(), 3);
        assert_eq!(oc.peak_slots(), 8);
        assert_eq!(oc.peak_tokens(), 128);
        assert!((oc.mean_slots() - 6.0).abs() < 1e-12);
        assert!((oc.mean_tokens() - 46.0).abs() < 1e-12);
        let r = oc.table().render();
        assert!(r.contains("slots"));
        assert_eq!(r.lines().count(), 2 + 3);
    }

    #[test]
    fn kv_occupancy_timeline_tracks_blocks_and_frag() {
        let mut kv = KvOccupancyTimeline::default();
        assert!(kv.is_empty());
        assert_eq!(kv.peak_blocks(), 0);
        assert_eq!(kv.mean_blocks(), 0.0);
        assert_eq!(kv.mean_frag_frac(16), 0.0, "no steps, no frag");
        kv.record(4, 64, 0);  // 4 blocks × 16 tokens, fully packed
        kv.record(4, 50, 2);  // 14 slack slots, 2 cache-only
        kv.record(0, 0, 0);   // idle step contributes no frag sample
        assert_eq!(kv.n_steps(), 3);
        assert_eq!(kv.peak_blocks(), 4);
        assert_eq!(kv.peak_tokens(), 64);
        assert!((kv.mean_blocks() - 8.0 / 3.0).abs() < 1e-12);
        assert!((kv.mean_frag_frac(16) - (14.0 / 64.0) / 2.0).abs()
                < 1e-12);
        assert_eq!(kv.peak_reclaimable(), 2);
        assert!((kv.mean_reclaimable() - 2.0 / 3.0).abs() < 1e-12);
        assert!((kv.mean_pinned() - 2.0).abs() < 1e-12);
        let r = kv.table().render();
        assert!(r.contains("kv blocks"));
        assert!(r.contains("cache-only"));
        assert_eq!(r.lines().count(), 2 + 3);
    }

    #[test]
    fn throughput_timeline_buckets_and_rates() {
        let mut tl = ThroughputTimeline::new(0.1);
        assert!(tl.is_empty());
        tl.record(0.05, 2, 64);
        tl.record(0.09, 1, 32);
        tl.record(0.31, 4, 128);
        assert_eq!(tl.n_buckets(), 4, "0.31 lands in bucket 3");
        assert_eq!(tl.total_requests(), 7);
        assert!((tl.peak_req_per_s() - 40.0).abs() < 1e-9,
                "4 completions in a 0.1s bucket");
        assert!((tl.mean_req_per_s() - 7.0 / 0.4).abs() < 1e-9);
        // Negative timestamps clamp into bucket 0 instead of
        // panicking.
        tl.record(-1.0, 1, 1);
        assert_eq!(tl.total_requests(), 8);
        let r = tl.table().render();
        assert!(r.contains("req/s"));
        assert_eq!(r.lines().count(), 2 + 4);
    }

    #[test]
    fn latency_breakdown_renders_queue_vs_service() {
        let mut q = LatencyRecorder::default();
        let mut s = LatencyRecorder::default();
        let mut e = LatencyRecorder::default();
        for i in 1..=10 {
            q.record("t0", i as f64 * 1e-3);
            s.record("t0", 2e-3);
            e.record("t0", i as f64 * 1e-3 + 2e-3);
        }
        e.record("t1", 5e-3); // e2e-only key still gets a row
        let r = latency_breakdown_table(&q, &s, &e, "tenant").render();
        assert!(r.contains("queue p99"));
        assert!(r.contains("t0") && r.contains("t1"));
        assert!(r.contains('-'), "missing recorders render blanks");
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_gb(23.4e9), "23.4G");
        assert_eq!(fmt_dur_h(7200.0), "2.0h");
        assert_eq!(fmt_dur_h(90.0), "2m");
        assert_eq!(fmt_params(21e6), "21M");
    }
}
