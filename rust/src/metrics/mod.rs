//! Training metrics: loss/accuracy curves, phase timers, throughput
//! counters, and CSV/markdown reporters used by the bench harness and
//! EXPERIMENTS.md generation.

use std::fmt::Write as _;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Default)]
pub struct LossCurve {
    pub steps: Vec<usize>,
    pub loss: Vec<f64>,
    pub acc: Vec<f64>,
}

impl LossCurve {
    pub fn push(&mut self, step: usize, loss: f64, acc: f64) {
        self.steps.push(step);
        self.loss.push(loss);
        self.acc.push(acc);
    }

    pub fn last_loss(&self) -> Option<f64> {
        self.loss.last().copied()
    }

    /// Mean of the final `k` recorded losses (noise-robust endpoint).
    pub fn tail_mean(&self, k: usize) -> f64 {
        let n = self.loss.len();
        let k = k.min(n).max(1);
        self.loss[n - k..].iter().sum::<f64>() / k as f64
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,loss,acc\n");
        for i in 0..self.steps.len() {
            let _ = writeln!(out, "{},{:.6},{:.6}", self.steps[i],
                             self.loss[i], self.acc[i]);
        }
        out
    }
}

/// Accumulates wall time per training phase.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimers {
    pub data_s: f64,
    pub h2d_s: f64,
    pub execute_s: f64,
    pub d2h_s: f64,
    pub total_s: f64,
}

impl PhaseTimers {
    pub fn report(&self) -> String {
        format!(
            "data {:.3}s | h2d {:.3}s | execute {:.3}s | d2h {:.3}s | \
             total {:.3}s",
            self.data_s, self.h2d_s, self.execute_s, self.d2h_s,
            self.total_s)
    }
}

pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch(Instant::now())
    }

    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.0;
        self.0 = now;
        d
    }
}

/// Fixed-width markdown table builder for the experiment reports.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(),
                rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], out: &mut String| {
            let _ = write!(out, "|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, " {:<w$} |", c, w = widths[i]);
            }
            let _ = writeln!(out);
        };
        line(&self.header, &mut out);
        let sep: Vec<String> =
            widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &mut out);
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }
}

pub fn fmt_gb(bytes: f64) -> String {
    format!("{:.1}G", bytes / 1e9)
}

pub fn fmt_dur_h(seconds: f64) -> String {
    if seconds >= 3600.0 {
        format!("{:.1}h", seconds / 3600.0)
    } else if seconds >= 60.0 {
        format!("{:.0}m", seconds / 60.0)
    } else {
        format!("{:.1}s", seconds)
    }
}

pub fn fmt_params(n: f64) -> String {
    if n >= 1e9 {
        format!("{:.1}B", n / 1e9)
    } else if n >= 1e6 {
        format!("{:.0}M", n / 1e6)
    } else {
        format!("{:.0}K", n / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_and_csv() {
        let mut c = LossCurve::default();
        c.push(1, 2.0, 0.1);
        c.push(2, 1.0, 0.2);
        assert_eq!(c.last_loss(), Some(1.0));
        assert_eq!(c.tail_mean(2), 1.5);
        let csv = c.to_csv();
        assert!(csv.starts_with("step,loss,acc\n"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Method", "Mem"]);
        t.row(&["LoRA".into(), "23G".into()]);
        t.row(&["PaCA (Ours)".into(), "20G".into()]);
        let r = t.render();
        assert!(r.contains("| Method"));
        assert!(r.contains("| PaCA (Ours) |"));
        assert_eq!(r.lines().count(), 4);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_gb(23.4e9), "23.4G");
        assert_eq!(fmt_dur_h(7200.0), "2.0h");
        assert_eq!(fmt_dur_h(90.0), "2m");
        assert_eq!(fmt_params(21e6), "21M");
    }
}
