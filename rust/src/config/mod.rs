//! Typed run configuration, loadable from a TOML-subset file or built
//! from CLI overrides. Presets mirror the paper's Appendix-C tables
//! (Tables 8–13): target modules, ranks, schedules, batch geometry.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::util::toml::TomlDoc;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedKind {
    Constant,
    Linear,
    Cosine,
}

impl SchedKind {
    pub fn parse(s: &str) -> Result<SchedKind> {
        Ok(match s {
            "constant" => SchedKind::Constant,
            "linear" => SchedKind::Linear,
            "cosine" => SchedKind::Cosine,
            other => return Err(anyhow!("unknown scheduler {other:?}")),
        })
    }
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Manifest artifact to train (e.g. "train_paca_tiny").
    pub artifact: String,
    pub steps: usize,
    /// Gradient-accumulation microbatches per optimizer step. The AOT
    /// graph consumes one microbatch; the coordinator averages over
    /// `grad_accum` consecutive dispatches (paper Tables 9–11 use 2–4).
    pub grad_accum: usize,
    pub peak_lr: f64,
    pub warmup_steps: usize,
    pub sched: SchedKind,
    pub seed: u64,
    /// PaCA column-selection strategy: random | weight | gradient.
    pub selection: String,
    /// Evaluate every N steps (0 = only at the end).
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Data task: "lm-zipf" | "mmlu-like" | "instr" (see data/).
    pub task: String,
    pub checkpoint: Option<String>,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact: "train_paca_tiny".into(),
            steps: 100,
            grad_accum: 1,
            peak_lr: 1e-3,
            warmup_steps: 10,
            sched: SchedKind::Cosine,
            seed: 42,
            selection: "random".into(),
            eval_every: 0,
            eval_batches: 8,
            task: "lm-zipf".into(),
            checkpoint: None,
            log_every: 10,
        }
    }
}

impl TrainConfig {
    pub fn from_toml_file(path: &Path) -> Result<TrainConfig> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        let doc = TomlDoc::parse(&src).map_err(|e| anyhow!("{e}"))?;
        Ok(Self::from_doc(&doc)?)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<TrainConfig> {
        let d = TrainConfig::default();
        Ok(TrainConfig {
            artifact: doc.str_or("train.artifact", &d.artifact).to_string(),
            steps: doc.i64_or("train.steps", d.steps as i64) as usize,
            grad_accum: doc.i64_or("train.grad_accum",
                                   d.grad_accum as i64) as usize,
            peak_lr: doc.f64_or("train.lr", d.peak_lr),
            warmup_steps: doc.i64_or("train.warmup_steps",
                                     d.warmup_steps as i64) as usize,
            sched: SchedKind::parse(doc.str_or("train.sched", "cosine"))?,
            seed: doc.i64_or("train.seed", d.seed as i64) as u64,
            selection: doc.str_or("train.selection", &d.selection)
                .to_string(),
            eval_every: doc.i64_or("train.eval_every",
                                   d.eval_every as i64) as usize,
            eval_batches: doc.i64_or("train.eval_batches",
                                     d.eval_batches as i64) as usize,
            task: doc.str_or("data.task", &d.task).to_string(),
            checkpoint: doc.get("train.checkpoint")
                .and_then(|v| v.as_str()).map(String::from),
            log_every: doc.i64_or("train.log_every",
                                  d.log_every as i64) as usize,
        })
    }

    /// Apply `key=value` CLI overrides (same keys as the TOML file).
    pub fn apply_override(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv.split_once('=')
            .ok_or_else(|| anyhow!("override must be key=value: {kv}"))?;
        match k {
            "train.artifact" | "artifact" => self.artifact = v.into(),
            "train.steps" | "steps" => self.steps = v.parse()?,
            "train.grad_accum" | "grad_accum" => {
                self.grad_accum = v.parse()?
            }
            "train.lr" | "lr" => self.peak_lr = v.parse()?,
            "train.warmup_steps" | "warmup" => {
                self.warmup_steps = v.parse()?
            }
            "train.sched" | "sched" => self.sched = SchedKind::parse(v)?,
            "train.seed" | "seed" => self.seed = v.parse()?,
            "train.selection" | "selection" => self.selection = v.into(),
            "train.eval_every" => self.eval_every = v.parse()?,
            "train.eval_batches" => self.eval_batches = v.parse()?,
            "data.task" | "task" => self.task = v.into(),
            "train.checkpoint" | "checkpoint" => {
                self.checkpoint = Some(v.into())
            }
            "train.log_every" => self.log_every = v.parse()?,
            other => return Err(anyhow!("unknown config key {other:?}")),
        }
        Ok(())
    }
}

/// `paca serve` configuration. CLI flags map 1:1 onto
/// `apply_override` keys; a `[serve]` TOML table works too.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Directory of `<tenant>.paca` adapter files (synthesized on
    /// first run if missing).
    pub adapters_dir: String,
    /// JSONL request trace (synthesized + written if missing).
    pub requests: String,
    /// Max requests coalesced per same-tenant batch.
    pub batch: usize,
    /// Scheduling policy: "fifo" | "swap-aware" | "slo-aware".
    pub policy: String,
    /// Tenant count when synthesizing adapters/trace.
    pub tenants: usize,
    /// Request count when synthesizing the trace.
    pub count: usize,
    /// PaCA rank of synthesized adapters.
    pub rank: usize,
    pub seed: u64,
    /// Registry LRU bound (resident adapters).
    pub capacity: usize,
    /// Forward backend: "auto" | "host" | "pjrt".
    pub backend: String,
    /// Mean prompt length for synthesized requests.
    pub mean_tokens: usize,
    /// Mean per-request deadline (ms after arrival) for synthesized
    /// traces; 0 = no SLOs.
    pub deadline_ms: f64,
    /// Arrival burstiness for synthesized traces (1 = pure Poisson).
    pub burstiness: f64,
    /// Mean arrival rate (requests/second) for synthesized traces.
    pub req_per_s: f64,
    /// Mean decode length (output tokens after the first) for
    /// synthesized traces; 0 = prefill-only requests.
    pub decode_tokens: usize,
    /// Per-step token budget of the iteration-level engine (prefill
    /// prompts + one per decoding slot); 0 = unlimited.
    pub max_batch_tokens: usize,
    /// Unit of service: "step" (iteration-level continuous batching —
    /// late same-tenant arrivals join mid-generation) or "batch" (the
    /// v2 whole-batch pipeline).
    pub service_unit: String,
    /// Paged KV-cache pool size in blocks; 0 = unlimited (no capacity
    /// gating, no preemption — the PR-3 behaviour).
    pub kv_blocks: usize,
    /// Tokens per KV block (block bytes derive from the model's
    /// kv_bytes_per_token).
    pub kv_block_tokens: usize,
    /// Evict the least-urgent decoding slot under memory pressure /
    /// urgent other-tenant deadlines (bounded pool only); false =
    /// drain-only.
    pub preempt: bool,
    /// Host-backend row cap per forward (was a hard-coded const;
    /// oversized batches still truncate visibly).
    pub host_max_tokens: usize,
    /// Per-tenant prefix-sharing radix KV cache (`--prefix-cache
    /// on|off`). Off is bit-for-bit the pre-prefix (PR-4) engine.
    pub prefix_cache: bool,
    /// Per-tenant system-prompt length for synthesized traces: each
    /// request's prompt is prepended with its tenant's shared prefix
    /// of this many tokens; 0 = fully unique prompts.
    pub shared_prefix_tokens: usize,
    /// Write the engine report as machine-readable JSON to this path
    /// (`--report-json PATH`); empty = text report only.
    pub report_json: String,
    /// Record the step-level engine event stream and export it to
    /// this path (`--trace-events PATH`); empty = tracing off (the
    /// null sink — zero cost, bit-identical engine output).
    pub trace_events: String,
    /// Event export format: "jsonl" (one event object per line) or
    /// "chrome" (Chrome/Perfetto trace-event JSON).
    pub trace_format: String,
    /// Chunked prefill: split each prompt into chunks of at most this
    /// many tokens, interleaved with decode steps so long prompts
    /// never stall the decoding slots; 0 = unchunked (the whole
    /// prompt in one step — the `--kv-blocks 0` convention).
    pub prefill_chunk_tokens: usize,
    /// Speculative prefix prefetch: spend genuinely idle step budget
    /// prefilling a known-but-cold tenant's shared prefix into the
    /// radix cache ahead of its next arrival. Requires the prefix
    /// cache; off is bit-for-bit today's engine.
    pub prefetch: bool,
    /// Cache-aware dispatch: among equally-urgent pending requests,
    /// prefer tenants whose prefix chains are already warm (and group
    /// cold same-prefix requests so the first prefill's donation
    /// serves the rest). Off is bit-for-bit today's ordering.
    pub cache_aware: bool,
    /// Heavy-tail prompt mix for synthesized traces: probability in
    /// [0, 1) that a prompt gains a lognormal stretch; 0 = the
    /// historical uniform lengths.
    pub prompt_tail: f64,
    /// Turns per chat session for synthesized traces (each follow-up
    /// turn re-sends the growing conversation as its shared prefix);
    /// 0 or 1 = single-turn requests.
    pub chat_turns: usize,
    /// Long-horizon arrival-rate shape for synthesized traces:
    /// "steady" (bit-for-bit the historical generator) | "diurnal"
    /// (one sinusoidal period) | "flash" (an 8× rate spike in one
    /// window — the shape that separates load-aware routing from
    /// shard hashing).
    pub arrival_pattern: String,
    /// Serving replicas in the in-process cluster. 1 = the single
    /// engine, bit-for-bit; N > 1 = N independent engines (own
    /// registry, KV pool, prefix cache, event stream) behind the
    /// ingress router, stepped on one merged virtual clock.
    pub replicas: usize,
    /// Ingress routing policy for `replicas > 1`:
    /// "shard" | "least-loaded" | "warmth".
    pub router: String,
    /// Failover drill: "R@T" kills replica R when the merged virtual
    /// clock reaches T seconds (its work replays exactly-once on the
    /// least-loaded survivor); empty = no kill.
    pub kill_replica: String,
    /// Streaming-sink ring size for `--trace-events` jsonl export:
    /// events flush to disk every N events DURING the run instead of
    /// one end-of-run rewrite, and the in-memory recorder keeps the
    /// FIRST N (the overflow is counted in `events_dropped`, never
    /// silent). Must be >= 1.
    pub trace_buffer_events: usize,
    /// Write Prometheus-text metric scrapes to this path
    /// (`--metrics PATH`); empty = off. Requires `--trace-events`
    /// (the registry is fed from the event bus).
    pub metrics: String,
    /// Virtual seconds between metric scrapes
    /// (`--metrics-interval S`). Must be > 0.
    pub metrics_interval_s: f64,
    /// Write per-phase folded stacks (flamegraph input) to this path
    /// (`--profile PATH`); empty = off. Requires `--trace-events`.
    pub profile: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            adapters_dir: "adapters".into(),
            requests: "serve_trace.jsonl".into(),
            batch: 8,
            policy: "swap-aware".into(),
            tenants: 8,
            count: 256,
            rank: 8,
            seed: 42,
            capacity: 64,
            backend: "auto".into(),
            mean_tokens: 64,
            deadline_ms: 0.0,
            burstiness: 1.0,
            req_per_s: 200.0,
            decode_tokens: 0,
            max_batch_tokens: 0,
            service_unit: "step".into(),
            kv_blocks: 0,
            kv_block_tokens: 16,
            preempt: true,
            host_max_tokens: 2048,
            prefix_cache: true,
            shared_prefix_tokens: 0,
            report_json: String::new(),
            trace_events: String::new(),
            trace_format: "jsonl".into(),
            prefill_chunk_tokens: 0,
            prefetch: false,
            cache_aware: false,
            prompt_tail: 0.0,
            chat_turns: 0,
            arrival_pattern: "steady".into(),
            replicas: 1,
            router: "shard".into(),
            kill_replica: String::new(),
            trace_buffer_events: 65536,
            metrics: String::new(),
            metrics_interval_s: 1.0,
            profile: String::new(),
        }
    }
}

impl ServeConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<ServeConfig> {
        let d = ServeConfig::default();
        // Guard the i64→usize casts: a negative TOML value must be an
        // error, not a wrap to ~1.8e19.
        let u = |key: &str, default: usize| -> Result<usize> {
            let v = doc.i64_or(key, default as i64);
            if v < 0 {
                return Err(anyhow!("{key} must be >= 0, got {v}"));
            }
            Ok(v as usize)
        };
        Ok(ServeConfig {
            adapters_dir: doc.str_or("serve.adapters", &d.adapters_dir)
                .to_string(),
            requests: doc.str_or("serve.requests", &d.requests)
                .to_string(),
            batch: u("serve.batch", d.batch)?,
            policy: doc.str_or("serve.policy", &d.policy).to_string(),
            tenants: u("serve.tenants", d.tenants)?,
            count: u("serve.count", d.count)?,
            rank: u("serve.rank", d.rank)?,
            seed: u("serve.seed", d.seed as usize)? as u64,
            capacity: u("serve.capacity", d.capacity)?,
            backend: doc.str_or("serve.backend", &d.backend).to_string(),
            mean_tokens: u("serve.mean_tokens", d.mean_tokens)?,
            deadline_ms: {
                let v = doc.f64_or("serve.deadline_ms", d.deadline_ms);
                if v < 0.0 {
                    return Err(anyhow!(
                        "serve.deadline_ms must be >= 0, got {v}"));
                }
                v
            },
            burstiness: {
                let v = doc.f64_or("serve.burstiness", d.burstiness);
                if v < 1.0 {
                    return Err(anyhow!(
                        "serve.burstiness must be >= 1, got {v}"));
                }
                v
            },
            req_per_s: {
                let v = doc.f64_or("serve.req_per_s", d.req_per_s);
                if v <= 0.0 {
                    return Err(anyhow!(
                        "serve.req_per_s must be > 0, got {v}"));
                }
                v
            },
            decode_tokens: u("serve.decode_tokens", d.decode_tokens)?,
            max_batch_tokens: u("serve.max_batch_tokens",
                                d.max_batch_tokens)?,
            service_unit: {
                let v = doc.str_or("serve.service_unit",
                                   &d.service_unit).to_string();
                if v != "step" && v != "batch" {
                    return Err(anyhow!(
                        "serve.service_unit must be step|batch, \
                         got {v:?}"));
                }
                v
            },
            kv_blocks: u("serve.kv_blocks", d.kv_blocks)?,
            kv_block_tokens: {
                let v = u("serve.kv_block_tokens",
                          d.kv_block_tokens)?;
                if v == 0 {
                    return Err(anyhow!(
                        "serve.kv_block_tokens must be >= 1"));
                }
                v
            },
            preempt: doc.bool_or("serve.preempt", d.preempt),
            host_max_tokens: {
                let v = u("serve.host_max_tokens",
                          d.host_max_tokens)?;
                if v == 0 {
                    return Err(anyhow!(
                        "serve.host_max_tokens must be >= 1"));
                }
                v
            },
            prefix_cache: doc.bool_or("serve.prefix_cache",
                                      d.prefix_cache),
            shared_prefix_tokens: u("serve.shared_prefix_tokens",
                                    d.shared_prefix_tokens)?,
            report_json: doc.str_or("serve.report_json",
                                    &d.report_json).to_string(),
            trace_events: doc.str_or("serve.trace_events",
                                     &d.trace_events).to_string(),
            trace_format: {
                let v = doc.str_or("serve.trace_format",
                                   &d.trace_format).to_string();
                if v != "jsonl" && v != "chrome" {
                    return Err(anyhow!(
                        "serve.trace_format must be jsonl|chrome, \
                         got {v:?}"));
                }
                v
            },
            prefill_chunk_tokens: u("serve.prefill_chunk_tokens",
                                    d.prefill_chunk_tokens)?,
            prefetch: doc.bool_or("serve.prefetch", d.prefetch),
            cache_aware: doc.bool_or("serve.cache_aware",
                                     d.cache_aware),
            prompt_tail: {
                let v = doc.f64_or("serve.prompt_tail", d.prompt_tail);
                if !(0.0..1.0).contains(&v) {
                    return Err(anyhow!(
                        "serve.prompt_tail must be in [0, 1), \
                         got {v}"));
                }
                v
            },
            chat_turns: u("serve.chat_turns", d.chat_turns)?,
            arrival_pattern: doc.str_or("serve.arrival_pattern",
                                        &d.arrival_pattern)
                .to_string(),
            replicas: u("serve.replicas", d.replicas)?,
            router: doc.str_or("serve.router", &d.router).to_string(),
            kill_replica: doc.str_or("serve.kill_replica",
                                     &d.kill_replica).to_string(),
            trace_buffer_events: {
                let v = u("serve.trace_buffer_events",
                          d.trace_buffer_events)?;
                if v == 0 {
                    return Err(anyhow!(
                        "serve.trace_buffer_events must be >= 1 (a \
                         0-event ring can never flush)"));
                }
                v
            },
            metrics: doc.str_or("serve.metrics", &d.metrics)
                .to_string(),
            metrics_interval_s: {
                let v = doc.f64_or("serve.metrics_interval_s",
                                   d.metrics_interval_s);
                if !(v > 0.0) || !v.is_finite() {
                    return Err(anyhow!(
                        "serve.metrics_interval_s must be > 0, \
                         got {v}"));
                }
                v
            },
            profile: doc.str_or("serve.profile", &d.profile)
                .to_string(),
        })
    }

    /// Parse `--kill-replica R@T` into (replica id, virtual kill
    /// time). Empty = no kill. Range checks against `replicas` live
    /// in [`ServeConfig::validate`].
    pub fn parse_kill_replica(&self)
                              -> Result<Option<(usize, f64)>> {
        if self.kill_replica.is_empty() {
            return Ok(None);
        }
        let (r, t) = self.kill_replica.split_once('@')
            .ok_or_else(|| anyhow!(
                "kill-replica must be R@T (replica id @ virtual \
                 seconds), got {:?}", self.kill_replica))?;
        let r: usize = r.parse().map_err(|_| anyhow!(
            "kill-replica replica id must be an integer, got {r:?}"))?;
        let t: f64 = t.parse().map_err(|_| anyhow!(
            "kill-replica time must be seconds, got {t:?}"))?;
        if !t.is_finite() || t < 0.0 {
            return Err(anyhow!(
                "kill-replica time must be >= 0, got {t}"));
        }
        Ok(Some((r, t)))
    }

    /// Cross-field checks that no single `apply_override` can see —
    /// run once after all flags/TOML keys have landed (the CLI calls
    /// this before building the engine).
    pub fn validate(&self) -> Result<()> {
        if self.max_batch_tokens > 0
            && self.prefill_chunk_tokens > self.max_batch_tokens
        {
            return Err(anyhow!(
                "prefill-chunk-tokens ({}) exceeds max-batch-tokens \
                 ({}): a chunk that large can never be admitted",
                self.prefill_chunk_tokens, self.max_batch_tokens));
        }
        if self.prefill_chunk_tokens > 0 && self.service_unit != "step"
        {
            return Err(anyhow!(
                "prefill-chunk-tokens requires service-unit=step \
                 (the whole-batch unit has no step budget to \
                 interleave chunks into)"));
        }
        if self.prefetch && !self.prefix_cache {
            return Err(anyhow!(
                "prefetch requires prefix-cache=on: speculative \
                 prefill warms the radix cache, which is off"));
        }
        if self.prefetch && self.service_unit != "step" {
            return Err(anyhow!(
                "prefetch requires service-unit=step (idle step \
                 budget is what it spends)"));
        }
        if self.replicas == 0 {
            return Err(anyhow!(
                "replicas must be >= 1 (0 replicas cannot serve \
                 anything)"));
        }
        if self.replicas > 1 && self.service_unit != "step" {
            return Err(anyhow!(
                "replicas > 1 requires service-unit=step (the \
                 cluster drives engines one iteration step at a \
                 time on the merged virtual clock)"));
        }
        if self.router == "warmth" && !self.prefix_cache {
            return Err(anyhow!(
                "router=warmth requires prefix-cache=on: warmth IS \
                 advertised radix-cache coverage, which is off"));
        }
        if self.metrics_interval_s <= 0.0
            || !self.metrics_interval_s.is_finite()
        {
            return Err(anyhow!(
                "metrics-interval must be > 0 virtual seconds, got \
                 {}", self.metrics_interval_s));
        }
        if self.trace_buffer_events == 0 {
            return Err(anyhow!(
                "trace-buffer-events must be >= 1 (a 0-event ring \
                 can never flush)"));
        }
        if !self.metrics.is_empty() && self.trace_events.is_empty() {
            return Err(anyhow!(
                "metrics requires trace-events: the registry is fed \
                 from the event bus, which is off"));
        }
        if !self.profile.is_empty() && self.trace_events.is_empty() {
            return Err(anyhow!(
                "profile requires trace-events: the step profiler \
                 rides the event-enabled engine path, which is off"));
        }
        match self.parse_kill_replica()? {
            None => {}
            Some((r, _)) => {
                if self.replicas < 2 {
                    return Err(anyhow!(
                        "kill-replica requires replicas >= 2 (a \
                         1-replica cluster cannot survive a kill)"));
                }
                if r >= self.replicas {
                    return Err(anyhow!(
                        "kill-replica {} out of range for {} \
                         replicas", r, self.replicas));
                }
            }
        }
        Ok(())
    }

    /// Apply `key=value` (CLI flag names double as keys).
    pub fn apply_override(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv.split_once('=')
            .ok_or_else(|| anyhow!("override must be key=value: {kv}"))?;
        match k {
            "serve.adapters" | "adapters" => self.adapters_dir = v.into(),
            "serve.requests" | "requests" => self.requests = v.into(),
            "serve.batch" | "batch" => self.batch = v.parse()?,
            "serve.policy" | "policy" => self.policy = v.into(),
            "serve.tenants" | "tenants" => self.tenants = v.parse()?,
            "serve.count" | "count" => self.count = v.parse()?,
            "serve.rank" | "rank" => self.rank = v.parse()?,
            "serve.seed" | "seed" => self.seed = v.parse()?,
            "serve.capacity" | "capacity" => self.capacity = v.parse()?,
            "serve.backend" | "backend" => self.backend = v.into(),
            "serve.mean_tokens" | "mean-tokens" => {
                self.mean_tokens = v.parse()?
            }
            "serve.deadline_ms" | "deadline-ms" | "deadline_ms" => {
                let d: f64 = v.parse()?;
                if d < 0.0 {
                    return Err(anyhow!(
                        "deadline-ms must be >= 0, got {d}"));
                }
                self.deadline_ms = d;
            }
            "serve.burstiness" | "burstiness" => {
                let b: f64 = v.parse()?;
                if b < 1.0 {
                    return Err(anyhow!(
                        "burstiness must be >= 1, got {b}"));
                }
                self.burstiness = b;
            }
            "serve.req_per_s" | "req-per-s" | "req_per_s" => {
                let r: f64 = v.parse()?;
                if r <= 0.0 {
                    return Err(anyhow!(
                        "req-per-s must be > 0, got {r}"));
                }
                self.req_per_s = r;
            }
            "serve.decode_tokens" | "decode-tokens"
                | "decode_tokens" => self.decode_tokens = v.parse()?,
            "serve.max_batch_tokens" | "max-batch-tokens"
                | "max_batch_tokens" => {
                self.max_batch_tokens = v.parse()?
            }
            "serve.service_unit" | "service-unit" | "service_unit" => {
                if v != "step" && v != "batch" {
                    return Err(anyhow!(
                        "service-unit must be step|batch, got {v:?}"));
                }
                self.service_unit = v.into();
            }
            "serve.kv_blocks" | "kv-blocks" | "kv_blocks" => {
                self.kv_blocks = v.parse()?
            }
            "serve.kv_block_tokens" | "kv-block-tokens"
                | "kv_block_tokens" => {
                let n: usize = v.parse()?;
                if n == 0 {
                    return Err(anyhow!(
                        "kv-block-tokens must be >= 1"));
                }
                self.kv_block_tokens = n;
            }
            "serve.preempt" | "preempt" => {
                self.preempt = match v {
                    "true" | "on" | "1" => true,
                    "false" | "off" | "0" => false,
                    other => {
                        return Err(anyhow!(
                            "preempt must be true|false, got \
                             {other:?}"))
                    }
                };
            }
            "serve.host_max_tokens" | "host-max-tokens"
                | "host_max_tokens" => {
                let n: usize = v.parse()?;
                if n == 0 {
                    return Err(anyhow!(
                        "host-max-tokens must be >= 1"));
                }
                self.host_max_tokens = n;
            }
            "serve.prefix_cache" | "prefix-cache"
                | "prefix_cache" => {
                self.prefix_cache = match v {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    other => {
                        return Err(anyhow!(
                            "prefix-cache must be on|off, got \
                             {other:?}"))
                    }
                };
            }
            "serve.shared_prefix_tokens" | "shared-prefix-tokens"
                | "shared_prefix_tokens" => {
                self.shared_prefix_tokens = v.parse()?
            }
            "serve.report_json" | "report-json" | "report_json" => {
                self.report_json = v.into()
            }
            "serve.trace_events" | "trace-events" | "trace_events" => {
                self.trace_events = v.into()
            }
            "serve.trace_format" | "trace-format" | "trace_format" => {
                if v != "jsonl" && v != "chrome" {
                    return Err(anyhow!(
                        "trace-format must be jsonl|chrome, got \
                         {v:?}"));
                }
                self.trace_format = v.into();
            }
            "serve.prefill_chunk_tokens" | "prefill-chunk-tokens"
                | "prefill_chunk_tokens" => {
                self.prefill_chunk_tokens = v.parse()?
            }
            "serve.prefetch" | "prefetch" => {
                self.prefetch = match v {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    other => {
                        return Err(anyhow!(
                            "prefetch must be on|off, got {other:?}"))
                    }
                };
            }
            "serve.cache_aware" | "cache-aware" | "cache_aware" => {
                self.cache_aware = match v {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    other => {
                        return Err(anyhow!(
                            "cache-aware must be on|off, got \
                             {other:?}"))
                    }
                };
            }
            "serve.prompt_tail" | "prompt-tail" | "prompt_tail" => {
                let p: f64 = v.parse()?;
                if !(0.0..1.0).contains(&p) {
                    return Err(anyhow!(
                        "prompt-tail must be in [0, 1), got {p}"));
                }
                self.prompt_tail = p;
            }
            "serve.chat_turns" | "chat-turns" | "chat_turns" => {
                self.chat_turns = v.parse()?
            }
            "serve.arrival_pattern" | "arrival-pattern"
                | "arrival_pattern" => {
                if v != "steady" && v != "diurnal" && v != "flash" {
                    return Err(anyhow!(
                        "arrival-pattern must be \
                         steady|diurnal|flash, got {v:?}"));
                }
                self.arrival_pattern = v.into();
            }
            "serve.replicas" | "replicas" => {
                self.replicas = v.parse()?
            }
            "serve.router" | "router" => {
                if v != "shard" && v != "least-loaded" && v != "warmth"
                {
                    return Err(anyhow!(
                        "router must be shard|least-loaded|warmth, \
                         got {v:?}"));
                }
                self.router = v.into();
            }
            "serve.kill_replica" | "kill-replica" | "kill_replica" => {
                self.kill_replica = v.into()
            }
            "serve.trace_buffer_events" | "trace-buffer-events"
                | "trace_buffer_events" => {
                let n: usize = v.parse()?;
                if n == 0 {
                    return Err(anyhow!(
                        "trace-buffer-events must be >= 1 (a 0-event \
                         ring can never flush)"));
                }
                self.trace_buffer_events = n;
            }
            "serve.metrics" | "metrics" => self.metrics = v.into(),
            "serve.metrics_interval_s" | "metrics-interval"
                | "metrics_interval_s" => {
                let s: f64 = v.parse()?;
                if !(s > 0.0) || !s.is_finite() {
                    return Err(anyhow!(
                        "metrics-interval must be > 0 virtual \
                         seconds, got {s}"));
                }
                self.metrics_interval_s = s;
            }
            "serve.profile" | "profile" => self.profile = v.into(),
            other => {
                return Err(anyhow!("unknown serve config key {other:?}"))
            }
        }
        Ok(())
    }
}

/// Appendix-C hyperparameter presets, by experiment.
pub fn preset(name: &str) -> Result<TrainConfig> {
    let mut c = TrainConfig::default();
    match name {
        // Table 9: MMLU fine-tuning (cosine, warmup 100).
        "mmlu" => {
            c.task = "mmlu-like".into();
            c.sched = SchedKind::Cosine;
            c.warmup_steps = 20;
            c.grad_accum = 4;
            c.steps = 150;
            c.peak_lr = 1e-3;
            c.eval_every = 0;
            c.eval_batches = 16;
        }
        // Table 10: Oasst1 instruction tuning (linear, warmup 10%).
        "instr" => {
            c.task = "instr".into();
            c.sched = SchedKind::Linear;
            c.grad_accum = 4;
            c.steps = 120;
            c.warmup_steps = 12;
            c.peak_lr = 1e-3;
            c.eval_batches = 16;
        }
        // Quick smoke run.
        "smoke" => {
            c.steps = 10;
            c.warmup_steps = 2;
            c.log_every = 1;
        }
        other => return Err(anyhow!("unknown preset {other:?}")),
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_overrides() {
        let mut c = TrainConfig::default();
        c.apply_override("steps=5").unwrap();
        c.apply_override("lr=0.01").unwrap();
        c.apply_override("sched=linear").unwrap();
        assert_eq!(c.steps, 5);
        assert_eq!(c.peak_lr, 0.01);
        assert_eq!(c.sched, SchedKind::Linear);
        assert!(c.apply_override("nonsense=1").is_err());
        assert!(c.apply_override("no-equals").is_err());
    }

    #[test]
    fn parses_toml() {
        let doc = TomlDoc::parse(
            "[train]\nartifact = \"train_lora_tiny\"\nsteps = 7\n\
             lr = 5e-4\nsched = \"linear\"\n[data]\ntask = \"instr\"\n",
        ).unwrap();
        let c = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(c.artifact, "train_lora_tiny");
        assert_eq!(c.steps, 7);
        assert_eq!(c.task, "instr");
    }

    #[test]
    fn serve_defaults_and_overrides() {
        let mut c = ServeConfig::default();
        c.apply_override("batch=16").unwrap();
        c.apply_override("policy=fifo").unwrap();
        c.apply_override("serve.tenants=32").unwrap();
        assert_eq!(c.batch, 16);
        assert_eq!(c.policy, "fifo");
        assert_eq!(c.tenants, 32);
        assert!(c.apply_override("bogus=1").is_err());
        assert!(c.apply_override("no-equals").is_err());
    }

    #[test]
    fn serve_slo_keys() {
        let mut c = ServeConfig::default();
        assert_eq!(c.deadline_ms, 0.0);
        assert_eq!(c.burstiness, 1.0);
        c.apply_override("deadline-ms=75.5").unwrap();
        c.apply_override("burstiness=4").unwrap();
        c.apply_override("policy=slo-aware").unwrap();
        assert_eq!(c.deadline_ms, 75.5);
        assert_eq!(c.burstiness, 4.0);
        assert_eq!(c.req_per_s, 200.0, "trace-default arrival rate");
        c.apply_override("req-per-s=1e6").unwrap();
        assert_eq!(c.req_per_s, 1e6);
        assert!(c.apply_override("req-per-s=0").is_err());
        assert!(c.apply_override("deadline-ms=-1").is_err());
        assert!(c.apply_override("burstiness=0.5").is_err(),
                "sub-Poisson burstiness is not a thing here");
        let doc = TomlDoc::parse(
            "[serve]\ndeadline_ms = 50\nburstiness = 2.5\n").unwrap();
        let c = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(c.deadline_ms, 50.0);
        assert_eq!(c.burstiness, 2.5);
        let bad = TomlDoc::parse("[serve]\nburstiness = 0\n").unwrap();
        assert!(ServeConfig::from_doc(&bad).is_err());
    }

    #[test]
    fn serve_decode_keys() {
        let mut c = ServeConfig::default();
        assert_eq!(c.decode_tokens, 0, "prefill-only by default");
        assert_eq!(c.max_batch_tokens, 0, "unbudgeted by default");
        assert_eq!(c.service_unit, "step",
                   "iteration-level is the default unit");
        c.apply_override("decode-tokens=24").unwrap();
        c.apply_override("max-batch-tokens=256").unwrap();
        c.apply_override("service-unit=batch").unwrap();
        assert_eq!(c.decode_tokens, 24);
        assert_eq!(c.max_batch_tokens, 256);
        assert_eq!(c.service_unit, "batch");
        assert!(c.apply_override("service-unit=token").is_err());
        let doc = TomlDoc::parse(
            "[serve]\ndecode_tokens = 16\nmax_batch_tokens = 128\n\
             service_unit = \"step\"\n").unwrap();
        let c = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(c.decode_tokens, 16);
        assert_eq!(c.max_batch_tokens, 128);
        let bad = TomlDoc::parse(
            "[serve]\nservice_unit = \"whole\"\n").unwrap();
        assert!(ServeConfig::from_doc(&bad).is_err());
        let bad = TomlDoc::parse(
            "[serve]\nmax_batch_tokens = -4\n").unwrap();
        assert!(ServeConfig::from_doc(&bad).is_err(),
                "negative budget must error, not wrap");
    }

    #[test]
    fn serve_kv_keys() {
        let mut c = ServeConfig::default();
        assert_eq!(c.kv_blocks, 0, "unlimited pool by default");
        assert_eq!(c.kv_block_tokens, 16);
        assert!(c.preempt, "preemption armed by default (inert while \
                            the pool is unlimited)");
        assert_eq!(c.host_max_tokens, 2048,
                   "the old HOST_MAX_TOKENS const is the default");
        c.apply_override("kv-blocks=64").unwrap();
        c.apply_override("kv-block-tokens=32").unwrap();
        c.apply_override("preempt=false").unwrap();
        c.apply_override("host-max-tokens=512").unwrap();
        assert_eq!(c.kv_blocks, 64);
        assert_eq!(c.kv_block_tokens, 32);
        assert!(!c.preempt);
        assert_eq!(c.host_max_tokens, 512);
        c.apply_override("preempt=on").unwrap();
        assert!(c.preempt);
        assert!(c.apply_override("kv-block-tokens=0").is_err(),
                "zero-token blocks are meaningless");
        assert!(c.apply_override("host-max-tokens=0").is_err());
        assert!(c.apply_override("preempt=maybe").is_err());
        let doc = TomlDoc::parse(
            "[serve]\nkv_blocks = 128\nkv_block_tokens = 8\n\
             preempt = false\nhost_max_tokens = 4096\n").unwrap();
        let c = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(c.kv_blocks, 128);
        assert_eq!(c.kv_block_tokens, 8);
        assert!(!c.preempt);
        assert_eq!(c.host_max_tokens, 4096);
        let bad = TomlDoc::parse(
            "[serve]\nkv_block_tokens = 0\n").unwrap();
        assert!(ServeConfig::from_doc(&bad).is_err());
        let bad = TomlDoc::parse(
            "[serve]\nkv_blocks = -1\n").unwrap();
        assert!(ServeConfig::from_doc(&bad).is_err(),
                "negative pool must error, not wrap");
    }

    #[test]
    fn serve_from_toml() {
        let doc = TomlDoc::parse(
            "[serve]\nbatch = 4\nadapters = \"a/b\"\n\
             backend = \"host\"\n").unwrap();
        let c = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(c.batch, 4);
        assert_eq!(c.adapters_dir, "a/b");
        assert_eq!(c.backend, "host");
        assert_eq!(c.policy, "swap-aware"); // default survives
        // Negative numeric values must error, not wrap to huge usize.
        let bad = TomlDoc::parse("[serve]\ncount = -1\n").unwrap();
        assert!(ServeConfig::from_doc(&bad).is_err());
    }

    #[test]
    fn serve_prefix_and_report_keys() {
        let mut c = ServeConfig::default();
        assert!(c.prefix_cache, "prefix cache defaults ON");
        assert_eq!(c.shared_prefix_tokens, 0);
        assert_eq!(c.report_json, "");
        c.apply_override("prefix-cache=off").unwrap();
        assert!(!c.prefix_cache);
        c.apply_override("prefix-cache=on").unwrap();
        assert!(c.prefix_cache);
        c.apply_override("shared-prefix-tokens=48").unwrap();
        assert_eq!(c.shared_prefix_tokens, 48);
        c.apply_override("report-json=out/report.json").unwrap();
        assert_eq!(c.report_json, "out/report.json");
        assert!(c.apply_override("prefix-cache=maybe").is_err(),
                "prefix-cache must be on|off");
        let doc = TomlDoc::parse(
            "[serve]\nprefix_cache = false\n\
             shared_prefix_tokens = 32\n\
             report_json = \"r.json\"\n").unwrap();
        let c = ServeConfig::from_doc(&doc).unwrap();
        assert!(!c.prefix_cache);
        assert_eq!(c.shared_prefix_tokens, 32);
        assert_eq!(c.report_json, "r.json");
    }

    #[test]
    fn serve_trace_keys() {
        let mut c = ServeConfig::default();
        assert_eq!(c.trace_events, "", "tracing off by default");
        assert_eq!(c.trace_format, "jsonl");
        c.apply_override("trace-events=out/events.jsonl").unwrap();
        c.apply_override("trace-format=chrome").unwrap();
        assert_eq!(c.trace_events, "out/events.jsonl");
        assert_eq!(c.trace_format, "chrome");
        assert!(c.apply_override("trace-format=xml").is_err(),
                "trace-format must be jsonl|chrome");
        let doc = TomlDoc::parse(
            "[serve]\ntrace_events = \"ev.jsonl\"\n\
             trace_format = \"jsonl\"\n").unwrap();
        let c = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(c.trace_events, "ev.jsonl");
        assert_eq!(c.trace_format, "jsonl");
        let bad = TomlDoc::parse(
            "[serve]\ntrace_format = \"csv\"\n").unwrap();
        assert!(ServeConfig::from_doc(&bad).is_err());
    }

    #[test]
    fn serve_telemetry_keys_and_cross_field_rules() {
        let mut c = ServeConfig::default();
        assert_eq!(c.trace_buffer_events, 65536);
        assert_eq!(c.metrics, "", "metrics off by default");
        assert_eq!(c.metrics_interval_s, 1.0);
        assert_eq!(c.profile, "", "profiler off by default");
        assert!(c.validate().is_ok(), "defaults must validate");
        c.apply_override("trace-events=out/ev.jsonl").unwrap();
        c.apply_override("trace-buffer-events=128").unwrap();
        c.apply_override("metrics=out/metrics.prom").unwrap();
        c.apply_override("metrics-interval=0.25").unwrap();
        c.apply_override("profile=out/profile.folded").unwrap();
        assert_eq!(c.trace_buffer_events, 128);
        assert_eq!(c.metrics, "out/metrics.prom");
        assert_eq!(c.metrics_interval_s, 0.25);
        assert_eq!(c.profile, "out/profile.folded");
        assert!(c.validate().is_ok());

        // Degenerate values die at the override.
        assert!(c.apply_override("trace-buffer-events=0").is_err(),
                "a 0-event ring can never flush");
        assert!(c.apply_override("metrics-interval=0").is_err());
        assert!(c.apply_override("metrics-interval=-1").is_err());
        assert!(c.apply_override("metrics-interval=inf").is_err());

        // Metrics / profile without the event bus would be inert:
        // validate() refuses instead of silently writing nothing.
        let mut c = ServeConfig::default();
        c.apply_override("metrics=m.prom").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("requires trace-events"), "{err}");
        let mut c = ServeConfig::default();
        c.apply_override("profile=p.folded").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("requires trace-events"), "{err}");

        // TOML table path, including its own degenerate rejections.
        let doc = TomlDoc::parse(
            "[serve]\ntrace_events = \"ev.jsonl\"\n\
             trace_buffer_events = 512\nmetrics = \"m.prom\"\n\
             metrics_interval_s = 0.5\nprofile = \"p.folded\"\n")
            .unwrap();
        let c = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(c.trace_buffer_events, 512);
        assert_eq!(c.metrics_interval_s, 0.5);
        assert!(c.validate().is_ok());
        let bad = TomlDoc::parse(
            "[serve]\ntrace_buffer_events = 0\n").unwrap();
        assert!(ServeConfig::from_doc(&bad).is_err());
        let bad = TomlDoc::parse(
            "[serve]\nmetrics_interval_s = 0.0\n").unwrap();
        assert!(ServeConfig::from_doc(&bad).is_err());
    }

    #[test]
    fn serve_cluster_keys_and_cross_field_rules() {
        let mut c = ServeConfig::default();
        assert_eq!(c.replicas, 1, "single engine by default");
        assert_eq!(c.router, "shard");
        assert_eq!(c.kill_replica, "");
        assert_eq!(c.arrival_pattern, "steady");
        c.apply_override("replicas=4").unwrap();
        c.apply_override("router=warmth").unwrap();
        c.apply_override("kill-replica=2@0.5").unwrap();
        c.apply_override("arrival-pattern=flash").unwrap();
        assert_eq!(c.replicas, 4);
        assert_eq!(c.router, "warmth");
        assert_eq!(c.parse_kill_replica().unwrap(), Some((2, 0.5)));
        assert_eq!(c.arrival_pattern, "flash");
        assert!(c.validate().is_ok());
        assert!(c.apply_override("router=random").is_err());
        assert!(c.apply_override("arrival-pattern=tidal").is_err());

        // replicas = 0 can serve nothing.
        let mut c = ServeConfig::default();
        c.apply_override("replicas=0").unwrap();
        assert!(c.validate().is_err());

        // The cluster steps engines: whole-batch unit is out.
        let mut c = ServeConfig::default();
        c.apply_override("replicas=2").unwrap();
        c.apply_override("service-unit=batch").unwrap();
        assert!(c.validate().is_err());

        // Warmth routing IS radix-cache coverage.
        let mut c = ServeConfig::default();
        c.apply_override("replicas=2").unwrap();
        c.apply_override("router=warmth").unwrap();
        c.apply_override("prefix-cache=off").unwrap();
        assert!(c.validate().is_err());

        // kill-replica: needs replicas >= 2, in-range id, valid R@T.
        let mut c = ServeConfig::default();
        c.apply_override("kill-replica=0@0.5").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("replicas >= 2"), "{err}");
        c.apply_override("replicas=2").unwrap();
        assert!(c.validate().is_ok());
        c.apply_override("kill-replica=2@0.5").unwrap();
        assert!(c.validate().is_err(), "id out of range");
        c.apply_override("kill-replica=1@-1").unwrap();
        assert!(c.validate().is_err(), "negative kill time");
        c.apply_override("kill-replica=oops").unwrap();
        assert!(c.validate().is_err(), "missing @");
        c.apply_override("kill-replica=").unwrap();
        assert!(c.validate().is_ok(), "empty = no kill");

        // TOML spellings round-trip too.
        let doc = TomlDoc::parse(
            "[serve]\nreplicas = 4\nrouter = \"least-loaded\"\n\
             kill_replica = \"1@0.25\"\n\
             arrival_pattern = \"diurnal\"\n").unwrap();
        let c = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(c.replicas, 4);
        assert_eq!(c.router, "least-loaded");
        assert_eq!(c.parse_kill_replica().unwrap(), Some((1, 0.25)));
        assert_eq!(c.arrival_pattern, "diurnal");
        assert!(c.validate().is_ok());
    }

    #[test]
    fn degenerate_cli_values_error_clearly() {
        // The div-by-zero / silent-wrap family: every degenerate
        // value is an explicit error at parse time, never a panic (or
        // a wrapped usize) deep inside `blocks_for`/the engine.
        let mut c = ServeConfig::default();
        assert!(c.apply_override("kv-block-tokens=0").is_err(),
                "a zero-token block would make blocks_for divide by \
                 zero");
        assert!(c.apply_override("host-max-tokens=0").is_err());
        assert!(c.apply_override("shared-prefix-tokens=-1").is_err(),
                "negative usize must be a parse error, not a wrap");
        assert!(c.apply_override("kv-blocks=-3").is_err());
        assert!(c.apply_override("batch=x").is_err());
        // And the same through TOML.
        for bad in ["[serve]\nkv_block_tokens = 0\n",
                    "[serve]\nkv_block_tokens = -2\n",
                    "[serve]\nhost_max_tokens = 0\n",
                    "[serve]\nshared_prefix_tokens = -1\n"] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(ServeConfig::from_doc(&doc).is_err(), "{bad}");
        }
        // Untouched config still valid after the failed overrides.
        assert_eq!(c.kv_block_tokens, 16);
        assert_eq!(c.host_max_tokens, 2048);
    }

    #[test]
    fn serve_chunked_prefill_and_prefetch_keys() {
        let mut c = ServeConfig::default();
        assert_eq!(c.prefill_chunk_tokens, 0, "unchunked by default");
        assert!(!c.prefetch, "prefetch off by default");
        assert!(!c.cache_aware, "historical ordering by default");
        assert_eq!(c.prompt_tail, 0.0);
        assert_eq!(c.chat_turns, 0);
        c.apply_override("prefill-chunk-tokens=32").unwrap();
        c.apply_override("prefetch=on").unwrap();
        c.apply_override("cache-aware=on").unwrap();
        c.apply_override("prompt-tail=0.2").unwrap();
        c.apply_override("chat-turns=3").unwrap();
        assert_eq!(c.prefill_chunk_tokens, 32);
        assert!(c.prefetch && c.cache_aware);
        assert_eq!(c.prompt_tail, 0.2);
        assert_eq!(c.chat_turns, 3);
        assert!(c.validate().is_ok());
        assert!(c.apply_override("prefetch=maybe").is_err());
        assert!(c.apply_override("cache-aware=2").is_err());
        let doc = TomlDoc::parse(
            "[serve]\nprefill_chunk_tokens = 16\nprefetch = true\n\
             cache_aware = true\nprompt_tail = 0.1\n\
             chat_turns = 2\n").unwrap();
        let c = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(c.prefill_chunk_tokens, 16);
        assert!(c.prefetch && c.cache_aware);
        assert_eq!(c.prompt_tail, 0.1);
        assert_eq!(c.chat_turns, 2);
    }

    #[test]
    fn degenerate_chunk_and_tail_combinations_error_clearly() {
        // The PR-5 degenerate-value family, extended: values that
        // parse fine in isolation but can never serve must fail at
        // validate(), with chunk 0 = "unchunked" mirroring the
        // `--kv-blocks 0` convention.
        let mut c = ServeConfig::default();
        assert!(c.apply_override("prompt-tail=1.0").is_err(),
                "tail probability 1 would stretch EVERY prompt — \
                 outside the mix's design range");
        assert!(c.apply_override("prompt-tail=-0.1").is_err());
        assert!(c.apply_override("prefill-chunk-tokens=-1").is_err(),
                "negative usize must be a parse error, not a wrap");
        // A chunk larger than the step budget can never be admitted.
        c.apply_override("max-batch-tokens=64").unwrap();
        c.apply_override("prefill-chunk-tokens=128").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("prefill-chunk-tokens"), "{err}");
        // Chunk 0 (unchunked) and chunk ≤ budget are both fine.
        c.apply_override("prefill-chunk-tokens=0").unwrap();
        assert!(c.validate().is_ok());
        c.apply_override("prefill-chunk-tokens=64").unwrap();
        assert!(c.validate().is_ok());
        // An UNBUDGETED engine accepts any chunk size.
        c.apply_override("max-batch-tokens=0").unwrap();
        c.apply_override("prefill-chunk-tokens=4096").unwrap();
        assert!(c.validate().is_ok());
        // Chunking and prefetch are step-mode features.
        c.apply_override("service-unit=batch").unwrap();
        assert!(c.validate().is_err());
        c.apply_override("prefill-chunk-tokens=0").unwrap();
        assert!(c.validate().is_ok());
        c.apply_override("prefetch=on").unwrap();
        assert!(c.validate().is_err());
        c.apply_override("service-unit=step").unwrap();
        assert!(c.validate().is_ok());
        // Prefetch warms the prefix cache, so it needs one.
        c.apply_override("prefix-cache=off").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("prefix-cache"), "{err}");
        c.apply_override("prefetch=off").unwrap();
        assert!(c.validate().is_ok());
        // And the TOML path hits the same range checks.
        for bad in ["[serve]\nprompt_tail = 1.5\n",
                    "[serve]\nprompt_tail = -0.2\n",
                    "[serve]\nprefill_chunk_tokens = -8\n",
                    "[serve]\nchat_turns = -2\n"] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(ServeConfig::from_doc(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn presets_exist() {
        assert!(preset("mmlu").is_ok());
        assert!(preset("instr").is_ok());
        assert!(preset("smoke").is_ok());
        assert!(preset("nope").is_err());
    }
}
