//! artifacts/manifest.json loader — the single source of truth shared
//! with the python AOT layer (see python/compile/aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::DType;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub profile_only: bool,
}

impl ModelInfo {
    /// (d_in, d_out) of the seven PEFT target matrices per block —
    /// mirrors ModelConfig.linear_shapes() in python/compile/configs.py.
    pub fn linear_shapes(&self) -> Vec<(&'static str, usize, usize)> {
        let (d, f) = (self.d_model, self.d_ff);
        vec![("q", d, d), ("k", d, d), ("v", d, d), ("o", d, d),
             ("gate", d, f), ("up", d, f), ("down", f, d)]
    }

    /// Resident KV-cache bytes per token at serving time: one K and
    /// one V vector of `d_model` each, bf16, per layer. THE single
    /// derivation of the KV footprint — `serve::cost` streams exactly
    /// this many bytes per context token per decode step, and the
    /// paged allocator in `serve::kv` charges it per resident token,
    /// so the time model and the capacity ledger can never drift
    /// apart.
    pub fn kv_bytes_per_token(&self) -> usize {
        self.n_layers * 2 * self.d_model * 2
    }

    pub fn n_params(&self) -> u64 {
        let per_block: u64 = self.linear_shapes().iter()
            .map(|(_, i, o)| (*i as u64) * (*o as u64)).sum::<u64>()
            + 2 * self.d_model as u64;
        self.vocab as u64 * self.d_model as u64
            + self.n_layers as u64 * per_block
            + self.d_model as u64
            + self.d_model as u64 * self.vocab as u64
    }
}

/// Declarative init spec executed by init.rs.
#[derive(Debug, Clone)]
pub enum Init {
    Normal { std: f32 },
    Zeros,
    Ones,
    Eye,
    /// r distinct indices from [0, n), stream-seeded by the tensor name.
    Choice { n: usize },
    /// L2 norm of each column of another (already initialized) tensor.
    ColNorm { of: String },
    /// NF4 codes/scales of a *virtual* weight ~N(0, std²) of of_shape.
    Nf4Codes { of_shape: (usize, usize), std: f32, block: usize },
    Nf4Scales { of_shape: (usize, usize), std: f32, block: usize },
    /// Rows (selected by the sibling idx tensor) of the virtual weight.
    RowsOf { of_shape: (usize, usize), std: f32, idx: String },
    ConstI32 { value: i32 },
    None,
}

#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub role: String,
    pub init: Init,
    pub updated: bool,
}

#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub model: String,
    pub method: String,
    pub rank: usize,
    pub alpha: f64,
    pub batch: usize,
    pub seq: usize,
    pub use_pallas: bool,
    pub trainable_params: u64,
    pub state: Vec<EntrySpec>,
    pub batch_inputs: Vec<EntrySpec>,
    pub extra_inputs: Vec<EntrySpec>,
    pub outputs: Vec<String>,
}

impl ArtifactInfo {
    pub fn n_inputs(&self) -> usize {
        self.state.len() + self.batch_inputs.len()
            + self.extra_inputs.len()
    }

    /// Indices into `state` for each output (None for loss/acc).
    pub fn updated_state_indices(&self) -> Vec<usize> {
        self.state.iter().enumerate()
            .filter(|(_, e)| e.updated).map(|(i, _)| i).collect()
    }

    pub fn state_bytes(&self) -> u64 {
        self.state.iter().map(|e| {
            e.shape.iter().product::<usize>() as u64
                * e.dtype.size() as u64
        }).sum()
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelInfo>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

fn parse_init(j: &Json) -> Result<Init> {
    let kind = match j.get("kind").and_then(|k| k.as_str()) {
        Some(k) => k,
        None => return Ok(Init::None),
    };
    let shape2 = |key: &str| -> Result<(usize, usize)> {
        let a = j.get(key).and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("init missing {key}"))?;
        Ok((a[0].as_usize().unwrap(), a[1].as_usize().unwrap()))
    };
    Ok(match kind {
        "normal" => Init::Normal {
            std: j.get("std").and_then(|v| v.as_f64()).unwrap_or(0.02)
                as f32,
        },
        "zeros" => Init::Zeros,
        "ones" => Init::Ones,
        "eye" => Init::Eye,
        "choice" => Init::Choice {
            n: j.get("n").and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("choice missing n"))?,
        },
        "col_norm" => Init::ColNorm {
            of: j.get("of").and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("col_norm missing of"))?
                .to_string(),
        },
        "nf4_codes" => Init::Nf4Codes {
            of_shape: shape2("of_shape")?,
            std: j.get("std").and_then(|v| v.as_f64()).unwrap_or(0.02)
                as f32,
            block: j.get("block").and_then(|v| v.as_usize()).unwrap_or(64),
        },
        "nf4_scales" => Init::Nf4Scales {
            of_shape: shape2("of_shape")?,
            std: j.get("std").and_then(|v| v.as_f64()).unwrap_or(0.02)
                as f32,
            block: j.get("block").and_then(|v| v.as_usize()).unwrap_or(64),
        },
        "rows_of" => Init::RowsOf {
            of_shape: shape2("of_shape")?,
            std: j.get("std").and_then(|v| v.as_f64()).unwrap_or(0.02)
                as f32,
            idx: j.get("idx").and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("rows_of missing idx"))?
                .to_string(),
        },
        "const_i32" => Init::ConstI32 {
            value: j.get("value").and_then(|v| v.as_i64()).unwrap_or(0)
                as i32,
        },
        other => bail!("unknown init kind {other:?}"),
    })
}

fn parse_entry(j: &Json) -> Result<EntrySpec> {
    let name = j.get("name").and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("entry missing name"))?.to_string();
    let shape = j.get("shape").and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("entry {name} missing shape"))?
        .iter().map(|d| d.as_usize().unwrap()).collect();
    let dtype = DType::from_manifest(
        j.get("dtype").and_then(|v| v.as_str()).unwrap_or("f32"))?;
    Ok(EntrySpec {
        name,
        shape,
        dtype,
        role: j.get("role").and_then(|v| v.as_str()).unwrap_or("")
            .to_string(),
        init: parse_init(j.get("init").unwrap_or(&Json::Null))?,
        updated: j.get("updated").and_then(|v| v.as_bool())
            .unwrap_or(false),
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = Json::parse(&src)
            .map_err(|e| anyhow!("{}: {}", path.display(), e))?;

        let mut models = BTreeMap::new();
        for (name, m) in root.get("models").and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            let u = |k: &str| m.get(k).and_then(|v| v.as_usize())
                .unwrap_or(0);
            models.insert(name.clone(), ModelInfo {
                name: name.clone(),
                vocab: u("vocab"),
                d_model: u("d_model"),
                n_layers: u("n_layers"),
                n_heads: u("n_heads"),
                d_ff: u("d_ff"),
                max_seq: u("max_seq"),
                profile_only: m.get("profile_only")
                    .and_then(|v| v.as_bool()).unwrap_or(false),
            });
        }

        let mut artifacts = BTreeMap::new();
        for a in root.get("artifacts").and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = a.get("name").and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let entries = |key: &str| -> Result<Vec<EntrySpec>> {
                a.get(key).and_then(|v| v.as_arr()).unwrap_or(&[])
                    .iter().map(parse_entry).collect()
            };
            artifacts.insert(name.clone(), ArtifactInfo {
                name: name.clone(),
                file: a.get("file").and_then(|v| v.as_str())
                    .unwrap_or("").to_string(),
                kind: a.get("kind").and_then(|v| v.as_str())
                    .unwrap_or("").to_string(),
                model: a.get("model").and_then(|v| v.as_str())
                    .unwrap_or("").to_string(),
                method: a.get("method").and_then(|v| v.as_str())
                    .unwrap_or("").to_string(),
                rank: a.get("rank").and_then(|v| v.as_usize())
                    .unwrap_or(0),
                alpha: a.get("alpha").and_then(|v| v.as_f64())
                    .unwrap_or(0.0),
                batch: a.get("batch").and_then(|v| v.as_usize())
                    .unwrap_or(0),
                seq: a.get("seq").and_then(|v| v.as_usize()).unwrap_or(0),
                use_pallas: a.get("use_pallas").and_then(|v| v.as_bool())
                    .unwrap_or(false),
                trainable_params: a.get("trainable_params")
                    .and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
                state: entries("state")?,
                batch_inputs: entries("batch_inputs")?,
                extra_inputs: entries("extra_inputs")?,
                outputs: a.get("outputs").and_then(|v| v.as_arr())
                    .unwrap_or(&[]).iter()
                    .filter_map(|o| o.as_str().map(String::from))
                    .collect(),
            });
        }

        Ok(Manifest { dir: dir.to_path_buf(), models, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts.get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest \
                                    (run `make artifacts`)"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest"))
    }

    pub fn hlo_path(&self, art: &ArtifactInfo) -> PathBuf {
        self.dir.join(&art.file)
    }
}
