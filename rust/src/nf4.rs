//! NF4 (4-bit NormalFloat) quantization substrate in rust — used by the
//! initializer to produce the QLoRA/QPaCA frozen-weight codes/scales and
//! by the memory accountant. Mirrors python/compile/kernels/ref.py
//! (nearest-codebook rounding, per-block absmax scaling).

/// Exact NF4 codebook (Dettmers et al. 2023); index 7 is exactly 0.
pub const NF4_CODEBOOK: [f32; 16] = [
    -1.0,
    -0.696_192_8,
    -0.525_073_05,
    -0.394_917_5,
    -0.284_441_38,
    -0.184_773_43,
    -0.091_050_036,
    0.0,
    0.079_580_3,
    0.160_930_2,
    0.246_112_3,
    0.337_915_24,
    0.440_709_83,
    0.562_617,
    0.722_956_84,
    1.0,
];

/// Quantize a flat weight buffer. Returns (codes[i8 per weight],
/// scales[f32 per block]). `w.len()` must be a multiple of `block`.
pub fn quantize(w: &[f32], block: usize) -> (Vec<i8>, Vec<f32>) {
    assert!(block > 0 && w.len() % block == 0,
            "weight len {} not a multiple of block {}", w.len(), block);
    let nblocks = w.len() / block;
    let mut codes = Vec::with_capacity(w.len());
    let mut scales = Vec::with_capacity(nblocks);
    for b in 0..nblocks {
        let chunk = &w[b * block..(b + 1) * block];
        let scale = chunk.iter().fold(0f32, |m, v| m.max(v.abs()));
        scales.push(scale);
        let inv = if scale == 0.0 { 1.0 } else { 1.0 / scale };
        for &v in chunk {
            codes.push(nearest_code(v * inv));
        }
    }
    (codes, scales)
}

/// Nearest codebook index (ties round down, matching argmin in jnp).
pub fn nearest_code(x: f32) -> i8 {
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for (i, c) in NF4_CODEBOOK.iter().enumerate() {
        let d = (x - c).abs();
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best as i8
}

pub fn dequantize(codes: &[i8], scales: &[f32], block: usize) -> Vec<f32> {
    assert_eq!(codes.len(), scales.len() * block);
    let mut out = Vec::with_capacity(codes.len());
    for (b, &scale) in scales.iter().enumerate() {
        for &c in &codes[b * block..(b + 1) * block] {
            out.push(NF4_CODEBOOK[c as usize] * scale);
        }
    }
    out
}

/// Bits per weight of NF4 storage (4-bit code + amortized f32 scale) —
/// the constant behind the paper's Table-3 memory reductions.
pub fn bits_per_weight(block: usize) -> f64 {
    4.0 + 32.0 / block as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codebook_sorted_and_symmetric_endpoints() {
        for i in 1..16 {
            assert!(NF4_CODEBOOK[i] > NF4_CODEBOOK[i - 1]);
        }
        assert_eq!(NF4_CODEBOOK[0], -1.0);
        assert_eq!(NF4_CODEBOOK[15], 1.0);
        assert_eq!(NF4_CODEBOOK[7], 0.0);
    }

    #[test]
    fn roundtrip_error_bounded() {
        // max half-gap of the codebook, times the block scale
        let mut max_gap = 0f32;
        for i in 1..16 {
            max_gap = max_gap.max(NF4_CODEBOOK[i] - NF4_CODEBOOK[i - 1]);
        }
        let mut rng = crate::util::rng::Rng::new(1);
        let w: Vec<f32> = (0..256).map(|_| rng.normal_f32(0.05)).collect();
        let (codes, scales) = quantize(&w, 64);
        let dq = dequantize(&codes, &scales, 64);
        for (b, &scale) in scales.iter().enumerate() {
            for i in 0..64 {
                let err = (w[b * 64 + i] - dq[b * 64 + i]).abs();
                assert!(err <= scale * max_gap / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn idempotent() {
        let w = vec![0.3, -0.7, 0.0, 0.05, 1.0, -1.0, 0.5, 0.25];
        let (c1, s1) = quantize(&w, 8);
        let d1 = dequantize(&c1, &s1, 8);
        let (c2, s2) = quantize(&d1, 8);
        let d2 = dequantize(&c2, &s2, 8);
        assert_eq!(d1, d2);
    }

    #[test]
    fn zero_block() {
        let (codes, scales) = quantize(&[0.0; 64], 64);
        assert!(scales[0] == 0.0);
        assert!(dequantize(&codes, &scales, 64).iter()
                .all(|&v| v == 0.0));
    }

    #[test]
    fn bits_accounting() {
        assert!((bits_per_weight(64) - 4.5).abs() < 1e-12);
    }
}
