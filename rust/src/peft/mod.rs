//! Host-side PEFT method descriptors: trainable-parameter accounting
//! (paper's `Param` column), and PaCA's connection-selection strategies
//! (§5 / Table 5: random, weight-based L2-norm, gradient-based).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::manifest::ModelInfo;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

pub const METHODS: [&str; 7] =
    ["full", "lora", "dora", "moslora", "paca", "qlora", "qpaca"];

/// PaCA connection-selection strategy (paper §5).
#[derive(Debug, Clone)]
pub enum Selection {
    /// Uniform without replacement (the paper's default).
    Random,
    /// Columns with the largest L2 norm in the pretrained weight.
    WeightNorm,
    /// Columns with the largest accumulated gradient norm, from a probe
    /// phase (paper: 100 iterations without updates). Keyed by the idx
    /// tensor name; each value is a per-column score vector.
    GradNorm(BTreeMap<String, Vec<f32>>),
}

impl Selection {
    pub fn parse(s: &str) -> Result<Selection> {
        Ok(match s {
            "random" => Selection::Random,
            "weight" | "weight-norm" => Selection::WeightNorm,
            other => {
                return Err(anyhow!(
                    "unknown selection strategy {other:?} \
                     (gradient-based is constructed programmatically)"))
            }
        })
    }

    /// Choose `r` of `pool` input-feature indices for the idx tensor
    /// `name`. `done` holds already-initialized sibling tensors (the
    /// merged weight lives at `<prefix>/w`).
    pub fn select(&self, seed: u64, name: &str, pool: usize, r: usize,
                  done: &BTreeMap<String, HostTensor>) -> Result<Vec<u32>> {
        match self {
            Selection::Random => {
                let mut rng = Rng::for_tag(seed, name);
                Ok(rng.choice(pool, r))
            }
            Selection::WeightNorm => {
                let wname = name.strip_suffix("/idx")
                    .map(|p| format!("{p}/w"))
                    .ok_or_else(|| anyhow!("bad idx name {name}"))?;
                let w = done.get(&wname).ok_or_else(|| {
                    anyhow!("weight-norm selection: {wname} \
                             not initialized before {name}")
                })?;
                // Row i of our (d_in, d_out) layout == paper's column i.
                let cols = w.shape[1];
                let scores: Vec<f32> = (0..pool).map(|i| {
                    (0..cols).map(|j| {
                        let v = w.f32_at(i * cols + j);
                        v * v
                    }).sum()
                }).collect();
                Ok(top_r(&scores, r))
            }
            Selection::GradNorm(map) => {
                let scores = map.get(name).ok_or_else(|| {
                    anyhow!("gradient selection has no scores for {name}")
                })?;
                if scores.len() != pool {
                    return Err(anyhow!("score len {} != pool {pool}",
                                       scores.len()));
                }
                Ok(top_r(scores, r))
            }
        }
    }
}

/// Indices of the r largest scores (stable order by descending score).
pub fn top_r(scores: &[f32], r: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        scores[b as usize].partial_cmp(&scores[a as usize]).unwrap()
            .then(a.cmp(&b))
    });
    idx.truncate(r);
    idx
}

/// Trainable parameters per method/rank on a model — the paper's Param
/// column. Mirrors python peft.trainable_param_count.
pub fn trainable_params(m: &ModelInfo, method: &str, rank: usize) -> u64 {
    let r = rank as u64;
    let per_block: u64 = m.linear_shapes().iter().map(|(_, din, dout)| {
        let (din, dout) = (*din as u64, *dout as u64);
        match method {
            "full" => din * dout,
            "paca" | "qpaca" => r * dout,
            "lora" | "qlora" => r * (din + dout),
            "moslora" => r * (din + dout) + r * r,
            "dora" => r * (din + dout) + dout,
            _ => 0,
        }
    }).sum();
    let mut n = m.n_layers as u64 * per_block;
    if method == "full" {
        n += 2 * m.vocab as u64 * m.d_model as u64          // embed+head
            + (2 * m.n_layers as u64 + 1) * m.d_model as u64; // norms
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelInfo {
        ModelInfo { name: "t".into(), vocab: 512, d_model: 64,
                    n_layers: 2, n_heads: 4, d_ff: 172, max_seq: 128,
                    profile_only: false }
    }

    #[test]
    fn paca_r16_matches_lora_r8_on_square_targets() {
        // On a square d×d target, PaCA r=2k trains exactly as many
        // params as LoRA r=k — the paper's Table-1 pairing.
        let m = ModelInfo { d_ff: 64, ..tiny() };
        assert_eq!(trainable_params(&m, "paca", 16),
                   trainable_params(&m, "lora", 8));
    }

    #[test]
    fn method_ordering_matches_paper() {
        let m = tiny();
        let lora = trainable_params(&m, "lora", 8);
        let paca = trainable_params(&m, "paca", 8);
        let dora = trainable_params(&m, "dora", 8);
        let mos = trainable_params(&m, "moslora", 8);
        assert!(paca < lora, "paca r8 has ~half of lora r8");
        assert!(dora > lora && mos > lora);
        assert!(trainable_params(&m, "full", 0) > 7 * lora);
    }

    #[test]
    fn top_r_picks_largest() {
        assert_eq!(top_r(&[0.1, 5.0, 3.0, 4.0], 2), vec![1, 3]);
    }

    #[test]
    fn weight_norm_selection_reads_sibling() {
        let mut done = BTreeMap::new();
        // rows 1 and 3 have the largest norms
        done.insert("l/w".to_string(), HostTensor::from_f32(
            &[4, 2], vec![0.1, 0.0, 9.0, 9.0, 0.2, 0.0, 5.0, 5.0]));
        let got = Selection::WeightNorm.select(0, "l/idx", 4, 2, &done)
            .unwrap();
        assert_eq!(got, vec![1, 3]);
    }

    #[test]
    fn random_selection_differs_across_seeds_and_tags() {
        let done = BTreeMap::new();
        let a = Selection::Random.select(1, "x/idx", 128, 8, &done)
            .unwrap();
        let b = Selection::Random.select(2, "x/idx", 128, 8, &done)
            .unwrap();
        assert_ne!(a, b);
    }
}
