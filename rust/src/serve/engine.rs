//! The serving engine: swap → forward → metrics.
//!
//! The engine owns ONE shared frozen base; per batch it hot-splices the
//! batch tenant's `(idx, P)` adapter (registry), runs a forward over
//! the batch tokens, and records per-request latency. Because the
//! spliced base IS the effective model, the forward is exactly the
//! frozen model's — PaCA's zero-inference-overhead property — and the
//! only multi-tenant cost is the swap, which the scheduler amortizes.
//!
//! Two forward backends:
//!   * `Host` — a real (measured, not simulated) GEMM pipeline over the
//!     base target weights via coordinator::merge::matmul. Always
//!     available; what `paca serve` and the serve bench use on a fresh
//!     checkout.
//!   * `Pjrt` — drives the lowered method-agnostic eval artifact
//!     (runtime::Executable) with the spliced weights, like
//!     Trainer::evaluate does after a host-side merge. Requires
//!     `make artifacts`.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::merge;
use crate::data::{Task, TokenGen};
use crate::init;
use crate::manifest::ModelInfo;
use crate::metrics::LatencyRecorder;
use crate::peft::Selection;
use crate::runtime::{Executable, Runtime};
use crate::serve::registry::{fingerprint, AdapterRegistry, SpliceGuard,
                             WeightMap};
use crate::serve::scheduler::Batch;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

/// Host-backend row cap per forward (keeps debug-mode tests fast; the
/// GEMM cost model above this point is linear anyway).
const HOST_MAX_TOKENS: usize = 2048;

/// Default serving geometry when no manifest model is available
/// (matches the tiny-lm training artifacts).
pub fn tiny_model() -> ModelInfo {
    ModelInfo { name: "serve-tiny".into(), vocab: 512, d_model: 64,
                n_layers: 2, n_heads: 4, d_ff: 172, max_seq: 128,
                profile_only: false }
}

/// The shared frozen base: model geometry + target weights
/// ("blocks/<layer>/<target>/w") that adapters splice into.
pub struct BaseModel {
    pub model: ModelInfo,
    pub weights: WeightMap,
}

impl BaseModel {
    /// Deterministic synthetic pretrained base (stand-in for a real
    /// checkpoint; same per-tensor streams as init.rs uses).
    pub fn synthetic(model: &ModelInfo, seed: u64) -> BaseModel {
        let mut weights = WeightMap::new();
        for layer in 0..model.n_layers {
            for (t, d_in, d_out) in model.linear_shapes() {
                let name = format!("blocks/{layer}/{t}/w");
                let mut rng = Rng::for_tag(seed, &name);
                let vals: Vec<f32> = (0..d_in * d_out)
                    .map(|_| rng.normal_f32(0.02)).collect();
                weights.insert(name,
                               HostTensor::from_f32(&[d_in, d_out],
                                                    vals));
            }
        }
        BaseModel { model: model.clone(), weights }
    }

    pub fn fingerprint(&self) -> u64 {
        fingerprint(&self.weights)
    }

    pub fn bytes(&self) -> usize {
        self.weights.values().map(|t| t.bytes()).sum()
    }
}

/// PJRT forward: the method-agnostic eval artifact driven with the
/// spliced weights (non-target state — embeddings, norms, head — is
/// initialized once from the manifest specs and reused).
pub struct PjrtForward {
    exe: Arc<Executable>,
    state_template: Vec<HostTensor>,
    gen: TokenGen,
}

impl PjrtForward {
    pub fn new(rt: &Runtime, model: &str, seed: u64) -> Result<PjrtForward> {
        let name = rt.manifest.artifacts.values()
            .find(|a| a.kind == "eval_step" && a.model == model)
            .map(|a| a.name.clone())
            .ok_or_else(|| {
                anyhow!("no eval artifact lowered for model {model}")
            })?;
        let exe = rt.load(&name)?;
        let state_template =
            init::init_state(&exe.info, seed, &Selection::Random)?;
        let m = rt.manifest.model(model)?;
        let gen = TokenGen::new(Task::LmZipf, m.vocab, seed);
        Ok(PjrtForward { exe, state_template, gen })
    }

    pub fn model_name(&self) -> &str {
        &self.exe.info.model
    }

    fn forward(&mut self, weights: &WeightMap) -> Result<f64> {
        let (b, s) = (self.exe.info.batch, self.exe.info.seq);
        let batch = self.gen.train_batch(b, s);
        let mut inputs: Vec<xla::Literal> =
            Vec::with_capacity(self.exe.info.state.len() + 1);
        for (e, template) in self.exe.info.state.iter()
            .zip(&self.state_template)
        {
            let src = weights.get(&e.name).unwrap_or(template);
            inputs.push(src.to_literal()?);
        }
        inputs.push(batch.to_literal()?);
        let outs = self.exe.run(&inputs)?;
        let loss = outs[0].get_first_element::<f32>()
            .map_err(|e| anyhow!("loss fetch: {e:?}"))?;
        Ok(loss as f64)
    }
}

pub enum Backend {
    Host,
    Pjrt(PjrtForward),
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Host => "host-gemm",
            Backend::Pjrt(_) => "pjrt",
        }
    }
}

/// Real measured host forward over the target weights: qkv → gated
/// mixing → o → SwiGLU-style MLP → residual + RMS normalization per
/// layer. Returns a checksum of the final activations so the result
/// observably depends on which adapter is spliced in.
fn host_forward(base: &BaseModel, input: &[f32], tokens: usize) -> f64 {
    let d = base.model.d_model;
    let f = base.model.d_ff;
    let t = tokens;
    let mut xd = input[..t * d].to_vec();
    let sig = |v: f32| 1.0 / (1.0 + (-v).exp());
    for layer in 0..base.model.n_layers {
        let w = |tgt: &str| {
            base.weights[&format!("blocks/{layer}/{tgt}/w")].as_f32()
        };
        let q = merge::matmul(&xd, &w("q"), t, d, d);
        let k = merge::matmul(&xd, &w("k"), t, d, d);
        let v = merge::matmul(&xd, &w("v"), t, d, d);
        // Cheap token-local stand-in for attention mixing.
        let h: Vec<f32> = (0..t * d)
            .map(|i| q[i] * sig(k[i]) + v[i]).collect();
        let o = merge::matmul(&h, &w("o"), t, d, d);
        let g = merge::matmul(&o, &w("gate"), t, d, f);
        let u = merge::matmul(&o, &w("up"), t, d, f);
        let gu: Vec<f32> = (0..t * f)
            .map(|i| g[i] * sig(g[i]) * u[i]).collect();
        let down = merge::matmul(&gu, &w("down"), t, f, d);
        // Residual + per-row RMS normalization to keep scales bounded.
        for row in 0..t {
            let xrow = &mut xd[row * d..(row + 1) * d];
            let drow = &down[row * d..(row + 1) * d];
            let mut ss = 0f32;
            for (x, dv) in xrow.iter_mut().zip(drow) {
                *x += dv;
                ss += *x * *x;
            }
            let scale = 1.0 / (ss / d as f32 + 1e-6).sqrt();
            for x in xrow.iter_mut() {
                *x *= scale;
            }
        }
    }
    xd.iter().map(|v| v.abs() as f64).sum::<f64>() / (t * d) as f64
}

#[derive(Debug, Default, Clone, Copy)]
pub struct EngineStats {
    pub requests: u64,
    /// Tokens the backend actually computed (host clamps oversized
    /// batches; PJRT runs the artifact's fixed geometry).
    pub tokens: u64,
    pub batches: u64,
    pub swaps: u64,
    pub swap_s: f64,
    pub forward_s: f64,
    pub wall_s: f64,
}

pub struct ServeEngine {
    pub base: BaseModel,
    pub registry: AdapterRegistry,
    backend: Backend,
    /// Live splice, if any: (tenant, displaced base rows).
    current: Option<(String, SpliceGuard)>,
    baseline_fp: u64,
    /// Deterministic activation source for the host backend.
    input: Vec<f32>,
    pub latencies: LatencyRecorder,
    pub stats: EngineStats,
    /// Accumulated forward outputs (keeps the host GEMMs observable).
    pub checksum: f64,
}

impl ServeEngine {
    pub fn new(base: BaseModel, registry: AdapterRegistry,
               backend: Backend) -> ServeEngine {
        let baseline_fp = base.fingerprint();
        ServeEngine { base, registry, backend, current: None,
                      baseline_fp, input: Vec::new(),
                      latencies: LatencyRecorder::default(),
                      stats: EngineStats::default(), checksum: 0.0 }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Tenant currently spliced into the base, if any.
    pub fn current_tenant(&self) -> Option<&str> {
        self.current.as_ref().map(|(t, _)| t.as_str())
    }

    /// Make `tenant` the live adapter: exact un-merge of the previous
    /// tenant, then O(r·d_out)-per-target splice of the new one.
    /// No-op (and no swap counted) if the tenant is already live.
    pub fn swap_to(&mut self, tenant: &str) -> Result<()> {
        if self.current_tenant() == Some(tenant) {
            return Ok(());
        }
        let t0 = Instant::now();
        if let Some((_, guard)) = self.current.take() {
            guard.restore(&mut self.base.weights)?;
        }
        let adapter = self.registry.fetch(tenant)?;
        let guard = adapter.splice(&mut self.base.weights)?;
        self.current = Some((tenant.to_string(), guard));
        self.stats.swaps += 1;
        self.stats.swap_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Returns (output checksum, tokens actually computed) — the
    /// host backend clamps at HOST_MAX_TOKENS and the PJRT backend
    /// runs the eval artifact's fixed (batch, seq) geometry, so the
    /// computed count is what throughput accounting must use.
    fn forward(&mut self, tokens: usize) -> Result<(f64, usize)> {
        match &mut self.backend {
            Backend::Host => {
                let t = tokens.clamp(1, HOST_MAX_TOKENS);
                let need = t * self.base.model.d_model;
                if self.input.len() < need {
                    let mut rng = Rng::for_tag(0x5e7e, "serve/input");
                    self.input = (0..need)
                        .map(|_| rng.normal_f32(1.0)).collect();
                }
                Ok((host_forward(&self.base, &self.input, t), t))
            }
            Backend::Pjrt(p) => {
                let computed = p.exe.info.batch * p.exe.info.seq;
                Ok((p.forward(&self.base.weights)?, computed))
            }
        }
    }

    /// Serve one batch: swap to its tenant, forward over its tokens,
    /// record every request's latency (swap + forward wall time).
    pub fn run_batch(&mut self, batch: &Batch) -> Result<()> {
        if batch.requests.is_empty() {
            return Ok(());
        }
        let t0 = Instant::now();
        self.swap_to(&batch.tenant)?;
        let tf = Instant::now();
        let (out, computed) = self.forward(batch.tokens().max(1))?;
        self.stats.forward_s += tf.elapsed().as_secs_f64();
        self.checksum += out;
        // Tokens the backend actually pushed through — tok/s stays
        // honest when the host backend clamps oversized batches.
        self.stats.tokens += computed as u64;
        let latency = t0.elapsed().as_secs_f64();
        self.stats.batches += 1;
        for _ in &batch.requests {
            self.latencies.record(&batch.tenant, latency);
            self.latencies.record("(all)", latency);
            self.stats.requests += 1;
        }
        Ok(())
    }

    pub fn serve(&mut self, batches: &[Batch]) -> Result<()> {
        let t0 = Instant::now();
        for b in batches {
            self.run_batch(b)?;
        }
        self.stats.wall_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    pub fn throughput_req_per_s(&self) -> f64 {
        self.stats.requests as f64 / self.stats.wall_s.max(1e-12)
    }

    pub fn throughput_tok_per_s(&self) -> f64 {
        self.stats.tokens as f64 / self.stats.wall_s.max(1e-12)
    }

    /// Un-splice the live adapter and verify the shared frozen base is
    /// byte-identical to its pre-serving state.
    pub fn finish(&mut self) -> Result<()> {
        if let Some((_, guard)) = self.current.take() {
            guard.restore(&mut self.base.weights)?;
        }
        let fp = self.base.fingerprint();
        if fp != self.baseline_fp {
            return Err(anyhow!(
                "shared base corrupted after un-merge: fingerprint \
                 {fp:016x} != baseline {:016x}", self.baseline_fp));
        }
        Ok(())
    }

    pub fn report(&self) -> String {
        let s = &self.stats;
        let mut out = format!(
            "backend {} | {} requests in {} batches | {} tenants in \
             registry | {} swaps ({:.1}ms total, {:.1}% of wall)\n\n",
            self.backend_name(), s.requests, s.batches,
            self.registry.len(), s.swaps, s.swap_s * 1e3,
            100.0 * s.swap_s / s.wall_s.max(1e-12));
        out.push_str(&self.latencies.table("tenant").render());
        out.push_str(&format!(
            "\naggregate: {:.1} req/s, {:.0} tok/s \
             (forward {:.1}ms, swap {:.1}ms, wall {:.1}ms)\n",
            self.throughput_req_per_s(), self.throughput_tok_per_s(),
            s.forward_s * 1e3, s.swap_s * 1e3, s.wall_s * 1e3));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::registry::PacaAdapter;
    use crate::serve::scheduler::{plan, Policy};
    use crate::serve::trace::{self, TraceSpec};

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine(n_tenants: usize) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for i in 0..n_tenants {
            reg.insert(PacaAdapter::synthetic(
                &trace::tenant_name(i), &m, 4, 11));
        }
        ServeEngine::new(base, reg, Backend::Host)
    }

    #[test]
    fn serves_multi_tenant_trace_and_restores_base() {
        let spec = TraceSpec { n_requests: 80, n_tenants: 5,
                               ..Default::default() };
        let reqs = trace::synthesize(&spec);
        let tenants = trace::tenants(&reqs);
        assert!(tenants.len() >= 4, "need ≥4 tenants, got {tenants:?}");
        let mut eng = engine(spec.n_tenants);
        let batches = plan(&reqs, 8, Policy::SwapAware);
        eng.serve(&batches).unwrap();
        assert_eq!(eng.stats.requests, 80);
        assert!(eng.stats.swaps as usize >= tenants.len());
        for t in &tenants {
            assert!(eng.latencies.count(t) > 0, "no latency for {t}");
        }
        assert!(eng.throughput_req_per_s() > 0.0);
        eng.finish().unwrap(); // bit-exact base restore
        // A second pass over the restored base works identically.
        eng.serve(&batches).unwrap();
        eng.finish().unwrap();
    }

    #[test]
    fn distinct_tenants_compute_distinct_outputs() {
        let b = |tenant: &str| Batch {
            tenant: tenant.into(),
            requests: vec![crate::serve::scheduler::Request {
                id: 0, tenant: tenant.into(), tokens: 32,
                arrival_s: 0.0,
            }],
        };
        let mut e1 = engine(2);
        e1.run_batch(&b(&trace::tenant_name(0))).unwrap();
        let mut e2 = engine(2);
        e2.run_batch(&b(&trace::tenant_name(1))).unwrap();
        assert_ne!(e1.checksum, e2.checksum,
                   "spliced adapters must change the forward output");
        // …and the same tenant is deterministic.
        let mut e3 = engine(2);
        e3.run_batch(&b(&trace::tenant_name(0))).unwrap();
        assert_eq!(e1.checksum, e3.checksum);
    }

    #[test]
    fn same_tenant_batches_skip_the_swap() {
        let mut eng = engine(2);
        let t0 = trace::tenant_name(0);
        let mk = |id| Batch {
            tenant: t0.clone(),
            requests: vec![crate::serve::scheduler::Request {
                id, tenant: t0.clone(), tokens: 8, arrival_s: 0.0,
            }],
        };
        eng.run_batch(&mk(0)).unwrap();
        eng.run_batch(&mk(1)).unwrap();
        assert_eq!(eng.stats.swaps, 1,
                   "consecutive same-tenant batches reuse the splice");
        eng.finish().unwrap();
    }

    #[test]
    fn unknown_tenant_is_an_error_not_a_crash() {
        let mut eng = engine(1);
        assert!(eng.swap_to("tenant-999").is_err());
        // Base must still be intact afterwards.
        eng.finish().unwrap();
    }
}
