//! The serving engine: admission → dispatch → swap → forward →
//! completion.
//!
//! The engine owns ONE shared frozen base; per batch it hot-splices the
//! batch tenant's `(idx, P)` adapter (registry), runs a forward over
//! the batch tokens, and records per-request metrics. Because the
//! spliced base IS the effective model, the forward is exactly the
//! frozen model's — PaCA's zero-inference-overhead property — and the
//! only multi-tenant cost is the swap, which the scheduler amortizes.
//!
//! Forwards go through the [`ForwardBackend`] trait:
//!   * [`HostBackend`] — a real (measured, not simulated) GEMM pipeline
//!     over the base target weights via coordinator::merge::matmul.
//!     Always available; what `paca serve` and the serve bench use on a
//!     fresh checkout. Clamps at [`HOST_MAX_TOKENS`]; the clamp is
//!     surfaced in `EngineStats::truncated_tokens` and the report.
//!   * [`PjrtForward`] — drives the lowered method-agnostic eval
//!     artifact (runtime::Executable) with the spliced weights, like
//!     Trainer::evaluate does after a host-side merge. Requires
//!     `make artifacts`.
//!
//! Three serving modes:
//!   * [`ServeEngine::serve`] — replay a static offline batch plan
//!     (the baseline the online pipeline is anchored against).
//!   * [`ServeEngine::serve_online`] — the event-driven WHOLE-BATCH
//!     loop over a virtual clock: admit arrivals, take one incremental
//!     dispatch from the [`OnlineScheduler`], swap + forward the
//!     batch's full generation (prefill + decode) in one unit, advance
//!     the clock by the service time ([`ClockModel::Measured`] wall
//!     time or the deterministic [`ClockModel::Analytic`]), account
//!     queueing delay and deadline misses per request.
//!   * [`ServeEngine::serve_iterative`] — decode-style ITERATION-LEVEL
//!     batching: the unit of service is one token step over a set of
//!     in-flight slots. Fresh requests prefill (their whole prompt in
//!     one step, emitting the first token — TTFT); decoding requests
//!     advance one token per step (TPOT); requests complete and leave
//!     their slot mid-batch, and late same-tenant arrivals JOIN the
//!     live batch mid-generation ([`OnlineScheduler::join_live`])
//!     instead of waiting for the next dispatch. With prefill-only
//!     requests and a fully-arrived queue it reduces exactly to
//!     `serve_online` — same forwards, same checksum, same swaps (the
//!     correctness anchor in tests/properties.rs).

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::merge;
use crate::data::{Task, TokenGen};
use crate::init;
use crate::manifest::ModelInfo;
use crate::metrics::{latency_breakdown_table, KvOccupancyTimeline,
                     LatencyRecorder, OccupancyTimeline, Table,
                     ThroughputTimeline};
use crate::peft::Selection;
use crate::runtime::{Executable, Runtime};
use crate::serve::events::{EventKind, Events};
use crate::serve::kv::{KvPool, KvSeq};
use crate::serve::prefix::PrefixCache;
use crate::serve::registry::{fingerprint, AdapterRegistry, SpliceGuard,
                             WeightMap};
use crate::serve::scheduler::{Batch, OnlineScheduler, Policy, Request,
                              TenantId, TenantPool};
use crate::serve::telemetry::{Phase, StepProfiler};
use crate::tensor::HostTensor;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Default host-backend row cap per forward (keeps debug-mode tests
/// fast; the GEMM cost model above this point is linear anyway).
/// Configurable per backend via [`HostBackend::with_cap`] /
/// `--host-max-tokens`; batches over the cap are truncated — visibly:
/// see `EngineStats`.
pub const HOST_MAX_TOKENS: usize = 2048;

/// Timeline bucket width for the time-resolved throughput view.
const TIMELINE_BUCKET_S: f64 = 0.1;

/// Default serving geometry when no manifest model is available
/// (matches the tiny-lm training artifacts).
pub fn tiny_model() -> ModelInfo {
    ModelInfo { name: "serve-tiny".into(), vocab: 512, d_model: 64,
                n_layers: 2, n_heads: 4, d_ff: 172, max_seq: 128,
                profile_only: false }
}

/// The shared frozen base: model geometry + target weights
/// ("blocks/<layer>/<target>/w") that adapters splice into.
pub struct BaseModel {
    pub model: ModelInfo,
    pub weights: WeightMap,
}

impl BaseModel {
    /// Deterministic synthetic pretrained base (stand-in for a real
    /// checkpoint; same per-tensor streams as init.rs uses).
    pub fn synthetic(model: &ModelInfo, seed: u64) -> BaseModel {
        let mut weights = WeightMap::new();
        for layer in 0..model.n_layers {
            for (t, d_in, d_out) in model.linear_shapes() {
                let name = format!("blocks/{layer}/{t}/w");
                let mut rng = Rng::for_tag(seed, &name);
                let vals: Vec<f32> = (0..d_in * d_out)
                    .map(|_| rng.normal_f32(0.02)).collect();
                weights.insert(name,
                               HostTensor::from_f32(&[d_in, d_out],
                                                    vals));
            }
        }
        BaseModel { model: model.clone(), weights }
    }

    pub fn fingerprint(&self) -> u64 {
        fingerprint(&self.weights)
    }

    pub fn bytes(&self) -> usize {
        self.weights.values().map(|t| t.bytes()).sum()
    }
}

/// A serving forward path. Implementations run the CURRENT (spliced)
/// base weights over `requested_tokens` and return the output
/// checksum plus the token count actually computed — backends with a
/// cap (host) or a fixed artifact geometry (PJRT) may compute fewer
/// or more than requested, and throughput/truncation accounting needs
/// the actually-computed number.
pub trait ForwardBackend {
    fn name(&self) -> &'static str;
    fn forward(&mut self, base: &BaseModel,
               requested_tokens: usize) -> Result<(f64, usize)>;

    /// Per-forward token cap, if the backend has a configurable one
    /// (the host backend's `--host-max-tokens`); None for backends
    /// whose geometry is fixed elsewhere (PJRT artifacts).
    fn token_cap(&self) -> Option<usize> {
        None
    }
}

/// Always-available host GEMM backend (see module docs).
pub struct HostBackend {
    /// Deterministic activation source, grown lazily.
    input: Vec<f32>,
    /// Row cap per forward (`--host-max-tokens`).
    max_tokens: usize,
}

impl Default for HostBackend {
    fn default() -> HostBackend {
        HostBackend::with_cap(HOST_MAX_TOKENS)
    }
}

impl HostBackend {
    pub fn with_cap(max_tokens: usize) -> HostBackend {
        HostBackend { input: Vec::new(),
                      max_tokens: max_tokens.max(1) }
    }
}

impl ForwardBackend for HostBackend {
    fn name(&self) -> &'static str {
        "host-gemm"
    }

    fn token_cap(&self) -> Option<usize> {
        Some(self.max_tokens)
    }

    fn forward(&mut self, base: &BaseModel,
               requested_tokens: usize) -> Result<(f64, usize)> {
        let t = requested_tokens.clamp(1, self.max_tokens);
        let need = t * base.model.d_model;
        if self.input.len() < need {
            let mut rng = Rng::for_tag(0x5e7e, "serve/input");
            self.input = (0..need)
                .map(|_| rng.normal_f32(1.0)).collect();
        }
        Ok((host_forward(base, &self.input, t), t))
    }
}

/// PJRT forward: the method-agnostic eval artifact driven with the
/// spliced weights (non-target state — embeddings, norms, head — is
/// initialized once from the manifest specs and reused).
pub struct PjrtForward {
    exe: Arc<Executable>,
    state_template: Vec<HostTensor>,
    gen: TokenGen,
}

impl PjrtForward {
    pub fn new(rt: &Runtime, model: &str, seed: u64) -> Result<PjrtForward> {
        let name = rt.manifest.artifacts.values()
            .find(|a| a.kind == "eval_step" && a.model == model)
            .map(|a| a.name.clone())
            .ok_or_else(|| {
                anyhow!("no eval artifact lowered for model {model}")
            })?;
        let exe = rt.load(&name)?;
        let state_template =
            init::init_state(&exe.info, seed, &Selection::Random)?;
        let m = rt.manifest.model(model)?;
        let gen = TokenGen::new(Task::LmZipf, m.vocab, seed);
        Ok(PjrtForward { exe, state_template, gen })
    }

    pub fn model_name(&self) -> &str {
        &self.exe.info.model
    }

    fn run(&mut self, weights: &WeightMap) -> Result<f64> {
        let (b, s) = (self.exe.info.batch, self.exe.info.seq);
        let batch = self.gen.train_batch(b, s);
        let mut inputs: Vec<xla::Literal> =
            Vec::with_capacity(self.exe.info.state.len() + 1);
        for (e, template) in self.exe.info.state.iter()
            .zip(&self.state_template)
        {
            let src = weights.get(&e.name).unwrap_or(template);
            inputs.push(src.to_literal()?);
        }
        inputs.push(batch.to_literal()?);
        let outs = self.exe.run(&inputs)?;
        let loss = outs[0].get_first_element::<f32>()
            .map_err(|e| anyhow!("loss fetch: {e:?}"))?;
        Ok(loss as f64)
    }
}

impl ForwardBackend for PjrtForward {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn forward(&mut self, base: &BaseModel,
               _requested_tokens: usize) -> Result<(f64, usize)> {
        // The artifact's geometry is fixed at lowering time.
        let computed = self.exe.info.batch * self.exe.info.seq;
        Ok((self.run(&base.weights)?, computed))
    }
}

/// How the online step loop advances its virtual clock per batch.
#[derive(Debug, Clone, Copy)]
pub enum ClockModel {
    /// Wall time of the real swap + forward (what `paca serve` uses).
    Measured,
    /// Deterministic analytic service time — the noise-free mode the
    /// bench and tests use so queueing/deadline numbers are exactly
    /// reproducible: `batch_s + token_s·tokens (+ swap_s if the batch
    /// swapped adapters)`.
    Analytic { swap_s: f64, batch_s: f64, token_s: f64 },
}

#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct EngineStats {
    pub requests: u64,
    /// Tokens the backend actually computed (host clamps oversized
    /// batches; PJRT runs the artifact's fixed geometry).
    pub tokens: u64,
    pub batches: u64,
    /// Iteration steps executed by `serve_iterative` (each step is
    /// one forward; `batches` counts those too).
    pub steps: u64,
    pub swaps: u64,
    pub swap_s: f64,
    pub forward_s: f64,
    pub wall_s: f64,
    /// Virtual-clock makespan accumulated by `serve_online`.
    pub virtual_s: f64,
    /// Requested-but-not-computed tokens (HOST_MAX_TOKENS clamp, or a
    /// PJRT artifact geometry smaller than the batch) — surfaced
    /// instead of silently dropped.
    pub truncated_tokens: u64,
    pub truncated_batches: u64,
    /// Requests that carried a finite deadline / those that missed it.
    pub deadline_total: u64,
    pub deadline_misses: u64,
    /// Decoding slots evicted mid-generation (blocks freed, request
    /// re-queued with recompute-on-resume), split by trigger: the pool
    /// ran out of blocks, or an urgent other-tenant deadline
    /// (slo-aware) claimed the capacity.
    pub preemptions: u64,
    pub preempt_memory: u64,
    pub preempt_deadline: u64,
    /// Slots evacuated off a killed replica by the cluster's failover
    /// path (a strict subset of `preemptions`; always 0 outside
    /// multi-replica runs).
    pub preempt_failover: u64,
    /// Prompt tokens the resume replays will recompute — the price
    /// paid for freeing preempted KV instead of swapping it out.
    /// (With the prefix cache on, a resume that hits its own donated
    /// prefix actually recomputes less — this counter stays the
    /// cache-free upper bound.)
    pub kv_recompute_tokens: u64,
    /// Prompt tokens of every seated request (resume replays
    /// included) — the denominator of the prefix-cache hit rate.
    pub prefill_tokens: u64,
    /// Chunked prefill (`--prefill-chunk-tokens`): prefill chunk
    /// steps computed, prompts that actually split into more than one
    /// chunk, and mid-prompt preemptions (a slot evicted before its
    /// final chunk, replayed from token zero).
    pub prefill_chunks: u64,
    pub chunked_prefills: u64,
    pub preempt_prefill: u64,
    /// Speculative prefix prefetch (`--prefetch`): prompt tokens
    /// warmed into the radix cache during idle clock gaps, and the
    /// blocks those warms donated.
    pub prefetch_tokens: u64,
    pub prefetch_donated_blocks: u64,
}

pub struct ServeEngine {
    pub base: BaseModel,
    pub registry: AdapterRegistry,
    backend: Box<dyn ForwardBackend>,
    /// Interner the batches' `TenantId`s resolve through.
    pub pool: TenantPool,
    /// Live splice, if any: (tenant, displaced base rows).
    current: Option<(TenantId, SpliceGuard)>,
    baseline_fp: u64,
    /// Per-batch service latency, offline replay path.
    pub latencies: LatencyRecorder,
    /// Online decomposition: time from arrival to dispatch…
    pub queueing: LatencyRecorder,
    /// …service time of the batch that carried the request…
    pub service: LatencyRecorder,
    /// …and end-to-end (arrival → completion).
    pub e2e: LatencyRecorder,
    /// Iteration-level decomposition: arrival → first output token…
    pub ttft: LatencyRecorder,
    /// …and time per output token after the first (decode requests
    /// only).
    pub tpot: LatencyRecorder,
    /// Per-step in-flight slots / step tokens of `serve_iterative`.
    pub occupancy: OccupancyTimeline,
    /// Per-step live blocks / resident tokens of the paged KV pool.
    pub kv_timeline: KvOccupancyTimeline,
    /// Time-bucketed completions on the online clock.
    pub timeline: ThroughputTimeline,
    /// The paged KV-cache pool (unlimited by default — configure with
    /// [`ServeEngine::configure_kv`] / `--kv-blocks`).
    pub kv: KvPool,
    /// Per-tenant prefix-sharing radix cache over the pool
    /// (`--prefix-cache`, default on; inert until a trace carries
    /// `shared_prefix_tokens`). Only `serve_iterative` consults it —
    /// the whole-batch unit of service allocates and frees its whole
    /// residency per dispatch, so there is nothing to share.
    pub prefix: PrefixCache,
    /// Preemption enabled? Only consulted when the pool is bounded;
    /// false = drain-only (admission is still capacity-gated, but a
    /// live batch is never evicted).
    pub preempt: bool,
    /// Chunked prefill: max prompt tokens any one slot computes per
    /// step (`--prefill-chunk-tokens`; 0 = unchunked — whole prompt
    /// in one step, the PR-6 reduction anchor).
    prefill_chunk: usize,
    /// Speculative prefix prefetch: spend idle clock gaps warming the
    /// next cold tenant's shared system prompt into the radix cache
    /// (`--prefetch`; off by default — the reduction anchor).
    prefetch: bool,
    /// Recompute-on-resume state of preempted requests, by request id:
    /// original first-token time and decode length (the requeued
    /// request's own fields were rewritten to cover the replay).
    resume: HashMap<u64, ResumeInfo>,
    /// Event-stream handle (off by default — see `serve::events`).
    /// [`ServeEngine::configure_events`] installs an enabled handle
    /// here and clones it into the KV pool, prefix cache, registry,
    /// and (at serve start) the scheduler, so all five write one
    /// totally-ordered stream.
    pub events: Events,
    /// Per-phase step profiler (`--profile`). `None` = off, the
    /// reduction anchor: no stamps are taken at all. With wall
    /// stamps armed (`--clock measured`) the begin/end pairs carry
    /// dual wall times next to the virtual attribution.
    pub profiler: Option<StepProfiler>,
    pub stats: EngineStats,
    /// Accumulated forward outputs (keeps the host GEMMs observable).
    pub checksum: f64,
}

/// Why a slot is being evicted — the Preempt event's `a` payload and
/// the stats counter it lands in. Discriminants are the wire codes
/// (deadline rescue was 0 and memory pressure 1 before failover
/// existed, so single-engine traces are unchanged).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EvictCause {
    Deadline = 0,
    Memory = 1,
    Failover = 2,
}

/// What survives a preemption, keyed off the engine's resume map.
/// Public (with [`ServeEngine::export_resume`] /
/// [`ServeEngine::import_resume`]) because replica failover migrates
/// these entries to the surviving engine — the exactly-once emission
/// discipline travels with the request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResumeInfo {
    /// Virtual time the request's FIRST token was emitted (TTFT was
    /// settled then; replays emit nothing). `None` when the slot was
    /// evicted MID-PROMPT (chunked prefill) — no token ever left, so
    /// the resumed residency emits the first token itself.
    pub first_token_s: Option<f64>,
    /// The request's original decode length — the TPOT denominator
    /// (its live `decode_tokens` now counts only the owed remainder).
    pub orig_decode: usize,
}

impl ServeEngine {
    pub fn new(base: BaseModel, registry: AdapterRegistry,
               backend: Box<dyn ForwardBackend>,
               pool: TenantPool) -> ServeEngine {
        let baseline_fp = base.fingerprint();
        let kv = KvPool::unlimited(&base.model);
        ServeEngine { base, registry, backend, pool, current: None,
                      baseline_fp,
                      latencies: LatencyRecorder::default(),
                      queueing: LatencyRecorder::default(),
                      service: LatencyRecorder::default(),
                      e2e: LatencyRecorder::default(),
                      ttft: LatencyRecorder::default(),
                      tpot: LatencyRecorder::default(),
                      occupancy: OccupancyTimeline::default(),
                      kv_timeline: KvOccupancyTimeline::default(),
                      timeline: ThroughputTimeline::new(
                          TIMELINE_BUCKET_S),
                      kv, prefix: PrefixCache::new(true),
                      preempt: true, prefill_chunk: 0,
                      prefetch: false, resume: HashMap::new(),
                      events: Events::off(), profiler: None,
                      stats: EngineStats::default(), checksum: 0.0 }
    }

    /// Arm the per-phase step profiler (`--profile`); `wall` adds
    /// wall-clock dual stamps next to the virtual attribution
    /// (`--clock measured`). Off is the reduction anchor.
    pub fn configure_profiler(&mut self, wall: bool) {
        self.profiler = Some(StepProfiler::new(wall));
    }

    /// Install an event-stream handle (usually [`Events::recording`])
    /// and fan clones out to every emitting component. Call in any
    /// order relative to `configure_kv`/`configure_prefix` — those
    /// re-propagate the handle into the fresh pool/cache.
    pub fn configure_events(&mut self, events: Events) {
        self.events = events;
        self.kv.set_events(self.events.clone());
        self.prefix.set_events(self.events.clone());
        self.registry.set_events(self.events.clone());
    }

    /// Install a paged KV pool: `n_blocks` blocks (0 = unlimited) of
    /// `block_tokens` tokens, bytes-per-token derived from the base
    /// model (the same arithmetic `serve::cost` streams per decode
    /// step). `preempt` arms eviction under memory pressure / urgent
    /// deadlines; false = drain-only.
    pub fn configure_kv(&mut self, n_blocks: usize,
                        block_tokens: usize, preempt: bool) {
        self.kv = KvPool::new(n_blocks, block_tokens,
                              self.base.model.kv_bytes_per_token());
        self.kv.set_events(self.events.clone());
        self.preempt = preempt;
    }

    /// Arm or disarm the prefix-sharing cache (`--prefix-cache`).
    /// Off is the reduction anchor: bit-for-bit the PR-4 engine.
    pub fn configure_prefix(&mut self, enabled: bool) {
        self.prefix = PrefixCache::new(enabled);
        self.prefix.set_events(self.events.clone());
    }

    /// Set the chunked-prefill step quota
    /// (`--prefill-chunk-tokens`): at most `chunk` prompt tokens of
    /// any one slot are computed per iteration step, interleaved with
    /// decode, so a long prompt trickles in instead of stalling the
    /// batch. 0 = unchunked (whole prompt in one step) — bit-for-bit
    /// the PR-6 engine.
    pub fn configure_chunking(&mut self, chunk: usize) {
        self.prefill_chunk = chunk;
    }

    /// Arm speculative prefix prefetch (`--prefetch`): when the
    /// engine is idle until the next arrival, warm a known-but-cold
    /// tenant's shared system prompt into the radix cache as donated
    /// blocks. Off is the reduction anchor.
    pub fn configure_prefetch(&mut self, on: bool) {
        self.prefetch = on;
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Tenant currently spliced into the base, if any.
    pub fn current_tenant_id(&self) -> Option<TenantId> {
        self.current.as_ref().map(|(t, _)| *t)
    }

    pub fn current_tenant(&self) -> Option<&str> {
        self.current.as_ref().map(|(t, _)| self.pool.name(*t))
    }

    /// Make `tenant` the live adapter: exact un-merge of the previous
    /// tenant, then O(r·d_out)-per-target splice of the new one.
    /// No-op (and no swap counted) if the tenant is already live.
    pub fn swap_to(&mut self, tenant: TenantId) -> Result<()> {
        if self.current_tenant_id() == Some(tenant) {
            return Ok(());
        }
        let t0 = Instant::now();
        if let Some((prev, guard)) = self.current.take() {
            guard.restore(&mut self.base.weights)?;
            self.events.emit(EventKind::SpliceOut, Some(prev.0), None,
                             0, 0);
        }
        let adapter = self.registry.fetch(self.pool.name(tenant))?;
        let rank = adapter.rank as u64;
        let guard = adapter.splice(&mut self.base.weights)?;
        self.current = Some((tenant, guard));
        self.stats.swaps += 1;
        self.events.emit(EventKind::SpliceIn, Some(tenant.0), None,
                         rank, self.stats.swaps);
        self.stats.swap_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Swap to `tenant` + one forward of `requested` tokens, with the
    /// shared accounting (checksum, token/truncation/batch counters);
    /// returns the wall time and whether an adapter swap happened.
    /// BOTH units of service — the whole-batch forward and the
    /// iteration step — go through here, so their accounting is
    /// bitwise-identical (what the reduction anchor asserts).
    fn forward_step(&mut self, tenant: TenantId,
                    requested: usize) -> Result<(f64, bool)> {
        let swapped = self.current_tenant_id() != Some(tenant);
        let t0 = Instant::now();
        self.swap_to(tenant)?;
        let tf = Instant::now();
        let requested = requested.max(1);
        let (out, computed) =
            self.backend.forward(&self.base, requested)?;
        self.stats.forward_s += tf.elapsed().as_secs_f64();
        self.checksum += out;
        // Tokens the backend actually pushed through — tok/s stays
        // honest when the host backend clamps oversized batches, and
        // the clamp itself is reported, not swallowed.
        self.stats.tokens += computed as u64;
        if computed < requested {
            self.stats.truncated_tokens += (requested - computed) as u64;
            self.stats.truncated_batches += 1;
        }
        self.stats.batches += 1;
        Ok((t0.elapsed().as_secs_f64(), swapped))
    }

    /// Swap + forward for one dispatched batch — the WHOLE-BATCH unit
    /// of service, i.e. every member's full generation (prefill +
    /// decode tokens) in a single forward; returns the service wall
    /// time and whether an adapter swap happened.
    fn service_batch(&mut self, batch: &Batch) -> Result<(f64, bool)> {
        self.forward_step(batch.tenant, batch.total_tokens())
    }

    /// Offline replay: serve one planned batch, recording every
    /// request's service latency (swap + forward wall time).
    pub fn run_batch(&mut self, batch: &Batch) -> Result<()> {
        if batch.requests.is_empty() {
            return Ok(());
        }
        let (latency, _) = self.service_batch(batch)?;
        let name = self.pool.name(batch.tenant);
        for _ in &batch.requests {
            self.latencies.record(name, latency);
            self.latencies.record("(all)", latency);
            self.stats.requests += 1;
        }
        Ok(())
    }

    /// Replay a static offline plan (the comparison baseline).
    pub fn serve(&mut self, batches: &[Batch]) -> Result<()> {
        let t0 = Instant::now();
        for b in batches {
            self.run_batch(b)?;
        }
        self.stats.wall_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// The online continuous-batching step loop: admission → dispatch
    /// → forward → completion on a virtual clock, until the scheduler
    /// is drained. Queueing delay (arrival → dispatch), service time,
    /// end-to-end latency, deadline misses, and time-bucketed
    /// throughput are all recorded on the virtual clock.
    pub fn serve_online(&mut self, sched: &mut OnlineScheduler,
                        clock: ClockModel) -> Result<()> {
        let wall0 = Instant::now();
        let mut now = 0.0f64;
        sched.events = self.events.clone();
        // Calibrate BEFORE the first admission: urgency keys freeze
        // at admit time, so requests arriving before the first
        // dispatch must already see the clock's decode slack.
        self.calibrate(sched, clock);
        loop {
            self.events.set_now(now);
            sched.admit(now);
            if sched.pending_len() == 0 {
                match sched.next_arrival() {
                    // Idle: event-jump the clock to the next arrival.
                    Some(t) => {
                        now = now.max(t);
                        self.events.set_now(now);
                        sched.admit(now);
                    }
                    None => break,
                }
            }
            self.calibrate(sched, clock);
            self.sync_kv_gate(sched);
            let live = self.current_tenant_id();
            let Some(batch) = sched.dispatch(live, now) else { break };
            if batch.requests.is_empty() {
                continue;
            }
            // Whole-batch KV residency: every member's full-lifetime
            // cache is live for the duration of the batch (this unit
            // of service never frees mid-flight — it is drain-only by
            // construction). Oversized first-fits batches clamp.
            let kv_seqs: Vec<KvSeq> = batch.requests.iter()
                .map(|r| self.kv.alloc_clamped(r.total_tokens()))
                .collect();
            self.kv_timeline.record(
                self.kv.used_blocks() as u64,
                self.kv.resident_tokens() as u64,
                self.kv.reclaimable_blocks() as u64);
            let (wall_service_s, swapped) =
                match self.service_batch(&batch) {
                    Ok(v) => v,
                    Err(e) => {
                        // Hand the blocks back before propagating, so
                        // a forward error doesn't read as a pool leak
                        // at finish().
                        for s in kv_seqs {
                            self.kv.release(s);
                        }
                        return Err(e);
                    }
                };
            let service_s = match clock {
                ClockModel::Measured => wall_service_s,
                ClockModel::Analytic { swap_s, batch_s, token_s } => {
                    // The whole-batch unit holds the server for its
                    // longest member's generation: one prefill step
                    // plus max(decode) iterations, each paying the
                    // per-step overhead, every token costing token_s —
                    // the same arithmetic the iteration-level loop
                    // pays step by step, minus its ability to free
                    // slots early and admit joiners mid-flight.
                    // Prefill-only batches reduce to the v2 formula.
                    let decode_steps = batch.requests.iter()
                        .map(|r| r.decode_tokens).max().unwrap_or(0);
                    batch_s * (1 + decode_steps) as f64
                        + token_s * batch.total_tokens() as f64
                        + if swapped { swap_s } else { 0.0 }
                }
            };
            let start = now;
            now += service_s;
            self.events.set_now(now);
            let name = self.pool.name(batch.tenant);
            let mut tokens = 0u64;
            for r in &batch.requests {
                let queue_s = (start - r.arrival_s).max(0.0);
                let e2e_s = (now - r.arrival_s).max(0.0);
                self.queueing.record(name, queue_s);
                self.queueing.record("(all)", queue_s);
                self.service.record(name, service_s);
                self.service.record("(all)", service_s);
                self.e2e.record(name, e2e_s);
                self.e2e.record("(all)", e2e_s);
                if r.deadline_s.is_finite() {
                    self.stats.deadline_total += 1;
                    let dl = r.absolute_deadline();
                    let missed = now > dl;
                    if missed {
                        self.stats.deadline_misses += 1;
                    }
                    self.events.emit(
                        EventKind::SloBurn, Some(batch.tenant.0),
                        Some(r.id), missed as u64,
                        if missed { ((now - dl) * 1e6) as u64 }
                        else { 0 });
                }
                tokens += r.total_tokens() as u64;
                self.stats.requests += 1;
                self.events.emit(EventKind::Complete,
                                 Some(batch.tenant.0), Some(r.id),
                                 (1 + r.decode_tokens) as u64, 0);
            }
            self.timeline.record(now, batch.requests.len() as u64,
                                 tokens);
            for s in kv_seqs {
                self.kv.release(s);
            }
        }
        self.stats.virtual_s += now;
        self.stats.wall_s += wall0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Keep the slo policy's scheduling prices calibrated to what the
    /// active clock actually charges: the swap hysteresis
    /// (`swap_penalty_s`) and the per-decode-token urgency credit
    /// (`decode_slack_s`) — analytic constants, or measured running
    /// averages.
    fn calibrate(&self, sched: &mut OnlineScheduler,
                 clock: ClockModel) {
        match clock {
            ClockModel::Analytic { swap_s, token_s, .. } => {
                sched.swap_penalty_s = swap_s;
                sched.decode_slack_s = token_s;
            }
            ClockModel::Measured => {
                sched.swap_penalty_s = if self.stats.swaps > 0 {
                    self.stats.swap_s / self.stats.swaps as f64
                } else {
                    0.0
                };
                sched.decode_slack_s = if self.stats.tokens > 0 {
                    self.stats.forward_s / self.stats.tokens as f64
                } else {
                    0.0
                };
            }
        }
    }

    /// Advertise the paged pool's state to the scheduler's admission
    /// gate; gating stays disabled while the pool is unlimited (the
    /// PR-3 reduction regime). With the prefix cache on, the gate
    /// also learns each tenant's cached cover (so dispatch/join
    /// charge only the uncached suffix) and counts cache-only blocks
    /// as available (the LRU reclaim yields them on demand). Stale
    /// subtrees — the registry evicted or reloaded the tenant's
    /// adapter since the KV was computed — are dropped FIRST, so the
    /// advertised cover, the engine's own lookups, and the
    /// scheduler's projections all see the same post-invalidation
    /// cache.
    fn sync_kv_gate(&mut self, sched: &mut OnlineScheduler) {
        // An empty cache advertises nothing and has nothing to go
        // stale — skip the per-tenant walk (this path runs twice per
        // dispatch iteration, and every pre-prefix workload would
        // otherwise pay it for no cover).
        if self.prefix.enabled() && self.prefix.cached_blocks() > 0 {
            for t in self.prefix.tenants() {
                let gen = self.registry
                    .generation(self.pool.name(t));
                self.prefix.invalidate_if_stale(t, gen, &mut self.kv);
            }
            let bt = self.kv.block_tokens();
            sched.prefix_block_tokens = bt;
            sched.kv_prefix_cover.clear();
            sched.kv_prefix_cover.extend(
                (0..self.pool.len())
                    .map(|i| self.prefix.cover(TenantId(i as u32),
                                               bt)));
        } else {
            sched.prefix_block_tokens = 0;
            sched.kv_prefix_cover.clear();
        }
        if self.kv.is_bounded() {
            sched.kv_block_tokens = self.kv.block_tokens();
            sched.kv_free_blocks = self.kv.available_blocks();
        } else {
            sched.kv_block_tokens = 0;
            sched.kv_free_blocks = usize::MAX;
        }
    }

    /// Reclaim cache-only blocks until `need` blocks fit the free
    /// list (or the cache runs dry). Inert without a populated cache
    /// — the PR-4 allocation paths are untouched.
    fn reclaim_shortfall(&mut self, need: usize) {
        let free = self.kv.free_blocks();
        if need > free {
            self.prefix.reclaim(need - free, &mut self.kv);
        }
    }

    /// `KvPool::alloc_clamped` behind the cache's reclaim: the cache
    /// yields unreferenced blocks before an allocation ever clamps on
    /// them.
    fn kv_alloc_clamped(&mut self, tokens: usize) -> KvSeq {
        self.reclaim_shortfall(self.kv.blocks_for(tokens));
        self.kv.alloc_clamped(tokens)
    }

    /// True when eviction is armed: a bounded pool with `preempt` on.
    fn preempting(&self) -> bool {
        self.preempt && self.kv.is_bounded()
    }

    /// Least-urgent eviction candidate among decoding (prefilled)
    /// slots, skipping `exclude`: the slot with the LARGEST
    /// decode-adjusted deadline slack at `now` (no-deadline slots rank
    /// +inf — prime victims). Ties break on request id for
    /// determinism. Returns (index, slack).
    ///
    /// With chunked prefill on (`mid_prompt`), slots still mid-prompt
    /// become eligible too — but only as a FALLBACK when no decoding
    /// victim exists (evicting a part-paid prefill throws its chunks
    /// away), with their remaining chunk work counted into the slack.
    fn pick_victim(slots: &[Slot], exclude: Option<u64>, now: f64,
                   decode_slack_s: f64,
                   mid_prompt: bool) -> Option<(usize, f64)> {
        let scan = |want_prefilled: bool| -> Option<(usize, f64)> {
            let mut best: Option<(f64, u64, usize)> = None;
            for (i, s) in slots.iter().enumerate() {
                if s.prefilled != want_prefilled
                    || exclude == Some(s.req.id)
                {
                    continue;
                }
                let owed = if s.prefilled {
                    0
                } else {
                    s.prefill_tokens - s.prefill_done
                };
                let slack = s.req.absolute_deadline() - now
                    - (s.remaining + owed) as f64 * decode_slack_s;
                let better = match &best {
                    None => true,
                    Some((bs, bid, _)) => match slack.total_cmp(bs) {
                        std::cmp::Ordering::Greater => true,
                        std::cmp::Ordering::Less => false,
                        std::cmp::Ordering::Equal => s.req.id > *bid,
                    },
                };
                if better {
                    best = Some((slack, s.req.id, i));
                }
            }
            best.map(|(slack, _, i)| (i, slack))
        };
        scan(true).or_else(|| {
            if mid_prompt {
                scan(false)
            } else {
                None
            }
        })
    }

    /// Step-token charge of one in-flight slot: one decode token, or
    /// this step's prefill chunk (the whole remaining prompt when
    /// unchunked).
    fn slot_step_tokens(chunk: usize, s: &Slot) -> usize {
        if s.prefilled {
            1
        } else {
            let owed = s.prefill_tokens - s.prefill_done;
            if chunk > 0 {
                owed.min(chunk)
            } else {
                owed
            }
        }
    }

    /// Evict the decoding slot at `idx`: free its blocks and re-queue
    /// the request with recompute-on-resume. The requeued request's
    /// prompt is extended to cover every token already emitted (the
    /// replay must rebuild their KV) and its decode debt shrinks to
    /// the owed remainder, so projection, replay cost and remaining
    /// emissions all stay consistent; the resume map pins the original
    /// first-token time and decode length so TTFT/TPOT and the
    /// exactly-once emission accounting are untouched by any number of
    /// evict/resume cycles.
    fn evict_slot(&mut self, slots: &mut Vec<Slot>, idx: usize,
                  sched: &mut OnlineScheduler, memory: bool) {
        let cause = if memory {
            EvictCause::Memory
        } else {
            EvictCause::Deadline
        };
        let r = self.evict_core(slots, idx, cause);
        sched.requeue(r);
    }

    /// The eviction itself, minus the re-queue: frees the slot's
    /// blocks, settles the resume-map bookkeeping, emits the Preempt
    /// event, and hands the rewritten request back. `evict_slot`
    /// re-queues it locally; the cluster's failover evacuation routes
    /// it to a SURVIVING replica instead — same replay discipline,
    /// different destination queue.
    fn evict_core(&mut self, slots: &mut Vec<Slot>, idx: usize,
                  cause: EvictCause) -> Request {
        let mut s = slots.swap_remove(idx);
        // An evicted sequence donates its shared prefix like a
        // completing one — the resume replay (and everyone else on
        // this tenant) then hits it instead of recomputing.
        let seq = std::mem::take(&mut s.kv);
        self.retire_seq(&s.req, seq);
        let mut r = s.req;
        if s.prefilled {
            // Tokens emitted in THIS residency: the first token if
            // this residency emitted it, plus finished decode
            // iterations.
            let decode_done = r.decode_tokens - s.remaining;
            let emitted = decode_done + usize::from(s.emit_first);
            let info = self.resume.entry(r.id)
                .or_insert(ResumeInfo {
                    first_token_s: None,
                    orig_decode: r.decode_tokens,
                });
            // First eviction after the first token left (including a
            // mid-prompt-evicted request whose REPLAY emitted it):
            // pin the emission time so later replays never re-emit.
            info.first_token_s.get_or_insert(s.first_token_s);
            r.tokens += emitted;
            r.decode_tokens = s.remaining;
        } else {
            // Mid-prompt eviction (chunked prefill only): nothing was
            // emitted yet, so the request replays with its original
            // fields; `first_token_s: None` tells the re-seat that
            // the first token (and TTFT) is still owed.
            self.resume.entry(r.id).or_insert(ResumeInfo {
                first_token_s: None,
                orig_decode: r.decode_tokens,
            });
            self.stats.preempt_prefill += 1;
        }
        self.stats.kv_recompute_tokens += r.tokens as u64;
        self.stats.preemptions += 1;
        match cause {
            EvictCause::Memory => self.stats.preempt_memory += 1,
            EvictCause::Deadline => self.stats.preempt_deadline += 1,
            EvictCause::Failover => self.stats.preempt_failover += 1,
        }
        // Preempt payload a is the cause code: 0 deadline rescue,
        // 1 memory pressure, 2 failover evacuation (docs/events.md).
        self.events.emit(EventKind::Preempt, Some(r.tenant.0),
                         Some(r.id), cause as u64,
                         r.decode_tokens as u64);
        r
    }

    /// Phase 1 of seating a dispatch/join group: the prefix-cache
    /// hold. Looks up the tenant's cached cover for `r`'s shared
    /// prefix and ATTACHES the matched blocks (refcount bump, zero
    /// compute) before any group member allocates its suffix — so one
    /// member's allocation can never reclaim blocks another member of
    /// the same admission decision was projected against. None = no
    /// usable hit; the request seats through the plain PR-4 path.
    fn hold_prefix(&mut self, r: &Request) -> Option<(KvSeq, usize)> {
        if !self.prefix.enabled() || r.shared_prefix_tokens == 0 {
            return None;
        }
        let want = crate::serve::prefix::usable_prefix(
            r.shared_prefix_tokens, r.tokens);
        let gen = self.registry.generation(self.pool.name(r.tenant));
        let m = self.prefix.lookup(r.tenant, want, gen, &mut self.kv);
        if m.tokens == 0 {
            return None;
        }
        Some((self.kv.attach(&m.blocks, m.tokens), m.tokens))
    }

    /// Seat a whole dispatch/join group: every member's cache hold
    /// first, then every member's suffix allocation.
    fn seat(&mut self, slots: &mut Vec<Slot>, reqs: Vec<Request>,
            now: f64) {
        let holds: Vec<Option<(KvSeq, usize)>> =
            reqs.iter().map(|r| self.hold_prefix(r)).collect();
        for (r, hold) in reqs.into_iter().zip(holds) {
            self.slot_in(slots, r, now, hold);
        }
    }

    /// Seat `r` in a fresh slot at virtual time `now` (phase 2):
    /// settle its queueing delay (first residency only — a resumed
    /// request already paid it), allocate the prompt's KV blocks —
    /// just the uncached suffix past a prefix-cache hold, clamped for
    /// a first-fits oversized request — and mark resume replays so
    /// the prefill step emits nothing twice.
    fn slot_in(&mut self, slots: &mut Vec<Slot>, r: Request, now: f64,
               hold: Option<(KvSeq, usize)>) {
        let resumed = self.resume.contains_key(&r.id);
        // This residency owes the first output token unless an
        // EARLIER residency already emitted it (decode-evict replay).
        // A mid-prompt-evicted request resumes with the token still
        // owed.
        let emit_first = match self.resume.get(&r.id) {
            Some(info) => info.first_token_s.is_none(),
            None => true,
        };
        if !resumed {
            let queue_s = (now - r.arrival_s).max(0.0);
            let name = self.pool.name(r.tenant);
            self.queueing.record(name, queue_s);
            self.queueing.record("(all)", queue_s);
        } else {
            // The re-seat's Dispatch (scheduler) already fired, so
            // the auditor sees the preempt → re-dispatch → resume
            // order it enforces.
            self.events.emit(EventKind::Resume, Some(r.tenant.0),
                             Some(r.id), r.tokens as u64, 0);
        }
        self.stats.prefill_tokens += r.tokens as u64;
        // Chunked prefill allocates only the FIRST chunk's KV at
        // seating; later chunks extend it step by step through the
        // grow path. Unchunked (chunk 0) allocates the whole prompt —
        // the PR-6 arithmetic, bit for bit.
        let chunk = self.prefill_chunk;
        let (kv, prefill_tokens) = match hold {
            Some((mut seq, hit)) => {
                // hit ≤ tokens − 1, so the computed suffix is ≥ 1
                // (the first output token always needs a forward).
                let suffix = r.tokens - hit;
                let first = if chunk > 0 {
                    suffix.min(chunk)
                } else {
                    suffix
                };
                // CoW fork slack only when the match ended on a
                // partially-filled shared tail — a full-block cover
                // can never fork, and over-reclaiming here would
                // evict a cached block (and a future hit) for free.
                let fork = usize::from(
                    hit % self.kv.block_tokens() != 0);
                let need = self.kv.blocks_for(hit + first)
                    .saturating_sub(seq.n_blocks())
                    + fork;
                self.reclaim_shortfall(need);
                self.kv.grow_clamped(&mut seq, first);
                (seq, suffix)
            }
            None => {
                let first = if chunk > 0 {
                    r.tokens.min(chunk)
                } else {
                    r.tokens
                };
                (self.kv_alloc_clamped(first), r.tokens)
            }
        };
        if chunk > 0 && prefill_tokens > chunk {
            self.stats.chunked_prefills += 1;
        }
        self.events.emit(EventKind::PrefillStart, Some(r.tenant.0),
                         Some(r.id), prefill_tokens as u64,
                         (r.tokens - prefill_tokens) as u64);
        slots.push(Slot { remaining: r.decode_tokens,
                          prefilled: false, emit_first,
                          dispatched_s: now, first_token_s: now, kv,
                          prefill_tokens, prefill_done: 0, req: r });
    }

    /// Return a finished (or evicted) sequence's blocks to the pool —
    /// donating the blocks that cover the request's shared prefix to
    /// the tenant's radix cache instead of freeing them, so the next
    /// same-tenant prompt attaches them without recompute.
    fn retire_seq(&mut self, r: &Request, seq: KvSeq) {
        if self.prefix.enabled() && r.shared_prefix_tokens > 0 {
            let gen = self.registry
                .generation(self.pool.name(r.tenant));
            self.prefix.donate(r.tenant, gen, &seq,
                               r.shared_prefix_tokens, &mut self.kv);
        }
        self.kv.release(seq);
    }

    /// Speculative prefix prefetch: the engine is idle until `until`,
    /// so spend the gap warming a known-but-cold tenant's shared
    /// system prompt into the radix cache as donated blocks. The
    /// target is the EARLIEST future request whose tenant's cached
    /// cover does not already span its usable shared prefix. Warm KV
    /// is built through the normal forward/alloc paths (same clock
    /// arithmetic, same checksum accounting, chunk-sized steps when
    /// chunking is on) but NEVER steals capacity: an allocation
    /// failure abandons the warm instead of reclaiming cache or
    /// preempting — speculation must not cost anyone anything. The
    /// donation is generation-checked: if the tenant's adapter
    /// reloaded mid-warm, the stale KV is released, never donated.
    /// Returns the advanced clock (never past work that matters —
    /// each warm step is projected against the gap before it runs).
    fn prefetch_gap(&mut self, sched: &OnlineScheduler,
                    clock: ClockModel, mut now: f64,
                    until: f64) -> Result<f64> {
        if !self.prefix.enabled() {
            return Ok(now);
        }
        let bt = self.kv.block_tokens();
        let target = sched.peek_future().find_map(|r| {
            if r.shared_prefix_tokens == 0 {
                return None;
            }
            let want = crate::serve::prefix::usable_prefix(
                r.shared_prefix_tokens, r.tokens);
            let (full, tail) = self.prefix.cover(r.tenant, bt);
            (full * bt + tail < want).then_some((r.tenant, want))
        });
        let Some((tenant, want)) = target else {
            return Ok(now);
        };
        let gen = self.registry.generation(self.pool.name(tenant));
        let cap = if self.prefill_chunk > 0 {
            self.prefill_chunk
        } else if sched.max_batch_tokens > 0 {
            sched.max_batch_tokens
        } else {
            want
        };
        let mut seq: Option<KvSeq> = None;
        let mut warmed = 0usize;
        while warmed < want {
            let toks = (want - warmed).min(cap).max(1);
            // A warm step that would overrun the gap (delaying the
            // real arrival it speculates for) is not taken.
            let projected = match clock {
                ClockModel::Analytic { swap_s, batch_s, token_s } => {
                    let swaps = self.current_tenant_id()
                        != Some(tenant);
                    batch_s + token_s * toks as f64
                        + if swaps { swap_s } else { 0.0 }
                }
                // No projection exists before a measured forward
                // runs; bound by the gap after the fact instead.
                ClockModel::Measured => 0.0,
            };
            if now + projected > until {
                break;
            }
            // Capacity: take only what the free list offers.
            let got = match seq.as_mut() {
                None => match self.kv.try_alloc(toks) {
                    Some(s) => {
                        seq = Some(s);
                        true
                    }
                    None => false,
                },
                Some(s) => self.kv.grow(s, toks),
            };
            if !got {
                break;
            }
            let (wall_step_s, swapped) =
                self.forward_step(tenant, toks)?;
            self.stats.steps += 1;
            self.events.set_step(self.stats.steps);
            let step_s = match clock {
                ClockModel::Measured => wall_step_s,
                ClockModel::Analytic { swap_s, batch_s, token_s } => {
                    batch_s + token_s * toks as f64
                        + if swapped { swap_s } else { 0.0 }
                }
            };
            now += step_s;
            self.events.set_now(now);
            warmed += toks;
            if let Some(p) = self.profiler.as_mut() {
                // Speculative warm time is service time spent on the
                // prefix cache — attribute the whole step there.
                p.add(Phase::Prefix, step_s, wall_step_s);
                p.add_step(step_s);
            }
            self.stats.prefetch_tokens += toks as u64;
            self.events.emit(EventKind::Prefetch, Some(tenant.0),
                             None, toks as u64,
                             (want - warmed) as u64);
            self.kv_timeline.record(
                self.kv.used_blocks() as u64,
                self.kv.resident_tokens() as u64,
                self.kv.reclaimable_blocks() as u64);
            if now >= until {
                break;
            }
        }
        if let Some(s) = seq {
            let fresh = self.registry
                .generation(self.pool.name(tenant));
            if warmed > 0 && fresh == gen {
                // A partial warm donates its partial chain — later
                // prefills attach the covered part and compute the
                // rest.
                let before = self.prefix.stats.donated_blocks;
                self.prefix.donate(tenant, gen, &s, warmed,
                                   &mut self.kv);
                let blocks =
                    self.prefix.stats.donated_blocks - before;
                self.stats.prefetch_donated_blocks += blocks;
                self.events.emit(EventKind::PrefetchDonate,
                                 Some(tenant.0), None, blocks,
                                 warmed as u64);
            }
            self.kv.release(s);
        }
        Ok(now)
    }

    /// Decode-style iteration-level batching: the unit of service is
    /// ONE token step over the in-flight slots (at most the
    /// scheduler's batch size, bounded by its `max_batch_tokens` step
    /// budget). A step prefills every freshly dispatched slot (whole
    /// prompt, emitting the first token) and advances every decoding
    /// slot by one token; completed slots free mid-batch, and pending
    /// same-tenant requests — including arrivals admitted
    /// mid-generation — join the live batch through
    /// [`OnlineScheduler::join_live`] instead of waiting for the
    /// batch to drain.
    ///
    /// Records everything `serve_online` records, plus TTFT (arrival →
    /// first token), TPOT (per output token after the first), and the
    /// per-step batch-occupancy timeline.
    ///
    /// Reduction anchor: with prefill-only requests and no token
    /// budget, every dispatched batch completes in exactly one step,
    /// so the loop issues the same forwards as `serve_online` — same
    /// checksum, same swaps, same token counts (property-tested).
    pub fn serve_iterative(&mut self, sched: &mut OnlineScheduler,
                           clock: ClockModel) -> Result<()> {
        let mut st = self.begin_iterative(sched, clock);
        while self.step_iterative(sched, &mut st)? {}
        self.end_iterative(st);
        Ok(())
    }

    /// Open an externally-driven iteration-level run: the carved-out
    /// prologue of [`ServeEngine::serve_iterative`]. The caller owns
    /// the returned [`IterState`] and drives the engine one
    /// [`ServeEngine::step_iterative`] at a time — this is how the
    /// multi-replica cluster steps N engines on one merged virtual
    /// clock. `serve_iterative` is exactly
    /// `begin → while step → end`, so the single-engine path and the
    /// `--replicas 1` cluster are the same code, bit for bit.
    pub fn begin_iterative(&mut self, sched: &mut OnlineScheduler,
                           clock: ClockModel) -> IterState {
        // Calibrate BEFORE the first admission — see `serve_online`.
        sched.events = self.events.clone();
        self.calibrate(sched, clock);
        IterState {
            wall0: Instant::now(),
            slot_cap: sched.batch_size(),
            budget: sched.max_batch_tokens,
            now: 0.0,
            slots: Vec::new(),
            last_step_s: 0.0,
            clock,
        }
    }

    /// Close an externally-driven run: settle the wall/virtual clocks
    /// into the engine stats (the carved-out epilogue of
    /// `serve_iterative`).
    pub fn end_iterative(&mut self, st: IterState) {
        self.stats.virtual_s += st.now;
        self.stats.wall_s += st.wall0.elapsed().as_secs_f64();
    }

    /// One full iteration of the `serve_iterative` loop body:
    /// admission, dispatch-or-join (or the idle clock jump), KV
    /// growth, ONE forward step, and slot advancement/completion.
    /// Returns `Ok(false)` when the run is complete (the monolithic
    /// loop's `break`), `Ok(true)` when there is more to do —
    /// including iterations that only shed or seat slots without
    /// forwarding (the monolithic loop's `continue`).
    pub fn step_iterative(&mut self, sched: &mut OnlineScheduler,
                          st: &mut IterState) -> Result<bool> {
        {
            let t_adm = self.profiler.as_ref().and_then(|p| p.begin());
            self.events.set_now(st.now);
            sched.admit(st.now);
            self.sync_kv_gate(sched);
            if let Some(p) = self.profiler.as_mut() {
                // Admission is pure bookkeeping on the virtual clock
                // (the clock only moves on forwards and idle jumps) —
                // 0 virtual seconds, wall measured when armed.
                p.end(Phase::Admission, t_adm, 0.0);
            }
            if st.slots.is_empty() {
                if sched.pending_len() == 0 {
                    match sched.next_arrival() {
                        // Idle: event-jump to the next arrival —
                        // after spending the gap on speculative
                        // prefix prefetch when armed.
                        Some(t) => {
                            if self.prefetch {
                                st.now = self.prefetch_gap(
                                    sched, st.clock, st.now, t)?;
                            }
                            st.now = st.now.max(t);
                            self.events.set_now(st.now);
                            sched.admit(st.now);
                        }
                        None => return Ok(false),
                    }
                }
                self.calibrate(sched, st.clock);
                self.sync_kv_gate(sched);
                let t_disp = self.profiler.as_ref()
                    .and_then(|p| p.begin());
                let live = self.current_tenant_id();
                let Some(batch) = sched.dispatch(live, st.now) else {
                    return Ok(false);
                };
                self.seat(&mut st.slots, batch.requests, st.now);
                if let Some(p) = self.profiler.as_mut() {
                    // Dispatch's VIRTUAL cost (per-step overhead +
                    // swap) is attributed where the clock charges it,
                    // after the forward; the stamp pair carries its
                    // wall time.
                    p.end(Phase::Dispatch, t_disp, 0.0);
                }
                if st.slots.is_empty() {
                    return Ok(true);
                }
            } else {
                let live = st.slots[0].req.tenant;
                // Slo-aware preemption: when an OTHER tenant's
                // deadline is still rescuable (non-negative penalized
                // slack — evicting for an already-doomed request buys
                // nothing and pays recompute) but cannot survive the
                // live batch's natural drain (slack below the
                // projected drain time at the last step's pace), shed
                // the least-urgent decoding slot — one per step, so
                // the rest of the batch still progresses — and stop
                // admitting joiners that would prolong the batch.
                // Only a DEADLINE-FREE slot is ever shed for urgency
                // (infinite slack: a background generation that loses
                // nothing but recompute); evicting a deadlined slot
                // to save another just moves the miss around —
                // validated by simulation, it thrashes. Once the
                // batch drains, the urgent tenant dispatches into the
                // freed blocks.
                let drain_s = st.slots.iter().map(|s| {
                    // Mid-prompt slots owe their remaining chunk
                    // steps before any decode (chunked only; equals
                    // s.remaining in the PR-6 regime).
                    let chunks = if self.prefill_chunk > 0 {
                        (s.prefill_tokens - s.prefill_done)
                            .div_ceil(self.prefill_chunk)
                    } else {
                        0
                    };
                    s.remaining + chunks
                }).max().unwrap_or(0) as f64 * st.last_step_s;
                let urgent_slack = if self.preempting()
                    && sched.policy() == Policy::SloAware
                {
                    sched.urgent_other_slack(Some(live), st.now)
                        .filter(|s| (0.0..drain_s).contains(s))
                } else {
                    None
                };
                if urgent_slack.is_some() {
                    let t_disp = self.profiler.as_ref()
                        .and_then(|p| p.begin());
                    let victim = Self::pick_victim(
                        &st.slots, None, st.now, sched.decode_slack_s,
                        self.prefill_chunk > 0)
                        .filter(|(_, slack)| slack.is_infinite());
                    if let Some((idx, _)) = victim {
                        self.evict_slot(&mut st.slots, idx, sched,
                                        false);
                    }
                    if let Some(p) = self.profiler.as_mut() {
                        p.end(Phase::Dispatch, t_disp, 0.0);
                    }
                    if st.slots.is_empty() {
                        // Batch fully shed: dispatch next.
                        return Ok(true);
                    }
                } else if st.slots.len() < st.slot_cap
                    && sched.pending_len() > 0
                {
                    let t_disp = self.profiler.as_ref()
                        .and_then(|p| p.begin());
                    // Continuous batching mid-generation: every
                    // in-flight slot costs one step token, the rest of
                    // the budget is open for same-tenant prefills to
                    // join (capacity-gated through the scheduler's
                    // kv_free_blocks — a join never over-commits).
                    let spare = if st.budget == 0 {
                        usize::MAX
                    } else {
                        // Charge every in-flight slot what THIS step
                        // will cost it (1 decode token, or its next
                        // prefill chunk) — in the PR-6 regime every
                        // slot charges exactly 1.
                        let held: usize = st.slots.iter()
                            .map(|s| Self::slot_step_tokens(
                                self.prefill_chunk, s))
                            .sum();
                        st.budget.saturating_sub(held)
                    };
                    let free = st.slot_cap - st.slots.len();
                    let joiners = sched.join_live(live, free, spare);
                    self.seat(&mut st.slots, joiners, st.now);
                    if let Some(p) = self.profiler.as_mut() {
                        p.end(Phase::Dispatch, t_disp, 0.0);
                    }
                }
            }

            // ---- KV growth: each decoding slot appends one token's
            // cache this step; with chunked prefill on, each
            // mid-prompt slot appends its NEXT chunk's cache (the
            // first chunk was allocated at seating). On pool
            // exhaustion, evict the least-urgent OTHER slot and retry
            // (memory-pressure preemption); with no victim left — or
            // with preemption off (drain-only) — the grower continues
            // CAPPED (ledgered overflow, never an over-commit).
            let chunk = self.prefill_chunk;
            let t_kv = self.profiler.as_ref().and_then(|p| p.begin());
            let grow_work: Vec<(u64, usize)> = st.slots.iter()
                .filter_map(|s| {
                    if s.prefilled {
                        Some((s.req.id, 1))
                    } else if chunk > 0 && s.prefill_done > 0 {
                        Some((s.req.id,
                              Self::slot_step_tokens(chunk, s)))
                    } else {
                        None // first chunk: allocated at seating
                    }
                })
                .collect();
            for (id, extra) in grow_work {
                'tokens: for _ in 0..extra {
                    loop {
                        let Some(i) = st.slots.iter()
                            .position(|s| s.req.id == id)
                        else {
                            // evicted as another's victim
                            break 'tokens;
                        };
                        if self.kv.grow(&mut st.slots[i].kv, 1) {
                            break;
                        }
                        // Under pressure the cache yields
                        // unreferenced blocks BEFORE any slot is
                        // preempted — reclaim and retry the grow.
                        if self.prefix.reclaim(1, &mut self.kv) > 0 {
                            continue;
                        }
                        let victim = if self.preempting() {
                            Self::pick_victim(&st.slots, Some(id),
                                              st.now,
                                              sched.decode_slack_s,
                                              chunk > 0)
                        } else {
                            None
                        };
                        match victim {
                            Some((v, _)) => {
                                self.evict_slot(&mut st.slots, v,
                                                sched, true);
                            }
                            None => {
                                self.kv.overflow(1);
                                break;
                            }
                        }
                    }
                }
            }
            if let Some(p) = self.profiler.as_mut() {
                // KV growth (incl. reclaim + memory-pressure
                // eviction) is bookkeeping on the virtual clock.
                p.end(Phase::KvGrow, t_kv, 0.0);
            }

            // ---- one iteration step over the in-flight batch ----
            let tenant = st.slots[0].req.tenant;
            // Freshly seated slots charge only their UNCACHED prompt
            // suffix — matched prefix KV is attached, not recomputed
            // (with no cache hit, prefill_tokens == the full prompt,
            // the PR-4 charge) — capped at one chunk when chunked
            // prefill is on.
            let step_tokens: usize = st.slots.iter()
                .map(|s| Self::slot_step_tokens(chunk, s))
                .sum();
            let (wall_step_s, swapped) =
                self.forward_step(tenant, step_tokens)?;
            self.stats.steps += 1;
            self.events.set_step(self.stats.steps);
            let step_s = match st.clock {
                ClockModel::Measured => wall_step_s,
                ClockModel::Analytic { swap_s, batch_s, token_s } => {
                    batch_s
                        + token_s * step_tokens as f64
                        + if swapped { swap_s } else { 0.0 }
                }
            };
            st.now += step_s;
            st.last_step_s = step_s;
            if self.profiler.is_some() {
                // Partition THIS step's service time across phases
                // exactly: the analytic clock's terms map one-to-one
                // (swap + per-step overhead → dispatch, the token
                // term split by what each token was — prefill chunk
                // vs decode); a measured step has no analytic
                // decomposition, so its whole time splits by tokens.
                // Σ phase.virtual_s == Σ step_s is the
                // no-unattributed-time property the tests assert.
                let prefill_tok: usize = st.slots.iter()
                    .filter(|s| !s.prefilled)
                    .map(|s| Self::slot_step_tokens(chunk, s))
                    .sum();
                let decode_tok = step_tokens - prefill_tok;
                let (sw, oh, tok_part) = match st.clock {
                    ClockModel::Analytic {
                        swap_s, batch_s, token_s } =>
                        (if swapped { swap_s } else { 0.0 }, batch_s,
                         token_s * step_tokens as f64),
                    ClockModel::Measured => (0.0, 0.0, step_s),
                };
                let p = self.profiler.as_mut().unwrap();
                if step_tokens == 0 {
                    p.add(Phase::Dispatch, sw + oh + tok_part, 0.0);
                } else {
                    let tok = step_tokens as f64;
                    let pf = prefill_tok as f64 / tok;
                    let df = decode_tok as f64 / tok;
                    p.add(Phase::Dispatch, sw + oh, 0.0);
                    p.add(Phase::Prefill, tok_part * pf,
                          wall_step_s * pf);
                    p.add(Phase::Decode, tok_part * df,
                          wall_step_s * df);
                }
                p.add_step(step_s);
            }
            self.events.set_now(st.now);
            self.occupancy.record(st.slots.len() as u64,
                                  step_tokens as u64);
            self.kv_timeline.record(
                self.kv.used_blocks() as u64,
                self.kv.resident_tokens() as u64,
                self.kv.reclaimable_blocks() as u64);
            let name = self.pool.name(tenant);

            // Advance every slot by one token; completed slots leave
            // the batch and settle their metrics.
            let mut i = 0;
            while i < st.slots.len() {
                if !st.slots[i].prefilled {
                    if chunk > 0 {
                        // Chunked: this step computed one chunk of
                        // the prompt. A non-final chunk just records
                        // progress; the final chunk falls through to
                        // the PrefillEnd emission below.
                        let owed = st.slots[i].prefill_tokens
                            - st.slots[i].prefill_done;
                        let this = owed.min(chunk);
                        st.slots[i].prefill_done += this;
                        self.stats.prefill_chunks += 1;
                        self.events.emit(
                            EventKind::PrefillChunk,
                            Some(st.slots[i].req.tenant.0),
                            Some(st.slots[i].req.id), this as u64,
                            (owed - this) as u64);
                        if owed > this {
                            i += 1;
                            continue; // more chunks owed
                        }
                    } else {
                        st.slots[i].prefill_done =
                            st.slots[i].prefill_tokens;
                    }
                    st.slots[i].prefilled = true;
                    if !st.slots[i].emit_first {
                        // Recompute replay: every token of this
                        // prefill was emitted in an earlier residency
                        // — nothing new leaves the engine, so TTFT
                        // stays settled and emission exactly-once.
                        self.events.emit(
                            EventKind::PrefillEnd,
                            Some(st.slots[i].req.tenant.0),
                            Some(st.slots[i].req.id), 0,
                            st.slots[i].prefill_tokens as u64);
                    } else {
                        st.slots[i].first_token_s = st.now;
                        let first_s = (st.now
                            - st.slots[i].req.arrival_s).max(0.0);
                        self.ttft.record(name, first_s);
                        self.ttft.record("(all)", first_s);
                        self.events.emit(
                            EventKind::PrefillEnd,
                            Some(st.slots[i].req.tenant.0),
                            Some(st.slots[i].req.id), 1,
                            st.slots[i].prefill_tokens as u64);
                    }
                } else {
                    st.slots[i].remaining -= 1;
                    self.events.emit(
                        EventKind::DecodeStep,
                        Some(st.slots[i].req.tenant.0),
                        Some(st.slots[i].req.id), 1,
                        st.slots[i].remaining as u64);
                }
                if st.slots[i].remaining > 0 {
                    i += 1;
                    continue;
                }
                let mut s = st.slots.swap_remove(i);
                let seq = std::mem::take(&mut s.kv);
                self.retire_seq(&s.req, seq);
                // A preempted request's own fields were rewritten for
                // the replay; TTFT/TPOT settle against the originals
                // pinned in the resume map.
                let (first_token_s, decode_total) =
                    match self.resume.remove(&s.req.id) {
                        // A mid-prompt-evicted request's first token
                        // left during THIS residency (None in the
                        // map) — settle against the slot's own stamp.
                        Some(r) => (r.first_token_s
                                        .unwrap_or(s.first_token_s),
                                    r.orig_decode),
                        None => (s.first_token_s,
                                 s.req.decode_tokens),
                    };
                let service_s = (st.now - s.dispatched_s).max(0.0);
                let e2e_s = (st.now - s.req.arrival_s).max(0.0);
                self.service.record(name, service_s);
                self.service.record("(all)", service_s);
                self.e2e.record(name, e2e_s);
                self.e2e.record("(all)", e2e_s);
                if decode_total > 0 {
                    let per_tok = (st.now - first_token_s).max(0.0)
                        / decode_total as f64;
                    self.tpot.record(name, per_tok);
                    self.tpot.record("(all)", per_tok);
                }
                if s.req.deadline_s.is_finite() {
                    self.stats.deadline_total += 1;
                    let dl = s.req.absolute_deadline();
                    let missed = st.now > dl;
                    if missed {
                        self.stats.deadline_misses += 1;
                    }
                    // SLO settlement: charge the tenant's rolling
                    // burn budget while the slot is still live —
                    // before `Complete`, per the kind's contract.
                    self.events.emit(
                        EventKind::SloBurn, Some(s.req.tenant.0),
                        Some(s.req.id), missed as u64,
                        if missed { ((st.now - dl) * 1e6) as u64 }
                        else { 0 });
                }
                self.timeline.record(st.now, 1,
                                     s.req.total_tokens() as u64);
                self.stats.requests += 1;
                self.events.emit(EventKind::Complete,
                                 Some(s.req.tenant.0), Some(s.req.id),
                                 (1 + decode_total) as u64, 0);
            }
        }
        Ok(true)
    }

    /// Advertised-load snapshot for the cluster router: queue depth,
    /// free KV blocks, and per-tenant warm radix-prefix tokens. Pure
    /// observation — reading a replica's load never perturbs it.
    pub fn load_snapshot(&self, sched: &OnlineScheduler,
                         st: &IterState) -> LoadSnapshot {
        let bt = self.kv.block_tokens();
        let warm_tokens = (0..self.pool.len())
            .map(|i| {
                if self.prefix.enabled() {
                    let (full, tail) =
                        self.prefix.cover(TenantId(i as u32), bt);
                    full * bt + tail
                } else {
                    0
                }
            })
            .collect();
        LoadSnapshot {
            pending: sched.pending_len(),
            in_flight: st.slots.len(),
            free_blocks: if self.kv.is_bounded() {
                self.kv.available_blocks()
            } else {
                usize::MAX
            },
            warm_tokens,
        }
    }

    /// Failover evacuation: evict EVERY seated slot with the
    /// `Failover` cause and return the requeue-ready requests in seat
    /// order. Each eviction runs the full PR-5/PR-7 discipline — KV
    /// released (shared-prefix tail donated to the radix cache),
    /// resume entry pinned, Preempt event (a = 2) emitted on THIS
    /// engine's stream — so a survivor replays them through the
    /// ordinary `requeue` path with exactly-once emission.
    pub fn evacuate(&mut self, st: &mut IterState) -> Vec<Request> {
        let mut out = Vec::with_capacity(st.slots.len());
        while !st.slots.is_empty() {
            let idx = st.slots.len() - 1;
            out.push(self.evict_core(&mut st.slots, idx,
                                     EvictCause::Failover));
        }
        out.reverse(); // evicted back-to-front; restore seat order
        out
    }

    /// Drain this engine's recompute-on-resume state for migration,
    /// sorted by request id so the transfer is deterministic.
    pub fn export_resume(&mut self) -> Vec<(u64, ResumeInfo)> {
        let mut v: Vec<_> =
            std::mem::take(&mut self.resume).into_iter().collect();
        v.sort_by_key(|(id, _)| *id);
        v
    }

    /// Adopt migrated resume state: the survivor settles TTFT/TPOT of
    /// failed-over requests against their ORIGINAL stamps, exactly as
    /// if the preemption had happened locally.
    pub fn import_resume(&mut self, entries: Vec<(u64, ResumeInfo)>) {
        self.resume.extend(entries);
    }

    pub fn throughput_req_per_s(&self) -> f64 {
        self.stats.requests as f64 / self.stats.wall_s.max(1e-12)
    }

    pub fn throughput_tok_per_s(&self) -> f64 {
        self.stats.tokens as f64 / self.stats.wall_s.max(1e-12)
    }

    /// Requests per second of virtual time — the load-meaningful
    /// throughput of an online run (wall time also counts admission
    /// idle gaps the virtual clock jumps over).
    pub fn virtual_req_per_s(&self) -> f64 {
        self.stats.requests as f64 / self.stats.virtual_s.max(1e-12)
    }

    /// Un-splice the live adapter and verify the shared frozen base is
    /// byte-identical to its pre-serving state.
    pub fn finish(&mut self) -> Result<()> {
        if let Some((tenant, guard)) = self.current.take() {
            guard.restore(&mut self.base.weights)?;
            self.events.emit(EventKind::SpliceOut, Some(tenant.0),
                             None, 0, 0);
        }
        let fp = self.base.fingerprint();
        if fp != self.baseline_fp {
            return Err(anyhow!(
                "shared base corrupted after un-merge: fingerprint \
                 {fp:016x} != baseline {:016x}", self.baseline_fp));
        }
        // The prefix cache's holds are pool references too: flush it
        // so the leak check below sees a quiescent pool. (Live caches
        // between runs are an engine-lifetime optimization; a drained
        // engine owns nothing.)
        self.prefix.clear(&mut self.kv);
        if self.kv.used_blocks() != 0 {
            return Err(anyhow!(
                "kv pool leaked {} blocks ({} resident tokens) after \
                 drain", self.kv.used_blocks(),
                self.kv.resident_tokens()));
        }
        // Beyond live blocks: every minted block must be back on the
        // free list — a leaked refcount (double-share, lost unref)
        // fails here even when the block ledger looks clean.
        self.kv.leak_check()
            .map_err(|e| anyhow!("kv pool after drain: {e}"))?;
        if !self.resume.is_empty() {
            return Err(anyhow!(
                "{} preempted requests never resumed to completion",
                self.resume.len()));
        }
        // End-of-run auditor sweep: open lifecycles, a live splice,
        // or a non-zero KV ledger become violations here.
        self.events.finalize();
        Ok(())
    }

    pub fn report(&self) -> String {
        let s = &self.stats;
        let mut out = format!(
            "backend {} | {} requests in {} batches | {} tenants in \
             registry | {} swaps ({:.1}ms total, {:.1}% of wall)\n",
            self.backend_name(), s.requests, s.batches,
            self.registry.len(), s.swaps, s.swap_s * 1e3,
            100.0 * s.swap_s / s.wall_s.max(1e-12));
        if s.truncated_tokens > 0 {
            let cap = match self.backend.token_cap() {
                Some(c) => format!("host cap {c} tokens/forward — \
                                    raise --host-max-tokens or"),
                None => "fixed backend geometry —".to_string(),
            };
            out.push_str(&format!(
                "backend truncation: {} requested tokens not computed \
                 across {} batches ({cap} shrink --batch or \
                 --mean-tokens to serve full prompts)\n",
                s.truncated_tokens, s.truncated_batches));
        }
        out.push('\n');
        if self.latencies.count("(all)") > 0 {
            out.push_str("offline replay latency (swap + forward per \
                          batch):\n");
            out.push_str(&self.latencies.table("tenant").render());
            out.push('\n');
        }
        if self.e2e.count("(all)") > 0 {
            out.push_str("online pipeline (virtual clock — queueing \
                          is arrival→dispatch):\n");
            out.push_str(&latency_breakdown_table(
                &self.queueing, &self.service, &self.e2e,
                "tenant").render());
            if s.deadline_total > 0 {
                out.push_str(&format!(
                    "deadline misses: {}/{} ({:.1}%)\n",
                    s.deadline_misses, s.deadline_total,
                    100.0 * s.deadline_misses as f64
                        / s.deadline_total as f64));
            }
            out.push_str(&format!(
                "virtual makespan {:.3}s | {:.1} req/s virtual \
                 (peak bucket {:.1} req/s)\n",
                s.virtual_s, self.virtual_req_per_s(),
                self.timeline.peak_req_per_s()));
            if self.timeline.n_buckets() <= 24 {
                out.push_str(&self.timeline.table().render());
            }
            out.push('\n');
        }
        if self.ttft.count("(all)") > 0 {
            out.push_str("iteration-level decode (TTFT = arrival → \
                          first token; TPOT = s per output token \
                          after the first):\n");
            let ms = |v: Option<f64>| match v {
                Some(v) => format!("{:.3}ms", v * 1e3),
                None => "-".to_string(),
            };
            let mut t = Table::new(&["tenant", "n", "ttft p50",
                                     "ttft p99", "tpot p50",
                                     "tpot p99"]);
            for key in self.ttft.keys() {
                t.row(&[key.to_string(),
                        self.ttft.count(key).to_string(),
                        ms(self.ttft.percentile(key, 0.50)),
                        ms(self.ttft.percentile(key, 0.99)),
                        ms(self.tpot.percentile(key, 0.50)),
                        ms(self.tpot.percentile(key, 0.99))]);
            }
            out.push_str(&t.render());
            out.push_str(&format!(
                "{} iteration steps | batch occupancy mean {:.1} / \
                 peak {} slots | step tokens mean {:.0} / peak {}\n",
                s.steps, self.occupancy.mean_slots(),
                self.occupancy.peak_slots(),
                self.occupancy.mean_tokens(),
                self.occupancy.peak_tokens()));
            if self.prefill_chunk > 0 {
                out.push_str(&format!(
                    "prefill chunks: {} steps ({} tokens/chunk cap) \
                     | {} prompts split | {} mid-prompt \
                     preemptions\n",
                    s.prefill_chunks, self.prefill_chunk,
                    s.chunked_prefills, s.preempt_prefill));
            }
            out.push('\n');
        }
        if self.kv.is_bounded() {
            let ks = &self.kv.stats;
            // The pinned-vs-reclaimable split only exists with the
            // cache on; keep the off-mode line byte-identical to the
            // PR-4 report.
            let reclaim_note = if self.prefix.enabled() {
                format!(" | cache-only peak {} mean {:.1}",
                        ks.peak_reclaimable,
                        self.kv_timeline.mean_reclaimable())
            } else {
                String::new()
            };
            out.push_str(&format!(
                "kv cache: {} | occupancy peak {}/{} blocks \
                 ({:.1}%) mean {:.1} | resident tokens peak {} | \
                 frag mean {:.1}%{reclaim_note}\n",
                self.kv.describe(), ks.peak_blocks,
                self.kv.n_blocks(),
                100.0 * ks.peak_blocks as f64
                    / self.kv.n_blocks() as f64,
                self.kv_timeline.mean_blocks(), ks.peak_tokens,
                100.0 * self.kv_timeline.mean_frag_frac(
                    self.kv.block_tokens())));
            out.push_str(&format!(
                "preemptions: {} (memory {}, deadline {}) | \
                 recompute {} tokens | grow fails {} | clamped \
                 allocs {} | overflow {} tokens{}\n\n",
                s.preemptions, s.preempt_memory, s.preempt_deadline,
                s.kv_recompute_tokens, ks.grow_fails,
                ks.alloc_clamps, ks.overflow_tokens,
                if self.preempt { "" } else { " | drain-only" }));
        }
        if self.prefix.enabled() && self.stats.steps > 0 {
            let ps = &self.prefix.stats;
            let pct = if self.stats.prefill_tokens > 0 {
                100.0 * ps.hit_tokens as f64
                    / self.stats.prefill_tokens as f64
            } else {
                0.0
            };
            out.push_str(&format!(
                "prefix cache: {} hits / {} lookups | {} prompt \
                 tokens served from cache ({:.1}% of prefill) | \
                 donated {} blocks | reclaimed {} | cow forks {} | \
                 invalidated {} subtrees\n\n",
                ps.hits, ps.lookups, ps.hit_tokens, pct,
                ps.donated_blocks, ps.reclaimed_blocks,
                self.kv.stats.cow_forks, ps.invalidations));
        }
        if self.prefetch && self.stats.steps > 0 {
            out.push_str(&format!(
                "speculative prefetch: {} tokens warmed in idle gaps \
                 | {} blocks donated\n\n",
                s.prefetch_tokens, s.prefetch_donated_blocks));
        }
        // Profiler and SLO-burn blocks exist only when their feature
        // is armed — off-mode reports stay byte-identical.
        if let Some(p) = &self.profiler {
            if p.steps > 0 {
                out.push_str(&format!(
                    "step profile: {} steps, {:.3}s virtual service \
                     time ({:.3}s attributed)\n",
                    p.steps, p.step_virtual_s, p.total_virtual()));
                out.push_str(&p.table().render());
                out.push('\n');
            }
        }
        if self.events.enabled() {
            let burns = self.events.slo_summary();
            if !burns.is_empty() {
                out.push_str(&format!(
                    "slo burn (rolling window: last {} deadlined \
                     completions per tenant):\n",
                    crate::serve::telemetry::SLO_WINDOW));
                let mut t = Table::new(&["tenant", "settled",
                                         "missed", "window burn",
                                         "max late ms"]);
                for b in &burns {
                    t.row(&[
                        self.pool.name(TenantId(b.tenant)).to_string(),
                        b.total.to_string(),
                        b.missed.to_string(),
                        format!("{:.1}%", 100.0 * b.burn_rate()),
                        format!("{:.3}",
                                b.max_lateness_us as f64 / 1e3),
                    ]);
                }
                out.push_str(&t.render());
                out.push('\n');
            }
        }
        // Event-trace lines exist only when tracing is on: the
        // null-sink report stays byte-identical to the untraced one.
        if self.events.enabled() {
            let violations = self.events.violation_count();
            let verdict = if violations == 0 {
                "auditor clean".to_string()
            } else {
                format!("auditor: {violations} VIOLATIONS")
            };
            out.push_str(&format!(
                "event trace: {} events | {}\n",
                self.events.total(), verdict));
            for v in self.events.violations() {
                out.push_str(&format!("  violation: {v}\n"));
            }
            out.push('\n');
        }
        out.push_str(&format!(
            "aggregate: {:.1} req/s, {:.0} tok/s \
             (forward {:.1}ms, swap {:.1}ms, wall {:.1}ms)\n",
            self.throughput_req_per_s(), self.throughput_tok_per_s(),
            s.forward_s * 1e3, s.swap_s * 1e3, s.wall_s * 1e3));
        out
    }

    /// The engine report as machine-readable JSON (`paca serve
    /// --report-json PATH`): the same latency/TTFT/TPOT/kv/preemption/
    /// hit-rate counters the text report renders, for CI greps and
    /// bench tooling. Latency sections appear only when they recorded
    /// samples (like the text report's conditional blocks).
    pub fn report_json(&self) -> Json {
        let s = &self.stats;
        let num = |v: f64| Json::Num(v);
        let mut root = BTreeMap::new();
        // Report-schema version: bump when a key is renamed or
        // removed; adding keys is not a bump (consumers must ignore
        // unknown keys — round-trip-tested). 2 = the telemetry
        // release: a gated `metrics` section (registry snapshot,
        // dropped-event accounting, profiler totals, slo burn)
        // joined the report; every schema-1 key is unchanged.
        root.insert("schema".to_string(), num(2.0));
        root.insert("backend".to_string(),
                    Json::Str(self.backend_name().to_string()));
        root.insert("requests".to_string(), num(s.requests as f64));
        root.insert("batches".to_string(), num(s.batches as f64));
        root.insert("steps".to_string(), num(s.steps as f64));
        root.insert("swaps".to_string(), num(s.swaps as f64));
        root.insert("tokens".to_string(), num(s.tokens as f64));
        root.insert("prefill_tokens".to_string(),
                    num(s.prefill_tokens as f64));
        root.insert("truncated_tokens".to_string(),
                    num(s.truncated_tokens as f64));
        root.insert("virtual_s".to_string(), num(s.virtual_s));
        root.insert("wall_s".to_string(), num(s.wall_s));
        let mut deadline = BTreeMap::new();
        deadline.insert("total".to_string(),
                        num(s.deadline_total as f64));
        deadline.insert("misses".to_string(),
                        num(s.deadline_misses as f64));
        root.insert("deadline".to_string(), Json::Obj(deadline));

        let mut latency = BTreeMap::new();
        let sections: [(&str, &LatencyRecorder); 6] = [
            ("offline", &self.latencies), ("queueing", &self.queueing),
            ("service", &self.service), ("e2e", &self.e2e),
            ("ttft", &self.ttft), ("tpot", &self.tpot)];
        for (name, rec) in sections {
            if rec.count("(all)") == 0 {
                continue;
            }
            let mut o = BTreeMap::new();
            o.insert("n".to_string(),
                     num(rec.count("(all)") as f64));
            for (k, q) in [("p50_ms", 0.50), ("p99_ms", 0.99)] {
                if let Some(v) = rec.percentile("(all)", q) {
                    o.insert(k.to_string(), num(v * 1e3));
                }
            }
            if let Some(m) = rec.mean("(all)") {
                o.insert("mean_ms".to_string(), num(m * 1e3));
            }
            latency.insert(name.to_string(), Json::Obj(o));
        }
        root.insert("latency".to_string(), Json::Obj(latency));

        let ks = &self.kv.stats;
        let mut kv = BTreeMap::new();
        kv.insert("blocks".to_string(),
                  num(self.kv.n_blocks() as f64));
        kv.insert("block_tokens".to_string(),
                  num(self.kv.block_tokens() as f64));
        kv.insert("peak_blocks".to_string(),
                  num(ks.peak_blocks as f64));
        kv.insert("peak_tokens".to_string(),
                  num(ks.peak_tokens as f64));
        kv.insert("peak_reclaimable".to_string(),
                  num(ks.peak_reclaimable as f64));
        kv.insert("grow_fails".to_string(), num(ks.grow_fails as f64));
        kv.insert("alloc_clamps".to_string(),
                  num(ks.alloc_clamps as f64));
        kv.insert("overflow_tokens".to_string(),
                  num(ks.overflow_tokens as f64));
        kv.insert("cow_forks".to_string(), num(ks.cow_forks as f64));
        root.insert("kv".to_string(), Json::Obj(kv));

        let mut pre = BTreeMap::new();
        pre.insert("total".to_string(), num(s.preemptions as f64));
        pre.insert("memory".to_string(),
                   num(s.preempt_memory as f64));
        pre.insert("deadline".to_string(),
                   num(s.preempt_deadline as f64));
        pre.insert("recompute_tokens".to_string(),
                   num(s.kv_recompute_tokens as f64));
        root.insert("preemptions".to_string(), Json::Obj(pre));

        if self.prefill_chunk > 0 {
            let mut c = BTreeMap::new();
            c.insert("chunk_tokens".to_string(),
                     num(self.prefill_chunk as f64));
            c.insert("chunks".to_string(),
                     num(s.prefill_chunks as f64));
            c.insert("chunked_prompts".to_string(),
                     num(s.chunked_prefills as f64));
            c.insert("preempt_prefill".to_string(),
                     num(s.preempt_prefill as f64));
            root.insert("chunked_prefill".to_string(), Json::Obj(c));
        }
        if self.prefetch {
            let mut p = BTreeMap::new();
            p.insert("tokens".to_string(),
                     num(s.prefetch_tokens as f64));
            p.insert("donated_blocks".to_string(),
                     num(s.prefetch_donated_blocks as f64));
            root.insert("prefetch".to_string(), Json::Obj(p));
        }

        if self.prefix.enabled() && s.steps > 0 {
            let ps = &self.prefix.stats;
            let mut p = BTreeMap::new();
            p.insert("lookups".to_string(), num(ps.lookups as f64));
            p.insert("hits".to_string(), num(ps.hits as f64));
            p.insert("hit_tokens".to_string(),
                     num(ps.hit_tokens as f64));
            p.insert("hit_rate".to_string(),
                     num(if s.prefill_tokens > 0 {
                         ps.hit_tokens as f64
                             / s.prefill_tokens as f64
                     } else {
                         0.0
                     }));
            p.insert("donated_blocks".to_string(),
                     num(ps.donated_blocks as f64));
            p.insert("reclaimed_blocks".to_string(),
                     num(ps.reclaimed_blocks as f64));
            p.insert("invalidations".to_string(),
                     num(ps.invalidations as f64));
            root.insert("prefix_cache".to_string(), Json::Obj(p));
        }

        if self.events.enabled() {
            let mut ev = BTreeMap::new();
            ev.insert("total".to_string(),
                      num(self.events.total() as f64));
            let mut counts = BTreeMap::new();
            for (name, n) in self.events.counts() {
                counts.insert(name.to_string(), num(n as f64));
            }
            ev.insert("counts".to_string(), Json::Obj(counts));
            let violations = self.events.violation_count();
            ev.insert("auditor_violations".to_string(),
                      num(violations as f64));
            ev.insert("auditor".to_string(),
                      Json::Str(if violations == 0 {
                          "clean".to_string()
                      } else {
                          "violations".to_string()
                      }));
            root.insert("events".to_string(), Json::Obj(ev));

            // The telemetry section rides the same gate as the
            // events section: with tracing off the report is
            // byte-identical to schema 1 modulo the version number.
            let mut metrics = BTreeMap::new();
            metrics.insert("events_dropped".to_string(),
                           num(self.events.events_dropped() as f64));
            if let Some(reg) = self.events.metrics_registry() {
                metrics.insert("registry".to_string(),
                               reg.snapshot_json());
                metrics.insert(
                    "scrapes".to_string(),
                    num(self.events.metrics_scrapes() as f64));
            }
            if let Some(p) = &self.profiler {
                metrics.insert("profiler".to_string(), p.to_json());
            }
            let burns = self.events.slo_summary();
            if !burns.is_empty() {
                let mut slo = BTreeMap::new();
                for b in &burns {
                    let mut o = BTreeMap::new();
                    o.insert("settled".to_string(),
                             num(b.total as f64));
                    o.insert("missed".to_string(),
                             num(b.missed as f64));
                    o.insert("burn_rate".to_string(),
                             num(b.burn_rate()));
                    o.insert("max_lateness_us".to_string(),
                             num(b.max_lateness_us as f64));
                    slo.insert(self.pool.name(TenantId(b.tenant))
                               .to_string(), Json::Obj(o));
                }
                metrics.insert("slo_burn".to_string(),
                               Json::Obj(slo));
            }
            root.insert("metrics".to_string(), Json::Obj(metrics));
        }
        Json::Obj(root)
    }
}

/// Loop-carried state of an iteration-level run, carved out of
/// `serve_iterative` so an external driver (the multi-replica
/// cluster) can interleave steps of several engines on one merged
/// virtual clock. Fields are private — same-module engine code is
/// the only writer; drivers observe through the accessors.
pub struct IterState {
    wall0: Instant,
    slot_cap: usize,
    budget: usize,
    now: f64,
    slots: Vec<Slot>,
    last_step_s: f64,
    clock: ClockModel,
}

impl IterState {
    /// Current virtual time of this run.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Sequences currently seated in the batch.
    pub fn in_flight(&self) -> usize {
        self.slots.len()
    }
}

/// What a replica advertises to the cluster router. Snapshot-in-time:
/// taken at the routed request's arrival instant on the merged clock.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadSnapshot {
    /// Admitted-but-unseated requests queued on the replica.
    pub pending: usize,
    /// Sequences currently seated in the replica's batch.
    pub in_flight: usize,
    /// Free KV blocks (`usize::MAX` for an unbounded pool).
    pub free_blocks: usize,
    /// Per-tenant warm radix-prefix tokens (indexed by tenant id);
    /// all zeros with the prefix cache off.
    pub warm_tokens: Vec<usize>,
}

/// One in-flight sequence of the iteration-level loop.
struct Slot {
    req: Request,
    /// Decode tokens still to emit after the first.
    remaining: usize,
    /// False until the prompt has been prefilled (first token out).
    prefilled: bool,
    /// Whether this residency owes the request's FIRST output token:
    /// true for fresh seats and for mid-prompt-evicted replays (the
    /// evicted residency never emitted); false only for decode-evict
    /// replays, whose prefill is pure recompute and emits nothing.
    emit_first: bool,
    /// Virtual time the request entered its slot (queueing ends).
    dispatched_s: f64,
    /// Virtual time the first token came out (TTFT ends, TPOT
    /// starts).
    first_token_s: f64,
    /// Prompt tokens the prefill step actually computes — the full
    /// prompt, minus any prefix-cache hit (always ≥ 1).
    prefill_tokens: usize,
    /// Of those, tokens already computed by earlier chunks of THIS
    /// residency (chunked prefill; equals `prefill_tokens` once the
    /// slot is prefilled).
    prefill_done: usize,
    /// The sequence's paged KV blocks (grown one token per decode
    /// step, released at completion or eviction — shared-prefix
    /// blocks are donated to the tenant's radix cache).
    kv: KvSeq,
}

/// Real measured host forward over the target weights: qkv → gated
/// mixing → o → SwiGLU-style MLP → residual + RMS normalization per
/// layer. Returns a checksum of the final activations so the result
/// observably depends on which adapter is spliced in.
fn host_forward(base: &BaseModel, input: &[f32], tokens: usize) -> f64 {
    let d = base.model.d_model;
    let f = base.model.d_ff;
    let t = tokens;
    let mut xd = input[..t * d].to_vec();
    let sig = |v: f32| 1.0 / (1.0 + (-v).exp());
    for layer in 0..base.model.n_layers {
        let w = |tgt: &str| {
            base.weights[&format!("blocks/{layer}/{tgt}/w")].as_f32()
        };
        let q = merge::matmul(&xd, &w("q"), t, d, d);
        let k = merge::matmul(&xd, &w("k"), t, d, d);
        let v = merge::matmul(&xd, &w("v"), t, d, d);
        // Cheap token-local stand-in for attention mixing.
        let h: Vec<f32> = (0..t * d)
            .map(|i| q[i] * sig(k[i]) + v[i]).collect();
        let o = merge::matmul(&h, &w("o"), t, d, d);
        let g = merge::matmul(&o, &w("gate"), t, d, f);
        let u = merge::matmul(&o, &w("up"), t, d, f);
        let gu: Vec<f32> = (0..t * f)
            .map(|i| g[i] * sig(g[i]) * u[i]).collect();
        let down = merge::matmul(&gu, &w("down"), t, f, d);
        // Residual + per-row RMS normalization to keep scales bounded.
        for row in 0..t {
            let xrow = &mut xd[row * d..(row + 1) * d];
            let drow = &down[row * d..(row + 1) * d];
            let mut ss = 0f32;
            for (x, dv) in xrow.iter_mut().zip(drow) {
                *x += dv;
                ss += *x * *x;
            }
            let scale = 1.0 / (ss / d as f32 + 1e-6).sqrt();
            for x in xrow.iter_mut() {
                *x *= scale;
            }
        }
    }
    xd.iter().map(|v| v.abs() as f64).sum::<f64>() / (t * d) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::events::span_latencies;
    use crate::serve::registry::PacaAdapter;
    use crate::serve::scheduler::{plan, Policy, Request};
    use crate::serve::trace::{self, Trace, TraceSpec};

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    /// Engine whose registry holds an adapter for every tenant in the
    /// pool.
    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    fn bursty_trace() -> Trace {
        trace::synthesize(&TraceSpec {
            n_requests: 80, n_tenants: 5, deadline_ms: 30.0,
            burstiness: 3.0, ..Default::default()
        })
    }

    fn one_req_batch(pool: &mut TenantPool, name: &str,
                     tokens: usize) -> Batch {
        let tenant = pool.intern(name);
        Batch {
            tenant,
            requests: vec![Request {
                id: 0, tenant, tokens, decode_tokens: 0,
                shared_prefix_tokens: 0,
                arrival_s: 0.0, deadline_s: f64::INFINITY,
            }],
        }
    }

    #[test]
    fn serves_multi_tenant_trace_and_restores_base() {
        let spec = TraceSpec { n_requests: 80, n_tenants: 5,
                               ..Default::default() };
        let trace = trace::synthesize(&spec);
        let tenants = trace.tenant_names();
        assert!(tenants.len() >= 4, "need ≥4 tenants, got {tenants:?}");
        let mut eng = engine_for(trace.pool.clone());
        let batches = plan(trace.requests.clone(), 8,
                           Policy::SwapAware);
        eng.serve(&batches).unwrap();
        assert_eq!(eng.stats.requests, 80);
        assert!(eng.stats.swaps as usize >= tenants.len());
        for t in &tenants {
            assert!(eng.latencies.count(t) > 0, "no latency for {t}");
        }
        assert!(eng.throughput_req_per_s() > 0.0);
        eng.finish().unwrap(); // bit-exact base restore
        // A second pass over the restored base works identically.
        eng.serve(&batches).unwrap();
        eng.finish().unwrap();
    }

    #[test]
    fn online_serves_trace_and_restores_base() {
        let trace = bursty_trace();
        let n = trace.requests.len() as u64;
        let mut eng = engine_for(trace.pool.clone());
        let mut sched = OnlineScheduler::new(
            trace.requests, trace.pool.len(), 8, Policy::SloAware);
        eng.serve_online(&mut sched, ClockModel::Analytic {
            swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
        }).unwrap();
        assert!(sched.is_done());
        assert_eq!(eng.stats.requests, n);
        assert_eq!(eng.queueing.count("(all)") as u64, n);
        assert_eq!(eng.e2e.count("(all)") as u64, n);
        assert_eq!(eng.stats.deadline_total, n,
                   "every request carried a deadline");
        assert!(eng.stats.virtual_s > 0.0);
        assert_eq!(eng.timeline.total_requests(), n);
        // e2e = queueing + service, so the decomposition must order.
        let q50 = eng.queueing.percentile("(all)", 0.5).unwrap();
        let e50 = eng.e2e.percentile("(all)", 0.5).unwrap();
        assert!(e50 >= q50);
        let report = eng.report();
        assert!(report.contains("online pipeline"));
        assert!(report.contains("deadline misses"));
        eng.finish().unwrap();
    }

    #[test]
    fn online_analytic_clock_is_deterministic() {
        let clock = ClockModel::Analytic {
            swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
        };
        let run = || {
            let trace = bursty_trace();
            let mut eng = engine_for(trace.pool.clone());
            let mut sched = OnlineScheduler::new(
                trace.requests, trace.pool.len(), 8,
                Policy::SloAware);
            eng.serve_online(&mut sched, clock).unwrap();
            (eng.stats.virtual_s, eng.stats.deadline_misses,
             eng.stats.swaps,
             eng.queueing.percentile("(all)", 0.99).unwrap())
        };
        assert_eq!(run(), run(), "virtual-clock runs must be \
                                  bit-reproducible");
    }

    #[test]
    fn online_fully_arrived_matches_offline_serve() {
        // The engine-level anchor: both paths serve the same batches,
        // count the same swaps, and restore the base.
        let spec = TraceSpec { n_requests: 60, n_tenants: 4,
                               ..Default::default() };
        let trace = trace::synthesize(&spec);
        let mut at_zero = trace.requests.clone();
        for r in &mut at_zero {
            r.arrival_s = 0.0;
        }
        let mut off = engine_for(trace.pool.clone());
        off.serve(&plan(at_zero.clone(), 8, Policy::SwapAware))
            .unwrap();
        off.finish().unwrap();
        let mut on = engine_for(trace.pool.clone());
        let mut sched = OnlineScheduler::new(
            at_zero, trace.pool.len(), 8, Policy::SwapAware);
        on.serve_online(&mut sched, ClockModel::Measured).unwrap();
        on.finish().unwrap();
        assert_eq!(on.stats.swaps, off.stats.swaps);
        assert_eq!(on.stats.requests, off.stats.requests);
        assert_eq!(on.stats.batches, off.stats.batches);
        assert_eq!(on.checksum, off.checksum,
                   "same dispatch sequence ⇒ same forwards");
    }

    #[test]
    fn iterative_prefill_only_reduces_to_whole_batch() {
        // THE reduction anchor: with decode_tokens = 0 and a
        // fully-arrived queue, iteration-level serving issues exactly
        // the forwards whole-batch serving issues — token-for-token.
        let spec = TraceSpec { n_requests: 60, n_tenants: 4,
                               deadline_ms: 40.0, burstiness: 2.0,
                               ..Default::default() };
        let trace = trace::synthesize(&spec);
        let clock = ClockModel::Analytic {
            swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
        };
        let mut at_zero = trace.requests.clone();
        for r in &mut at_zero {
            r.arrival_s = 0.0;
        }
        for policy in Policy::ALL {
            let mut whole = engine_for(trace.pool.clone());
            let mut sched = OnlineScheduler::new(
                at_zero.clone(), trace.pool.len(), 8, policy);
            whole.serve_online(&mut sched, clock).unwrap();
            whole.finish().unwrap();
            let mut iter = engine_for(trace.pool.clone());
            let mut sched = OnlineScheduler::new(
                at_zero.clone(), trace.pool.len(), 8, policy);
            iter.serve_iterative(&mut sched, clock).unwrap();
            iter.finish().unwrap();
            assert_eq!(iter.checksum, whole.checksum,
                       "{policy:?}: same forwards ⇒ same checksum");
            assert_eq!(iter.stats.swaps, whole.stats.swaps,
                       "{policy:?}");
            assert_eq!(iter.stats.batches, whole.stats.batches,
                       "{policy:?}: one step per batch");
            assert_eq!(iter.stats.tokens, whole.stats.tokens,
                       "{policy:?}");
            assert_eq!(iter.stats.requests, whole.stats.requests,
                       "{policy:?}");
            assert_eq!(iter.stats.virtual_s, whole.stats.virtual_s,
                       "{policy:?}: identical analytic makespan");
        }
    }

    #[test]
    fn iterative_serves_decode_trace_and_restores_base() {
        let trace = trace::synthesize(&TraceSpec {
            n_requests: 60, n_tenants: 4, deadline_ms: 40.0,
            burstiness: 3.0, decode_tokens: 12,
            ..Default::default()
        });
        let n = trace.requests.len() as u64;
        let decode_reqs = trace.requests.iter()
            .filter(|r| r.decode_tokens > 0).count() as u64;
        let mut eng = engine_for(trace.pool.clone());
        let mut sched = OnlineScheduler::new(
            trace.requests, trace.pool.len(), 8, Policy::SloAware);
        eng.serve_iterative(&mut sched, ClockModel::Analytic {
            swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
        }).unwrap();
        assert!(sched.is_done());
        assert_eq!(eng.stats.requests, n);
        assert_eq!(eng.queueing.count("(all)") as u64, n);
        assert_eq!(eng.ttft.count("(all)") as u64, n);
        assert_eq!(eng.tpot.count("(all)") as u64, decode_reqs);
        assert_eq!(eng.e2e.count("(all)") as u64, n);
        assert_eq!(eng.stats.deadline_total, n);
        // Decode makes steps strictly outnumber dispatches, and every
        // step is on the occupancy timeline.
        assert!(eng.stats.steps > n / 8);
        assert_eq!(eng.occupancy.n_steps() as u64, eng.stats.steps);
        assert!(eng.occupancy.peak_slots() <= 8);
        // TTFT ≤ e2e at matching percentiles.
        for q in [0.5, 0.99] {
            assert!(eng.ttft.percentile("(all)", q).unwrap()
                    <= eng.e2e.percentile("(all)", q).unwrap());
        }
        let report = eng.report();
        assert!(report.contains("iteration-level decode"));
        assert!(report.contains("ttft p99"));
        eng.finish().unwrap();
    }

    #[test]
    fn late_same_tenant_arrival_joins_mid_generation() {
        // Request B (same tenant) arrives while A is decoding: it
        // must enter a free slot at the next step instead of waiting
        // for A's batch to drain — the whole point of iteration-level
        // batching.
        let mut pool = TenantPool::new();
        let t0 = pool.intern(&trace::tenant_name(0));
        let reqs = vec![
            Request { id: 0, tenant: t0, tokens: 4, decode_tokens: 10,
                      shared_prefix_tokens: 0,
                      arrival_s: 0.0, deadline_s: f64::INFINITY },
            Request { id: 1, tenant: t0, tokens: 2, decode_tokens: 0,
                      shared_prefix_tokens: 0,
                      arrival_s: 6e-3, deadline_s: f64::INFINITY },
        ];
        let mut eng = engine_for(pool);
        let mut sched = OnlineScheduler::new(reqs, 1, 4,
                                             Policy::SwapAware);
        eng.serve_iterative(&mut sched, ClockModel::Analytic {
            swap_s: 0.0, batch_s: 1e-3, token_s: 1e-3,
        }).unwrap();
        assert_eq!(eng.stats.requests, 2);
        assert_eq!(eng.occupancy.peak_slots(), 2,
                   "B must share the batch with A mid-generation");
        // B joined at the first step boundary after its arrival, so
        // its queueing delay is ~one decode step — far below A's
        // remaining ~20ms of generation, which a whole-batch unit of
        // service would have made it wait out.
        let worst_queue = eng.queueing.percentile("(all)", 1.0)
            .unwrap();
        assert!(worst_queue < 2e-3, "queued {worst_queue}s");
        // …and B (prefill-only) finishes long before A.
        let b_e2e = eng.e2e.percentile("(all)", 0.0).unwrap();
        let a_e2e = eng.e2e.percentile("(all)", 1.0).unwrap();
        assert!(b_e2e < 0.5 * a_e2e, "B {b_e2e}s vs A {a_e2e}s");
        assert_eq!(eng.tpot.count("(all)"), 1, "only A decodes");
        eng.finish().unwrap();
    }

    #[test]
    fn step_token_budget_bounds_occupancy() {
        let mut pool = TenantPool::new();
        let t0 = pool.intern(&trace::tenant_name(0));
        let reqs: Vec<Request> = (0..8).map(|id| Request {
            id, tenant: t0, tokens: 16, decode_tokens: 4,
            shared_prefix_tokens: 0,
            arrival_s: 0.0, deadline_s: f64::INFINITY,
        }).collect();
        let mut eng = engine_for(pool);
        let mut sched = OnlineScheduler::new(reqs, 1, 8,
                                             Policy::SwapAware);
        sched.max_batch_tokens = 40;
        eng.serve_iterative(&mut sched, ClockModel::Analytic {
            swap_s: 1e-3, batch_s: 5e-4, token_s: 2e-5,
        }).unwrap();
        assert_eq!(eng.stats.requests, 8);
        assert!(eng.occupancy.peak_tokens() <= 40,
                "step budget violated: {} tokens",
                eng.occupancy.peak_tokens());
        eng.finish().unwrap();
    }

    #[test]
    fn distinct_tenants_compute_distinct_outputs() {
        let mut pool = TenantPool::new();
        let b0 = one_req_batch(&mut pool, &trace::tenant_name(0), 32);
        let b1 = one_req_batch(&mut pool, &trace::tenant_name(1), 32);
        let mut e1 = engine_for(pool.clone());
        e1.run_batch(&b0).unwrap();
        let mut e2 = engine_for(pool.clone());
        e2.run_batch(&b1).unwrap();
        assert_ne!(e1.checksum, e2.checksum,
                   "spliced adapters must change the forward output");
        // …and the same tenant is deterministic.
        let mut e3 = engine_for(pool);
        e3.run_batch(&b0).unwrap();
        assert_eq!(e1.checksum, e3.checksum);
    }

    #[test]
    fn same_tenant_batches_skip_the_swap() {
        let mut pool = TenantPool::new();
        let b = one_req_batch(&mut pool, &trace::tenant_name(0), 8);
        let mut eng = engine_for(pool);
        eng.run_batch(&b).unwrap();
        eng.run_batch(&b).unwrap();
        assert_eq!(eng.stats.swaps, 1,
                   "consecutive same-tenant batches reuse the splice");
        eng.finish().unwrap();
    }

    #[test]
    fn host_truncation_is_surfaced_not_silent() {
        let mut pool = TenantPool::new();
        let big = one_req_batch(&mut pool, &trace::tenant_name(0),
                                HOST_MAX_TOKENS + 512);
        let mut eng = engine_for(pool);
        eng.run_batch(&big).unwrap();
        assert_eq!(eng.stats.truncated_tokens, 512);
        assert_eq!(eng.stats.truncated_batches, 1);
        assert_eq!(eng.stats.tokens, HOST_MAX_TOKENS as u64);
        assert!(eng.report().contains("backend truncation"),
                "the clamp must show up in the report");
        eng.finish().unwrap();
    }

    #[test]
    fn host_cap_is_configurable_and_reported() {
        let mut pool = TenantPool::new();
        let b = one_req_batch(&mut pool, &trace::tenant_name(0), 100);
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(8);
        reg.insert(PacaAdapter::synthetic(&trace::tenant_name(0), &m,
                                          4, 11));
        let mut eng = ServeEngine::new(
            base, reg, Box::new(HostBackend::with_cap(64)), pool);
        eng.run_batch(&b).unwrap();
        assert_eq!(eng.stats.tokens, 64);
        assert_eq!(eng.stats.truncated_tokens, 36);
        let report = eng.report();
        assert!(report.contains("host cap 64"),
                "the configured cap must be reported, not the \
                 default: {report}");
        eng.finish().unwrap();
    }

    #[test]
    fn kv_ample_bounded_drain_only_matches_unlimited() {
        // The reduction anchor at unit scale: a bounded pool that
        // never binds (and drain-only, so deadline preemption is off)
        // must reproduce the unlimited (`--kv-blocks 0`) run
        // checksum-for-checksum — the gating/alloc/grow plumbing is
        // provably pass-through when capacity never binds.
        let trace = trace::synthesize(&TraceSpec {
            n_requests: 60, n_tenants: 4, deadline_ms: 40.0,
            burstiness: 3.0, decode_tokens: 12,
            ..Default::default()
        });
        let clock = ClockModel::Analytic {
            swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
        };
        for policy in Policy::ALL {
            let run = |kv: Option<(usize, usize, bool)>| {
                let mut eng = engine_for(trace.pool.clone());
                if let Some((blocks, bt, preempt)) = kv {
                    eng.configure_kv(blocks, bt, preempt);
                }
                let mut sched = OnlineScheduler::new(
                    trace.requests.clone(), trace.pool.len(), 8,
                    policy);
                eng.serve_iterative(&mut sched, clock).unwrap();
                eng.finish().unwrap();
                (eng.checksum, eng.stats.tokens, eng.stats.swaps,
                 eng.stats.steps, eng.stats.virtual_s,
                 eng.stats.deadline_misses, eng.stats.preemptions)
            };
            let unlimited = run(None);
            let ample = run(Some((1_000_000, 16, false)));
            assert_eq!(unlimited, ample,
                       "{policy:?}: ample bound must be inert");
            assert_eq!(ample.6, 0, "{policy:?}: drain-only never \
                                    preempts");
        }
    }

    #[test]
    fn kv_pressure_preempts_and_stays_exactly_once() {
        // Two same-tenant decode-heavy requests whose caches jointly
        // exceed the pool: admission lets the second join while the
        // first is small (projection vs free blocks is a watermark,
        // not a reservation), so decode growth MUST hit the wall —
        // the least-urgent slot is evicted, its blocks freed, and the
        // request replayed to completion with every ledger exact.
        let mut pool = TenantPool::new();
        let t0 = pool.intern(&trace::tenant_name(0));
        let reqs: Vec<Request> = (0..2).map(|id| Request {
            id, tenant: t0, tokens: 8, decode_tokens: 32,
            shared_prefix_tokens: 0,
            arrival_s: 0.0, deadline_s: f64::INFINITY,
        }).collect();
        let mut eng = engine_for(pool);
        eng.configure_kv(8, 8, true); // 64-token pool vs 2×40 needed
        let mut sched = OnlineScheduler::new(reqs, 1, 4,
                                             Policy::SwapAware);
        eng.serve_iterative(&mut sched, ClockModel::Analytic {
            swap_s: 0.0, batch_s: 1e-3, token_s: 1e-4,
        }).unwrap();
        assert!(sched.is_done());
        assert!(eng.stats.preempt_memory >= 1,
                "joint growth past the pool must preempt");
        assert!(eng.kv.stats.grow_fails >= 1);
        assert!(eng.stats.kv_recompute_tokens > 0,
                "resume must pay recompute");
        // No over-commit, ever.
        assert!(eng.kv.stats.peak_blocks <= 8,
                "over-committed: {} blocks", eng.kv.stats.peak_blocks);
        // Exactly-once across evict/resume cycles.
        assert_eq!(eng.stats.requests, 2);
        assert_eq!(eng.queueing.count("(all)"), 2);
        assert_eq!(eng.ttft.count("(all)"), 2);
        assert_eq!(eng.tpot.count("(all)"), 2);
        assert_eq!(eng.e2e.count("(all)"), 2);
        let report = eng.report();
        assert!(report.contains("kv cache:"), "{report}");
        assert!(report.contains("preemptions:"), "{report}");
        eng.finish().unwrap(); // also proves no leaked blocks
    }

    #[test]
    fn deadline_preemption_rescues_urgent_tenant() {
        // Tenant A decodes a long no-deadline sequence; tenant B
        // arrives mid-generation with a deadline far tighter than A's
        // natural drain. Drain-only: B waits out ~120ms of decode and
        // misses. Preemption: A's slot is evicted (it has infinite
        // slack), B is served in time, and A replays to completion —
        // the open ROADMAP item this PR closes.
        let mk = || {
            let mut pool = TenantPool::new();
            let t0 = pool.intern(&trace::tenant_name(0));
            let t1 = pool.intern(&trace::tenant_name(1));
            let reqs = vec![
                Request { id: 0, tenant: t0, tokens: 4,
                          decode_tokens: 60, shared_prefix_tokens: 0, arrival_s: 0.0,
                          deadline_s: f64::INFINITY },
                Request { id: 1, tenant: t1, tokens: 4,
                          decode_tokens: 0, shared_prefix_tokens: 0, arrival_s: 5e-3,
                          deadline_s: 20e-3 },
            ];
            (pool, reqs)
        };
        let clock = ClockModel::Analytic {
            swap_s: 1e-4, batch_s: 1e-3, token_s: 1e-3,
        };
        let run = |preempt: bool| {
            let (pool, reqs) = mk();
            let mut eng = engine_for(pool);
            eng.configure_kv(1024, 16, preempt);
            let mut sched = OnlineScheduler::new(reqs, 2, 4,
                                                 Policy::SloAware);
            eng.serve_iterative(&mut sched, clock).unwrap();
            eng.finish().unwrap();
            assert_eq!(eng.stats.requests, 2);
            assert_eq!(eng.stats.deadline_total, 1);
            (eng.stats.deadline_misses, eng.stats.preempt_deadline)
        };
        let (drain_misses, drain_preempts) = run(false);
        assert_eq!(drain_misses, 1, "waiting out the batch misses B");
        assert_eq!(drain_preempts, 0);
        let (misses, preempts) = run(true);
        assert_eq!(misses, 0, "preemption must rescue B's deadline");
        assert!(preempts >= 1);
    }

    #[test]
    fn prefix_cache_hits_cut_prefill_tokens_and_ttft() {
        // Two same-tenant requests sharing a 16-token system prompt,
        // far enough apart that the first completes (and donates its
        // prefix) before the second dispatches. With the cache on,
        // the second prefill computes only its 8-token suffix — fewer
        // total tokens AND a lower TTFT on the analytic clock.
        let mut pool = TenantPool::new();
        let t0 = pool.intern(&trace::tenant_name(0));
        let reqs = || -> Vec<Request> {
            (0..2).map(|id| Request {
                id, tenant: t0, tokens: 24, decode_tokens: 0,
                shared_prefix_tokens: 16,
                arrival_s: id as f64, deadline_s: f64::INFINITY,
            }).collect()
        };
        let clock = ClockModel::Analytic {
            swap_s: 0.0, batch_s: 1e-3, token_s: 1e-3,
        };
        let run = |cache: bool| {
            let mut eng = engine_for(pool.clone());
            eng.configure_prefix(cache);
            let mut sched = OnlineScheduler::new(reqs(), 1, 4,
                                                 Policy::SwapAware);
            eng.serve_iterative(&mut sched, clock).unwrap();
            let out = (eng.stats.tokens, eng.prefix.stats.hits,
                       eng.prefix.stats.hit_tokens,
                       eng.ttft.percentile("(all)", 0.0).unwrap());
            eng.finish().unwrap();
            out
        };
        let (cold_tokens, _, _, cold_best_ttft) = run(false);
        let (warm_tokens, hits, hit_tokens, warm_best_ttft) =
            run(true);
        assert_eq!(cold_tokens, 48);
        assert_eq!(hits, 1,
                   "the second request hits the donated prefix");
        assert_eq!(hit_tokens, 16);
        assert_eq!(warm_tokens, 48 - 16,
                   "the hit prefill computes only the suffix");
        assert!(warm_best_ttft < cold_best_ttft,
                "cached prefill must land the first token sooner: \
                 {warm_best_ttft} !< {cold_best_ttft}");
    }

    #[test]
    fn shared_partial_tail_forks_copy_on_write_in_the_engine() {
        // 8-token blocks; the donor's 12-token prompt IS the shared
        // prefix, so the cache holds one full block plus a 4-token
        // partial tail. The second request attaches both and extends
        // — the engine must fork the shared tail, never write it.
        let mut pool = TenantPool::new();
        let t0 = pool.intern(&trace::tenant_name(0));
        let reqs = vec![
            Request { id: 0, tenant: t0, tokens: 12, decode_tokens: 0,
                      shared_prefix_tokens: 12, arrival_s: 0.0,
                      deadline_s: f64::INFINITY },
            Request { id: 1, tenant: t0, tokens: 20, decode_tokens: 4,
                      shared_prefix_tokens: 12, arrival_s: 1.0,
                      deadline_s: f64::INFINITY },
        ];
        let mut eng = engine_for(pool);
        eng.configure_kv(1024, 8, false);
        let mut sched = OnlineScheduler::new(reqs, 1, 4,
                                             Policy::SwapAware);
        eng.serve_iterative(&mut sched, ClockModel::Analytic {
            swap_s: 0.0, batch_s: 1e-3, token_s: 1e-3,
        }).unwrap();
        assert_eq!(eng.prefix.stats.donated_blocks, 2,
                   "full block + partial tail donated");
        assert_eq!(eng.prefix.stats.hit_tokens, 12,
                   "the partial tail matched too");
        assert_eq!(eng.kv.stats.cow_forks, 1,
                   "extending the shared tail must fork it");
        assert_eq!(eng.stats.requests, 2);
        eng.finish().unwrap();
    }

    #[test]
    fn registry_eviction_invalidates_prefix_and_blocks_stale_reuse() {
        // Tenant 0's prefix is cached, then fetching tenant 1 evicts
        // tenant 0 from a capacity-1 registry (generation bump). The
        // re-loaded tenant 0 must NEVER reuse its pre-eviction cached
        // blocks — they hold KV of a splice that no longer exists.
        let m = small();
        let dir = std::env::temp_dir().join(format!(
            "paca-prefix-reg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut pool = TenantPool::new();
        let t0 = pool.intern(&trace::tenant_name(0));
        let t1 = pool.intern(&trace::tenant_name(1));
        for name in pool.names() {
            PacaAdapter::synthetic(name, &m, 4, 11)
                .save(&AdapterRegistry::adapter_path(&dir, name))
                .unwrap();
        }
        let reqs = || vec![
            Request { id: 0, tenant: t0, tokens: 24, decode_tokens: 0,
                      shared_prefix_tokens: 16, arrival_s: 0.0,
                      deadline_s: f64::INFINITY },
            Request { id: 1, tenant: t1, tokens: 8, decode_tokens: 0,
                      shared_prefix_tokens: 0, arrival_s: 1.0,
                      deadline_s: f64::INFINITY },
            Request { id: 2, tenant: t0, tokens: 24, decode_tokens: 0,
                      shared_prefix_tokens: 16, arrival_s: 2.0,
                      deadline_s: f64::INFINITY },
        ];
        let run = |capacity: usize| {
            let base = BaseModel::synthetic(&m, 7);
            let reg = AdapterRegistry::with_dir(&dir, capacity);
            let mut eng = ServeEngine::new(
                base, reg, Box::<HostBackend>::default(),
                pool.clone());
            let mut sched = OnlineScheduler::new(
                reqs(), 2, 4, Policy::SwapAware);
            eng.serve_iterative(&mut sched, ClockModel::Analytic {
                swap_s: 1e-4, batch_s: 1e-3, token_s: 1e-4,
            }).unwrap();
            let out = (eng.prefix.stats.hits,
                       eng.prefix.stats.invalidations,
                       eng.stats.tokens);
            eng.finish().unwrap();
            out
        };
        // Roomy registry: tenant 0 stays resident, request 2 hits.
        let (hits, invalidations, warm_tokens) = run(2);
        assert_eq!(hits, 1);
        assert_eq!(invalidations, 0);
        // Capacity 1: the eviction invalidates the subtree.
        let (hits, invalidations, cold_tokens) = run(1);
        assert_eq!(hits, 0,
                   "a re-loaded tenant must never reuse pre-eviction \
                    cached blocks");
        assert!(invalidations >= 1);
        assert_eq!(cold_tokens, warm_tokens + 16,
                   "the lost hit is recomputed in full");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prefix_off_is_bit_identical_to_a_prefix_free_run() {
        // The reduction anchor at unit scale (the 25-seed property
        // lives in tests/properties.rs): --prefix-cache off on a
        // shared-prefix trace equals (a) off on the same trace with
        // the prefix FIELD stripped — i.e. a PR-4-era trace with
        // identical prompts — and (b) cache ON on that stripped
        // trace (an unmatched cache is provably inert).
        let trace = trace::synthesize(&TraceSpec {
            n_requests: 60, n_tenants: 4, deadline_ms: 40.0,
            burstiness: 2.0, decode_tokens: 8,
            shared_prefix_tokens: 24, ..Default::default()
        });
        let stripped: Vec<Request> = trace.requests.iter().cloned()
            .map(|mut r| {
                r.shared_prefix_tokens = 0;
                r
            }).collect();
        let clock = ClockModel::Analytic {
            swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
        };
        for policy in Policy::ALL {
            let run = |reqs: Vec<Request>, cache: bool| {
                let mut eng = engine_for(trace.pool.clone());
                eng.configure_kv(32, 16, true);
                eng.configure_prefix(cache);
                let mut sched = OnlineScheduler::new(
                    reqs, trace.pool.len(), 8, policy);
                eng.serve_iterative(&mut sched, clock).unwrap();
                eng.finish().unwrap();
                (eng.checksum, eng.stats.tokens, eng.stats.swaps,
                 eng.stats.steps, eng.stats.virtual_s,
                 eng.stats.deadline_misses, eng.stats.preemptions)
            };
            let off = run(trace.requests.clone(), false);
            let off_stripped = run(stripped.clone(), false);
            let on_stripped = run(stripped.clone(), true);
            assert_eq!(off, off_stripped,
                       "{policy:?}: off-mode must ignore the prefix \
                        fields entirely");
            assert_eq!(off, on_stripped,
                       "{policy:?}: an unmatched cache must be inert");
        }
    }

    #[test]
    fn report_json_exposes_the_counters() {
        let trace = trace::synthesize(&TraceSpec {
            n_requests: 40, n_tenants: 3, deadline_ms: 40.0,
            decode_tokens: 8, shared_prefix_tokens: 32,
            ..Default::default()
        });
        let n = trace.requests.len() as f64;
        let mut eng = engine_for(trace.pool.clone());
        eng.configure_kv(64, 16, true);
        let mut sched = OnlineScheduler::new(
            trace.requests, trace.pool.len(), 8, Policy::SloAware);
        eng.serve_iterative(&mut sched, ClockModel::Analytic {
            swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
        }).unwrap();
        let j = eng.report_json();
        let f = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap();
        assert_eq!(f("requests"), n);
        assert!(f("steps") > 0.0);
        assert!(f("tokens") > 0.0);
        assert_eq!(j.get("deadline").and_then(|d| d.get("total"))
                   .and_then(|v| v.as_f64()).unwrap(), n);
        let ttft = j.get("latency").and_then(|l| l.get("ttft"))
            .expect("iterative run reports ttft");
        assert_eq!(ttft.get("n").and_then(|v| v.as_f64()).unwrap(), n);
        assert!(ttft.get("p99_ms").is_some());
        assert!(j.get("kv").and_then(|k| k.get("peak_blocks"))
                .is_some());
        assert!(j.get("preemptions").and_then(|p| p.get("total"))
                .is_some());
        let pc = j.get("prefix_cache").expect("cache on by default");
        assert!(pc.get("hits").and_then(|v| v.as_f64()).unwrap()
                >= 1.0, "the shared-prefix trace must actually hit");
        // Machine-readable round trip.
        let text = j.to_string();
        assert_eq!(crate::util::json::Json::parse(&text).unwrap(), j);
        eng.finish().unwrap();
    }

    #[test]
    fn unknown_tenant_is_an_error_not_a_crash() {
        let mut pool = TenantPool::new();
        pool.intern(&trace::tenant_name(0));
        let mut eng = engine_for(pool);
        // A tenant interned AFTER the registry was filled has no
        // adapter to fetch.
        let ghost = eng.pool.intern("tenant-999");
        assert!(eng.swap_to(ghost).is_err());
        // Base must still be intact afterwards.
        eng.finish().unwrap();
    }

    #[test]
    fn chunk_zero_and_oversized_chunk_reduce_to_unchunked() {
        // The PR-7 reduction anchor at unit scale (the 25-seed × 3-
        // policy property lives in tests/properties.rs): chunk 0 is
        // bit-for-bit the PR-6 engine, and a chunk at least as large
        // as every prompt issues the SAME forwards (one chunk per
        // prefill) — same checksum, tokens, steps, makespan.
        let trace = trace::synthesize(&TraceSpec {
            n_requests: 60, n_tenants: 4, deadline_ms: 40.0,
            burstiness: 3.0, decode_tokens: 12,
            ..Default::default()
        });
        let clock = ClockModel::Analytic {
            swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
        };
        let run = |chunk: Option<usize>| {
            let mut eng = engine_for(trace.pool.clone());
            if let Some(c) = chunk {
                eng.configure_chunking(c);
            }
            let mut sched = OnlineScheduler::new(
                trace.requests.clone(), trace.pool.len(), 8,
                Policy::SloAware);
            sched.prefill_chunk_tokens = chunk.unwrap_or(0);
            eng.serve_iterative(&mut sched, clock).unwrap();
            eng.finish().unwrap();
            eng
        };
        let base = run(None);
        let zero = run(Some(0));
        assert_eq!(zero.checksum, base.checksum);
        assert_eq!(scrub_wall(zero.stats), scrub_wall(base.stats));
        assert_eq!(zero.report(), base.report(),
                   "chunk 0 must not even change the report");
        // Chunk ≥ every prompt: every prefill is a single chunk.
        let huge = run(Some(1 << 20));
        assert_eq!(huge.checksum, base.checksum);
        assert_eq!(huge.stats.tokens, base.stats.tokens);
        assert_eq!(huge.stats.steps, base.stats.steps);
        assert_eq!(huge.stats.virtual_s, base.stats.virtual_s);
        assert_eq!(huge.stats.chunked_prefills, 0,
                   "no prompt outgrew the chunk");
        assert!(huge.stats.prefill_chunks >= 60,
                "chunked mode ledgers every prefill step");
    }

    #[test]
    fn chunked_prefill_keeps_decode_flowing_past_a_long_prompt() {
        // Tenant 0's slot is decoding when a 96-token same-tenant
        // prompt joins. Unchunked, the joiner's whole prompt lands in
        // one step and every decode token in that step costs
        // token_s·97; chunked at 8, no step carries more than 9
        // tokens, so the decoder's TPOT stays flat — the tentpole win
        // at unit scale — while total computed tokens are unchanged.
        let mut pool = TenantPool::new();
        let t0 = pool.intern(&trace::tenant_name(0));
        let reqs = || vec![
            Request { id: 0, tenant: t0, tokens: 4,
                      decode_tokens: 30, shared_prefix_tokens: 0,
                      arrival_s: 0.0, deadline_s: f64::INFINITY },
            Request { id: 1, tenant: t0, tokens: 96,
                      decode_tokens: 0, shared_prefix_tokens: 0,
                      arrival_s: 4e-3, deadline_s: f64::INFINITY },
        ];
        let clock = ClockModel::Analytic {
            swap_s: 0.0, batch_s: 1e-4, token_s: 1e-3,
        };
        let run = |chunk: usize| {
            let mut eng = engine_for(pool.clone());
            eng.configure_events(Events::recording());
            eng.configure_chunking(chunk);
            let mut sched = OnlineScheduler::new(
                reqs(), 1, 4, Policy::SwapAware);
            sched.prefill_chunk_tokens = chunk;
            eng.serve_iterative(&mut sched, clock).unwrap();
            eng.finish().unwrap();
            assert_eq!(eng.events.violation_count(), 0,
                       "violations: {:?}", eng.events.violations());
            eng
        };
        let whole = run(0);
        let chunked = run(8);
        assert_eq!(chunked.stats.tokens, whole.stats.tokens,
                   "chunking moves tokens between steps, never \
                    drops or adds any");
        assert_eq!(chunked.stats.requests, 2);
        assert_eq!(chunked.stats.chunked_prefills, 1);
        assert_eq!(chunked.stats.prefill_chunks, 12 + 1,
                   "96/8 chunks for the long prompt + 1 for the \
                    short one");
        assert!(chunked.occupancy.peak_tokens() <= 9,
                "chunked steps stay small: peak {}",
                chunked.occupancy.peak_tokens());
        assert_eq!(whole.occupancy.peak_tokens(), 97);
        let tpot = |e: &ServeEngine| {
            e.tpot.percentile("(all)", 0.99).unwrap()
        };
        assert!(tpot(&chunked) < tpot(&whole),
                "decode TPOT must stay flat while the prompt \
                 trickles in: {} !< {}",
                tpot(&chunked), tpot(&whole));
        let counts: HashMap<&str, u64> =
            chunked.events.counts().into_iter().collect();
        assert_eq!(counts["prefill_chunk"], 13);
        assert!(chunked.report().contains("prefill chunks:"));
        assert!(!whole.report().contains("prefill chunks:"));
    }

    #[test]
    fn mid_prompt_preemption_replays_and_emits_exactly_once() {
        // Slo-aware urgency eviction of a slot that is still
        // CHUNKING its prompt: tenant 0's deadline-free 64-token
        // prompt is trickling in when tenant 1 arrives with a
        // deadline far tighter than the remaining chunks. The
        // mid-prompt slot is shed (nothing was emitted, so nothing
        // can double-emit), the urgent tenant is served in time, and
        // the replay prefills from token zero — emitting the first
        // token and TTFT exactly once, at replay time.
        let mut pool = TenantPool::new();
        let t0 = pool.intern(&trace::tenant_name(0));
        let t1 = pool.intern(&trace::tenant_name(1));
        let reqs = vec![
            Request { id: 0, tenant: t0, tokens: 64,
                      decode_tokens: 4, shared_prefix_tokens: 0,
                      arrival_s: 0.0, deadline_s: f64::INFINITY },
            Request { id: 1, tenant: t1, tokens: 4,
                      decode_tokens: 0, shared_prefix_tokens: 0,
                      arrival_s: 6e-3, deadline_s: 25e-3 },
        ];
        let mut eng = engine_for(pool);
        eng.configure_events(Events::recording());
        eng.configure_kv(1024, 16, true);
        eng.configure_chunking(4);
        let mut sched = OnlineScheduler::new(reqs, 2, 4,
                                             Policy::SloAware);
        sched.prefill_chunk_tokens = 4;
        eng.serve_iterative(&mut sched, ClockModel::Analytic {
            swap_s: 1e-4, batch_s: 1e-3, token_s: 1e-3,
        }).unwrap();
        assert_eq!(eng.stats.requests, 2);
        assert_eq!(eng.stats.preempt_prefill, 1,
                   "the chunking slot must be shed for the urgent \
                    deadline");
        assert_eq!(eng.stats.preempt_deadline, 1);
        assert_eq!(eng.stats.deadline_misses, 0,
                   "shedding the prefill must rescue the deadline");
        assert_eq!(eng.stats.kv_recompute_tokens, 64,
                   "the replay recomputes the whole prompt");
        // Exactly-once: both requests emit one first token, one
        // completion, one queueing sample.
        assert_eq!(eng.ttft.count("(all)"), 2);
        assert_eq!(eng.queueing.count("(all)"), 2);
        assert_eq!(eng.e2e.count("(all)"), 2);
        assert_eq!(eng.tpot.count("(all)"), 1, "only t0 decodes");
        assert_eq!(eng.events.violation_count(), 0,
                   "violations: {:?}", eng.events.violations());
        let report = eng.report();
        assert!(report.contains("mid-prompt preemptions"));
        eng.finish().unwrap();
    }

    #[test]
    fn prefetch_warms_the_cache_before_the_arrival() {
        // One future request with a cold 16-token shared prefix and a
        // 1-second idle gap in front of it: with prefetch armed the
        // engine spends the gap warming the prefix into the radix
        // cache, so the real prefill attaches it and computes only
        // the 8-token suffix — and TTFT drops by the difference.
        let mut pool = TenantPool::new();
        let t0 = pool.intern(&trace::tenant_name(0));
        let reqs = || vec![Request {
            id: 0, tenant: t0, tokens: 24, decode_tokens: 0,
            shared_prefix_tokens: 16, arrival_s: 1.0,
            deadline_s: f64::INFINITY,
        }];
        let clock = ClockModel::Analytic {
            swap_s: 1e-3, batch_s: 1e-3, token_s: 1e-3,
        };
        let run = |prefetch: bool| {
            let mut eng = engine_for(pool.clone());
            eng.configure_events(Events::recording());
            eng.configure_prefetch(prefetch);
            let mut sched = OnlineScheduler::new(reqs(), 1, 4,
                                                 Policy::SwapAware);
            eng.serve_iterative(&mut sched, clock).unwrap();
            eng.finish().unwrap();
            assert_eq!(eng.events.violation_count(), 0,
                       "violations: {:?}", eng.events.violations());
            eng
        };
        let cold = run(false);
        assert_eq!(cold.stats.prefetch_tokens, 0);
        assert_eq!(cold.stats.tokens, 24);
        let warm = run(true);
        assert_eq!(warm.stats.prefetch_tokens, 16,
                   "the usable prefix is warmed in the idle gap");
        assert_eq!(warm.stats.prefetch_donated_blocks, 1);
        assert_eq!(warm.prefix.stats.hits, 1,
                   "the real prefill hits the donated chain");
        assert_eq!(warm.prefix.stats.hit_tokens, 16);
        assert_eq!(warm.stats.tokens, 16 + 8,
                   "warm tokens + the uncached suffix");
        let ttft = |e: &ServeEngine| {
            e.ttft.percentile("(all)", 0.5).unwrap()
        };
        assert!(ttft(&warm) < ttft(&cold),
                "prefetched prefix must land the first token \
                 sooner: {} !< {}", ttft(&warm), ttft(&cold));
        let counts: HashMap<&str, u64> =
            warm.events.counts().into_iter().collect();
        assert_eq!(counts["prefetch"], 1);
        assert_eq!(counts["prefetch_donate"], 1);
        assert!(warm.report().contains("speculative prefetch:"));
        assert!(warm.report_json().get("prefetch").is_some());
        assert!(cold.report_json().get("prefetch").is_none());
    }

    /// Wall-clock fields are the only non-deterministic EngineStats
    /// members; zero them so two runs of the same virtual-clock
    /// schedule compare bit-for-bit.
    fn scrub_wall(mut s: EngineStats) -> EngineStats {
        s.wall_s = 0.0;
        s.forward_s = 0.0;
        s.swap_s = 0.0;
        s
    }

    #[test]
    fn tracing_is_invisible_to_the_engine_and_audits_clean() {
        // Same trace, same clock, under kv pressure with preemption,
        // prefix hits and resumes in play: the traced run must leave
        // bit-identical engine state (the reduction anchor) and the
        // online auditor must see a violation-free stream.
        let spec = TraceSpec {
            n_requests: 60, n_tenants: 4, deadline_ms: 30.0,
            burstiness: 3.0, decode_tokens: 12,
            shared_prefix_tokens: 32, ..Default::default()
        };
        let clock = ClockModel::Analytic {
            swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
        };
        let run = |events: Events| {
            let trace = trace::synthesize(&spec);
            let mut eng = engine_for(trace.pool.clone());
            eng.configure_events(events);
            eng.configure_kv(48, 16, true);
            let mut sched = OnlineScheduler::new(
                trace.requests, trace.pool.len(), 8,
                Policy::SloAware);
            eng.serve_iterative(&mut sched, clock).unwrap();
            assert!(sched.is_done());
            eng.finish().unwrap();
            eng
        };
        let plain = run(Events::off());
        let traced = run(Events::recording());
        assert_eq!(scrub_wall(traced.stats), scrub_wall(plain.stats));
        assert_eq!(traced.checksum, plain.checksum);
        assert_eq!(traced.e2e.percentile("(all)", 0.99),
                   plain.e2e.percentile("(all)", 0.99));
        // The untraced report carries no event section.
        assert!(!plain.report().contains("event trace:"));
        assert!(traced.report().contains("auditor clean"),
                "{}", traced.report());
        assert_eq!(traced.events.violation_count(), 0,
                   "violations: {:?}", traced.events.violations());
        assert!(traced.events.total() > 0);
        let counts: HashMap<&str, u64> =
            traced.events.counts().into_iter().collect();
        for kind in ["arrival", "admit", "dispatch", "prefill_start",
                     "prefill_end", "decode_step", "complete",
                     "splice_in", "splice_out", "kv_alloc",
                     "kv_free"] {
            assert!(counts.contains_key(kind), "no {kind} events");
        }
        assert_eq!(counts["arrival"], 60);
        assert_eq!(counts["complete"], 60);
        assert_eq!(counts["kv_alloc"], counts["kv_free"],
                   "alloc/free must balance over a drained run");
    }

    #[test]
    fn profiler_partitions_service_time_and_stays_inert() {
        // Deadlines + preemption + prefix sharing in the mix: the
        // profiler must (a) attribute every virtual service second
        // to a phase (no unattributed time), (b) leave scrubbed
        // engine stats bit-identical, and (c) the slo tracker must
        // settle exactly one burn row entry per deadlined
        // completion.
        let spec = TraceSpec {
            n_requests: 60, n_tenants: 4, deadline_ms: 30.0,
            burstiness: 3.0, decode_tokens: 12,
            shared_prefix_tokens: 32, ..Default::default()
        };
        let clock = ClockModel::Analytic {
            swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
        };
        let run = |profile: bool| {
            let trace = trace::synthesize(&spec);
            let mut eng = engine_for(trace.pool.clone());
            eng.configure_events(Events::recording());
            if profile {
                eng.configure_profiler(false);
            }
            eng.configure_kv(48, 16, true);
            let mut sched = OnlineScheduler::new(
                trace.requests, trace.pool.len(), 8,
                Policy::SloAware);
            eng.serve_iterative(&mut sched, clock).unwrap();
            eng.finish().unwrap();
            eng
        };
        let plain = run(false);
        let prof = run(true);
        assert_eq!(scrub_wall(prof.stats), scrub_wall(plain.stats));
        assert_eq!(prof.checksum, plain.checksum);
        let p = prof.profiler.as_ref().unwrap();
        assert!(p.steps > 0);
        let (got, want) = (p.total_virtual(), p.step_virtual_s);
        assert!((got - want).abs() <= 1e-9 * want.max(1.0),
                "unattributed step time: {got} vs {want}");
        // Idle jumps are NOT service time: attributed time is
        // bounded by the virtual makespan.
        assert!(want <= prof.stats.virtual_s + 1e-9);
        // No wall stamps on the analytic clock.
        assert_eq!(p.phase(Phase::Admission).wall_s, 0.0);
        // The slo tracker settles the same totals the stats do.
        let burns = prof.events.slo_summary();
        let settled: u64 = burns.iter().map(|b| b.total).sum();
        assert_eq!(settled, prof.stats.deadline_total);
        let missed: u64 = burns.iter().map(|b| b.missed).sum();
        assert_eq!(missed, prof.stats.deadline_misses);
        assert_eq!(prof.events.violation_count(), 0,
                   "violations: {:?}", prof.events.violations());
        assert!(prof.report().contains("step profile:"));
        assert!(p.folded().lines().count() >= Phase::COUNT);
    }

    #[test]
    fn spans_reconstruct_the_recorders_bit_for_bit() {
        // Every latency the engine records during an iterative run is
        // a virtual-clock difference; the span reconstructor folds
        // the SAME clock stamps out of the event stream, so its
        // percentiles must be equal as bits, not just close.
        let trace = trace::synthesize(&TraceSpec {
            n_requests: 50, n_tenants: 4, deadline_ms: 25.0,
            burstiness: 3.0, decode_tokens: 10,
            shared_prefix_tokens: 32, ..Default::default()
        });
        let mut eng = engine_for(trace.pool.clone());
        eng.configure_events(Events::recording());
        eng.configure_kv(40, 16, true); // tight: resumes in the mix
        let mut sched = OnlineScheduler::new(
            trace.requests, trace.pool.len(), 8, Policy::SloAware);
        eng.serve_iterative(&mut sched, ClockModel::Analytic {
            swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
        }).unwrap();
        eng.finish().unwrap();
        assert_eq!(eng.events.violation_count(), 0,
                   "violations: {:?}", eng.events.violations());
        let events = eng.events.snapshot();
        let lat = span_latencies(&events, eng.pool.names());
        let pairs: [(&str, &LatencyRecorder, &LatencyRecorder); 5] = [
            ("queueing", &eng.queueing, &lat.queueing),
            ("service", &eng.service, &lat.service),
            ("e2e", &eng.e2e, &lat.e2e),
            ("ttft", &eng.ttft, &lat.ttft),
            ("tpot", &eng.tpot, &lat.tpot),
        ];
        let mut keys: Vec<String> = eng.pool.names().to_vec();
        keys.push("(all)".to_string());
        for (name, rec, span) in pairs {
            for key in &keys {
                assert_eq!(rec.count(key), span.count(key),
                           "{name}/{key} sample count");
                for q in [0.0, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
                    assert_eq!(rec.percentile(key, q),
                               span.percentile(key, q),
                               "{name}/{key} p{q} drifted");
                }
            }
        }
    }

    #[test]
    fn report_json_schema_and_events_section() {
        let trace = trace::synthesize(&TraceSpec {
            n_requests: 20, n_tenants: 2, decode_tokens: 4,
            ..Default::default()
        });
        let run = |events: Events| {
            let mut eng = engine_for(trace.pool.clone());
            eng.configure_events(events);
            let mut sched = OnlineScheduler::new(
                trace.requests.clone(), trace.pool.len(), 8,
                Policy::SwapAware);
            eng.serve_iterative(&mut sched, ClockModel::Analytic {
                swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
            }).unwrap();
            eng.finish().unwrap();
            eng
        };
        let plain = run(Events::off());
        let j = plain.report_json();
        assert_eq!(j.get("schema").and_then(|v| v.as_f64()).unwrap(),
                   2.0);
        assert!(j.get("events").is_none(),
                "events section only exists when tracing is on");
        assert!(j.get("metrics").is_none(),
                "metrics section only exists when tracing is on");
        let traced = run(Events::recording());
        let j = traced.report_json();
        let ev = j.get("events").expect("traced run exports events");
        assert_eq!(ev.get("auditor").and_then(|v| v.as_str())
                   .unwrap(), "clean");
        assert_eq!(ev.get("auditor_violations")
                   .and_then(|v| v.as_f64()).unwrap(), 0.0);
        assert!(ev.get("total").and_then(|v| v.as_f64()).unwrap()
                > 0.0);
        assert_eq!(ev.get("counts").and_then(|c| c.get("complete"))
                   .and_then(|v| v.as_f64()).unwrap(), 20.0);
        // Bump-tolerance round trip: a consumer reading known keys
        // must survive unknown keys a future schema adds.
        let text = j.to_string();
        let extended = format!("{{\"aaa_future_key\":42,{}",
                               &text[1..]);
        let back = Json::parse(&extended).unwrap();
        assert_eq!(back.get("schema").and_then(|v| v.as_f64())
                   .unwrap(), 2.0);
        assert_eq!(back.get("events").and_then(|e| e.get("total")),
                   ev.get("total"));
        assert!(back.get("aaa_future_key").is_some());
    }
}
