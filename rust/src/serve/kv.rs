//! Paged KV-cache memory manager — the serving stack's SECOND capacity
//! axis, orthogonal to the `max_batch_tokens` step-compute budget.
//!
//! The iteration-level engine (PR 3) bounds how many tokens a step may
//! COMPUTE, but nothing bounded how many bytes the in-flight sequences
//! keep RESIDENT: every decoding slot re-reads its whole KV cache each
//! step, and at paper scale the cache — not the weights — is what
//! limits how many sequences fit ("23% longer sequences" in the paper
//! is exactly a KV/activation capacity claim). This module manages
//! that capacity vLLM-style:
//!
//!   * the cache is a bounded pool of fixed-size TOKEN BLOCKS
//!     (`--kv-blocks N` blocks of `--kv-block-tokens` tokens; block
//!     bytes derive from [`ModelInfo::kv_bytes_per_token`], the same
//!     arithmetic `serve::cost::decode_step_time` streams per step);
//!   * each in-flight sequence holds a block list ([`KvSeq`]) that
//!     grows one token per decode step — alloc and free are O(1) pops
//!     and pushes on a free-list stack;
//!   * blocks are REFERENCE-COUNTED (PR 5): the prefix cache
//!     (`serve::prefix`) and any number of sequences can hold the same
//!     block, so same-tenant prompts share their system-prompt KV
//!     instead of recomputing it. A shared partially-filled tail block
//!     is never written in place — extending one forks it
//!     copy-on-write ([`KvPool::grow`] allocates a fresh block for the
//!     extender's share and drops its reference on the shared
//!     original);
//!   * the occupancy ledger ([`KvStats`]) distinguishes PINNED blocks
//!     (referenced by at least one live sequence) from RECLAIMABLE
//!     ones (held only by the prefix cache, refcount 1): reclaimable
//!     blocks are free capacity the admission gate may count and the
//!     cache's LRU reclaim hands back under pressure.
//!
//! `--kv-blocks 0` (the default) is the UNLIMITED pool: block ids are
//! minted on demand, nothing ever fails, and admission gating is
//! disabled — the engine provably reduces to the PR-3 iteration loop
//! (the reduction anchor in tests/properties.rs).

use crate::manifest::ModelInfo;
use crate::serve::events::{EventKind, Events};

/// Default block granularity (tokens per block) when none is
/// configured — small enough that a tiny-model prompt spans several
/// blocks, big enough that the free list stays short at paper scale.
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// THE token→block rounding rule: blocks needed to hold `tokens`
/// token slots at a `block_tokens` granularity (a sequence always
/// holds at least one block). Shared by [`KvPool`]'s allocation and
/// the scheduler's admission-gate projection, so what the gate
/// projects and what alloc/grow actually charge can never drift.
pub fn blocks_for(tokens: usize, block_tokens: usize) -> usize {
    tokens.max(1).div_ceil(block_tokens.max(1))
}

/// One in-flight sequence's slice of the pool: the block list plus the
/// number of token slots the sequence logically covers (shared prefix
/// blocks included). Handles are move-only and must be returned via
/// [`KvPool::release`] — dropping one leaks its references (caught by
/// the pool's live-block ledger in tests).
#[derive(Debug, Default)]
pub struct KvSeq {
    blocks: Vec<u32>,
    tokens: usize,
}

impl KvSeq {
    pub fn tokens(&self) -> usize {
        self.tokens
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Block ids, in sequence order (shared prefix blocks first).
    pub fn block_ids(&self) -> &[u32] {
        &self.blocks
    }

    /// Token slots allocated but not filled — the sequence's internal
    /// fragmentation (always < one block).
    pub fn frag_tokens(&self, block_tokens: usize) -> usize {
        self.blocks.len() * block_tokens - self.tokens
    }
}

/// Occupancy / fragmentation / failure ledger of a [`KvPool`].
#[derive(Debug, Default, Clone, Copy)]
pub struct KvStats {
    /// Sequences allocated / released.
    pub allocs: u64,
    pub frees: u64,
    /// High-water marks over the pool's lifetime.
    pub peak_blocks: usize,
    pub peak_tokens: usize,
    /// Peak cache-only (reclaimable) occupancy.
    pub peak_reclaimable: usize,
    /// `grow` calls refused for lack of free blocks (each is a
    /// memory-pressure event the engine answers with preemption).
    pub grow_fails: u64,
    /// Allocations clamped below the requested size by `alloc_clamped`
    /// / `grow_clamped` (an oversized request degrading to a capped
    /// cache).
    pub alloc_clamps: u64,
    /// Tokens that continued WITHOUT cache growth (capped sequences —
    /// the sliding-window degrade path for requests bigger than the
    /// entire pool). Never counted against pool blocks.
    pub overflow_tokens: u64,
    /// Copy-on-write forks: a sequence extended a shared
    /// partially-filled tail block and got its own copy instead of
    /// corrupting the shared KV.
    pub cow_forks: u64,
    /// Dereferences refused because the block was already free — the
    /// double-free guard (state is left untouched).
    pub double_free_refused: u64,
}

/// The paged allocator. Fixed-size token blocks, reference-counted,
/// O(1) alloc/free via a free-list stack; bounded (`n_blocks > 0`) or
/// unlimited (`n_blocks == 0`, ids minted on demand, nothing fails).
#[derive(Debug)]
pub struct KvPool {
    /// Pool bound in blocks; 0 = unlimited.
    n_blocks: usize,
    block_tokens: usize,
    /// KV bytes per resident token (model-derived; see
    /// [`ModelInfo::kv_bytes_per_token`]).
    bytes_per_token: usize,
    /// Recycled block ids, LIFO.
    free: Vec<u32>,
    /// Next never-used id (bounded: < n_blocks; unlimited: unbounded).
    next_fresh: u32,
    /// Per-block reference count (sequences + at most one prefix-cache
    /// hold), indexed by block id; 0 ⇔ the id is on the free list.
    refs: Vec<u32>,
    /// Per-block prefix-cache hold flag (set/cleared via
    /// `mark_cached`/`uncache`).
    cached: Vec<bool>,
    /// Per-block filled token slots (counted ONCE however many
    /// sequences share the block).
    fill: Vec<u32>,
    /// Live (refcount > 0) blocks / distinct filled token slots.
    used_blocks: usize,
    resident_tokens: usize,
    /// Blocks held ONLY by the prefix cache (cached && refs == 1) —
    /// reclaimable capacity.
    reclaimable: usize,
    /// Event-stream handle (off by default). Alloc/free emit at the
    /// ONLY two sites where `used_blocks` changes, so the audited
    /// ledger is exact by construction.
    events: Events,
    pub stats: KvStats,
}

impl KvPool {
    /// `n_blocks == 0` means unlimited.
    pub fn new(n_blocks: usize, block_tokens: usize,
               bytes_per_token: usize) -> KvPool {
        KvPool { n_blocks, block_tokens: block_tokens.max(1),
                 bytes_per_token, free: Vec::new(), next_fresh: 0,
                 refs: Vec::new(), cached: Vec::new(),
                 fill: Vec::new(), used_blocks: 0, resident_tokens: 0,
                 reclaimable: 0, events: Events::off(),
                 stats: KvStats::default() }
    }

    /// Install an event-stream handle (the engine clones its own in;
    /// also tells the stream's auditor the pool bound so it can flag
    /// over-commit). Off by default.
    pub fn set_events(&mut self, events: Events) {
        // 0 = unbounded for both the pool and the auditor, so always
        // propagate — a reconfigure from bounded to unbounded must
        // not leave a stale bound behind.
        events.set_kv_capacity(self.n_blocks as u64);
        self.events = events;
    }

    /// The unlimited pool the engine defaults to: pure accounting, no
    /// gating, no failures — PR-3 behaviour.
    pub fn unlimited(model: &ModelInfo) -> KvPool {
        KvPool::new(0, DEFAULT_BLOCK_TOKENS,
                    model.kv_bytes_per_token())
    }

    pub fn is_bounded(&self) -> bool {
        self.n_blocks > 0
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn block_bytes(&self) -> usize {
        self.block_tokens * self.bytes_per_token
    }

    pub fn used_blocks(&self) -> usize {
        self.used_blocks
    }

    /// Live blocks referenced by at least one sequence (live minus
    /// cache-only holds) — what genuinely cannot be freed right now.
    pub fn pinned_blocks(&self) -> usize {
        self.used_blocks - self.reclaimable
    }

    /// Live blocks held ONLY by the prefix cache — capacity the
    /// cache's LRU reclaim can hand back on demand, so the admission
    /// gate may count it as available.
    pub fn reclaimable_blocks(&self) -> usize {
        self.reclaimable
    }

    pub fn resident_tokens(&self) -> usize {
        self.resident_tokens
    }

    pub fn resident_bytes(&self) -> usize {
        self.resident_tokens * self.bytes_per_token
    }

    /// Strictly free blocks (usize::MAX when unlimited); reclaimable
    /// cached blocks are NOT counted — see [`Self::available_blocks`].
    pub fn free_blocks(&self) -> usize {
        if self.is_bounded() {
            self.n_blocks - self.used_blocks
        } else {
            usize::MAX
        }
    }

    /// Free plus reclaimable blocks — what the scheduler's admission
    /// gate compares projected needs against (the cache yields its
    /// unreferenced blocks before admission ever fails on them).
    pub fn available_blocks(&self) -> usize {
        if self.is_bounded() {
            self.free_blocks() + self.reclaimable
        } else {
            usize::MAX
        }
    }

    /// Blocks needed to hold `tokens` token slots (the module-level
    /// [`blocks_for`] rule at this pool's granularity).
    pub fn blocks_for(&self, tokens: usize) -> usize {
        blocks_for(tokens, self.block_tokens)
    }

    /// Allocated-but-unfilled token slots across all live blocks —
    /// the pool's aggregate internal fragmentation.
    pub fn frag_tokens(&self) -> usize {
        self.used_blocks * self.block_tokens - self.resident_tokens
    }

    /// Current reference count of a minted block (0 = free).
    pub fn refs_of(&self, id: u32) -> u32 {
        self.refs[id as usize]
    }

    /// Filled token slots of a minted block.
    pub fn fill_of(&self, id: u32) -> usize {
        self.fill[id as usize] as usize
    }

    fn take_block(&mut self) -> Option<u32> {
        if let Some(id) = self.free.pop() {
            return Some(id);
        }
        if self.is_bounded() && self.next_fresh as usize >= self.n_blocks
        {
            return None;
        }
        let id = self.next_fresh;
        self.next_fresh += 1;
        self.refs.push(0);
        self.cached.push(false);
        self.fill.push(0);
        Some(id)
    }

    fn note_peaks(&mut self) {
        self.stats.peak_blocks =
            self.stats.peak_blocks.max(self.used_blocks);
        self.stats.peak_tokens =
            self.stats.peak_tokens.max(self.resident_tokens);
        self.stats.peak_reclaimable =
            self.stats.peak_reclaimable.max(self.reclaimable);
    }

    /// Mint one block with refcount 1 holding `fill` token slots.
    fn new_block(&mut self, fill: usize) -> Option<u32> {
        let id = self.take_block()?;
        let i = id as usize;
        self.refs[i] = 1;
        self.cached[i] = false;
        self.fill[i] = fill as u32;
        self.used_blocks += 1;
        self.resident_tokens += fill;
        self.events.emit(EventKind::KvAlloc, None, None, 1,
                         self.used_blocks as u64);
        Some(id)
    }

    /// Take one more reference on a live block (a sequence attaching a
    /// cached prefix block, or the cache taking its donation hold).
    pub fn share(&mut self, id: u32) {
        let i = id as usize;
        assert!(self.refs[i] > 0, "sharing free block {id}");
        if self.refs[i] == 1 && self.cached[i] {
            self.reclaimable -= 1;
        }
        self.refs[i] += 1;
    }

    /// Drop one reference; frees the block at zero. Refuses (and
    /// ledgers) a dereference of an already-free block instead of
    /// corrupting the free list — the double-free guard.
    pub fn unref(&mut self, id: u32) -> bool {
        let i = id as usize;
        if self.refs[i] == 0 {
            self.stats.double_free_refused += 1;
            return false;
        }
        self.refs[i] -= 1;
        if self.refs[i] == 0 {
            if self.cached[i] {
                // The cache's own hold is a reference, so a cached
                // block can only die through `uncache`; clear the
                // flag defensively if a caller got here anyway.
                self.cached[i] = false;
                self.reclaimable -= 1;
            }
            self.used_blocks -= 1;
            self.resident_tokens -= self.fill[i] as usize;
            self.fill[i] = 0;
            self.free.push(id);
            self.events.emit(EventKind::KvFree, None, None, 1,
                             self.used_blocks as u64);
        } else if self.refs[i] == 1 && self.cached[i] {
            self.reclaimable += 1;
            self.stats.peak_reclaimable =
                self.stats.peak_reclaimable.max(self.reclaimable);
        }
        true
    }

    /// The prefix cache takes its hold on a live block (one extra
    /// reference + the cached flag). No-op if already cached.
    pub fn mark_cached(&mut self, id: u32) {
        let i = id as usize;
        if self.cached[i] {
            return;
        }
        self.share(id);
        self.cached[i] = true;
        if self.refs[i] == 1 {
            // Unreachable in practice (the donor still holds it), but
            // keep the ledger closed under any call order.
            self.reclaimable += 1;
        }
        self.note_peaks();
    }

    /// The prefix cache drops its hold (reclaim or invalidation): the
    /// cached flag clears and the cache's reference is released —
    /// freeing the block if no sequence still pins it.
    pub fn uncache(&mut self, id: u32) {
        let i = id as usize;
        assert!(self.cached[i], "uncaching a block the cache does not \
                                 hold: {id}");
        if self.refs[i] == 1 {
            self.reclaimable -= 1;
        }
        self.cached[i] = false;
        self.unref(id);
    }

    /// Allocate a sequence holding `tokens`; None (and no state
    /// change) if the blocks don't fit the pool's FREE list (the
    /// caller reclaims cached blocks first — see `serve::prefix`).
    pub fn try_alloc(&mut self, tokens: usize) -> Option<KvSeq> {
        let need = self.blocks_for(tokens);
        if need > self.free_blocks() {
            return None;
        }
        let mut blocks = Vec::with_capacity(need);
        let mut left = tokens;
        for _ in 0..need {
            let f = left.min(self.block_tokens);
            blocks.push(self.new_block(f).expect("free-count checked"));
            left -= f;
        }
        self.stats.allocs += 1;
        self.note_peaks();
        Some(KvSeq { blocks, tokens })
    }

    /// Allocate as much of `tokens` as fits — the graceful-degrade
    /// path for a request bigger than the whole pool (mirrors the step
    /// budget's oversized-prompt rule: serve it capped rather than
    /// wedge the queue). The shortfall is counted in
    /// `stats.overflow_tokens`; the pool NEVER over-commits blocks.
    pub fn alloc_clamped(&mut self, tokens: usize) -> KvSeq {
        if let Some(seq) = self.try_alloc(tokens) {
            return seq;
        }
        let fit = (self.free_blocks() * self.block_tokens).min(tokens);
        self.stats.alloc_clamps += 1;
        self.stats.overflow_tokens += (tokens - fit) as u64;
        self.events.emit(EventKind::Overflow, None, None,
                         (tokens - fit) as u64,
                         self.stats.overflow_tokens);
        if fit == 0 {
            self.stats.allocs += 1;
            return KvSeq::default();
        }
        self.try_alloc(fit).expect("clamped size fits by construction")
    }

    /// Start a sequence on `blocks` already resident in the pool —
    /// prefix-cache hits. Each block gains a reference; the sequence
    /// starts at `tokens` logical tokens (the cached coverage). The
    /// uncached prompt suffix is then added with [`Self::grow`] /
    /// [`Self::grow_clamped`].
    pub fn attach(&mut self, blocks: &[u32], tokens: usize) -> KvSeq {
        for &b in blocks {
            self.share(b);
        }
        self.stats.allocs += 1;
        KvSeq { blocks: blocks.to_vec(), tokens }
    }

    /// True when growing `seq` would write into a tail block some
    /// other holder (cache or sequence) also references — the
    /// copy-on-write trigger.
    fn tail_needs_fork(&self, seq: &KvSeq) -> bool {
        seq.blocks.len() * self.block_tokens > seq.tokens
            && seq.blocks.last()
                .is_some_and(|&b| self.refs[b as usize] > 1)
    }

    /// Fork the shared tail: a fresh block takes over this sequence's
    /// share of it (the copy-on-write write side), and the sequence
    /// drops its reference on the shared original.
    fn fork_tail(&mut self, seq: &mut KvSeq) {
        let old = *seq.blocks.last().expect("fork of an empty seq");
        let tail_tokens =
            seq.tokens - (seq.blocks.len() - 1) * self.block_tokens;
        let nb = self.new_block(tail_tokens)
            .expect("caller checked free blocks");
        *seq.blocks.last_mut().unwrap() = nb;
        self.unref(old);
        self.stats.cow_forks += 1;
        self.events.emit(EventKind::CowFork, None, None,
                         old as u64, nb as u64);
    }

    /// Extend `seq` by `extra` token slots, allocating blocks as
    /// boundaries are crossed and copy-on-write-forking a shared
    /// partially-filled tail before writing into it. False (and NO
    /// state change) when the pool is out of free blocks — the
    /// memory-pressure signal the engine's reclaim/preemption path
    /// answers.
    pub fn grow(&mut self, seq: &mut KvSeq, extra: usize) -> bool {
        if extra == 0 {
            return true;
        }
        let fork = self.tail_needs_fork(seq);
        let need = self.blocks_for(seq.tokens + extra)
            .saturating_sub(seq.blocks.len())
            + usize::from(fork);
        if need > self.free_blocks() {
            self.stats.grow_fails += 1;
            return false;
        }
        if fork {
            self.fork_tail(seq);
        }
        // Fill the tail's spare slots, then whole fresh blocks.
        let mut left = extra;
        let tail_space =
            seq.blocks.len() * self.block_tokens - seq.tokens;
        if tail_space > 0 {
            let add = left.min(tail_space);
            let t = *seq.blocks.last().unwrap() as usize;
            self.fill[t] += add as u32;
            self.resident_tokens += add;
            left -= add;
        }
        while left > 0 {
            let f = left.min(self.block_tokens);
            seq.blocks.push(self.new_block(f)
                            .expect("free-count checked"));
            left -= f;
        }
        seq.tokens += extra;
        self.note_peaks();
        true
    }

    /// Grow by as much of `extra` as fits (all of it preferred) — the
    /// clamped-degrade analogue of [`Self::alloc_clamped`] for the
    /// uncached suffix of a prefix-cache hit. Returns the tokens
    /// actually grown; the shortfall is ledgered as overflow.
    pub fn grow_clamped(&mut self, seq: &mut KvSeq,
                        extra: usize) -> usize {
        if self.grow(seq, extra) {
            return extra;
        }
        // grow() counted the grow_fail; now mirror alloc_clamped's
        // clamp ledger on the shortfall and take what fits.
        let free = self.free_blocks();
        let tail_space =
            seq.blocks.len() * self.block_tokens - seq.tokens;
        let fit = if self.tail_needs_fork(seq) {
            // The fork itself costs one free block, which then has
            // the tail's spare slots.
            if free == 0 {
                0
            } else {
                tail_space + (free - 1) * self.block_tokens
            }
        } else {
            tail_space + free * self.block_tokens
        }
        .min(extra);
        self.stats.alloc_clamps += 1;
        self.stats.overflow_tokens += (extra - fit) as u64;
        self.events.emit(EventKind::Overflow, None, None,
                         (extra - fit) as u64,
                         self.stats.overflow_tokens);
        if fit > 0 {
            assert!(self.grow(seq, fit),
                    "clamped growth fits by construction");
        }
        fit
    }

    /// A capped sequence advanced one token WITHOUT cache growth (no
    /// free blocks, no evictable victim): pure ledger entry.
    pub fn overflow(&mut self, tokens: usize) {
        self.stats.overflow_tokens += tokens as u64;
        self.events.emit(EventKind::Overflow, None, None,
                         tokens as u64, self.stats.overflow_tokens);
    }

    /// Drop a sequence's references (O(1) per block); blocks nobody
    /// else holds return to the free list, blocks the prefix cache
    /// still holds become reclaimable.
    pub fn release(&mut self, seq: KvSeq) {
        for id in seq.blocks {
            self.unref(id);
        }
        self.stats.frees += 1;
    }

    /// Post-drain consistency check: nothing live, nothing cached,
    /// every minted block back on the free list — i.e. no leaked
    /// references anywhere. Call after the prefix cache is flushed.
    pub fn leak_check(&self) -> Result<(), String> {
        if self.used_blocks != 0 || self.resident_tokens != 0
            || self.reclaimable != 0
        {
            return Err(format!(
                "{} live blocks ({} resident tokens, {} reclaimable) \
                 after drain", self.used_blocks, self.resident_tokens,
                self.reclaimable));
        }
        if self.free.len() != self.next_fresh as usize {
            return Err(format!(
                "free list holds {} of {} minted blocks — leaked \
                 refcounts", self.free.len(), self.next_fresh));
        }
        Ok(())
    }

    /// One-line occupancy summary for reports.
    pub fn describe(&self) -> String {
        if self.is_bounded() {
            format!("{} blocks x {} tokens ({:.1}KB/block, {:.1}MB \
                     pool)",
                    self.n_blocks, self.block_tokens,
                    self.block_bytes() as f64 / 1e3,
                    (self.n_blocks * self.block_bytes()) as f64 / 1e6)
        } else {
            format!("unlimited ({}-token blocks, {:.1}KB/block)",
                    self.block_tokens,
                    self.block_bytes() as f64 / 1e3)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::tiny_model;

    fn pool(n: usize, bt: usize) -> KvPool {
        KvPool::new(n, bt, 4)
    }

    #[test]
    fn bytes_per_token_comes_from_the_model() {
        let m = tiny_model();
        let p = KvPool::unlimited(&m);
        assert_eq!(p.block_bytes(),
                   DEFAULT_BLOCK_TOKENS * m.kv_bytes_per_token());
        // tiny model: 2 layers × 2 (K,V) × 64 d_model × 2 bytes.
        assert_eq!(m.kv_bytes_per_token(), 2 * 2 * 64 * 2);
    }

    #[test]
    fn alloc_grow_release_roundtrip() {
        let mut p = pool(8, 4);
        let mut a = p.try_alloc(6).unwrap(); // 2 blocks
        assert_eq!(a.n_blocks(), 2);
        assert_eq!(a.tokens(), 6);
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.free_blocks(), 6);
        assert_eq!(a.frag_tokens(4), 2);
        assert_eq!(p.frag_tokens(), 2);
        // Grow within the last block: no new block.
        assert!(p.grow(&mut a, 2));
        assert_eq!(a.n_blocks(), 2);
        assert_eq!(p.frag_tokens(), 0);
        // Next token crosses a boundary.
        assert!(p.grow(&mut a, 1));
        assert_eq!(a.n_blocks(), 3);
        assert_eq!(p.used_blocks(), 3);
        p.release(a);
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.resident_tokens(), 0);
        assert_eq!(p.free_blocks(), 8);
        assert_eq!(p.stats.peak_blocks, 3);
        assert_eq!(p.stats.peak_tokens, 9);
        p.leak_check().unwrap();
    }

    #[test]
    fn block_ids_are_recycled_not_leaked() {
        let mut p = pool(4, 4);
        let a = p.try_alloc(16).unwrap(); // whole pool
        assert_eq!(p.free_blocks(), 0);
        p.release(a);
        let b = p.try_alloc(16).unwrap(); // must reuse the same ids
        let mut ids: Vec<u32> = b.blocks.clone();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        p.release(b);
        p.leak_check().unwrap();
    }

    #[test]
    fn bounded_pool_refuses_overcommit() {
        let mut p = pool(4, 4);
        let a = p.try_alloc(12).unwrap(); // 3 of 4 blocks
        assert!(p.try_alloc(8).is_none(), "2 blocks > 1 free");
        let mut b = p.try_alloc(4).unwrap(); // last block
        assert_eq!(p.free_blocks(), 0);
        // Growing past the pool fails WITHOUT state change…
        assert!(!p.grow(&mut b, 1));
        assert_eq!(b.tokens(), 4);
        assert_eq!(p.used_blocks(), 4);
        assert_eq!(p.stats.grow_fails, 1);
        // …until a release frees a block.
        p.release(a);
        assert!(p.grow(&mut b, 1));
        p.release(b);
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn alloc_clamped_degrades_without_overcommit() {
        let mut p = pool(2, 4);
        let a = p.alloc_clamped(100); // 100 tokens into an 8-token pool
        assert_eq!(a.n_blocks(), 2);
        assert_eq!(a.tokens(), 8);
        assert_eq!(p.stats.alloc_clamps, 1);
        assert_eq!(p.stats.overflow_tokens, 92);
        assert_eq!(p.free_blocks(), 0);
        // A second clamped alloc on the exhausted pool yields an empty
        // handle, never a panic or an over-commit.
        let b = p.alloc_clamped(5);
        assert_eq!(b.n_blocks(), 0);
        assert_eq!(p.used_blocks(), 2);
        p.release(a);
        p.release(b);
    }

    #[test]
    fn unlimited_pool_never_fails_but_still_accounts() {
        let m = tiny_model();
        let mut p = KvPool::unlimited(&m);
        assert!(!p.is_bounded());
        assert_eq!(p.free_blocks(), usize::MAX);
        let mut seqs = Vec::new();
        for _ in 0..100 {
            let mut s = p.try_alloc(33).unwrap();
            assert!(p.grow(&mut s, 7));
            seqs.push(s);
        }
        assert_eq!(p.resident_tokens(), 100 * 40);
        assert_eq!(p.used_blocks(),
                   100 * p.blocks_for(40));
        assert_eq!(p.stats.grow_fails, 0);
        for s in seqs {
            p.release(s);
        }
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.stats.peak_tokens, 4000);
        p.leak_check().unwrap();
    }

    #[test]
    fn blocks_for_rounds_up() {
        let p = pool(0, 16);
        assert_eq!(p.blocks_for(0), 1, "a sequence holds ≥1 block");
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
        assert_eq!(p.blocks_for(160), 10);
    }

    #[test]
    fn describe_mentions_geometry() {
        let p = KvPool::new(64, 16, 512);
        let s = p.describe();
        assert!(s.contains("64 blocks"));
        assert!(s.contains("16 tokens"));
        assert!(KvPool::new(0, 16, 512).describe()
                .contains("unlimited"));
    }

    // ---- PR-5 refcount / CoW / reclaimable-ledger invariants ------

    #[test]
    fn shared_blocks_free_only_at_refcount_zero() {
        let mut p = pool(8, 4);
        let a = p.try_alloc(8).unwrap(); // 2 full blocks
        let ids = a.blocks.clone();
        // A second sequence attaches the same 2 blocks.
        let b = p.attach(&ids, 8);
        assert_eq!(p.used_blocks(), 2, "sharing mints nothing");
        assert_eq!(p.resident_tokens(), 8,
                   "shared slots are counted once");
        assert_eq!(p.refs_of(ids[0]), 2);
        p.release(a);
        assert_eq!(p.used_blocks(), 2, "b still pins the blocks");
        assert_eq!(p.refs_of(ids[0]), 1);
        p.release(b);
        assert_eq!(p.used_blocks(), 0);
        p.leak_check().unwrap();
    }

    #[test]
    fn double_free_is_refused_not_corrupting() {
        let mut p = pool(4, 4);
        let a = p.try_alloc(4).unwrap();
        let id = a.blocks[0];
        p.release(a);
        assert_eq!(p.used_blocks(), 0);
        // A stray dereference of the already-free block is refused…
        assert!(!p.unref(id));
        assert_eq!(p.stats.double_free_refused, 1);
        // …and the free list is intact: the whole pool still allocates
        // exactly once.
        let b = p.try_alloc(16).unwrap();
        assert_eq!(b.n_blocks(), 4);
        assert!(p.try_alloc(4).is_none());
        p.release(b);
        p.leak_check().unwrap();
    }

    #[test]
    fn cached_blocks_are_reclaimable_until_pinned() {
        let mut p = pool(8, 4);
        let a = p.try_alloc(8).unwrap();
        let ids = a.blocks.clone();
        // The cache takes its hold: blocks stay pinned by `a`.
        p.mark_cached(ids[0]);
        p.mark_cached(ids[1]);
        assert_eq!(p.reclaimable_blocks(), 0, "donor still holds them");
        assert_eq!(p.pinned_blocks(), 2);
        p.release(a);
        // Now cache-only: live but reclaimable, not pinned.
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.reclaimable_blocks(), 2);
        assert_eq!(p.pinned_blocks(), 0);
        assert_eq!(p.available_blocks(), 8, "reclaimable counts as \
                                             available");
        assert_eq!(p.free_blocks(), 6, "but not as strictly free");
        // A hit re-pins one of them.
        let b = p.attach(&ids[..1], 4);
        assert_eq!(p.reclaimable_blocks(), 1);
        assert_eq!(p.pinned_blocks(), 1);
        p.release(b);
        assert_eq!(p.reclaimable_blocks(), 2);
        // Uncache frees them.
        p.uncache(ids[0]);
        p.uncache(ids[1]);
        assert_eq!(p.used_blocks(), 0);
        assert!(p.stats.peak_reclaimable >= 2);
        p.leak_check().unwrap();
    }

    #[test]
    fn growing_a_shared_partial_tail_forks_copy_on_write() {
        let mut p = pool(8, 4);
        // Donor: 6 tokens = 1 full block + a 2-token tail.
        let a = p.try_alloc(6).unwrap();
        let ids = a.blocks.clone();
        p.mark_cached(ids[0]);
        p.mark_cached(ids[1]);
        p.release(a);
        // A new sequence attaches the cached prefix and extends it.
        let mut b = p.attach(&ids, 6);
        assert!(p.grow(&mut b, 4), "fork + growth fit the pool");
        assert_eq!(p.stats.cow_forks, 1);
        // The shared tail was NOT written: it keeps its 2 tokens and
        // its cache hold; b's new tail is a different block.
        assert_eq!(p.fill_of(ids[1]), 2);
        assert_ne!(b.blocks[1], ids[1], "tail must be forked");
        assert_eq!(b.tokens(), 10);
        assert_eq!(p.fill_of(b.blocks[1]), 2 + 2,
                   "fork copies the 2 shared tail tokens and growth \
                    fills its 2 spare slots; a fresh block takes the \
                    remaining 2");
        assert_eq!(p.fill_of(b.blocks[2]), 2);
        // Full shared blocks are never forked.
        assert_eq!(b.blocks[0], ids[0]);
        // The cached tail went back to reclaimable when b forked off.
        assert_eq!(p.refs_of(ids[1]), 1);
        assert_eq!(p.reclaimable_blocks(), 1);
        p.release(b);
        p.uncache(ids[0]);
        p.uncache(ids[1]);
        p.leak_check().unwrap();
    }

    #[test]
    fn fork_counts_against_free_blocks() {
        // Pool of 2: donor fills both (full + partial tail). After the
        // donor releases, an attacher extending the shared tail needs
        // ONE free block for the fork — and there is none until the
        // cache yields.
        let mut p = pool(2, 4);
        let a = p.try_alloc(6).unwrap();
        let ids = a.blocks.clone();
        p.mark_cached(ids[0]);
        p.mark_cached(ids[1]);
        p.release(a);
        let mut b = p.attach(&ids, 6);
        assert!(!p.grow(&mut b, 1), "fork needs a free block");
        assert_eq!(p.stats.grow_fails, 1);
        // The cache yields the tail (simulating LRU reclaim)… but the
        // tail is still shared by b, so uncache only unpins it; the
        // fork still needs the free list. Release b's hold first.
        p.release(b);
        p.uncache(ids[1]);
        assert_eq!(p.free_blocks(), 1);
        let mut c = p.attach(&ids[..1], 4);
        assert!(p.grow(&mut c, 2), "full-block tail: append, no fork");
        assert_eq!(p.stats.cow_forks, 0);
        p.release(c);
        p.uncache(ids[0]);
        p.leak_check().unwrap();
    }

    #[test]
    fn grow_clamped_takes_what_fits_and_ledgers_the_rest() {
        let mut p = pool(3, 4);
        let mut a = p.try_alloc(4).unwrap(); // 1 block
        // Ask for 100 more: 2 free blocks = 8 slots fit.
        assert_eq!(p.grow_clamped(&mut a, 100), 8);
        assert_eq!(a.tokens(), 12);
        assert_eq!(p.stats.alloc_clamps, 1);
        assert_eq!(p.stats.overflow_tokens, 92);
        assert_eq!(p.used_blocks(), 3);
        // Nothing left: clamp to zero, ledger only.
        assert_eq!(p.grow_clamped(&mut a, 5), 0);
        assert_eq!(p.stats.overflow_tokens, 97);
        p.release(a);
        p.leak_check().unwrap();
    }

    #[test]
    fn attach_with_no_blocks_is_an_empty_start() {
        let mut p = pool(4, 4);
        let mut a = p.attach(&[], 0);
        assert_eq!(a.n_blocks(), 0);
        assert!(p.grow(&mut a, 5));
        assert_eq!(a.n_blocks(), 2);
        assert_eq!(a.tokens(), 5);
        p.release(a);
        p.leak_check().unwrap();
    }
}
