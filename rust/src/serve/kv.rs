//! Paged KV-cache memory manager — the serving stack's SECOND capacity
//! axis, orthogonal to the `max_batch_tokens` step-compute budget.
//!
//! The iteration-level engine (PR 3) bounds how many tokens a step may
//! COMPUTE, but nothing bounded how many bytes the in-flight sequences
//! keep RESIDENT: every decoding slot re-reads its whole KV cache each
//! step, and at paper scale the cache — not the weights — is what
//! limits how many sequences fit ("23% longer sequences" in the paper
//! is exactly a KV/activation capacity claim). This module manages
//! that capacity vLLM-style:
//!
//!   * the cache is a bounded pool of fixed-size TOKEN BLOCKS
//!     (`--kv-blocks N` blocks of `--kv-block-tokens` tokens; block
//!     bytes derive from [`ModelInfo::kv_bytes_per_token`], the same
//!     arithmetic `serve::cost::decode_step_time` streams per step);
//!   * each in-flight sequence holds a block list ([`KvSeq`]) that
//!     grows one token per decode step — alloc and free are O(1) pops
//!     and pushes on a free-list stack;
//!   * the pool keeps an occupancy/fragmentation ledger
//!     ([`KvStats`]): peak/live blocks and resident tokens, internal
//!     fragmentation (allocated-but-unfilled token slots in each
//!     sequence's last block), allocation clamps and grow failures —
//!     the raw signals the scheduler's admission gate and the engine's
//!     preemption policy act on.
//!
//! `--kv-blocks 0` (the default) is the UNLIMITED pool: block ids are
//! minted on demand, nothing ever fails, and admission gating is
//! disabled — the engine provably reduces to the PR-3 iteration loop
//! (the reduction anchor in tests/properties.rs).

use crate::manifest::ModelInfo;

/// Default block granularity (tokens per block) when none is
/// configured — small enough that a tiny-model prompt spans several
/// blocks, big enough that the free list stays short at paper scale.
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// THE token→block rounding rule: blocks needed to hold `tokens`
/// token slots at a `block_tokens` granularity (a sequence always
/// holds at least one block). Shared by [`KvPool`]'s allocation and
/// the scheduler's admission-gate projection, so what the gate
/// projects and what alloc/grow actually charge can never drift.
pub fn blocks_for(tokens: usize, block_tokens: usize) -> usize {
    tokens.max(1).div_ceil(block_tokens.max(1))
}

/// One in-flight sequence's slice of the pool: the block list plus the
/// number of token slots actually filled. Handles are move-only and
/// must be returned via [`KvPool::release`] — dropping one leaks its
/// blocks (caught by the pool's live-block ledger in tests).
#[derive(Debug, Default)]
pub struct KvSeq {
    blocks: Vec<u32>,
    tokens: usize,
}

impl KvSeq {
    pub fn tokens(&self) -> usize {
        self.tokens
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Token slots allocated but not filled — the sequence's internal
    /// fragmentation (always < one block).
    pub fn frag_tokens(&self, block_tokens: usize) -> usize {
        self.blocks.len() * block_tokens - self.tokens
    }
}

/// Occupancy / fragmentation / failure ledger of a [`KvPool`].
#[derive(Debug, Default, Clone, Copy)]
pub struct KvStats {
    /// Sequences allocated / released.
    pub allocs: u64,
    pub frees: u64,
    /// High-water marks over the pool's lifetime.
    pub peak_blocks: usize,
    pub peak_tokens: usize,
    /// `grow` calls refused for lack of free blocks (each is a
    /// memory-pressure event the engine answers with preemption).
    pub grow_fails: u64,
    /// Allocations clamped below the requested size by `alloc_clamped`
    /// (an oversized request degrading to a capped cache).
    pub alloc_clamps: u64,
    /// Tokens that continued WITHOUT cache growth (capped sequences —
    /// the sliding-window degrade path for requests bigger than the
    /// entire pool). Never counted against pool blocks.
    pub overflow_tokens: u64,
}

/// The paged allocator. Fixed-size token blocks, O(1) alloc/free via a
/// free-list stack; bounded (`n_blocks > 0`) or unlimited
/// (`n_blocks == 0`, ids minted on demand, nothing fails).
#[derive(Debug)]
pub struct KvPool {
    /// Pool bound in blocks; 0 = unlimited.
    n_blocks: usize,
    block_tokens: usize,
    /// KV bytes per resident token (model-derived; see
    /// [`ModelInfo::kv_bytes_per_token`]).
    bytes_per_token: usize,
    /// Recycled block ids, LIFO.
    free: Vec<u32>,
    /// Next never-used id (bounded: < n_blocks; unlimited: unbounded).
    next_fresh: u32,
    /// Live (handed-out) blocks / filled token slots across all
    /// sequences.
    used_blocks: usize,
    resident_tokens: usize,
    pub stats: KvStats,
}

impl KvPool {
    /// `n_blocks == 0` means unlimited.
    pub fn new(n_blocks: usize, block_tokens: usize,
               bytes_per_token: usize) -> KvPool {
        KvPool { n_blocks, block_tokens: block_tokens.max(1),
                 bytes_per_token, free: Vec::new(), next_fresh: 0,
                 used_blocks: 0, resident_tokens: 0,
                 stats: KvStats::default() }
    }

    /// The unlimited pool the engine defaults to: pure accounting, no
    /// gating, no failures — PR-3 behaviour.
    pub fn unlimited(model: &ModelInfo) -> KvPool {
        KvPool::new(0, DEFAULT_BLOCK_TOKENS,
                    model.kv_bytes_per_token())
    }

    pub fn is_bounded(&self) -> bool {
        self.n_blocks > 0
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn block_bytes(&self) -> usize {
        self.block_tokens * self.bytes_per_token
    }

    pub fn used_blocks(&self) -> usize {
        self.used_blocks
    }

    pub fn resident_tokens(&self) -> usize {
        self.resident_tokens
    }

    pub fn resident_bytes(&self) -> usize {
        self.resident_tokens * self.bytes_per_token
    }

    /// Free blocks (usize::MAX when unlimited) — what the scheduler's
    /// admission gate compares projected needs against.
    pub fn free_blocks(&self) -> usize {
        if self.is_bounded() {
            self.n_blocks - self.used_blocks
        } else {
            usize::MAX
        }
    }

    /// Blocks needed to hold `tokens` token slots (the module-level
    /// [`blocks_for`] rule at this pool's granularity).
    pub fn blocks_for(&self, tokens: usize) -> usize {
        blocks_for(tokens, self.block_tokens)
    }

    /// Allocated-but-unfilled token slots across all live sequences —
    /// the pool's aggregate internal fragmentation.
    pub fn frag_tokens(&self) -> usize {
        self.used_blocks * self.block_tokens - self.resident_tokens
    }

    fn take_block(&mut self) -> Option<u32> {
        if let Some(id) = self.free.pop() {
            return Some(id);
        }
        if self.is_bounded() && self.next_fresh as usize >= self.n_blocks
        {
            return None;
        }
        let id = self.next_fresh;
        self.next_fresh += 1;
        Some(id)
    }

    fn note_peaks(&mut self) {
        self.stats.peak_blocks =
            self.stats.peak_blocks.max(self.used_blocks);
        self.stats.peak_tokens =
            self.stats.peak_tokens.max(self.resident_tokens);
    }

    /// Allocate a sequence holding `tokens`; None (and no state
    /// change) if the blocks don't fit the pool.
    pub fn try_alloc(&mut self, tokens: usize) -> Option<KvSeq> {
        let need = self.blocks_for(tokens);
        if need > self.free_blocks() {
            return None;
        }
        let mut blocks = Vec::with_capacity(need);
        for _ in 0..need {
            blocks.push(self.take_block().expect("free-count checked"));
        }
        self.used_blocks += need;
        self.resident_tokens += tokens;
        self.stats.allocs += 1;
        self.note_peaks();
        Some(KvSeq { blocks, tokens })
    }

    /// Allocate as much of `tokens` as fits — the graceful-degrade
    /// path for a request bigger than the whole pool (mirrors the step
    /// budget's oversized-prompt rule: serve it capped rather than
    /// wedge the queue). The shortfall is counted in
    /// `stats.overflow_tokens`; the pool NEVER over-commits blocks.
    pub fn alloc_clamped(&mut self, tokens: usize) -> KvSeq {
        if let Some(seq) = self.try_alloc(tokens) {
            return seq;
        }
        let fit = (self.free_blocks() * self.block_tokens).min(tokens);
        self.stats.alloc_clamps += 1;
        self.stats.overflow_tokens += (tokens - fit) as u64;
        if fit == 0 {
            self.stats.allocs += 1;
            return KvSeq::default();
        }
        self.try_alloc(fit).expect("clamped size fits by construction")
    }

    /// Extend `seq` by `extra` token slots, allocating blocks as
    /// boundaries are crossed. False (and NO state change) when the
    /// pool is out of blocks — the memory-pressure signal the engine's
    /// preemption path answers.
    pub fn grow(&mut self, seq: &mut KvSeq, extra: usize) -> bool {
        let need = self.blocks_for(seq.tokens + extra)
            .saturating_sub(seq.blocks.len());
        if need > self.free_blocks() {
            self.stats.grow_fails += 1;
            return false;
        }
        for _ in 0..need {
            seq.blocks.push(self.take_block()
                            .expect("free-count checked"));
        }
        self.used_blocks += need;
        self.resident_tokens += extra;
        seq.tokens += extra;
        self.note_peaks();
        true
    }

    /// A capped sequence advanced one token WITHOUT cache growth (no
    /// free blocks, no evictable victim): pure ledger entry.
    pub fn overflow(&mut self, tokens: usize) {
        self.stats.overflow_tokens += tokens as u64;
    }

    /// Return a sequence's blocks to the free list (O(1) per block).
    pub fn release(&mut self, seq: KvSeq) {
        self.used_blocks -= seq.blocks.len();
        self.resident_tokens -= seq.tokens;
        for id in seq.blocks {
            self.free.push(id);
        }
        self.stats.frees += 1;
    }

    /// One-line occupancy summary for reports.
    pub fn describe(&self) -> String {
        if self.is_bounded() {
            format!("{} blocks x {} tokens ({:.1}KB/block, {:.1}MB \
                     pool)",
                    self.n_blocks, self.block_tokens,
                    self.block_bytes() as f64 / 1e3,
                    (self.n_blocks * self.block_bytes()) as f64 / 1e6)
        } else {
            format!("unlimited ({}-token blocks, {:.1}KB/block)",
                    self.block_tokens,
                    self.block_bytes() as f64 / 1e3)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::tiny_model;

    fn pool(n: usize, bt: usize) -> KvPool {
        KvPool::new(n, bt, 4)
    }

    #[test]
    fn bytes_per_token_comes_from_the_model() {
        let m = tiny_model();
        let p = KvPool::unlimited(&m);
        assert_eq!(p.block_bytes(),
                   DEFAULT_BLOCK_TOKENS * m.kv_bytes_per_token());
        // tiny model: 2 layers × 2 (K,V) × 64 d_model × 2 bytes.
        assert_eq!(m.kv_bytes_per_token(), 2 * 2 * 64 * 2);
    }

    #[test]
    fn alloc_grow_release_roundtrip() {
        let mut p = pool(8, 4);
        let mut a = p.try_alloc(6).unwrap(); // 2 blocks
        assert_eq!(a.n_blocks(), 2);
        assert_eq!(a.tokens(), 6);
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.free_blocks(), 6);
        assert_eq!(a.frag_tokens(4), 2);
        assert_eq!(p.frag_tokens(), 2);
        // Grow within the last block: no new block.
        assert!(p.grow(&mut a, 2));
        assert_eq!(a.n_blocks(), 2);
        assert_eq!(p.frag_tokens(), 0);
        // Next token crosses a boundary.
        assert!(p.grow(&mut a, 1));
        assert_eq!(a.n_blocks(), 3);
        assert_eq!(p.used_blocks(), 3);
        p.release(a);
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.resident_tokens(), 0);
        assert_eq!(p.free_blocks(), 8);
        assert_eq!(p.stats.peak_blocks, 3);
        assert_eq!(p.stats.peak_tokens, 9);
    }

    #[test]
    fn block_ids_are_recycled_not_leaked() {
        let mut p = pool(4, 4);
        let a = p.try_alloc(16).unwrap(); // whole pool
        assert_eq!(p.free_blocks(), 0);
        p.release(a);
        let b = p.try_alloc(16).unwrap(); // must reuse the same ids
        let mut ids: Vec<u32> = b.blocks.clone();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        p.release(b);
    }

    #[test]
    fn bounded_pool_refuses_overcommit() {
        let mut p = pool(4, 4);
        let a = p.try_alloc(12).unwrap(); // 3 of 4 blocks
        assert!(p.try_alloc(8).is_none(), "2 blocks > 1 free");
        let mut b = p.try_alloc(4).unwrap(); // last block
        assert_eq!(p.free_blocks(), 0);
        // Growing past the pool fails WITHOUT state change…
        assert!(!p.grow(&mut b, 1));
        assert_eq!(b.tokens(), 4);
        assert_eq!(p.used_blocks(), 4);
        assert_eq!(p.stats.grow_fails, 1);
        // …until a release frees a block.
        p.release(a);
        assert!(p.grow(&mut b, 1));
        p.release(b);
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn alloc_clamped_degrades_without_overcommit() {
        let mut p = pool(2, 4);
        let a = p.alloc_clamped(100); // 100 tokens into an 8-token pool
        assert_eq!(a.n_blocks(), 2);
        assert_eq!(a.tokens(), 8);
        assert_eq!(p.stats.alloc_clamps, 1);
        assert_eq!(p.stats.overflow_tokens, 92);
        assert_eq!(p.free_blocks(), 0);
        // A second clamped alloc on the exhausted pool yields an empty
        // handle, never a panic or an over-commit.
        let b = p.alloc_clamped(5);
        assert_eq!(b.n_blocks(), 0);
        assert_eq!(p.used_blocks(), 2);
        p.release(a);
        p.release(b);
    }

    #[test]
    fn unlimited_pool_never_fails_but_still_accounts() {
        let m = tiny_model();
        let mut p = KvPool::unlimited(&m);
        assert!(!p.is_bounded());
        assert_eq!(p.free_blocks(), usize::MAX);
        let mut seqs = Vec::new();
        for _ in 0..100 {
            let mut s = p.try_alloc(33).unwrap();
            assert!(p.grow(&mut s, 7));
            seqs.push(s);
        }
        assert_eq!(p.resident_tokens(), 100 * 40);
        assert_eq!(p.used_blocks(),
                   100 * p.blocks_for(40));
        assert_eq!(p.stats.grow_fails, 0);
        for s in seqs {
            p.release(s);
        }
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.stats.peak_tokens, 4000);
    }

    #[test]
    fn blocks_for_rounds_up() {
        let p = pool(0, 16);
        assert_eq!(p.blocks_for(0), 1, "a sequence holds ≥1 block");
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
        assert_eq!(p.blocks_for(160), 10);
    }

    #[test]
    fn describe_mentions_geometry() {
        let p = KvPool::new(64, 16, 512);
        let s = p.describe();
        assert!(s.contains("64 blocks"));
        assert!(s.contains("16 tokens"));
        assert!(KvPool::new(0, 16, 512).describe()
                .contains("unlimited"));
    }
}
