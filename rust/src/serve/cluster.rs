//! Multi-replica serving cluster: N independent virtual-clock
//! [`ServeEngine`]s in one process, fronted by a [`Router`] that owns
//! global ingress.
//!
//! PaCA's economics make replication unusually clean: every replica
//! holds the SAME shared frozen base, adapters hot-splice in
//! O(r·d_out) and pin zero resident bytes, so any replica can serve
//! any tenant at any time. Replicas differ only in what their history
//! gave them — queue depth, free KV blocks, and radix-prefix warmth —
//! and the router picks among them from exactly those advertised
//! signals (a [`LoadSnapshot`] per replica at the request's arrival
//! instant).
//!
//! Determinism model: the cluster steps all replicas on ONE merged
//! virtual-clock event loop. At each turn it takes the earliest
//! event in the system — the next global arrival, or the
//! earliest-clocked replica's next engine step — with ties broken
//! (arrival first, then lowest replica id) so identical traces
//! replay identically. Each replica still runs the unmodified
//! `begin_iterative / step_iterative / end_iterative` engine loop;
//! the cluster merely decides WHO steps next. With `--replicas 1`
//! the whole trace is injected up front and the loop degenerates to
//! `begin → while step → end` — exactly `serve_iterative`, bit for
//! bit (the property-test anchor).
//!
//! Failover: `--kill-replica R@T` marks replica R dead the moment
//! the merged clock reaches T. Its in-flight slots are evicted with
//! [`EvictCause::Failover`] (KV freed, resume ledger recording any
//! already-emitted first token), its admitted queue drains in
//! admission order, and everything replays on the least-loaded
//! survivor through the same `requeue()` + resume-ledger discipline
//! mid-prompt preemption already uses — so first tokens and
//! completions are emitted exactly once across the migration, which
//! the merged-stream [`ClusterAuditor`] checks event by event.
//! Not-yet-admitted future arrivals simply return to the global
//! ingress queue and get routed fresh.
//!
//! [`EvictCause::Failover`]: crate::serve::engine::EvictCause
//! [`ClusterAuditor`]: crate::serve::events::ClusterAuditor

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::metrics::{latency_breakdown_table, LatencyRecorder};
use crate::serve::engine::{ClockModel, IterState, LoadSnapshot,
                           ServeEngine};
use crate::serve::events::{merge_replica_streams, ClusterAuditor,
                           EngineEvent};
use crate::serve::router::{Router, RouterPolicy};
use crate::serve::scheduler::{OnlineScheduler, Request};
use crate::serve::telemetry::{MetricsRegistry, Phase, SloTenant,
                              StepProfiler, TelemetryOut};
use crate::util::json::Json;

/// One engine + its scheduler + the iteration state the cluster
/// drives it through. `st` is `Some` between `run`'s begin and end;
/// `alive` flips false when `--kill-replica` fires.
pub struct Replica {
    pub engine: ServeEngine,
    pub sched: OnlineScheduler,
    st: Option<IterState>,
    pub alive: bool,
}

impl Replica {
    /// Virtual-clock time of this replica's next engine event on the
    /// merged loop. A replica with work (seated slots or an admitted
    /// queue) is ready to step NOW at its own clock; an idle replica
    /// with delivered-but-future arrivals becomes ready at the
    /// earliest of those (never before its own clock — the step
    /// performs the idle jump itself); a drained or dead replica
    /// never steps.
    fn next_time(&self) -> f64 {
        if !self.alive {
            return f64::INFINITY;
        }
        let Some(st) = &self.st else { return f64::INFINITY };
        if st.in_flight() > 0 || self.sched.pending_len() > 0 {
            return st.now();
        }
        match self.sched.next_arrival() {
            Some(t) => t.max(st.now()),
            None => f64::INFINITY,
        }
    }
}

/// The cluster: replicas, router, and the global ingress queue.
pub struct Cluster {
    pub replicas: Vec<Replica>,
    pub router: Router,
    /// Undelivered arrivals, descending by arrival time (pop from
    /// the back = earliest; same layout the scheduler uses). Empty
    /// in single-replica mode — see [`Cluster::new`].
    global: Vec<Request>,
    kill: Option<(usize, f64)>,
    killed: bool,
    /// Merged-clock Prometheus scrapes (`--metrics` under
    /// `--replicas N`): per-replica feeders accumulate registries
    /// only; the cluster renders the MERGED registry at every
    /// interval boundary of the shared virtual clock, so one scrape
    /// sequence covers the whole fleet.
    metrics_out: Option<TelemetryOut>,
    metrics_interval_s: f64,
    next_scrape_s: f64,
    scrapes: u64,
    metrics_error: Option<String>,
}

impl Cluster {
    /// Build a cluster over pre-constructed (engine, scheduler)
    /// pairs. Schedulers must be EMPTY (built over `Vec::new()`) —
    /// the cluster owns ingress.
    ///
    /// Single-replica reduction: with N == 1 the entire trace is
    /// injected into replica 0's scheduler up front, in input order
    /// (which `inject` guarantees reproduces `OnlineScheduler::new`'s
    /// future vector bit for bit). Eager injection matters because
    /// the prefetch planner scans the WHOLE future via
    /// `peek_future` — lazy delivery would hide arrivals from it and
    /// diverge from `serve_iterative` under `--prefetch`. With
    /// N > 1 arrivals stay in the global queue and are routed at
    /// their arrival instant, when load snapshots mean something.
    pub fn new(parts: Vec<(ServeEngine, OnlineScheduler)>,
               requests: Vec<Request>, policy: RouterPolicy,
               margin: usize, kill: Option<(usize, f64)>) -> Cluster {
        assert!(!parts.is_empty(), "cluster needs at least 1 replica");
        if let Some((r, _)) = kill {
            assert!(parts.len() > 1 && r < parts.len(),
                    "kill-replica {r} out of range for {} replicas \
                     (and a 1-replica cluster cannot survive a kill)",
                    parts.len());
        }
        let n = parts.len();
        let mut replicas: Vec<Replica> = parts.into_iter()
            .map(|(engine, sched)| Replica {
                engine, sched, st: None, alive: true,
            })
            .collect();
        let global = if n == 1 {
            for r in requests {
                replicas[0].sched.inject(r);
            }
            Vec::new()
        } else {
            let mut g = requests;
            // Stable-sort ascending then reverse: equal arrivals pop
            // in input order, matching the scheduler's own layout.
            g.sort_by(|a, b| {
                a.arrival_s.partial_cmp(&b.arrival_s).unwrap()
            });
            g.reverse();
            g
        };
        Cluster {
            replicas,
            router: Router::new(policy, margin),
            global,
            kill,
            killed: false,
            metrics_out: None,
            metrics_interval_s: 0.0,
            next_scrape_s: f64::INFINITY,
            scrapes: 0,
            metrics_error: None,
        }
    }

    /// Arm merged-clock metrics scrapes: render the union of every
    /// replica's registry (each carries its own `replica` base
    /// label) to `out` every `interval_s` virtual seconds.
    pub fn configure_metrics(&mut self, out: TelemetryOut,
                             interval_s: f64) {
        assert!(interval_s > 0.0,
                "metrics interval must be positive");
        self.metrics_out = Some(out);
        self.metrics_interval_s = interval_s;
        self.next_scrape_s = interval_s;
    }

    /// Union of every replica's event-fed registry (None when no
    /// feeder is installed anywhere). Per-replica `replica` base
    /// labels keep merged series collision-free.
    pub fn merged_registry(&self) -> Option<MetricsRegistry> {
        let mut acc: Option<MetricsRegistry> = None;
        for rep in &self.replicas {
            let Some(r) = rep.engine.events.metrics_registry() else {
                continue;
            };
            match &mut acc {
                None => acc = Some(r),
                Some(a) => a.merge(&r),
            }
        }
        acc
    }

    /// Fold of every replica's step profiler, router phase included
    /// (the cluster stamps routing onto the picked replica's
    /// profiler). None when profiling is off everywhere.
    pub fn merged_profiler(&self) -> Option<StepProfiler> {
        let mut acc: Option<StepProfiler> = None;
        for rep in &self.replicas {
            if let Some(p) = &rep.engine.profiler {
                match &mut acc {
                    None => acc = Some(p.clone()),
                    Some(a) => a.merge(p),
                }
            }
        }
        acc
    }

    /// Per-tenant SLO burn rows summed across replicas (totals and
    /// window counters add; worst lateness wins).
    pub fn merged_slo(&self) -> Vec<SloTenant> {
        let mut by_tenant: std::collections::BTreeMap<u32, SloTenant> =
            std::collections::BTreeMap::new();
        for rep in &self.replicas {
            for b in rep.engine.events.slo_summary() {
                by_tenant.entry(b.tenant)
                    .and_modify(|a| {
                        a.total += b.total;
                        a.missed += b.missed;
                        a.window_len += b.window_len;
                        a.window_missed += b.window_missed;
                        a.max_lateness_us =
                            a.max_lateness_us.max(b.max_lateness_us);
                    })
                    .or_insert(b);
            }
        }
        by_tenant.into_values().collect()
    }

    pub fn metrics_scrapes(&self) -> u64 {
        self.scrapes
    }

    pub fn metrics_error(&self) -> Option<String> {
        self.metrics_error.clone()
    }

    /// Append one merged-registry scrape block stamped at `t_s`.
    fn scrape(&mut self, t_s: f64) {
        let Some(reg) = self.merged_registry() else { return };
        let Some(out) = &mut self.metrics_out else { return };
        self.scrapes += 1;
        let body = format!("# scrape {} t_s {t_s:.6}\n{}\n",
                           self.scrapes, reg.render());
        if let Err(e) = out.put(body.as_bytes()) {
            if self.metrics_error.is_none() {
                self.metrics_error = Some(e.to_string());
            }
        }
    }

    /// Scrape-boundary check against the merged clock: the next
    /// event in the system is about to happen at `t` — every
    /// boundary at or before the registries' current state gets one
    /// scrape (multi-interval jumps collapse).
    fn scrape_boundary(&mut self, t: f64) {
        if self.metrics_out.is_none() || t < self.next_scrape_s
            || t.is_infinite()
        {
            return;
        }
        let at = self.next_scrape_s;
        self.scrape(at);
        let k = (t / self.metrics_interval_s).floor() + 1.0;
        self.next_scrape_s = k * self.metrics_interval_s;
    }

    /// Earliest event anywhere in the system — the kill trigger
    /// compares against this so a kill at T fires after every event
    /// strictly before T has been processed.
    fn next_event_time(&self) -> f64 {
        let t_arr = self.global.last().map(|r| r.arrival_s)
            .unwrap_or(f64::INFINITY);
        let t_step = self.replicas.iter()
            .map(Replica::next_time)
            .fold(f64::INFINITY, f64::min);
        t_arr.min(t_step)
    }

    /// Replica to step next: argmin next_time, ties to lowest id.
    fn next_step(&self) -> (usize, f64) {
        let mut best = (0, f64::INFINITY);
        for (i, rep) in self.replicas.iter().enumerate() {
            let t = rep.next_time();
            if t < best.1 {
                best = (i, t);
            }
        }
        best
    }

    /// Drive every replica to completion on the merged virtual
    /// clock, then settle and audit each engine (`finish` restores
    /// the shared base bit-exactly and runs the per-replica leak and
    /// event checks).
    pub fn run(&mut self, clock: ClockModel) -> Result<()> {
        for rep in &mut self.replicas {
            rep.st = Some(rep.engine.begin_iterative(&mut rep.sched,
                                                     clock));
        }
        loop {
            if let Some((kr, kill_t)) = self.kill {
                if !self.killed && self.next_event_time() >= kill_t {
                    self.failover(kr);
                    self.killed = true;
                    continue;
                }
            }
            let t_arr = self.global.last().map(|r| r.arrival_s)
                .unwrap_or(f64::INFINITY);
            let (idx, t_step) = self.next_step();
            // Scrape BEFORE the next event applies: a block stamped
            // at boundary T covers exactly the events before T, so
            // counters are monotone across the scrape sequence.
            self.scrape_boundary(t_arr.min(t_step));
            if t_arr <= t_step {
                if t_arr.is_infinite() {
                    break;
                }
                let r = self.global.pop().expect("finite arrival");
                self.deliver(r);
            } else {
                let rep = &mut self.replicas[idx];
                let st = rep.st.as_mut().expect("begun above");
                rep.engine.step_iterative(&mut rep.sched, st)?;
            }
        }
        let makespan = self.replicas.iter()
            .filter_map(|rep| rep.st.as_ref().map(|st| st.now()))
            .fold(0.0f64, f64::max);
        for rep in &mut self.replicas {
            if let Some(st) = rep.st.take() {
                rep.engine.end_iterative(st);
            }
            rep.engine.finish()?;
        }
        // Closing scrape: the final registry state at the cluster
        // makespan (after finalize settled every sink).
        if self.metrics_out.is_some() {
            self.scrape(makespan);
        }
        Ok(())
    }

    /// Route one arrival: snapshot every alive replica's advertised
    /// load, ask the router, inject into the pick's scheduler. The
    /// request is then that replica's to admit at its own clock.
    fn deliver(&mut self, r: Request) {
        // Routing is bookkeeping on the merged clock (0 virtual
        // seconds); the wall stamp pair lands on the PICKED
        // replica's profiler so the merged profile carries a Router
        // row.
        let wall_armed = self.replicas.iter().any(
            |rep| rep.engine.profiler.as_ref()
                .is_some_and(|p| p.wall));
        let t0 = if wall_armed { Some(Instant::now()) } else { None };
        let loads = self.snapshots(None);
        let name = self.replicas[0].engine.pool.name(r.tenant)
            .to_string();
        let pick = self.router.route(&name, r.tenant.0, &loads);
        let rep = &mut self.replicas[pick];
        if let Some(p) = rep.engine.profiler.as_mut() {
            p.end(Phase::Router, t0, 0.0);
        }
        rep.sched.inject(r);
    }

    /// Advertised loads, `None` for dead replicas (and for
    /// `exclude`, which the failover path uses to hide the
    /// about-to-die replica from survivor selection).
    fn snapshots(&self, exclude: Option<usize>)
                 -> Vec<Option<LoadSnapshot>> {
        self.replicas.iter().enumerate()
            .map(|(i, rep)| {
                if Some(i) == exclude || !rep.alive {
                    return None;
                }
                rep.st.as_ref().map(|st| {
                    rep.engine.load_snapshot(&rep.sched, st)
                })
            })
            .collect()
    }

    /// Kill replica `kr` and migrate its work, exactly once:
    ///   * seated slots → evicted (`Failover` cause: KV freed, resume
    ///     ledger keeps any already-emitted first token) and requeued
    ///     on the least-loaded survivor, in seat order;
    ///   * admitted-but-unseated requests → requeued after them, in
    ///     admission order;
    ///   * the resume ledger moves with them, so replays skip
    ///     duplicate first-token emission;
    ///   * event-auditor custody transfers per request
    ///     (`migrate_out` / `adopt`), so BOTH per-replica auditors
    ///     stay clean across the migration;
    ///   * never-admitted future arrivals → back into the global
    ///     ingress queue for fresh routing (no events exist for them
    ///     yet, so nothing to transfer).
    fn failover(&mut self, kr: usize) {
        let loads = self.snapshots(Some(kr));
        let survivor = Router::least_loaded(&loads);
        let (evacuated, pending, future, resume) = {
            let rep = &mut self.replicas[kr];
            rep.alive = false;
            let st = rep.st.as_mut().expect("kill fires inside run");
            let evacuated = rep.engine.evacuate(st);
            let pending = rep.sched.drain_pending();
            let future = rep.sched.drain_future();
            let resume = rep.engine.export_resume();
            (evacuated, pending, future, resume)
        };
        self.router.stats.failover +=
            (evacuated.len() + pending.len() + future.len()) as u64;
        let flags: HashMap<u64, bool> = resume.iter()
            .map(|(id, info)| (*id, info.first_token_s.is_some()))
            .collect();
        let killed_events = self.replicas[kr].engine.events.clone();
        let surv_events =
            self.replicas[survivor].engine.events.clone();
        for r in evacuated.iter().chain(pending.iter()) {
            killed_events.migrate_out(r.id);
            let awaiting = flags.contains_key(&r.id);
            let first = flags.get(&r.id).copied().unwrap_or(false);
            surv_events.adopt(r.id, r.arrival_s, awaiting, first);
        }
        self.replicas[survivor].engine.import_resume(resume);
        for r in evacuated.into_iter().chain(pending) {
            self.replicas[survivor].sched.requeue(r);
        }
        for r in future {
            let at = self.global
                .partition_point(|x| x.arrival_s > r.arrival_s);
            self.global.insert(at, r);
        }
    }

    /// Per-replica event streams in replica-id order (empty vecs
    /// when tracing is off).
    pub fn event_streams(&self) -> Vec<Vec<EngineEvent>> {
        self.replicas.iter()
            .map(|rep| rep.engine.events.snapshot())
            .collect()
    }

    /// Audit the merged cross-replica interleaving: single
    /// residency, exactly-once first token and completion across
    /// failover, merged-clock monotonicity.
    pub fn audit(&self) -> ClusterAuditor {
        ClusterAuditor::audit(&merge_replica_streams(
            &self.event_streams()))
    }

    /// Human report. Single replica: exactly the engine's own report
    /// (the CLI reduction anchor). Multi-replica: a `cluster:` block
    /// (per-replica load + router counters), then merged-across-
    /// replicas latency percentiles.
    pub fn report(&self) -> String {
        if self.replicas.len() == 1 {
            return self.replicas[0].engine.report();
        }
        let mut out = format!("cluster: {} replicas | router {}\n",
                              self.replicas.len(),
                              self.router.policy().name());
        for (i, rep) in self.replicas.iter().enumerate() {
            let s = &rep.engine.stats;
            out.push_str(&format!(
                "  replica {}{}: {} requests | {} steps | {} \
                 preemptions (failover {}) | virtual {:.3}s | \
                 checksum {:.6}\n",
                i, if rep.alive { "" } else { " [killed]" },
                s.requests, s.steps, s.preemptions,
                s.preempt_failover, s.virtual_s,
                rep.engine.checksum));
        }
        let rs = self.router.stats;
        out.push_str(&format!(
            "router: home {} | warm {} | steal {} | spill {} | \
             failover: {}\n",
            rs.home, rs.warm, rs.steal, rs.spill, rs.failover));
        let mut queueing = LatencyRecorder::default();
        let mut service = LatencyRecorder::default();
        let mut e2e = LatencyRecorder::default();
        let mut ttft = LatencyRecorder::default();
        let (mut misses, mut total) = (0u64, 0u64);
        let mut makespan = 0.0f64;
        for rep in &self.replicas {
            queueing.absorb(&rep.engine.queueing);
            service.absorb(&rep.engine.service);
            e2e.absorb(&rep.engine.e2e);
            ttft.absorb(&rep.engine.ttft);
            misses += rep.engine.stats.deadline_misses;
            total += rep.engine.stats.deadline_total;
            makespan = makespan.max(rep.engine.stats.virtual_s);
        }
        if e2e.count("(all)") > 0 {
            out.push_str("\nmerged online pipeline (all replicas, \
                          shared virtual clock):\n");
            out.push_str(&latency_breakdown_table(
                &queueing, &service, &e2e, "tenant").render());
        }
        if ttft.count("(all)") > 0 {
            let ms = |v: Option<f64>| match v {
                Some(v) => format!("{:.3}ms", v * 1e3),
                None => "-".to_string(),
            };
            out.push_str(&format!(
                "merged ttft: p50 {} p99 {} ({} first tokens)\n",
                ms(ttft.percentile("(all)", 0.50)),
                ms(ttft.percentile("(all)", 0.99)),
                ttft.count("(all)")));
        }
        if total > 0 {
            out.push_str(&format!(
                "deadline misses: {}/{} ({:.1}%)\n", misses, total,
                100.0 * misses as f64 / total as f64));
        }
        out.push_str(&format!("cluster makespan {:.3}s\n", makespan));
        if let Some(p) = self.merged_profiler() {
            if p.steps > 0 {
                out.push_str(&format!(
                    "\nmerged step profile ({} replicas): {} steps, \
                     {:.3}s virtual service time\n",
                    self.replicas.len(), p.steps, p.step_virtual_s));
                out.push_str(&p.table().render());
            }
        }
        let burns = self.merged_slo();
        if !burns.is_empty() {
            out.push_str("\nmerged slo burn:\n");
            for b in &burns {
                let name = self.replicas[0].engine.pool.name(
                    crate::serve::scheduler::TenantId(b.tenant));
                out.push_str(&format!(
                    "  {name}: {}/{} missed ({:.1}% of window) | \
                     max late {:.3}ms\n",
                    b.missed, b.total, 100.0 * b.burn_rate(),
                    b.max_lateness_us as f64 / 1e3));
            }
        }
        if self.scrapes > 0 {
            out.push_str(&format!(
                "metrics: {} merged scrapes\n", self.scrapes));
        }
        out
    }

    /// Machine report. Single replica: exactly the engine's own
    /// JSON. Multi-replica: per-replica engine reports plus router
    /// counters.
    pub fn report_json(&self) -> Json {
        if self.replicas.len() == 1 {
            return self.replicas[0].engine.report_json();
        }
        let mut root = std::collections::BTreeMap::new();
        root.insert("replicas".to_string(), Json::Arr(
            self.replicas.iter()
                .map(|rep| rep.engine.report_json())
                .collect()));
        root.insert("alive".to_string(), Json::Arr(
            self.replicas.iter()
                .map(|rep| Json::Bool(rep.alive))
                .collect()));
        let rs = self.router.stats;
        let mut router = std::collections::BTreeMap::new();
        let num = |v: u64| Json::Num(v as f64);
        router.insert("policy".to_string(),
                      Json::Str(self.router.policy().name()
                                .to_string()));
        router.insert("home".to_string(), num(rs.home));
        router.insert("warm".to_string(), num(rs.warm));
        router.insert("steal".to_string(), num(rs.steal));
        router.insert("spill".to_string(), num(rs.spill));
        router.insert("failover".to_string(), num(rs.failover));
        root.insert("router".to_string(), Json::Obj(router));
        let mut metrics = std::collections::BTreeMap::new();
        if let Some(reg) = self.merged_registry() {
            metrics.insert("registry".to_string(),
                           reg.snapshot_json());
            metrics.insert("scrapes".to_string(),
                           num(self.scrapes));
        }
        if let Some(p) = self.merged_profiler() {
            metrics.insert("profiler".to_string(), p.to_json());
        }
        if !metrics.is_empty() {
            root.insert("metrics".to_string(), Json::Obj(metrics));
        }
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::ModelInfo;
    use crate::serve::engine::{tiny_model, BaseModel, EngineStats,
                               HostBackend};
    use crate::serve::events::Events;
    use crate::serve::registry::{AdapterRegistry, PacaAdapter};
    use crate::serve::scheduler::{Policy, TenantPool};
    use crate::serve::trace::{self, Trace, TraceSpec};

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(BaseModel::synthetic(&m, 7), reg,
                         Box::<HostBackend>::default(), pool)
    }

    fn trace(n: usize, seed: u64) -> Trace {
        trace::synthesize(&TraceSpec {
            n_requests: n,
            n_tenants: 3,
            mean_tokens: 12,
            decode_tokens: 4,
            req_per_s: 40.0,
            seed,
            ..TraceSpec::default()
        })
    }

    const CLOCK: ClockModel = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };

    fn cluster_for(n: usize, tr: &Trace, policy: RouterPolicy,
                   kill: Option<(usize, f64)>) -> Cluster {
        let parts = (0..n).map(|_| {
            let mut eng = engine_for(tr.pool.clone());
            eng.configure_events(Events::recording());
            let mut sched = OnlineScheduler::new(
                Vec::new(), tr.pool.len(), 4, Policy::SwapAware);
            sched.decode_slack_s = 0.0;
            (eng, sched)
        }).collect();
        Cluster::new(parts, tr.requests.clone(), policy, 4, kill)
    }

    fn scrub_wall(mut s: EngineStats) -> EngineStats {
        s.wall_s = 0.0;
        s.forward_s = 0.0;
        s.swap_s = 0.0;
        s
    }

    #[test]
    fn single_replica_cluster_is_serve_iterative_bit_for_bit() {
        let tr = trace(24, 3);
        let mut base = engine_for(tr.pool.clone());
        let mut sched = OnlineScheduler::new(
            tr.requests.clone(), tr.pool.len(), 4, Policy::SwapAware);
        sched.decode_slack_s = 0.0;
        base.serve_iterative(&mut sched, CLOCK).unwrap();
        base.finish().unwrap();

        let mut cl = cluster_for(1, &tr, RouterPolicy::Shard, None);
        cl.run(CLOCK).unwrap();
        let eng = &cl.replicas[0].engine;
        assert_eq!(scrub_wall(eng.stats), scrub_wall(base.stats));
        assert_eq!(eng.checksum, base.checksum);
        // Virtual-clock latency samples are deterministic (wall
        // times are not — the scrub above); every percentile must
        // agree with the monolithic loop's.
        for q in [0.0, 0.25, 0.50, 0.75, 0.99, 1.0] {
            assert_eq!(eng.e2e.percentile("(all)", q),
                       base.e2e.percentile("(all)", q));
            assert_eq!(eng.queueing.percentile("(all)", q),
                       base.queueing.percentile("(all)", q));
            assert_eq!(eng.ttft.percentile("(all)", q),
                       base.ttft.percentile("(all)", q));
        }
    }

    #[test]
    fn two_replicas_complete_every_request_with_clean_audit() {
        for policy in RouterPolicy::ALL {
            let tr = trace(30, 9);
            let mut cl = cluster_for(2, &tr, policy, None);
            cl.run(CLOCK).unwrap();
            let done: u64 = cl.replicas.iter()
                .map(|r| r.engine.stats.requests).sum();
            assert_eq!(done, 30, "{}", policy.name());
            let audit = cl.audit();
            assert_eq!(audit.violation_count(), 0, "{}: {:?}",
                       policy.name(), audit.violations());
        }
    }

    #[test]
    fn shard_policy_pins_each_tenant_to_one_replica() {
        let tr = trace(30, 5);
        let mut cl = cluster_for(4, &tr, RouterPolicy::Shard, None);
        cl.run(CLOCK).unwrap();
        // Every request routed home; nothing stolen or spilled.
        assert_eq!(cl.router.stats.home, 30);
        assert_eq!(cl.router.stats.steal + cl.router.stats.spill
                   + cl.router.stats.failover, 0);
        // Each tenant's completions live on exactly its home shard.
        let streams = cl.event_streams();
        for (rid, evs) in streams.iter().enumerate() {
            for ev in evs {
                if let Some(t) = ev.tenant {
                    let name = tr.pool.name(
                        crate::serve::scheduler::TenantId(t));
                    assert_eq!(cl.router.home_shard(name, 4), rid,
                               "tenant {t} event on replica {rid}");
                }
            }
        }
    }

    #[test]
    fn kill_replica_fails_over_exactly_once() {
        let tr = trace(40, 13);
        // Kill mid-trace: shard policy guarantees the victim holds
        // work for its tenants when it dies.
        let mut cl = cluster_for(2, &tr, RouterPolicy::Shard,
                                 Some((0, 0.2)));
        cl.run(CLOCK).unwrap();
        assert!(!cl.replicas[0].alive);
        assert!(cl.router.stats.failover > 0, "kill moved nothing");
        let done: u64 = cl.replicas.iter()
            .map(|r| r.engine.stats.requests).sum();
        assert_eq!(done, 40);
        let audit = cl.audit();
        assert_eq!(audit.violation_count(), 0, "{:?}",
                   audit.violations());
        // The survivor replays evictions through the resume ledger.
        assert!(cl.replicas[0].engine.stats.preempt_failover > 0
                || cl.replicas[0].engine.stats.requests < 40);
        let rep = cl.report();
        assert!(rep.contains("[killed]"), "{rep}");
        assert!(rep.contains("failover:"), "{rep}");
    }

    #[test]
    fn kill_after_drain_is_a_harmless_noop() {
        let tr = trace(10, 2);
        let mut cl = cluster_for(2, &tr, RouterPolicy::LeastLoaded,
                                 Some((1, 1e9)));
        cl.run(CLOCK).unwrap();
        assert!(!cl.replicas[1].alive);
        let done: u64 = cl.replicas.iter()
            .map(|r| r.engine.stats.requests).sum();
        assert_eq!(done, 10);
        assert_eq!(cl.audit().violation_count(), 0);
    }

    #[test]
    fn cluster_telemetry_merges_scrapes_and_profiles() {
        use crate::serve::telemetry::MetricsFeeder;
        let tr = trace(30, 9);
        let plain = {
            let mut cl = cluster_for(2, &tr,
                                     RouterPolicy::LeastLoaded, None);
            cl.run(CLOCK).unwrap();
            cl
        };
        let mut cl = cluster_for(2, &tr, RouterPolicy::LeastLoaded,
                                 None);
        for (i, rep) in cl.replicas.iter_mut().enumerate() {
            let replica = i.to_string();
            // Registry-only feeders (no per-replica output): the
            // cluster scrapes the merged registry itself.
            let feeder = MetricsFeeder::new(
                &[("replica", replica.as_str())], tr.pool.names(),
                0.05, None);
            rep.engine.events.configure_metrics(feeder);
            rep.engine.configure_profiler(false);
        }
        cl.configure_metrics(TelemetryOut::memory(), 0.05);
        cl.run(CLOCK).unwrap();
        // Observation never perturbs scheduling: engine stats are
        // bit-identical to the un-telemetered cluster.
        for (a, b) in cl.replicas.iter().zip(&plain.replicas) {
            assert_eq!(scrub_wall(a.engine.stats),
                       scrub_wall(b.engine.stats));
            assert_eq!(a.engine.checksum, b.engine.checksum);
        }
        assert_eq!(cl.audit().violation_count(), 0, "{:?}",
                   cl.audit().violations());
        assert!(cl.metrics_scrapes() > 1, "interval scrapes + close");
        assert!(cl.metrics_error().is_none());
        let text = String::from_utf8(
            cl.metrics_out.as_ref().unwrap().mem().unwrap()
                .to_vec()).unwrap();
        assert!(text.contains("# scrape 1 "), "{text}");
        assert!(text.contains("replica=\"0\""));
        assert!(text.contains("replica=\"1\""));
        assert!(!text.contains("NaN"));
        // Counters are monotone per series across scrape blocks.
        let mut seen: HashMap<&str, u64> = HashMap::new();
        for line in text.lines() {
            if !line.starts_with("paca_events_total{") {
                continue;
            }
            let (key, val) = line.rsplit_once(' ').unwrap();
            let val: u64 = val.parse().unwrap();
            let prev = seen.insert(key, val).unwrap_or(0);
            assert!(val >= prev, "counter went down: {line}");
        }
        // The merged profile folds both engines plus the router
        // stamps the cluster put on the picked replicas.
        let p = cl.merged_profiler().expect("profilers armed");
        assert!(p.steps > 0);
        assert_eq!(p.phase(Phase::Router).count, 30,
                   "every arrival routed exactly once");
        let (got, want) = (p.total_virtual(), p.step_virtual_s);
        assert!((got - want).abs() <= 1e-9 * want.max(1.0),
                "unattributed cluster step time: {got} vs {want}");
        let report = cl.report();
        assert!(report.contains("merged step profile"), "{report}");
        assert!(report.contains("merged scrapes"), "{report}");
        let j = cl.report_json();
        assert!(j.get("metrics").and_then(|m| m.get("registry"))
                .is_some());
    }

    #[test]
    fn report_json_carries_replicas_and_router_counters() {
        let tr = trace(16, 4);
        let mut cl = cluster_for(2, &tr, RouterPolicy::Warmth, None);
        cl.run(CLOCK).unwrap();
        let j = cl.report_json();
        let reps = match j.get("replicas") {
            Some(Json::Arr(a)) => a.len(),
            _ => 0,
        };
        assert_eq!(reps, 2);
        assert_eq!(j.get("router").and_then(|r| r.get("policy"))
                       .and_then(Json::as_str),
                   Some("warmth"));
    }
}
