//! Multi-tenant adapter serving — the inference half of the north star.
//!
//! PaCA's central property (paper §2–3) is that an adapter is not an
//! extra layer but a set of *partial connections inside* the pretrained
//! weights: per tenant it is just `(idx, P)` — r selected input rows
//! per target linear — which splices into the shared frozen base in
//! O(r·d_out) byte copies and un-splices bit-exactly. Serving therefore
//! pays ZERO per-token adapter overhead (the spliced base IS the
//! effective model), where LoRA-family serving either merges per tenant
//! (un-shareable full-weight copies) or keeps adapters unmerged and
//! pays the serialized extra-kernel path on every request ("LoRA Is
//! Slower Than You Think"; LoRAFusion).
//!
//! Modules:
//!   * [`registry`]  — LRU-bounded [`registry::AdapterRegistry`] of
//!     compact per-tenant `(idx, P)` adapters with load/save/evict and
//!     the hot-splice / exact-un-splice swap built on
//!     `coordinator::merge::{splice_rows, unsplice_rows}`.
//!   * [`scheduler`] — tenant-name interning
//!     ([`scheduler::TenantPool`]), the offline batch planner (kept as
//!     the correctness baseline), and the online
//!     [`scheduler::OnlineScheduler`]: arrival-time admission,
//!     per-tenant pending queues, incremental fifo / swap-aware /
//!     slo-aware dispatch under a `max_batch_tokens` step budget,
//!     with continuous batching down to the token level
//!     (`join_live`: pending same-tenant requests enter a live batch
//!     mid-generation).
//!   * [`trace`]     — synthetic multi-tenant workloads (Zipf tenant
//!     popularity, Poisson or bursty arrivals, per-request SLO
//!     deadlines, jittered decode lengths) + JSONL persistence
//!     (absent fields read back as the old defaults, so archived
//!     traces stay valid).
//!   * [`kv`]        — the paged KV-cache memory manager: a bounded
//!     pool of fixed-size REFERENCE-COUNTED token blocks
//!     (`--kv-blocks` / `--kv-block-tokens`, bytes per token from
//!     `ModelInfo::kv_bytes_per_token`), per-sequence block lists
//!     with O(1) alloc/free, copy-on-write forks of shared
//!     partially-filled tails, and the occupancy / fragmentation /
//!     pressure ledger — now split into pinned vs reclaimable
//!     (cache-only) occupancy — that the admission gate and
//!     preemption policy act on. `--kv-blocks 0` = unlimited (pure
//!     accounting, PR-3 behaviour).
//!   * [`prefix`]    — the per-tenant prefix-sharing radix cache
//!     (`--prefix-cache`, default on): completed and preempted
//!     sequences donate the blocks covering their shared prompt
//!     prefix (`--shared-prefix-tokens` system prompts), later
//!     same-tenant prefills attach them (refcount bump, zero
//!     compute) and charge only the uncached suffix to the step
//!     budget and the clock; LRU reclaim yields cache-only blocks
//!     under pressure, and a registry eviction/reload of a tenant's
//!     adapter invalidates that tenant's subtree (the splice changed
//!     the merged weights ⇒ its cached KV is stale). Sharing is
//!     strictly per-tenant for the same reason. `--prefix-cache off`
//!     = bit-for-bit the PR-4 engine.
//!   * [`events`]    — step-level observability: the typed
//!     [`events::EngineEvent`] stream every serve-layer module emits
//!     behind the zero-cost-when-disabled [`events::Events`] handle
//!     (`--trace-events PATH`, `--trace-format jsonl|chrome`), the
//!     per-request span reconstructor that re-derives
//!     queueing/TTFT/TPOT from events alone, and the online
//!     [`events::EventAuditor`] enforcing the causal invariants
//!     (dispatch-after-arrival, exactly-once completion, paired
//!     splices, a balanced KV ledger) DURING the run.
//!   * [`engine`]    — the serving engine around the
//!     [`engine::ForwardBackend`] trait (host GEMM always available;
//!     PJRT drives the lowered eval artifact when `make artifacts`
//!     has run): offline plan replay, the whole-batch virtual-clock
//!     loop (`serve_online`), and the decode-style iteration-level
//!     loop (`serve_iterative`: prefill/decode token steps, slots
//!     freed mid-batch, TTFT/TPOT + per-step occupancy accounting,
//!     and — under a bounded KV pool — decode preemption: under
//!     memory pressure or an urgent other-tenant deadline the
//!     least-urgent decoding slot is evicted, its blocks freed, and
//!     the request re-queued with recompute-on-resume, emitted-token
//!     accounting staying exactly-once).
//!   * [`telemetry`] — live observability riding the event bus: the
//!     streaming JSONL sink ([`telemetry::JsonlStreamSink`], a
//!     bounded ring flushing `--trace-events` incrementally during
//!     the run), the Prometheus-style
//!     [`telemetry::MetricsRegistry`] (counters / gauges /
//!     log-bucketed histograms with tenant/replica/policy labels,
//!     scraped every `--metrics-interval` virtual seconds to
//!     `--metrics PATH` by the event-fed
//!     [`telemetry::MetricsFeeder`] — zero new emission sites), the
//!     per-phase [`telemetry::StepProfiler`] (admission / dispatch /
//!     prefill / decode / kv-grow / prefix / router, virtual
//!     attribution partitioning step time exactly, wall dual stamps
//!     under `--clock measured`, folded stacks via `--profile`), and
//!     the per-tenant rolling SLO burn budget
//!     ([`telemetry::SloBurnTracker`] fed by `SloBurn` events).
//!   * [`router`]    — cluster ingress routing. PaCA replicas pin
//!     zero adapter bytes, so any replica can serve any tenant; the
//!     [`router::Router`] picks one purely from advertised load
//!     signals (queue depth, free KV blocks, radix-prefix warmth)
//!     under `--router shard|least-loaded|warmth`, with overflow
//!     spill and dead-shard failover.
//!   * [`cluster`]   — the multi-replica serving cluster
//!     (`--replicas N`): N independent engines (own registry, KV
//!     pool, prefix cache, event stream) stepped on ONE merged
//!     virtual-clock event loop — deterministic and
//!     property-testable — with router-owned global ingress,
//!     `--kill-replica R@T` failover that replays a dead replica's
//!     work on the least-loaded survivor through the existing
//!     requeue + resume-ledger discipline (first tokens and
//!     completions stay exactly-once), and the merged-stream
//!     [`events::ClusterAuditor`] checking the cross-replica
//!     invariants. `--replicas 1` reduces bit-for-bit to
//!     `serve_iterative`.
//!   * [`cost`]      — analytic serving-cost extension of `simulator`
//!     (A100/Gaudi2): merged-PaCA vs unmerged-LoRA throughput,
//!     adapter-swap amortization, the M/D/1 queueing-delay term, the
//!     prefill/decode arithmetic-intensity split
//!     (`decode_step_time`, TTFT/TPOT projections), and the
//!     KV-capacity tables (max concurrent sequences / max context
//!     per method — the paper's longer-sequence framing at serving
//!     time), for `paca bench --exp serve`.
//!
//! Entry point: `paca serve --adapters DIR --requests TRACE --batch N`
//! (main.rs), which synthesizes the trace/adapters on first run and
//! serves it through the online pipeline.

pub mod cluster;
pub mod cost;
pub mod engine;
pub mod events;
pub mod kv;
pub mod prefix;
pub mod registry;
pub mod router;
pub mod scheduler;
pub mod telemetry;
pub mod trace;
