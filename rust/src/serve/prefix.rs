//! Prefix-sharing radix cache over the paged KV pool (PR 5).
//!
//! PaCA's merged serving makes each tenant's forward the bare spliced
//! base model, so two same-tenant requests that open with the same
//! tokens — a system prompt, a few-shot header — compute IDENTICAL KV
//! for that prefix on every request. This module converts that repeat
//! compute into block reuse on the `serve::kv` pool: completed (and
//! preempted) sequences DONATE the blocks covering their shared prompt
//! prefix to a per-tenant radix tree instead of freeing them, and a
//! later prefill ATTACHES the matched blocks (refcount bump, zero
//! compute) and pays only the uncached suffix — the measured TTFT and
//! prefill-token win of "LoRA Is Slower Than You Think" /
//! LoRAFusion's shared-prefix regime, on the PaCA serving stack.
//!
//! Two PaCA-specific correctness rules:
//!
//!   * Sharing is strictly PER-TENANT. Hot-splicing a tenant's
//!     adapter columns changes the merged weights, so the same tokens
//!     produce DIFFERENT KV under different tenants — a cross-tenant
//!     hit would serve silently wrong attention state. Each tenant
//!     gets its own tree; there is no global match path at all.
//!   * A tenant's cached KV is only valid for the adapter generation
//!     it was computed under. When the registry evicts or reloads a
//!     tenant's adapter ([`AdapterRegistry`] bumps the tenant's
//!     generation), the whole subtree is invalidated — the spliced
//!     base that produced those blocks no longer exists.
//!
//! Because the synthesized workload models one system prompt per
//! tenant, each per-tenant tree is a single radix PATH: a chain of
//! blocks, all full except possibly the last ([`Chain`]). Matching is
//! block-granular — a cached block matches only if the request's
//! prompt covers the block's entire filled content ([`cover_match`],
//! shared verbatim with the scheduler's admission projection so the
//! gate and the attach can never disagree). Donations extend the
//! chain; a donor whose block out-fills a cached partial tail replaces
//! it (the radix "longest prefix wins" rule).
//!
//! Cached blocks nobody is running on (pool refcount 1 — the cache's
//! own hold) are RECLAIMABLE: [`PrefixCache::reclaim`] hands them back
//! under memory pressure, least-recently-hit tenant first, deepest
//! block first (a chain must stay a prefix — the tail is always the
//! only removable block). Blocks a live sequence shares stay pinned
//! and are never reclaimed from under it.

use crate::serve::events::{EventKind, Events};
use crate::serve::kv::KvPool;
use crate::serve::scheduler::TenantId;

/// The usable shared prefix of a prompt: the LAST prompt token is
/// always computed (it emits the request's first output token), so at
/// most `prompt_tokens − 1` prefix tokens can ever come from cache.
/// Shared by the engine's attach and the scheduler's projection — the
/// same no-drift discipline as [`cover_match`].
pub fn usable_prefix(shared_prefix_tokens: usize,
                     prompt_tokens: usize) -> usize {
    shared_prefix_tokens.min(prompt_tokens.saturating_sub(1))
}

/// THE block-granular match rule, shared by the cache's lookup and the
/// scheduler's admission projection: given a tenant's cached cover
/// (`full_blocks` full blocks plus a partial tail of `tail_fill`
/// tokens, 0 = none), how much of a `want`-token prefix is served from
/// cache. Returns (full blocks matched, partial-tail tokens matched);
/// hit tokens = `full·block_tokens + tail`. A block matches only if
/// its ENTIRE filled content fits inside `want`.
pub fn cover_match(full_blocks: usize, tail_fill: usize,
                   block_tokens: usize,
                   want: usize) -> (usize, usize) {
    let bt = block_tokens.max(1);
    let full = full_blocks.min(want / bt);
    let tail = if full == full_blocks && tail_fill > 0
        && full_blocks * bt + tail_fill <= want
    {
        tail_fill
    } else {
        0
    };
    (full, tail)
}

/// One lookup's result: the cached blocks to attach (in sequence
/// order) and the prompt tokens they cover.
#[derive(Debug, Default)]
pub struct PrefixMatch {
    pub blocks: Vec<u32>,
    pub tokens: usize,
}

/// Hit / donation / reclaim / invalidation ledger.
#[derive(Debug, Default, Clone, Copy)]
pub struct PrefixStats {
    pub lookups: u64,
    /// Lookups that matched at least one block.
    pub hits: u64,
    /// Prompt tokens served from cache instead of recomputed.
    pub hit_tokens: u64,
    /// Blocks handed to the cache by completing/preempted sequences.
    pub donated_blocks: u64,
    /// Cache-only blocks reclaimed under memory pressure (LRU).
    pub reclaimed_blocks: u64,
    /// Tenant subtrees dropped because the registry evicted/reloaded
    /// the tenant's adapter (stale KV) — plus explicit invalidations.
    pub invalidations: u64,
}

/// One tenant's radix path: blocks all full except possibly the last.
#[derive(Debug)]
struct Chain {
    blocks: Vec<u32>,
    /// Filled tokens of the LAST block (== block_tokens when full).
    tail_fill: usize,
    /// Adapter generation this KV was computed under (see
    /// `AdapterRegistry::generation`).
    gen: u64,
    /// LRU stamp: monotone counter value of the last hit/donation.
    last_hit: u64,
}

impl Chain {
    /// (full blocks, partial-tail tokens or 0) — the cover the
    /// scheduler's projection consumes.
    fn cover(&self, block_tokens: usize) -> (usize, usize) {
        if self.tail_fill == block_tokens {
            (self.blocks.len(), 0)
        } else {
            (self.blocks.len() - 1, self.tail_fill)
        }
    }
}

/// The per-tenant prefix cache (see module docs).
#[derive(Debug)]
pub struct PrefixCache {
    enabled: bool,
    /// Chains indexed by dense `TenantId` (grown on demand).
    chains: Vec<Option<Chain>>,
    /// Monotone LRU clock.
    clock: u64,
    /// Event-stream handle (off by default; the engine installs its
    /// own so hit/donate/reclaim/invalidate join the run's stream).
    events: Events,
    pub stats: PrefixStats,
}

impl PrefixCache {
    pub fn new(enabled: bool) -> PrefixCache {
        PrefixCache { enabled, chains: Vec::new(), clock: 0,
                      events: Events::off(),
                      stats: PrefixStats::default() }
    }

    /// Install an event-stream handle. Off by default.
    pub fn set_events(&mut self, events: Events) {
        self.events = events;
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    fn chain_mut(&mut self, t: TenantId) -> &mut Option<Chain> {
        let i = t.index();
        if i >= self.chains.len() {
            self.chains.resize_with(i + 1, || None);
        }
        &mut self.chains[i]
    }

    /// Tenants that currently have a cached subtree.
    pub fn tenants(&self) -> Vec<TenantId> {
        self.chains.iter().enumerate()
            .filter(|(_, c)| c.is_some())
            .map(|(i, _)| TenantId(i as u32))
            .collect()
    }

    /// Blocks currently held by the cache across all tenants.
    pub fn cached_blocks(&self) -> usize {
        self.chains.iter().flatten().map(|c| c.blocks.len()).sum()
    }

    /// The tenant's cover for admission projection: (full blocks,
    /// partial-tail tokens). (0, 0) when nothing is cached.
    pub fn cover(&self, t: TenantId, block_tokens: usize)
                 -> (usize, usize) {
        match self.chains.get(t.index()).and_then(Option::as_ref) {
            Some(c) => c.cover(block_tokens),
            None => (0, 0),
        }
    }

    /// Drop a tenant's chain, releasing every cache hold; returns the
    /// number of blocks dropped (0 = no chain; chains are never
    /// empty).
    fn drop_chain(&mut self, t: TenantId, kv: &mut KvPool) -> usize {
        let Some(chain) = self.chains.get_mut(t.index())
            .and_then(Option::take)
        else {
            return 0;
        };
        let n = chain.blocks.len();
        for b in chain.blocks {
            kv.uncache(b);
        }
        n
    }

    /// Drop the tenant's whole subtree: the registry evicted or
    /// reloaded its adapter, so every cached block holds KV of a base
    /// that no longer exists. Blocks live sequences still share are
    /// merely un-cached (they finish on their own holder's refs).
    pub fn invalidate_tenant(&mut self, t: TenantId,
                             kv: &mut KvPool) {
        let dropped = self.drop_chain(t, kv);
        if dropped > 0 {
            self.stats.invalidations += 1;
            self.events.emit(EventKind::Invalidate, Some(t.0), None,
                             dropped as u64,
                             self.stats.invalidations);
        }
    }

    /// Invalidate the tenant's subtree iff it was built under a
    /// different adapter generation than `gen` (the engine calls this
    /// each sync, so scheduler projections, lookups, and donations
    /// all see the same post-invalidation cache).
    pub fn invalidate_if_stale(&mut self, t: TenantId, gen: u64,
                               kv: &mut KvPool) {
        let stale = self.chains.get(t.index())
            .and_then(Option::as_ref)
            .is_some_and(|c| c.gen != gen);
        if stale {
            self.invalidate_tenant(t, kv);
        }
    }

    /// Flush everything (engine drain): every cache hold is released
    /// so the pool's leak check sees a quiescent pool. Not counted as
    /// invalidations — nothing was stale.
    pub fn clear(&mut self, kv: &mut KvPool) {
        for i in 0..self.chains.len() {
            self.drop_chain(TenantId(i as u32), kv);
        }
    }

    /// Longest cached prefix of ≤ `want` tokens for `t` under adapter
    /// generation `gen`. The returned blocks are NOT yet referenced —
    /// the caller attaches them via [`KvPool::attach`] (the engine
    /// holds a whole dispatch group's matches before any member
    /// allocates, so one member's suffix can't reclaim another's
    /// match).
    pub fn lookup(&mut self, t: TenantId, want: usize, gen: u64,
                  kv: &mut KvPool) -> PrefixMatch {
        if !self.enabled || want == 0 {
            return PrefixMatch::default();
        }
        self.stats.lookups += 1;
        self.invalidate_if_stale(t, gen, kv);
        let bt = kv.block_tokens();
        let clock = {
            self.clock += 1;
            self.clock
        };
        let Some(chain) = self.chains.get_mut(t.index())
            .and_then(Option::as_mut)
        else {
            return PrefixMatch::default();
        };
        let (cf, ct) = chain.cover(bt);
        let (full, tail) = cover_match(cf, ct, bt, want);
        let n = full + usize::from(tail > 0);
        if n == 0 {
            return PrefixMatch::default();
        }
        chain.last_hit = clock;
        let tokens = full * bt + tail;
        let blocks = chain.blocks[..n].to_vec();
        self.stats.hits += 1;
        self.stats.hit_tokens += tokens as u64;
        self.events.emit(EventKind::PrefixHit, Some(t.0), None,
                         tokens as u64, n as u64);
        PrefixMatch { blocks, tokens }
    }

    /// A completing (or preempted) sequence hands its shared-prefix
    /// blocks to the cache instead of freeing them. Only blocks whose
    /// ENTIRE filled content lies inside the request's
    /// `shared_prefix_tokens` are donated — a block that also holds
    /// request-unique prompt or generated tokens would poison the
    /// tenant's tree. Donations extend the chain (radix: longest
    /// prefix wins — a full donor block replaces a cached partial
    /// tail at the same position). The caller still releases the
    /// sequence afterwards; the cache keeps its own hold.
    pub fn donate(&mut self, t: TenantId, gen: u64,
                  seq: &crate::serve::kv::KvSeq,
                  shared_prefix_tokens: usize, kv: &mut KvPool) {
        if !self.enabled || shared_prefix_tokens == 0 {
            return;
        }
        let bt = kv.block_tokens();
        let donate_tokens = shared_prefix_tokens.min(seq.tokens());
        let full = donate_tokens / bt;
        // The partial tail is donatable only when the sequence ends
        // exactly at the prefix boundary (its tail block holds prefix
        // tokens and nothing else).
        let tail = if donate_tokens == seq.tokens() {
            donate_tokens % bt
        } else {
            0
        };
        if full == 0 && tail == 0 {
            return;
        }
        self.invalidate_if_stale(t, gen, kv);
        self.clock += 1;
        let clock = self.clock;
        let slot = self.chain_mut(t);
        if slot.is_none() {
            *slot = Some(Chain { blocks: Vec::new(), tail_fill: bt,
                                 gen, last_hit: clock });
        }
        let chain = slot.as_mut().unwrap();
        chain.last_hit = clock;
        let mut donated = 0u64;
        let blocks = seq.block_ids();
        for pos in 0..full {
            let b = blocks[pos];
            if pos + 1 < chain.blocks.len()
                || (pos + 1 == chain.blocks.len()
                    && chain.tail_fill == bt)
            {
                continue; // already cached full at this position
            }
            if pos + 1 == chain.blocks.len() {
                // Cached partial tail at this position; the donor's
                // block here is FULL (it precedes more donor blocks)
                // — longest prefix wins.
                kv.uncache(chain.blocks[pos]);
                chain.blocks[pos] = b;
            } else {
                debug_assert_eq!(pos, chain.blocks.len());
                chain.blocks.push(b);
            }
            kv.mark_cached(b);
            chain.tail_fill = bt;
            donated += 1;
        }
        if tail > 0 {
            let pos = full;
            let b = blocks[pos];
            if pos == chain.blocks.len() {
                chain.blocks.push(b);
                kv.mark_cached(b);
                chain.tail_fill = tail;
                donated += 1;
            } else if pos + 1 == chain.blocks.len()
                && chain.tail_fill < tail
            {
                kv.uncache(chain.blocks[pos]);
                chain.blocks[pos] = b;
                kv.mark_cached(b);
                chain.tail_fill = tail;
                donated += 1;
            }
            // Else the cached cover at this position is at least as
            // long — keep it.
        }
        let chain_len = chain.blocks.len();
        self.stats.donated_blocks += donated;
        if donated > 0 {
            self.events.emit(EventKind::Donate, Some(t.0), None,
                             donated, chain_len as u64);
        }
    }

    /// Free up to `need` blocks by dropping cache-only (pool refcount
    /// 1) blocks: least-recently-hit tenant first, tail block first —
    /// a chain must stay a prefix, so the tail is the only removable
    /// block; a pinned tail makes the whole chain unreclaimable for
    /// now. Returns the number of blocks actually freed.
    pub fn reclaim(&mut self, need: usize, kv: &mut KvPool) -> usize {
        let mut freed = 0;
        while freed < need {
            let mut pick: Option<(u64, usize)> = None;
            for (i, c) in self.chains.iter().enumerate() {
                let Some(c) = c else { continue };
                let Some(&tail) = c.blocks.last() else { continue };
                if kv.refs_of(tail) != 1 {
                    continue; // pinned by a live sequence
                }
                if pick.is_none_or(|(best, _)| c.last_hit < best) {
                    pick = Some((c.last_hit, i));
                }
            }
            let Some((_, i)) = pick else { break };
            let chain = self.chains[i].as_mut().unwrap();
            let b = chain.blocks.pop().unwrap();
            kv.uncache(b);
            chain.tail_fill = kv.block_tokens(); // remaining are full
            if chain.blocks.is_empty() {
                self.chains[i] = None;
            }
            freed += 1;
        }
        self.stats.reclaimed_blocks += freed as u64;
        if freed > 0 {
            self.events.emit(EventKind::Reclaim, None, None,
                             freed as u64, need as u64);
        }
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::kv::KvPool;

    fn pool(n: usize, bt: usize) -> KvPool {
        KvPool::new(n, bt, 4)
    }

    const T0: TenantId = TenantId(0);
    const T1: TenantId = TenantId(1);

    #[test]
    fn cover_match_is_block_granular() {
        // 2 full 16-token blocks + a 4-token tail cached.
        let m = |want| cover_match(2, 4, 16, want);
        assert_eq!(m(0), (0, 0));
        assert_eq!(m(15), (0, 0), "a block matches only whole");
        assert_eq!(m(16), (1, 0));
        assert_eq!(m(31), (1, 0));
        assert_eq!(m(32), (2, 0));
        assert_eq!(m(35), (2, 0), "tail needs its full 4 tokens");
        assert_eq!(m(36), (2, 4));
        assert_eq!(m(500), (2, 4));
        // No partial tail cached.
        assert_eq!(cover_match(2, 0, 16, 500), (2, 0));
    }

    #[test]
    fn donate_then_lookup_roundtrips_and_shares() {
        let mut kv = pool(16, 4);
        let mut pc = PrefixCache::new(true);
        // Donor: 10-token prompt, 8 of them shared prefix.
        let a = kv.try_alloc(10).unwrap(); // [4, 4, 2]
        pc.donate(T0, 0, &a, 8, &mut kv);
        assert_eq!(pc.stats.donated_blocks, 2,
                   "only the 2 full prefix blocks; the tail holds \
                    unique tokens");
        kv.release(a);
        assert_eq!(kv.used_blocks(), 2, "donated blocks survive");
        assert_eq!(kv.reclaimable_blocks(), 2);
        // Next same-tenant request: wants up to 9 tokens of prefix.
        let m = pc.lookup(T0, 9, 0, &mut kv);
        assert_eq!(m.tokens, 8);
        assert_eq!(m.blocks.len(), 2);
        assert_eq!(pc.stats.hits, 1);
        assert_eq!(pc.stats.hit_tokens, 8);
        let mut b = kv.attach(&m.blocks, m.tokens);
        assert_eq!(kv.pinned_blocks(), 2);
        assert!(kv.grow(&mut b, 6)); // unique suffix
        assert_eq!(b.tokens(), 14);
        kv.release(b);
        // A 7-token lookup matches only 1 block.
        let m = pc.lookup(T0, 7, 0, &mut kv);
        assert_eq!(m.tokens, 4);
        // Cross-tenant: NEVER matches.
        let m = pc.lookup(T1, 8, 0, &mut kv);
        assert_eq!(m.tokens, 0);
        pc.clear(&mut kv);
        kv.leak_check().unwrap();
    }

    #[test]
    fn partial_tail_is_donated_and_replaced_by_longer_cover() {
        let mut kv = pool(16, 4);
        let mut pc = PrefixCache::new(true);
        // Donor ends exactly at the 6-token prefix: tail donatable.
        let a = kv.try_alloc(6).unwrap(); // [4, 2]
        pc.donate(T0, 0, &a, 6, &mut kv);
        assert_eq!(pc.stats.donated_blocks, 2);
        assert_eq!(pc.cover(T0, 4), (1, 2));
        kv.release(a);
        // Lookup(6) matches the partial tail too.
        let m = pc.lookup(T0, 6, 0, &mut kv);
        assert_eq!(m.tokens, 6);
        // A longer donor (prefix 8, both blocks full) replaces the
        // partial tail — longest prefix wins.
        let b = kv.try_alloc(8).unwrap();
        pc.donate(T0, 0, &b, 8, &mut kv);
        assert_eq!(pc.cover(T0, 4), (2, 0));
        kv.release(b);
        // A shorter/equal donor never downgrades the cover.
        let c = kv.try_alloc(6).unwrap();
        pc.donate(T0, 0, &c, 6, &mut kv);
        assert_eq!(pc.cover(T0, 4), (2, 0));
        kv.release(c);
        assert_eq!(pc.lookup(T0, 8, 0, &mut kv).tokens, 8);
        pc.clear(&mut kv);
        kv.leak_check().unwrap();
    }

    #[test]
    fn reclaim_takes_lru_tenant_tail_first_and_skips_pinned() {
        let mut kv = pool(16, 4);
        let mut pc = PrefixCache::new(true);
        let a = kv.try_alloc(8).unwrap();
        pc.donate(T0, 0, &a, 8, &mut kv);
        kv.release(a);
        let b = kv.try_alloc(8).unwrap();
        pc.donate(T1, 0, &b, 8, &mut kv);
        kv.release(b);
        // Touch T0: T1 becomes the LRU chain.
        assert_eq!(pc.lookup(T0, 8, 0, &mut kv).tokens, 8);
        assert_eq!(kv.reclaimable_blocks(), 4);
        // Reclaim 3: T1's tail, T1's head, then T0's tail.
        assert_eq!(pc.reclaim(3, &mut kv), 3);
        assert_eq!(pc.stats.reclaimed_blocks, 3);
        assert_eq!(pc.cover(T1, 4), (0, 0), "T1 fully reclaimed");
        assert_eq!(pc.cover(T0, 4), (1, 0), "T0 kept its head");
        // Pin T0's remaining block: nothing left to reclaim.
        let m = pc.lookup(T0, 4, 0, &mut kv);
        let s = kv.attach(&m.blocks, m.tokens);
        assert_eq!(pc.reclaim(5, &mut kv), 0,
                   "a pinned tail blocks the chain");
        kv.release(s);
        assert_eq!(pc.reclaim(5, &mut kv), 1);
        kv.leak_check().unwrap();
    }

    #[test]
    fn generation_change_invalidates_the_subtree() {
        let mut kv = pool(16, 4);
        let mut pc = PrefixCache::new(true);
        let a = kv.try_alloc(8).unwrap();
        pc.donate(T0, 3, &a, 8, &mut kv);
        kv.release(a);
        assert_eq!(pc.lookup(T0, 8, 3, &mut kv).tokens, 8,
                   "same generation hits");
        // The registry reloaded the adapter: generation 4. The stale
        // KV must never be served again.
        assert_eq!(pc.lookup(T0, 8, 4, &mut kv).tokens, 0);
        assert_eq!(pc.stats.invalidations, 1);
        assert_eq!(kv.used_blocks(), 0, "stale blocks were freed");
        assert_eq!(pc.cover(T0, 4), (0, 0));
        // invalidate_if_stale is idempotent for a missing chain.
        pc.invalidate_if_stale(T0, 5, &mut kv);
        assert_eq!(pc.stats.invalidations, 1);
        // A fresh donation under the new generation works.
        let b = kv.try_alloc(8).unwrap();
        pc.donate(T0, 4, &b, 8, &mut kv);
        kv.release(b);
        assert_eq!(pc.lookup(T0, 8, 4, &mut kv).tokens, 8);
        pc.clear(&mut kv);
        kv.leak_check().unwrap();
    }

    #[test]
    fn disabled_cache_is_inert() {
        let mut kv = pool(16, 4);
        let mut pc = PrefixCache::new(false);
        let a = kv.try_alloc(8).unwrap();
        pc.donate(T0, 0, &a, 8, &mut kv);
        assert_eq!(pc.lookup(T0, 8, 0, &mut kv).tokens, 0);
        assert_eq!(pc.stats.lookups, 0);
        assert_eq!(pc.stats.donated_blocks, 0);
        assert_eq!(pc.cached_blocks(), 0);
        kv.release(a);
        kv.leak_check().unwrap();
    }

    #[test]
    fn zero_prefix_requests_donate_nothing() {
        let mut kv = pool(16, 4);
        let mut pc = PrefixCache::new(true);
        let a = kv.try_alloc(8).unwrap();
        pc.donate(T0, 0, &a, 0, &mut kv);
        assert_eq!(pc.cached_blocks(), 0);
        assert_eq!(pc.lookup(T0, 0, 0, &mut kv).tokens, 0);
        kv.release(a);
        kv.leak_check().unwrap();
    }
}
