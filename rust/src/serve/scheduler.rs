//! Request scheduling: queue → batch plan.
//!
//! Serving PaCA adapters from one shared base means the only per-tenant
//! cost is the adapter *swap* (splice/un-splice) between batches; the
//! forward itself is method-free. The scheduler therefore has one job:
//! coalesce same-adapter requests into batches and order batches so
//! adjacent ones share a tenant whenever possible (swap-cost-aware
//! batching — LoRAFusion's grouping insight applied to PaCA's splice
//! model). FIFO is kept as the baseline the bench compares against.

use anyhow::{anyhow, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub tenant: String,
    /// Prompt length in tokens (drives forward cost).
    pub tokens: usize,
    /// Synthetic arrival timestamp, seconds from trace start.
    pub arrival_s: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Batch strictly in arrival order; a batch breaks whenever the
    /// tenant changes or the batch is full.
    Fifo,
    /// Group by tenant (stable in first-arrival order), then chunk —
    /// one swap per tenant instead of one per tenant *run*.
    SwapAware,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy> {
        Ok(match s {
            "fifo" => Policy::Fifo,
            "swap-aware" | "swap" | "grouped" => Policy::SwapAware,
            other => {
                return Err(anyhow!(
                    "unknown policy {other:?} (fifo | swap-aware)"))
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::SwapAware => "swap-aware",
        }
    }
}

/// One dispatch unit: requests sharing a tenant, served under one
/// splice of that tenant's adapter.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tenant: String,
    pub requests: Vec<Request>,
}

impl Batch {
    pub fn tokens(&self) -> usize {
        self.requests.iter().map(|r| r.tokens).sum()
    }
}

/// Plan the queue into batches of at most `batch_size` requests.
/// Every request appears in exactly one batch; within a tenant,
/// arrival order is preserved under both policies.
pub fn plan(requests: &[Request], batch_size: usize,
            policy: Policy) -> Vec<Batch> {
    let cap = batch_size.max(1);
    match policy {
        Policy::Fifo => {
            let mut out: Vec<Batch> = Vec::new();
            for r in requests {
                let start_new = match out.last() {
                    Some(b) => b.tenant != r.tenant
                        || b.requests.len() >= cap,
                    None => true,
                };
                if start_new {
                    out.push(Batch { tenant: r.tenant.clone(),
                                     requests: Vec::new() });
                }
                out.last_mut().unwrap().requests.push(r.clone());
            }
            out
        }
        Policy::SwapAware => {
            // Stable grouping by tenant in first-arrival order.
            let mut groups: Vec<(String, Vec<Request>)> = Vec::new();
            for r in requests {
                match groups.iter_mut().find(|(t, _)| *t == r.tenant) {
                    Some((_, g)) => g.push(r.clone()),
                    None => groups.push((r.tenant.clone(),
                                         vec![r.clone()])),
                }
            }
            let mut out = Vec::new();
            for (tenant, g) in groups {
                for chunk in g.chunks(cap) {
                    out.push(Batch { tenant: tenant.clone(),
                                     requests: chunk.to_vec() });
                }
            }
            out
        }
    }
}

/// Adapter splices needed to serve the plan starting from the bare
/// base: 1 for the first batch plus 1 per adjacent tenant change
/// (consecutive same-tenant batches reuse the live splice).
pub fn swap_count(batches: &[Batch]) -> usize {
    let mut swaps = 0;
    let mut current: Option<&str> = None;
    for b in batches {
        if current != Some(b.tenant.as_str()) {
            swaps += 1;
            current = Some(&b.tenant);
        }
    }
    swaps
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, tenant: &str) -> Request {
        Request { id, tenant: tenant.into(), tokens: 16,
                  arrival_s: id as f64 * 0.01 }
    }

    fn mixed() -> Vec<Request> {
        // Interleaved tenants — the worst case for FIFO.
        ["a", "b", "a", "c", "b", "a", "c", "b", "a", "b"]
            .iter().enumerate()
            .map(|(i, t)| req(i as u64, t)).collect()
    }

    fn ids(batches: &[Batch]) -> Vec<u64> {
        let mut v: Vec<u64> = batches.iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn both_policies_preserve_all_requests() {
        let reqs = mixed();
        for policy in [Policy::Fifo, Policy::SwapAware] {
            let batches = plan(&reqs, 4, policy);
            assert_eq!(ids(&batches), (0..10).collect::<Vec<_>>(),
                       "{policy:?}");
            for b in &batches {
                assert!(b.requests.len() <= 4);
                assert!(b.requests.iter().all(|r| r.tenant == b.tenant));
            }
        }
    }

    #[test]
    fn swap_aware_beats_fifo_on_interleaved_tenants() {
        let reqs = mixed();
        let fifo = swap_count(&plan(&reqs, 4, Policy::Fifo));
        let aware = swap_count(&plan(&reqs, 4, Policy::SwapAware));
        assert_eq!(aware, 3, "one swap per distinct tenant");
        assert!(fifo > aware, "fifo {fifo} !> swap-aware {aware}");
    }

    #[test]
    fn fifo_preserves_arrival_order() {
        let reqs = mixed();
        let batches = plan(&reqs, 4, Policy::Fifo);
        let flat: Vec<u64> = batches.iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        assert_eq!(flat, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn swap_aware_keeps_per_tenant_order_and_chunks() {
        let reqs: Vec<Request> = (0..9).map(|i| req(i, "t")).collect();
        let batches = plan(&reqs, 4, Policy::SwapAware);
        assert_eq!(batches.len(), 3); // 4 + 4 + 1
        assert_eq!(batches[2].requests.len(), 1);
        assert_eq!(swap_count(&batches), 1);
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("fifo").unwrap(), Policy::Fifo);
        assert_eq!(Policy::parse("swap-aware").unwrap(),
                   Policy::SwapAware);
        assert!(Policy::parse("lifo").is_err());
    }

    #[test]
    fn empty_queue_plans_empty() {
        assert!(plan(&[], 8, Policy::Fifo).is_empty());
        assert_eq!(swap_count(&[]), 0);
    }
}
