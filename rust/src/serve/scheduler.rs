//! Request scheduling: offline batch planning + the online
//! continuous-batching scheduler.
//!
//! Serving PaCA adapters from one shared base means the only per-tenant
//! cost is the adapter *swap* (splice/un-splice) between batches; the
//! forward itself is method-free. Scheduling therefore trades two
//! currencies: swaps saved by coalescing same-adapter requests
//! (LoRAFusion's grouping insight applied to PaCA's splice model) and
//! queueing delay paid by requests that wait for their adapter's turn.
//!
//! Two layers:
//!   * [`plan`] — the offline planner: consumes a fully-arrived queue
//!     and emits a static batch list. Kept as the correctness anchor —
//!     on a fully-arrived queue the online scheduler must reproduce its
//!     dispatch sequence (see `tests/properties.rs`).
//!   * [`OnlineScheduler`] — the event-driven online layer: admits
//!     requests as their `arrival_s` passes a virtual clock, keeps
//!     per-tenant pending queues, and makes one incremental dispatch
//!     decision at a time. New same-tenant arrivals join the next
//!     dispatch instead of waiting for a full replan (continuous
//!     batching).
//!
//! Tenant names are interned to dense [`TenantId`]s at trace load
//! ([`TenantPool`]), so the hot loop moves `Copy` ids around instead of
//! cloning `String`s per request.

use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};

use anyhow::{anyhow, Result};

use crate::serve::events::{EventKind, Events};

/// Dense interned tenant handle — index into a [`TenantPool`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub u32);

impl TenantId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// String-interning table for tenant names: ids are dense (0..n in
/// first-appearance order), so per-tenant state can live in plain
/// `Vec`s indexed by [`TenantId`].
#[derive(Debug, Clone, Default)]
pub struct TenantPool {
    names: Vec<String>,
    index: HashMap<String, TenantId>,
}

impl TenantPool {
    pub fn new() -> TenantPool {
        TenantPool::default()
    }

    /// Id for `name`, allocating the next dense id on first sight.
    pub fn intern(&mut self, name: &str) -> TenantId {
        if let Some(&id) = self.index.get(name) {
            return id;
        }
        let id = TenantId(self.names.len() as u32);
        self.names.push(name.to_string());
        self.index.insert(name.to_string(), id);
        id
    }

    pub fn get(&self, name: &str) -> Option<TenantId> {
        self.index.get(name).copied()
    }

    pub fn name(&self, id: TenantId) -> &str {
        &self.names[id.index()]
    }

    /// Interned names in first-appearance order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub tenant: TenantId,
    /// Prompt length in tokens (drives prefill cost; the prefill step
    /// emits the request's first output token).
    pub tokens: usize,
    /// Output tokens generated AFTER the first one — each costs one
    /// decode iteration in the iteration-level engine. 0 = prefill-only
    /// (the default for traces that predate the field).
    pub decode_tokens: usize,
    /// Leading prompt tokens shared with every other request of this
    /// TENANT (its system prompt / few-shot header) — what the prefix
    /// cache can serve without recompute. 0 = fully unique prompt
    /// (the default for traces that predate the field). Sharing is
    /// strictly per-tenant: splicing changes the merged weights, so
    /// the same tokens under another tenant are different KV.
    pub shared_prefix_tokens: usize,
    /// Arrival timestamp, seconds from trace start. The online
    /// scheduler only sees a request once the clock passes this.
    pub arrival_s: f64,
    /// Per-request SLO: seconds after arrival by which the request
    /// must complete. `f64::INFINITY` = no deadline (the default for
    /// traces that predate the field).
    pub deadline_s: f64,
}

impl Request {
    /// Absolute completion deadline on the trace clock.
    pub fn absolute_deadline(&self) -> f64 {
        self.arrival_s + self.deadline_s
    }

    /// Total tokens the backend must compute for this request
    /// (prefill + decode).
    pub fn total_tokens(&self) -> usize {
        self.tokens + self.decode_tokens
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Dispatch strictly in arrival order; a batch breaks whenever the
    /// tenant changes or the batch is full.
    Fifo,
    /// Coalesce by tenant (stable in first-arrival order) — one swap
    /// per tenant instead of one per tenant *run*. Online, the live
    /// tenant keeps dispatching while it has pending work.
    SwapAware,
    /// Earliest-deadline-first across tenants, with the adapter-swap
    /// cost charged as a slack penalty against switching away from the
    /// live tenant. Offline (no clock) it plans like `SwapAware`.
    SloAware,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy> {
        Ok(match s {
            "fifo" => Policy::Fifo,
            "swap-aware" | "swap" | "grouped" => Policy::SwapAware,
            "slo-aware" | "slo" | "deadline" => Policy::SloAware,
            other => {
                return Err(anyhow!(
                    "unknown policy {other:?} (fifo | swap-aware | \
                     slo-aware)"))
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::SwapAware => "swap-aware",
            Policy::SloAware => "slo-aware",
        }
    }

    pub const ALL: [Policy; 3] =
        [Policy::Fifo, Policy::SwapAware, Policy::SloAware];
}

/// One dispatch unit: requests sharing a tenant, served under one
/// splice of that tenant's adapter.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tenant: TenantId,
    pub requests: Vec<Request>,
}

impl Batch {
    /// Prefill tokens of the batch (what one iteration step computes
    /// when every member is freshly dispatched).
    pub fn tokens(&self) -> usize {
        self.requests.iter().map(|r| r.tokens).sum()
    }

    /// Prefill + decode tokens — the whole-batch engine's unit of
    /// service (it runs a request's full generation in one dispatch).
    pub fn total_tokens(&self) -> usize {
        self.requests.iter().map(Request::total_tokens).sum()
    }
}

/// Offline planner: the whole queue into batches of at most
/// `batch_size` requests. Every request appears in exactly one batch;
/// within a tenant, input order is preserved under every policy.
/// Requests are moved, never cloned. `SloAware` has no clock to
/// consult offline, so it plans like `SwapAware`.
pub fn plan(requests: Vec<Request>, batch_size: usize,
            policy: Policy) -> Vec<Batch> {
    let cap = batch_size.max(1);
    match policy {
        Policy::Fifo => {
            let mut out: Vec<Batch> = Vec::new();
            for r in requests {
                let start_new = match out.last() {
                    Some(b) => b.tenant != r.tenant
                        || b.requests.len() >= cap,
                    None => true,
                };
                if start_new {
                    out.push(Batch { tenant: r.tenant,
                                     requests: Vec::new() });
                }
                out.last_mut().unwrap().requests.push(r);
            }
            out
        }
        Policy::SwapAware | Policy::SloAware => {
            // Stable grouping by tenant in first-arrival order: a
            // HashMap index instead of the old O(n·t) linear scan over
            // the group list.
            let mut order: Vec<TenantId> = Vec::new();
            let mut groups: HashMap<TenantId, Vec<Request>> =
                HashMap::new();
            for r in requests {
                match groups.entry(r.tenant) {
                    Entry::Occupied(mut e) => e.get_mut().push(r),
                    Entry::Vacant(e) => {
                        order.push(r.tenant);
                        e.insert(vec![r]);
                    }
                }
            }
            let mut out = Vec::new();
            for tenant in order {
                // Chunk by moving: split_off leaves the head chunk in
                // place and hands back the tail, so no request is ever
                // cloned.
                let mut head = groups.remove(&tenant).unwrap();
                while head.len() > cap {
                    let tail = head.split_off(cap);
                    out.push(Batch { tenant, requests: head });
                    head = tail;
                }
                if !head.is_empty() {
                    out.push(Batch { tenant, requests: head });
                }
            }
            out
        }
    }
}

/// Adapter splices needed to serve the plan starting from the bare
/// base: 1 for the first batch plus 1 per adjacent tenant change
/// (consecutive same-tenant batches reuse the live splice).
pub fn swap_count(batches: &[Batch]) -> usize {
    let mut swaps = 0;
    let mut current: Option<TenantId> = None;
    for b in batches {
        if current != Some(b.tenant) {
            swaps += 1;
            current = Some(b.tenant);
        }
    }
    swaps
}

/// One tenant's pending FIFO plus a monotonic deque over *urgency*
/// keys, so the tightest key of the queue is O(1) per dispatch instead
/// of a scan of the whole backlog (which would make slo-aware dispatch
/// quadratic exactly in the overload regime it exists for).
///
/// Urgency = absolute deadline − decode_tokens·decode_slack_s: a
/// request that still owes d decode iterations must START d·step-time
/// earlier to finish by its deadline, so its effective deadline is
/// tighter by its remaining decode work. The key is computed once at
/// push time (with the scheduler's then-current `decode_slack_s`) and
/// stored alongside the request, so the monotonic deque stays
/// consistent even if the calibration drifts between push and pop.
#[derive(Debug, Default)]
struct PendingQueue {
    /// (admission seq, urgency key at push, request).
    q: VecDeque<(u64, f64, Request)>,
    /// Non-decreasing urgency keys of the requests in `q`; front is
    /// the queue's minimum.
    min_urgency: VecDeque<f64>,
}

impl PendingQueue {
    fn push(&mut self, seq: u64, r: Request, decode_slack_s: f64) {
        let d = if r.deadline_s.is_finite() {
            r.absolute_deadline()
                - r.decode_tokens as f64 * decode_slack_s
        } else {
            f64::INFINITY
        };
        while self.min_urgency.back().is_some_and(|&b| b > d) {
            self.min_urgency.pop_back();
        }
        self.min_urgency.push_back(d);
        self.q.push_back((seq, d, r));
    }

    fn pop(&mut self) -> Option<(u64, Request)> {
        let (seq, d, r) = self.q.pop_front()?;
        // Bitwise-identical value: it was stored at this request's
        // push.
        if self.min_urgency.front() == Some(&d) {
            self.min_urgency.pop_front();
        }
        Some((seq, r))
    }

    fn front_seq(&self) -> Option<u64> {
        self.q.front().map(|(seq, _, _)| *seq)
    }

    /// The front request itself (for budget/gate projection).
    fn front(&self) -> Option<&Request> {
        self.q.front().map(|(_, _, r)| r)
    }

    /// Tightest urgency key among queued requests.
    fn earliest_urgency(&self) -> Option<f64> {
        self.min_urgency.front().copied()
    }
}

/// The online continuous-batching scheduler.
///
/// Owns the not-yet-arrived tail of the trace plus per-tenant pending
/// queues of admitted requests. The engine's step loop drives it:
/// `admit(clock)` → `dispatch(live_tenant, clock)` → serve → repeat,
/// jumping the virtual clock to `next_arrival()` when idle. Admission
/// order is tracked with a per-request sequence number so FIFO
/// head-of-line decisions are exact.
pub struct OnlineScheduler {
    policy: Policy,
    cap: usize,
    /// Not-yet-admitted requests, ascending `arrival_s` — stored
    /// reversed so `pop()` yields the next arrival.
    future: Vec<Request>,
    /// Per-tenant pending queues, indexed by `TenantId`.
    pending: Vec<PendingQueue>,
    pending_count: usize,
    next_seq: u64,
    /// Seconds of slack the slo-aware policy charges a tenant switch —
    /// the scheduling price of an adapter swap. The engine's serving
    /// loops keep this calibrated to the active clock model (analytic
    /// swap cost, or the measured running average); set it manually
    /// only when driving the scheduler directly.
    pub swap_penalty_s: f64,
    /// Seconds of urgency credited per remaining decode token when the
    /// slo-aware policy ranks tenants: a request owing d decode
    /// iterations must start ~d·step-time earlier, so its effective
    /// deadline tightens by d·decode_slack_s. Calibrated by the engine
    /// like `swap_penalty_s` (analytic per-token cost, or the measured
    /// running average); 0 disables the adjustment.
    pub decode_slack_s: f64,
    /// Per-dispatch token budget (prefill tokens of freshly dispatched
    /// requests — what one iteration step computes). 0 = unlimited.
    /// A single request larger than the budget still dispatches alone,
    /// so an oversized prompt degrades to a batch of one instead of
    /// wedging the queue.
    pub max_batch_tokens: usize,
    /// Chunked prefill (Sarathi-style stall-free batching): when > 0,
    /// admission charges a prompt at most this many prefill tokens per
    /// step — the engine computes the prompt chunk-by-chunk,
    /// interleaved with decode — so a long prompt no longer consumes
    /// the whole step budget at once. 0 = unchunked (the whole
    /// uncached prompt is one charge, the pre-chunking behavior,
    /// matching the `--kv-blocks 0` off convention). KV-block
    /// projection is unchanged: it is a LIFETIME watermark either way.
    pub prefill_chunk_tokens: usize,
    /// Cache-aware dispatch ordering: among equally-attractive pending
    /// tenants, prefer the one with the most cached-prefix cover
    /// (`kv_prefix_cover`) so dispatches ride warm radix chains, and
    /// cold same-prefix requests group behind one tenant pick (the
    /// first seat's donation then serves the rest). Off by default —
    /// ordering is bit-identical to the pre-chunking scheduler.
    pub cache_aware: bool,
    /// KV-cache block granularity (tokens per block) of the engine's
    /// paged pool; 0 disables capacity gating. When set, dispatch and
    /// joins admit a request only if its PROJECTED cache footprint —
    /// prefill plus every decode token it still owes, rounded up to
    /// blocks — fits the free blocks the engine advertised in
    /// `kv_free_blocks`. Like the token budget, the first request of a
    /// fresh dispatch always passes (an oversized sequence degrades to
    /// a capped batch-of-one instead of wedging the queue); joins
    /// never over-admit.
    pub kv_block_tokens: usize,
    /// Free blocks in the engine's pool, refreshed by the serving loop
    /// before every dispatch/join decision (usize::MAX = unlimited).
    /// With a prefix cache the engine advertises free PLUS reclaimable
    /// (cache-only blocks its LRU reclaim yields on demand). NOTE:
    /// with a cache this makes the gate a WATERMARK even for joins —
    /// a request's projected suffix may be admitted against
    /// reclaimable capacity that its own attach then pins (the
    /// matched blocks are counted twice: as cost-free cover here and
    /// as reclaimable in the advert). Such a sequence degrades to the
    /// same ledgered clamped cache the budget's oversized-prompt rule
    /// uses — never an over-commit (fuzz-asserted). Without a cache,
    /// reclaimable is 0 and the PR-4 join guarantee is unchanged.
    pub kv_free_blocks: usize,
    /// Block granularity of the prefix-cache cover below (the pool's
    /// block size; set even when capacity gating is off, because the
    /// token budget charges the uncached suffix regardless).
    pub prefix_block_tokens: usize,
    /// Per-tenant cached-prefix cover advertised by the engine before
    /// each dispatch/join decision: (full blocks, partial-tail
    /// tokens). Empty = no prefix cache. Projections run through
    /// `serve::prefix::cover_match` — the SAME rule the engine's
    /// attach uses — so what the gate/budget charges and what prefill
    /// actually computes can never drift.
    pub kv_prefix_cover: Vec<(usize, usize)>,
    /// Event-stream handle (off by default — every emit is then a
    /// no-op). The engine installs a clone of its own handle at serve
    /// start, so admission/dispatch/gate events interleave with the
    /// engine's in one totally-ordered stream.
    pub events: Events,
}

impl OnlineScheduler {
    /// `n_tenants` bounds the dense `TenantId` space (usually
    /// `pool.len()`). Requests are stably sorted by arrival, so ties
    /// keep their input order.
    pub fn new(mut requests: Vec<Request>, n_tenants: usize,
               batch_size: usize, policy: Policy) -> OnlineScheduler {
        requests.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        for r in &requests {
            assert!(r.tenant.index() < n_tenants,
                    "tenant id {} outside pool of {n_tenants}",
                    r.tenant.0);
        }
        requests.reverse();
        OnlineScheduler {
            policy,
            cap: batch_size.max(1),
            future: requests,
            pending: (0..n_tenants)
                .map(|_| PendingQueue::default()).collect(),
            pending_count: 0,
            next_seq: 0,
            swap_penalty_s: 0.0,
            decode_slack_s: 0.0,
            max_batch_tokens: 0,
            prefill_chunk_tokens: 0,
            cache_aware: false,
            kv_block_tokens: 0,
            kv_free_blocks: usize::MAX,
            prefix_block_tokens: 0,
            kv_prefix_cover: Vec::new(),
            events: Events::off(),
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Max requests per dispatch (the engine's slot count).
    pub fn batch_size(&self) -> usize {
        self.cap
    }

    /// The per-dispatch token budget as a comparable bound
    /// (`usize::MAX` when unbudgeted).
    fn step_budget(&self) -> usize {
        if self.max_batch_tokens == 0 {
            usize::MAX
        } else {
            self.max_batch_tokens
        }
    }

    /// THE budget-bounded pop loop — every dispatch path (plain take,
    /// the fifo run, mid-generation joins) comes through here so the
    /// cap/budget/first-fits edge rules can never diverge between
    /// policies. Pops from `t`'s queue in admission order while
    /// `keep_going` holds, at most `max_requests`, stopping before a
    /// request whose prefill would exceed `token_budget` or whose
    /// projected KV blocks (see `kv_block_tokens`) would exceed the
    /// engine's advertised free blocks — except the very first pop
    /// when `first_fits` (a fresh dispatch must make progress even on
    /// an oversized request; joins pass false and never exceed either
    /// budget). With a prefix cache, both charges cover only the
    /// UNCACHED part of the request: the prefill step computes (and
    /// the pool newly allocates) just the suffix beyond the tenant's
    /// advertised cached cover — see [`Self::projection`].
    fn pop_bounded(&mut self, t: TenantId, max_requests: usize,
                   token_budget: usize, first_fits: bool,
                   keep_going: impl Fn(&OnlineScheduler) -> bool)
                   -> Vec<Request> {
        let mut out: Vec<Request> = Vec::new();
        let mut tokens = 0usize;
        let mut blocks = 0usize;
        while out.len() < max_requests && keep_going(self) {
            let Some(front) = self.pending[t.index()].front() else {
                break;
            };
            let (charge, need) = self.projection(front);
            let fits = charge <= token_budget.saturating_sub(tokens)
                && need <= self.kv_free_blocks.saturating_sub(blocks);
            if !(fits || (first_fits && out.is_empty())) {
                // The gate deferred the head request this attempt.
                self.events.emit(EventKind::Reject, Some(t.0),
                                 Some(front.id), charge as u64,
                                 need as u64);
                break;
            }
            let (_, r) = self.pending[t.index()].pop().unwrap();
            self.pending_count -= 1;
            tokens += charge;
            blocks += need;
            self.events.emit(EventKind::Dispatch, Some(t.0),
                             Some(r.id), r.tokens as u64,
                             r.decode_tokens as u64);
            out.push(r);
        }
        out
    }

    /// What admitting `r` is projected to cost: (prefill tokens the
    /// seating step will compute, KV blocks its lifetime cache will
    /// newly allocate) — both net of the tenant's cached-prefix cover.
    /// Matched FULL blocks are already resident (they cost nothing);
    /// everything past them — including a matched partial tail, which
    /// the engine copy-on-write-forks into a fresh block on extension
    /// — is charged, so the block projection never undershoots.
    fn projection(&self, r: &Request) -> (usize, usize) {
        let bt = self.prefix_block_tokens;
        let (full, tail) = match self.kv_prefix_cover
            .get(r.tenant.index())
        {
            Some(&(cf, ct)) if bt > 0 => {
                let want = crate::serve::prefix::usable_prefix(
                    r.shared_prefix_tokens, r.tokens);
                crate::serve::prefix::cover_match(cf, ct, bt, want)
            }
            _ => (0, 0),
        };
        let hit = full * bt + tail;
        // hit ≤ tokens − 1 by the `want` cap, so both subtractions
        // stay in range and the charge is always ≥ 1.
        let mut charge = r.tokens - hit;
        if self.prefill_chunk_tokens > 0 {
            // Chunked prefill: the seating step computes only the
            // FIRST chunk of the uncached suffix; later chunks ride
            // the engine's per-step budget. The KV projection below
            // stays the full-lifetime watermark — chunking spreads
            // compute over steps, not the sequence's cache footprint.
            charge = charge.min(self.prefill_chunk_tokens);
        }
        let need = self.kv_blocks_of(r.total_tokens() - full * bt);
        (charge, need)
    }

    /// Projected KV blocks for a lifetime footprint of `total_tokens`
    /// under the configured block granularity (0 = gating disabled) —
    /// the allocator's own rounding rule (`serve::kv::blocks_for`),
    /// so projection and allocation can never drift.
    pub fn kv_blocks_of(&self, total_tokens: usize) -> usize {
        if self.kv_block_tokens == 0 {
            0
        } else {
            crate::serve::kv::blocks_for(total_tokens,
                                         self.kv_block_tokens)
        }
    }

    /// Admit every request whose arrival has passed; returns how many
    /// were admitted.
    pub fn admit(&mut self, clock: f64) -> usize {
        let mut n = 0;
        while self.future.last()
            .is_some_and(|r| r.arrival_s <= clock)
        {
            let r = self.future.pop().unwrap();
            // Arrival rides the ORIGINAL timestamp (the one kind that
            // may point backwards); admission rides the clock that
            // just passed it.
            self.events.emit_at(r.arrival_s, EventKind::Arrival,
                                Some(r.tenant.0), Some(r.id),
                                r.tokens as u64,
                                r.decode_tokens as u64);
            self.events.emit_at(clock, EventKind::Admit,
                                Some(r.tenant.0), Some(r.id),
                                r.tokens as u64,
                                r.decode_tokens as u64);
            let seq = self.next_seq;
            self.next_seq += 1;
            let slack = self.decode_slack_s;
            self.pending[r.tenant.index()].push(seq, r, slack);
            self.pending_count += 1;
            n += 1;
        }
        n
    }

    /// Arrival time of the next not-yet-admitted request.
    pub fn next_arrival(&self) -> Option<f64> {
        self.future.last().map(|r| r.arrival_s)
    }

    /// Admitted-but-undispatched requests.
    pub fn pending_len(&self) -> usize {
        self.pending_count
    }

    /// True when nothing is pending and nothing is still to arrive.
    pub fn is_done(&self) -> bool {
        self.pending_count == 0 && self.future.is_empty()
    }

    /// Tenant of the earliest-admitted pending request.
    fn head_of_line(&self) -> Option<TenantId> {
        self.pending.iter().enumerate()
            .filter_map(|(t, q)| {
                q.front_seq().map(|seq| (seq, TenantId(t as u32)))
            })
            .min_by_key(|(seq, _)| *seq)
            .map(|(_, t)| t)
    }

    fn front_seq(&self, t: TenantId) -> Option<u64> {
        self.pending[t.index()].front_seq()
    }

    /// Cached-prefix warmth of a tenant, in tokens of advertised radix
    /// cover — what cache-aware ordering prefers among otherwise-equal
    /// candidates.
    fn warm_tokens(&self, t: TenantId) -> usize {
        let bt = self.prefix_block_tokens;
        match self.kv_prefix_cover.get(t.index()) {
            Some(&(full, tail)) if bt > 0 => full * bt + tail,
            _ => 0,
        }
    }

    /// Cache-aware tenant choice for the non-deadline policies: the
    /// pending tenant with the warmest radix chain, ties broken by
    /// earliest admission (which is exactly `head_of_line` when every
    /// chain is cold — so enabling the flag with no cache is inert).
    /// Grouping falls out for free: picking one tenant drains its
    /// same-prefix queue as one batch, and once its first seat donates,
    /// that tenant IS the warm chain for the follow-ups.
    fn warmest_tenant(&self) -> Option<TenantId> {
        self.pending.iter().enumerate()
            .filter_map(|(i, q)| {
                let t = TenantId(i as u32);
                q.front_seq().map(|seq| {
                    (std::cmp::Reverse(self.warm_tokens(t)), seq, t)
                })
            })
            .min()
            .map(|(_, _, t)| t)
    }

    /// Not-yet-arrived requests in arrival order (soonest first). The
    /// engine's speculative prefetch peeks here during idle steps for
    /// a known-but-cold tenant's shared prefix worth warming before
    /// its requests land.
    pub fn peek_future(&self) -> impl Iterator<Item = &Request> {
        self.future.iter().rev()
    }

    /// Cluster ingress: hand this scheduler a request the ROUTER
    /// assigned to it. Inserts into the not-yet-admitted tail at the
    /// request's arrival time; among equal arrivals, earlier-injected
    /// pops first, so the router's delivery order is the tiebreak —
    /// exactly the stable-sort rule `new` applies to a whole trace
    /// (injecting a full trace one request at a time reproduces
    /// `new`'s future vector bit-for-bit).
    pub fn inject(&mut self, r: Request) {
        assert!(r.tenant.index() < self.pending.len(),
                "tenant id {} outside pool of {}", r.tenant.0,
                self.pending.len());
        // `future` is descending by arrival; find the first index
        // whose arrival is ≤ ours and insert before it, leaving
        // already-present equal arrivals at higher pop priority.
        let at = self.future
            .partition_point(|x| x.arrival_s > r.arrival_s);
        self.future.insert(at, r);
    }

    /// Failover: drain every admitted-but-unseated request, in
    /// admission order, for re-injection on a survivor. The queues
    /// and the pending count are left empty; admission seq state is
    /// untouched (seqs are per-scheduler and never compared across
    /// replicas).
    pub fn drain_pending(&mut self) -> Vec<Request> {
        let mut out: Vec<(u64, Request)> = Vec::new();
        for q in &mut self.pending {
            while let Some((seq, r)) = q.pop() {
                out.push((seq, r));
            }
        }
        out.sort_by_key(|(seq, _)| *seq);
        self.pending_count = 0;
        out.into_iter().map(|(_, r)| r).collect()
    }

    /// Failover: drain every not-yet-admitted request in arrival
    /// order. These were routed to a now-dead replica but never
    /// arrived (no events emitted), so the cluster returns them to
    /// global ingress for fresh routing.
    pub fn drain_future(&mut self) -> Vec<Request> {
        let mut v = std::mem::take(&mut self.future);
        v.reverse();
        v
    }

    /// Slo-aware tenant choice: earliest-deadline-first on each
    /// tenant's tightest slack (decode-adjusted: remaining decode work
    /// tightens a request's effective deadline — see [`PendingQueue`]),
    /// where switching away from the live tenant pays `swap_penalty_s`
    /// of extra slack — so a swap only happens when another tenant's
    /// deadline pressure exceeds the swap cost. Ties prefer the live
    /// tenant, then earliest admission.
    fn pick_slo(&self, live: Option<TenantId>,
                clock: f64) -> Option<TenantId> {
        let mut best: Option<(f64, bool, usize, u64, TenantId)> = None;
        for (i, q) in self.pending.iter().enumerate() {
            let front = match q.front_seq() {
                Some(seq) => seq,
                None => continue,
            };
            let t = TenantId(i as u32);
            // O(1): the per-queue monotonic deque tracks the minimum.
            let slack = q.earliest_urgency()
                .unwrap_or(f64::INFINITY) - clock;
            let is_switch = live != Some(t);
            let score = if is_switch {
                slack + self.swap_penalty_s
            } else {
                slack
            };
            // Cache-aware ordering only breaks ties BETWEEN equally
            // urgent candidates — deadline pressure always wins.
            let warm = if self.cache_aware {
                self.warm_tokens(t)
            } else {
                0
            };
            // Serve the tenant whose penalized slack is SMALLEST,
            // preferring the live tenant, then (cache-aware) the
            // warmest radix chain, then FIFO.
            let key = (score, is_switch, warm, front, t);
            let better = match &best {
                None => true,
                Some((bs, bsw, bw, bf, _)) => {
                    match score.total_cmp(bs) {
                        std::cmp::Ordering::Less => true,
                        std::cmp::Ordering::Greater => false,
                        std::cmp::Ordering::Equal => {
                            (is_switch, std::cmp::Reverse(warm), front)
                                < (*bsw, std::cmp::Reverse(*bw), *bf)
                        }
                    }
                }
            };
            if better {
                best = Some(key);
            }
        }
        best.map(|(_, _, _, _, t)| t)
    }

    /// Pop up to `cap` requests from `t`'s queue, in admission order,
    /// stopping before a request whose prefill would push the batch
    /// over `max_batch_tokens` (the first request always fits — see
    /// the field docs).
    fn take(&mut self, t: TenantId) -> Batch {
        let budget = self.step_budget();
        let requests =
            self.pop_bounded(t, self.cap, budget, true, |_| true);
        Batch { tenant: t, requests }
    }

    /// One incremental dispatch decision. `live` is the tenant whose
    /// adapter is currently spliced into the base (None = bare base);
    /// `clock` is the virtual now. Returns None when nothing is
    /// pending (the caller should jump the clock to `next_arrival`).
    pub fn dispatch(&mut self, live: Option<TenantId>,
                    clock: f64) -> Option<Batch> {
        if self.pending_count == 0 {
            return None;
        }
        match self.policy {
            Policy::Fifo => {
                // The batch is the maximal same-tenant *run* in global
                // admission order, capped at `cap` and the token
                // budget — exactly the offline FIFO batch boundary
                // when unbudgeted.
                let t = self.head_of_line()?;
                let budget = self.step_budget();
                let requests = self.pop_bounded(
                    t, self.cap, budget, true,
                    move |s| s.head_of_line() == Some(t));
                Some(Batch { tenant: t, requests })
            }
            Policy::SwapAware => {
                // Continuous batching: stay on the live tenant while
                // it has pending work (new same-tenant arrivals join
                // here), else move to the earliest-admitted tenant —
                // or, cache-aware, to the warmest pending chain.
                let t = match live {
                    Some(t) if self.front_seq(t).is_some() => t,
                    _ if self.cache_aware => self.warmest_tenant()?,
                    _ => self.head_of_line()?,
                };
                Some(self.take(t))
            }
            Policy::SloAware => {
                let t = self.pick_slo(live, clock)?;
                Some(self.take(t))
            }
        }
    }

    /// Continuous-batching join: pop up to `max_requests` pending
    /// requests of the LIVE tenant (admission order) so they can enter
    /// the in-flight batch mid-generation, their prefills fitting in
    /// `token_budget` spare step tokens (`usize::MAX` = unlimited).
    ///
    /// Policy gating: `SwapAware` and `SloAware` admit any pending
    /// same-tenant request (that is the point of continuous batching);
    /// `Fifo` only joins requests that are at the global head of line,
    /// preserving its strict admission-order discipline. Unlike a
    /// fresh dispatch, a join never exceeds the budget — the batch
    /// already has work, so an oversized prompt just waits for the
    /// batch to drain.
    pub fn join_live(&mut self, live: TenantId, max_requests: usize,
                     token_budget: usize) -> Vec<Request> {
        self.pop_bounded(live, max_requests, token_budget, false,
                         move |s| s.policy != Policy::Fifo
                             || s.head_of_line() == Some(live))
    }

    /// Re-queue a preempted request at the back of its tenant's
    /// pending queue (a fresh admission sequence number — the request
    /// gave up its slot, so it re-queues behind already-pending work;
    /// under slo-aware its urgency key, recomputed from its remaining
    /// decode debt, is what gets it back in). The engine calls this
    /// when it evicts a decoding slot; the request's prompt field has
    /// been extended to cover the recompute-on-resume replay.
    pub fn requeue(&mut self, r: Request) {
        let seq = self.next_seq;
        self.next_seq += 1;
        let slack = self.decode_slack_s;
        self.pending[r.tenant.index()].push(seq, r, slack);
        self.pending_count += 1;
    }

    /// Tightest decode-adjusted slack among tenants OTHER than `live`:
    /// seconds until the most urgent other-tenant request must START
    /// to make its effective deadline. Serving it means paying an
    /// adapter swap first, so the swap penalty is SUBTRACTED — it
    /// tightens the real start-by time (unlike `pick_slo`, where the
    /// penalty is added as hysteresis against switching). Negative
    /// means it is already past due even with an immediate swap — the
    /// engine's slo-aware preemption trigger treats those as beyond
    /// rescue. None when no other tenant has pending work.
    pub fn urgent_other_slack(&self, live: Option<TenantId>,
                              clock: f64) -> Option<f64> {
        let mut best: Option<f64> = None;
        for (i, q) in self.pending.iter().enumerate() {
            if live == Some(TenantId(i as u32)) {
                continue;
            }
            let Some(u) = q.earliest_urgency() else { continue };
            let slack = u - clock - self.swap_penalty_s;
            if best.is_none_or(|b| slack < b) {
                best = Some(slack);
            }
        }
        best
    }

    /// Drain the scheduler as if every request had already arrived
    /// (admission at +inf) — the fully-arrived dispatch sequence the
    /// offline planner anchors against.
    pub fn drain_fully_arrived(&mut self) -> Vec<Batch> {
        self.admit(f64::INFINITY);
        let mut out: Vec<Batch> = Vec::new();
        let mut live = None;
        while let Some(b) = self.dispatch(live, 0.0) {
            live = Some(b.tenant);
            out.push(b);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool_of(n: usize) -> TenantPool {
        let mut p = TenantPool::new();
        for i in 0..n {
            p.intern(&format!("t{i}"));
        }
        p
    }

    fn req(id: u64, tenant: u32) -> Request {
        Request { id, tenant: TenantId(tenant), tokens: 16,
                  decode_tokens: 0, shared_prefix_tokens: 0,
                  arrival_s: id as f64 * 0.01,
                  deadline_s: f64::INFINITY }
    }

    fn mixed() -> Vec<Request> {
        // Interleaved tenants — the worst case for FIFO.
        [0u32, 1, 0, 2, 1, 0, 2, 1, 0, 1].iter().enumerate()
            .map(|(i, &t)| req(i as u64, t)).collect()
    }

    fn ids(batches: &[Batch]) -> Vec<u64> {
        let mut v: Vec<u64> = batches.iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn tenant_pool_interns_densely() {
        let mut p = TenantPool::new();
        let a = p.intern("a");
        let b = p.intern("b");
        assert_eq!(p.intern("a"), a, "re-intern must be stable");
        assert_eq!(a, TenantId(0));
        assert_eq!(b, TenantId(1));
        assert_eq!(p.name(a), "a");
        assert_eq!(p.get("b"), Some(b));
        assert_eq!(p.get("zz"), None);
        assert_eq!(p.len(), 2);
        assert_eq!(p.names(), &["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn all_policies_preserve_all_requests() {
        for policy in Policy::ALL {
            let batches = plan(mixed(), 4, policy);
            assert_eq!(ids(&batches), (0..10).collect::<Vec<_>>(),
                       "{policy:?}");
            for b in &batches {
                assert!(b.requests.len() <= 4);
                assert!(b.requests.iter().all(|r| r.tenant == b.tenant));
            }
        }
    }

    #[test]
    fn swap_aware_beats_fifo_on_interleaved_tenants() {
        let fifo = swap_count(&plan(mixed(), 4, Policy::Fifo));
        let aware = swap_count(&plan(mixed(), 4, Policy::SwapAware));
        assert_eq!(aware, 3, "one swap per distinct tenant");
        assert!(fifo > aware, "fifo {fifo} !> swap-aware {aware}");
    }

    #[test]
    fn fifo_preserves_arrival_order() {
        let batches = plan(mixed(), 4, Policy::Fifo);
        let flat: Vec<u64> = batches.iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        assert_eq!(flat, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn swap_aware_keeps_per_tenant_order_and_chunks() {
        let reqs: Vec<Request> = (0..9).map(|i| req(i, 0)).collect();
        let batches = plan(reqs, 4, Policy::SwapAware);
        assert_eq!(batches.len(), 3); // 4 + 4 + 1
        assert_eq!(batches[2].requests.len(), 1);
        assert_eq!(swap_count(&batches), 1);
        let flat: Vec<u64> = batches.iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        assert_eq!(flat, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("fifo").unwrap(), Policy::Fifo);
        assert_eq!(Policy::parse("swap-aware").unwrap(),
                   Policy::SwapAware);
        assert_eq!(Policy::parse("slo-aware").unwrap(),
                   Policy::SloAware);
        assert!(Policy::parse("lifo").is_err());
    }

    #[test]
    fn empty_queue_plans_empty() {
        assert!(plan(Vec::new(), 8, Policy::Fifo).is_empty());
        assert_eq!(swap_count(&[]), 0);
        let mut s = OnlineScheduler::new(Vec::new(), 0, 8,
                                         Policy::Fifo);
        assert!(s.is_done());
        assert!(s.dispatch(None, 0.0).is_none());
        assert!(s.next_arrival().is_none());
    }

    #[test]
    fn online_admits_by_arrival_time() {
        let pool = pool_of(3);
        let reqs = mixed(); // arrivals at id * 0.01
        let mut s = OnlineScheduler::new(reqs, pool.len(), 4,
                                         Policy::Fifo);
        assert_eq!(s.admit(-1.0), 0, "nothing has arrived yet");
        assert_eq!(s.pending_len(), 0);
        assert_eq!(s.next_arrival(), Some(0.0));
        assert_eq!(s.admit(0.035), 4, "ids 0..=3 have arrived");
        assert_eq!(s.pending_len(), 4);
        assert_eq!(s.next_arrival(), Some(0.04));
        assert_eq!(s.admit(10.0), 6, "the rest");
        assert!(s.next_arrival().is_none());
        assert!(!s.is_done(), "still pending");
    }

    #[test]
    fn online_fully_arrived_matches_offline_plan() {
        // The refactor's correctness anchor, at unit scale: on a
        // fully-arrived queue the online dispatch sequence IS the
        // offline plan, batch for batch, for fifo and swap-aware.
        for policy in [Policy::Fifo, Policy::SwapAware] {
            let offline = plan(mixed(), 4, policy);
            let mut s = OnlineScheduler::new(mixed(), 3, 4, policy);
            let online = s.drain_fully_arrived();
            assert_eq!(online.len(), offline.len(), "{policy:?}");
            for (a, b) in online.iter().zip(&offline) {
                assert_eq!(a.tenant, b.tenant, "{policy:?}");
                let ia: Vec<u64> =
                    a.requests.iter().map(|r| r.id).collect();
                let ib: Vec<u64> =
                    b.requests.iter().map(|r| r.id).collect();
                assert_eq!(ia, ib, "{policy:?}");
            }
            assert_eq!(swap_count(&online), swap_count(&offline));
        }
    }

    #[test]
    fn continuous_batching_joins_live_tenant() {
        // A new same-tenant arrival admitted mid-service joins the
        // next dispatch instead of waiting behind other tenants.
        let mut reqs = vec![req(0, 0), req(1, 0), req(2, 1)];
        reqs.push(Request { id: 3, tenant: TenantId(0), tokens: 16,
                            decode_tokens: 0, shared_prefix_tokens: 0,
                            arrival_s: 0.5,
                            deadline_s: f64::INFINITY });
        let mut s = OnlineScheduler::new(reqs, 2, 1,
                                         Policy::SwapAware);
        s.admit(0.1); // ids 0, 1, 2
        let b0 = s.dispatch(None, 0.1).unwrap();
        assert_eq!(b0.requests[0].id, 0);
        // id 3 (tenant 0) arrives while tenant 0 is live.
        s.admit(0.6);
        let b1 = s.dispatch(Some(TenantId(0)), 0.6).unwrap();
        assert_eq!(b1.tenant, TenantId(0));
        assert_eq!(b1.requests[0].id, 1);
        let b2 = s.dispatch(Some(TenantId(0)), 0.7).unwrap();
        assert_eq!(b2.tenant, TenantId(0),
                   "late arrival keeps the live tenant dispatching");
        assert_eq!(b2.requests[0].id, 3);
        let b3 = s.dispatch(Some(TenantId(0)), 0.8).unwrap();
        assert_eq!(b3.tenant, TenantId(1), "then the queued tenant");
        assert_eq!(b3.requests[0].id, 2);
        assert!(s.is_done());
    }

    #[test]
    fn slo_aware_serves_urgent_tenant_first() {
        // Tenant 1's deadline is much tighter; slo-aware jumps to it
        // even though tenant 0 arrived first.
        let mk = |id, tenant, deadline_s| Request {
            id, tenant: TenantId(tenant), tokens: 8, decode_tokens: 0,
            shared_prefix_tokens: 0, arrival_s: 0.0, deadline_s,
        };
        let reqs = vec![mk(0, 0, 10.0), mk(1, 1, 0.05)];
        let mut s = OnlineScheduler::new(reqs, 2, 4, Policy::SloAware);
        s.admit(0.0);
        let b = s.dispatch(None, 0.0).unwrap();
        assert_eq!(b.tenant, TenantId(1), "tighter deadline first");
        // FIFO on the same queue would serve tenant 0 first.
        let reqs = vec![mk(0, 0, 10.0), mk(1, 1, 0.05)];
        let mut s = OnlineScheduler::new(reqs, 2, 4, Policy::Fifo);
        s.admit(0.0);
        assert_eq!(s.dispatch(None, 0.0).unwrap().tenant, TenantId(0));
    }

    #[test]
    fn slo_aware_swap_penalty_keeps_live_tenant() {
        // Tenant 1 is slightly more urgent than live tenant 0, but by
        // less than the swap penalty — the scheduler stays put. With
        // the penalty at zero it would switch immediately.
        let mk = |id, tenant, deadline_s| Request {
            id, tenant: TenantId(tenant), tokens: 8, decode_tokens: 0,
            shared_prefix_tokens: 0, arrival_s: 0.0, deadline_s,
        };
        let reqs = || vec![mk(0, 0, 0.50), mk(1, 0, 0.50),
                           mk(2, 1, 0.45)];
        let mut s = OnlineScheduler::new(reqs(), 2, 1,
                                         Policy::SloAware);
        s.swap_penalty_s = 0.2;
        s.admit(0.0);
        let order: Vec<TenantId> = std::iter::successors(
            s.dispatch(Some(TenantId(0)), 0.0),
            |prev| s.dispatch(Some(prev.tenant), 0.0))
            .map(|b| b.tenant).collect();
        assert_eq!(order, vec![TenantId(0), TenantId(0), TenantId(1)],
                   "0.05 of extra urgency does not buy a 0.2 swap");
        // Same queue, no penalty: the urgent tenant preempts at once.
        let mut s = OnlineScheduler::new(reqs(), 2, 1,
                                         Policy::SloAware);
        s.admit(0.0);
        assert_eq!(s.dispatch(Some(TenantId(0)), 0.0).unwrap().tenant,
                   TenantId(1));
    }

    #[test]
    fn online_preserves_every_request_exactly_once() {
        for policy in Policy::ALL {
            let mut s = OnlineScheduler::new(mixed(), 3, 4, policy);
            let batches = s.drain_fully_arrived();
            assert_eq!(ids(&batches), (0..10).collect::<Vec<_>>(),
                       "{policy:?}");
            assert!(s.is_done());
        }
    }

    #[test]
    fn token_budget_splits_batches_without_losing_requests() {
        // 9 same-tenant requests of 16 tokens under a 40-token budget:
        // 2 requests per batch (32 ≤ 40 < 48), every request served.
        let reqs: Vec<Request> = (0..9).map(|i| req(i, 0)).collect();
        for policy in Policy::ALL {
            let mut s = OnlineScheduler::new(reqs.clone(), 1, 8,
                                             policy);
            s.max_batch_tokens = 40;
            let batches = s.drain_fully_arrived();
            assert_eq!(ids(&batches), (0..9).collect::<Vec<_>>(),
                       "{policy:?}");
            for b in &batches {
                assert!(b.tokens() <= 40, "{policy:?}: {} tokens",
                        b.tokens());
            }
            assert_eq!(batches.len(), 5, "{policy:?}: 2+2+2+2+1");
        }
    }

    #[test]
    fn oversized_request_dispatches_alone() {
        // A prompt larger than the budget must still be served (batch
        // of one), not wedge the queue.
        let mut reqs = vec![req(0, 0), req(1, 0)];
        reqs[0].tokens = 100;
        let mut s = OnlineScheduler::new(reqs, 1, 8,
                                         Policy::SwapAware);
        s.max_batch_tokens = 40;
        let batches = s.drain_fully_arrived();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].requests.len(), 1);
        assert_eq!(batches[0].requests[0].id, 0);
        assert!(s.is_done());
    }

    #[test]
    fn join_live_pops_same_tenant_within_budget() {
        let reqs = vec![req(0, 0), req(1, 0), req(2, 1), req(3, 0)];
        let mut s = OnlineScheduler::new(reqs, 2, 8,
                                         Policy::SwapAware);
        s.admit(10.0);
        // Live tenant 0 has three pending; budget fits two prefills.
        let joined = s.join_live(TenantId(0), 8, 32);
        let ids: Vec<u64> = joined.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1], "admission order, 32-token cap");
        // Slot cap binds too.
        let joined = s.join_live(TenantId(0), 0, usize::MAX);
        assert!(joined.is_empty());
        // Remaining tenant-0 request joins; tenant 1 never does.
        let joined = s.join_live(TenantId(0), 8, usize::MAX);
        assert_eq!(joined.len(), 1);
        assert_eq!(joined[0].id, 3);
        assert_eq!(s.pending_len(), 1, "tenant 1 still queued");
    }

    #[test]
    fn fifo_join_requires_head_of_line() {
        // Tenant 1's request sits at the head of the global line, so a
        // fifo join on live tenant 0 must refuse — serving id 2 first
        // would reorder arrivals.
        let reqs = vec![req(0, 1), req(1, 1), req(2, 0)];
        let mut s = OnlineScheduler::new(reqs, 2, 8, Policy::Fifo);
        s.admit(10.0);
        assert!(s.join_live(TenantId(0), 8, usize::MAX).is_empty());
        // Swap-aware has no such constraint.
        let reqs = vec![req(0, 1), req(1, 1), req(2, 0)];
        let mut s = OnlineScheduler::new(reqs, 2, 8,
                                         Policy::SwapAware);
        s.admit(10.0);
        assert_eq!(s.join_live(TenantId(0), 8, usize::MAX).len(), 1);
    }

    #[test]
    fn kv_gate_bounds_dispatch_and_joins() {
        // 16-token prompts owing 16 decode tokens → a 32-token
        // lifetime cache = 2 blocks at 16-token granularity.
        let reqs = || -> Vec<Request> {
            (0..4).map(|i| {
                let mut r = req(i, 0);
                r.decode_tokens = 16;
                r
            }).collect()
        };
        let mut s = OnlineScheduler::new(reqs(), 1, 8,
                                         Policy::SwapAware);
        s.kv_block_tokens = 16;
        s.admit(10.0);
        assert_eq!(s.kv_blocks_of(32), 2);
        // 5 free blocks: two requests fit (4 blocks), not three.
        s.kv_free_blocks = 5;
        let b = s.dispatch(None, 10.0).unwrap();
        assert_eq!(b.requests.len(), 2, "kv gate must bound dispatch");
        // 1 free block: a join admits nothing (joins never exceed)…
        s.kv_free_blocks = 1;
        assert!(s.join_live(TenantId(0), 8, usize::MAX).is_empty());
        // …but a FRESH dispatch still makes progress (first fits:
        // the oversized sequence degrades to a capped batch of one
        // instead of wedging the queue).
        s.kv_free_blocks = 0;
        let b = s.dispatch(Some(TenantId(0)), 10.0).unwrap();
        assert_eq!(b.requests.len(), 1);
        // 2 free blocks: exactly one more joins.
        s.kv_free_blocks = 2;
        assert_eq!(s.join_live(TenantId(0), 8, usize::MAX).len(), 1);
        assert!(s.is_done());
        // Granularity 0 disables the gate entirely (the PR-3 path).
        let mut s = OnlineScheduler::new(reqs(), 1, 8,
                                         Policy::SwapAware);
        s.kv_block_tokens = 0;
        s.kv_free_blocks = 0;
        s.admit(10.0);
        assert_eq!(s.kv_blocks_of(32), 0);
        assert_eq!(s.dispatch(None, 10.0).unwrap().requests.len(), 4,
                   "gating off: free blocks are irrelevant");
    }

    #[test]
    fn prefix_cover_charges_only_the_uncached_suffix() {
        // 40-token prompts whose first 32 tokens are the tenant's
        // cached prefix (2 full 16-token blocks advertised): the
        // step budget and the kv gate must both charge only the
        // 8-token suffix.
        let reqs = || -> Vec<Request> {
            (0..5).map(|id| {
                let mut r = req(id, 0);
                r.tokens = 40;
                r.shared_prefix_tokens = 32;
                r
            }).collect()
        };
        let mut s = OnlineScheduler::new(reqs(), 1, 8,
                                         Policy::SwapAware);
        s.max_batch_tokens = 40;
        s.prefix_block_tokens = 16;
        s.kv_prefix_cover = vec![(2, 0)];
        s.admit(10.0);
        let b = s.dispatch(None, 10.0).unwrap();
        assert_eq!(b.requests.len(), 5,
                   "5 × 8-token suffixes fit a 40-token budget");
        // Without the cover the same budget takes exactly one.
        let mut s = OnlineScheduler::new(reqs(), 1, 8,
                                         Policy::SwapAware);
        s.max_batch_tokens = 40;
        s.admit(10.0);
        assert_eq!(s.dispatch(None, 10.0).unwrap().requests.len(), 1);
        // The kv gate projects suffix blocks too: lifetime 40 − 32
        // cached = 8 tokens = 1 block each; 3 free blocks admit 3.
        let mut s = OnlineScheduler::new(reqs(), 1, 8,
                                         Policy::SwapAware);
        s.prefix_block_tokens = 16;
        s.kv_prefix_cover = vec![(2, 0)];
        s.kv_block_tokens = 16;
        s.kv_free_blocks = 3;
        s.admit(10.0);
        assert_eq!(s.dispatch(None, 10.0).unwrap().requests.len(), 3);
        // A partial-tail cover only matches when the whole tail fits
        // inside the usable prefix (block-granular rule).
        let mut s = OnlineScheduler::new(reqs(), 1, 8,
                                         Policy::SwapAware);
        s.max_batch_tokens = 40;
        s.prefix_block_tokens = 16;
        s.kv_prefix_cover = vec![(1, 12)]; // covers 16 + 12 = 28 ≤ 32
        s.admit(10.0);
        let b = s.dispatch(None, 10.0).unwrap();
        // charge = 40 − 28 = 12 per request → 3 fit in 40.
        assert_eq!(b.requests.len(), 3);
    }

    #[test]
    fn requeue_reenters_behind_pending_work() {
        let reqs = vec![req(0, 0), req(1, 0)];
        let mut s = OnlineScheduler::new(reqs, 1, 1,
                                         Policy::SwapAware);
        s.admit(10.0);
        let b = s.dispatch(None, 10.0).unwrap();
        assert_eq!(b.requests[0].id, 0);
        // Preempted: id 0 re-queues BEHIND the still-pending id 1.
        s.requeue(b.requests.into_iter().next().unwrap());
        assert_eq!(s.pending_len(), 2);
        assert_eq!(s.dispatch(None, 10.0).unwrap().requests[0].id, 1);
        assert_eq!(s.dispatch(None, 10.0).unwrap().requests[0].id, 0);
        assert!(s.is_done());
    }

    #[test]
    fn urgent_other_slack_probes_other_tenants_only() {
        let mk = |id, tenant, deadline_s| Request {
            id, tenant: TenantId(tenant), tokens: 8, decode_tokens: 0,
            shared_prefix_tokens: 0, arrival_s: 0.0, deadline_s,
        };
        let reqs = vec![mk(0, 0, 0.10), mk(1, 1, 0.30),
                        mk(2, 2, 0.20)];
        let mut s = OnlineScheduler::new(reqs, 3, 4, Policy::SloAware);
        s.swap_penalty_s = 0.01;
        s.admit(0.0);
        // Live tenant 0 is excluded; the tightest OTHER is tenant 2
        // (0.20), tightened by the swap it would have to pay first:
        // 0.20 − 0.05 − 0.01.
        let slack = s.urgent_other_slack(Some(TenantId(0)), 0.05)
            .unwrap();
        assert!((slack - 0.14).abs() < 1e-12, "got {slack}");
        // With no live tenant, tenant 0's 0.10 is tightest.
        let slack = s.urgent_other_slack(None, 0.05).unwrap();
        assert!((slack - 0.04).abs() < 1e-12, "got {slack}");
        // Drain tenants 1 and 2: only the live tenant remains → None.
        let _ = s.dispatch(None, 0.0); // tenant 0 (tightest deadline)
        let _ = s.dispatch(Some(TenantId(0)), 0.0); // tenant 2
        let _ = s.dispatch(Some(TenantId(2)), 0.0); // tenant 1
        assert!(s.urgent_other_slack(Some(TenantId(1)), 0.0).is_none());
    }

    #[test]
    fn slo_urgency_accounts_for_remaining_decode_work() {
        // Same deadline, but tenant 1's request owes 100 decode
        // iterations: with decode slack calibrated it must be served
        // first; with the adjustment off, the tie prefers the live
        // tenant 0.
        let mk = |id, tenant, decode_tokens| Request {
            id, tenant: TenantId(tenant), tokens: 8, decode_tokens,
            shared_prefix_tokens: 0, arrival_s: 0.0, deadline_s: 1.0,
        };
        let reqs = || vec![mk(0, 0, 0), mk(1, 1, 100)];
        let mut s = OnlineScheduler::new(reqs(), 2, 4,
                                         Policy::SloAware);
        s.decode_slack_s = 1e-3;
        s.admit(0.0);
        assert_eq!(s.dispatch(Some(TenantId(0)), 0.0).unwrap().tenant,
                   TenantId(1),
                   "100 pending decode steps tighten the deadline");
        let mut s = OnlineScheduler::new(reqs(), 2, 4,
                                         Policy::SloAware);
        s.admit(0.0);
        assert_eq!(s.dispatch(Some(TenantId(0)), 0.0).unwrap().tenant,
                   TenantId(0), "no slack adjustment: live tie wins");
    }

    #[test]
    fn chunked_admission_charges_one_chunk_per_prompt() {
        // Four 64-token prompts under a 64-token step budget: the
        // unchunked scheduler fits exactly one per dispatch; with
        // 16-token chunks the same budget seats all four (each charged
        // one first chunk), so long prompts stop monopolizing steps.
        let reqs = || -> Vec<Request> {
            (0..4).map(|i| {
                let mut r = req(i, 0);
                r.tokens = 64;
                r
            }).collect()
        };
        let mut s = OnlineScheduler::new(reqs(), 1, 8,
                                         Policy::SwapAware);
        s.max_batch_tokens = 64;
        s.admit(10.0);
        assert_eq!(s.dispatch(None, 10.0).unwrap().requests.len(), 1);
        let mut s = OnlineScheduler::new(reqs(), 1, 8,
                                         Policy::SwapAware);
        s.max_batch_tokens = 64;
        s.prefill_chunk_tokens = 16;
        s.admit(10.0);
        assert_eq!(s.dispatch(None, 10.0).unwrap().requests.len(), 4,
                   "4 × 16-token first chunks fit a 64-token budget");
        // Chunking composes with the prefix cover: charge is the
        // MIN(chunk, uncached suffix), never padded back up.
        let mut s = OnlineScheduler::new(reqs(), 1, 8,
                                         Policy::SwapAware);
        s.prefill_chunk_tokens = 16;
        s.prefix_block_tokens = 16;
        s.kv_prefix_cover = vec![(3, 8)]; // 56 tokens warm
        let mut r = req(9, 0);
        r.tokens = 64;
        r.shared_prefix_tokens = 60;
        let (charge, _) = s.projection(&r);
        assert_eq!(charge, 8, "8-token suffix < 16-token chunk");
        // Chunk 0 is the unchunked projection, bit for bit.
        let mut s0 = OnlineScheduler::new(reqs(), 1, 8,
                                          Policy::SwapAware);
        let held = reqs();
        let big = &held[0];
        assert_eq!(s0.projection(big), (64, 0));
        s0.prefill_chunk_tokens = 16;
        assert_eq!(s0.projection(big), (16, 0));
        // KV-block projection is the lifetime watermark either way.
        s0.kv_block_tokens = 16;
        assert_eq!(s0.projection(big).1, 4);
        s0.prefill_chunk_tokens = 0;
        assert_eq!(s0.projection(big), (64, 4));
    }

    #[test]
    fn cache_aware_prefers_warm_chains_on_ties() {
        // Three tenants, no deadlines, no live adapter. Tenant 2's
        // radix chain is warm; cache-aware swap-aware dispatch starts
        // there instead of at the head of line, and slo-aware breaks
        // its (infinite-slack) tie the same way.
        let reqs = || vec![req(0, 0), req(1, 1), req(2, 2)];
        for policy in [Policy::SwapAware, Policy::SloAware] {
            let mut s = OnlineScheduler::new(reqs(), 3, 4, policy);
            s.cache_aware = true;
            s.prefix_block_tokens = 16;
            s.kv_prefix_cover = vec![(0, 0), (0, 0), (2, 4)];
            s.admit(10.0);
            assert_eq!(s.dispatch(None, 10.0).unwrap().tenant,
                       TenantId(2), "{policy:?}: warm chain first");
        }
        // Flag off (or every chain cold): head-of-line order exactly.
        for cover in [Vec::new(), vec![(0, 0), (0, 0), (0, 0)]] {
            let mut s = OnlineScheduler::new(reqs(), 3, 4,
                                             Policy::SwapAware);
            s.cache_aware = true;
            s.prefix_block_tokens = 16;
            s.kv_prefix_cover = cover;
            s.admit(10.0);
            assert_eq!(s.dispatch(None, 10.0).unwrap().tenant,
                       TenantId(0), "cold chains → FIFO");
        }
        let mut s = OnlineScheduler::new(reqs(), 3, 4,
                                         Policy::SwapAware);
        s.prefix_block_tokens = 16;
        s.kv_prefix_cover = vec![(0, 0), (0, 0), (2, 4)];
        s.admit(10.0);
        assert_eq!(s.dispatch(None, 10.0).unwrap().tenant, TenantId(0),
                   "flag off: warmth is ignored");
        // Deadline pressure still beats warmth under slo-aware.
        let mk = |id, tenant, deadline_s| Request {
            id, tenant: TenantId(tenant), tokens: 8, decode_tokens: 0,
            shared_prefix_tokens: 0, arrival_s: 0.0, deadline_s,
        };
        let mut s = OnlineScheduler::new(
            vec![mk(0, 0, 0.05), mk(1, 1, 10.0)], 2, 4,
            Policy::SloAware);
        s.cache_aware = true;
        s.prefix_block_tokens = 16;
        s.kv_prefix_cover = vec![(0, 0), (4, 0)]; // tenant 1 warm
        s.admit(0.0);
        assert_eq!(s.dispatch(None, 0.0).unwrap().tenant, TenantId(0),
                   "urgency dominates warmth");
    }

    #[test]
    fn peek_future_yields_arrival_order_without_admitting() {
        let reqs = vec![req(0, 0), req(1, 1), req(2, 0)];
        let mut s = OnlineScheduler::new(reqs, 2, 4,
                                         Policy::SwapAware);
        let ids: Vec<u64> = s.peek_future().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2], "soonest first");
        assert_eq!(s.pending_len(), 0, "peeking admits nothing");
        s.admit(0.005); // id 0 arrives
        let ids: Vec<u64> = s.peek_future().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn batch_total_tokens_includes_decode() {
        let mut r = req(0, 0);
        r.decode_tokens = 24;
        assert_eq!(r.total_tokens(), 40);
        let b = Batch { tenant: TenantId(0),
                        requests: vec![r, req(1, 0)] };
        assert_eq!(b.tokens(), 32, "prefill only");
        assert_eq!(b.total_tokens(), 56, "prefill + decode");
    }
}
