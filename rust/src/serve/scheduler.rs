//! Request scheduling: offline batch planning + the online
//! continuous-batching scheduler.
//!
//! Serving PaCA adapters from one shared base means the only per-tenant
//! cost is the adapter *swap* (splice/un-splice) between batches; the
//! forward itself is method-free. Scheduling therefore trades two
//! currencies: swaps saved by coalescing same-adapter requests
//! (LoRAFusion's grouping insight applied to PaCA's splice model) and
//! queueing delay paid by requests that wait for their adapter's turn.
//!
//! Two layers:
//!   * [`plan`] — the offline planner: consumes a fully-arrived queue
//!     and emits a static batch list. Kept as the correctness anchor —
//!     on a fully-arrived queue the online scheduler must reproduce its
//!     dispatch sequence (see `tests/properties.rs`).
//!   * [`OnlineScheduler`] — the event-driven online layer: admits
//!     requests as their `arrival_s` passes a virtual clock, keeps
//!     per-tenant pending queues, and makes one incremental dispatch
//!     decision at a time. New same-tenant arrivals join the next
//!     dispatch instead of waiting for a full replan (continuous
//!     batching).
//!
//! Tenant names are interned to dense [`TenantId`]s at trace load
//! ([`TenantPool`]), so the hot loop moves `Copy` ids around instead of
//! cloning `String`s per request.

use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};

use anyhow::{anyhow, Result};

/// Dense interned tenant handle — index into a [`TenantPool`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub u32);

impl TenantId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// String-interning table for tenant names: ids are dense (0..n in
/// first-appearance order), so per-tenant state can live in plain
/// `Vec`s indexed by [`TenantId`].
#[derive(Debug, Clone, Default)]
pub struct TenantPool {
    names: Vec<String>,
    index: HashMap<String, TenantId>,
}

impl TenantPool {
    pub fn new() -> TenantPool {
        TenantPool::default()
    }

    /// Id for `name`, allocating the next dense id on first sight.
    pub fn intern(&mut self, name: &str) -> TenantId {
        if let Some(&id) = self.index.get(name) {
            return id;
        }
        let id = TenantId(self.names.len() as u32);
        self.names.push(name.to_string());
        self.index.insert(name.to_string(), id);
        id
    }

    pub fn get(&self, name: &str) -> Option<TenantId> {
        self.index.get(name).copied()
    }

    pub fn name(&self, id: TenantId) -> &str {
        &self.names[id.index()]
    }

    /// Interned names in first-appearance order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub tenant: TenantId,
    /// Prompt length in tokens (drives forward cost).
    pub tokens: usize,
    /// Arrival timestamp, seconds from trace start. The online
    /// scheduler only sees a request once the clock passes this.
    pub arrival_s: f64,
    /// Per-request SLO: seconds after arrival by which the request
    /// must complete. `f64::INFINITY` = no deadline (the default for
    /// traces that predate the field).
    pub deadline_s: f64,
}

impl Request {
    /// Absolute completion deadline on the trace clock.
    pub fn absolute_deadline(&self) -> f64 {
        self.arrival_s + self.deadline_s
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Dispatch strictly in arrival order; a batch breaks whenever the
    /// tenant changes or the batch is full.
    Fifo,
    /// Coalesce by tenant (stable in first-arrival order) — one swap
    /// per tenant instead of one per tenant *run*. Online, the live
    /// tenant keeps dispatching while it has pending work.
    SwapAware,
    /// Earliest-deadline-first across tenants, with the adapter-swap
    /// cost charged as a slack penalty against switching away from the
    /// live tenant. Offline (no clock) it plans like `SwapAware`.
    SloAware,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy> {
        Ok(match s {
            "fifo" => Policy::Fifo,
            "swap-aware" | "swap" | "grouped" => Policy::SwapAware,
            "slo-aware" | "slo" | "deadline" => Policy::SloAware,
            other => {
                return Err(anyhow!(
                    "unknown policy {other:?} (fifo | swap-aware | \
                     slo-aware)"))
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::SwapAware => "swap-aware",
            Policy::SloAware => "slo-aware",
        }
    }

    pub const ALL: [Policy; 3] =
        [Policy::Fifo, Policy::SwapAware, Policy::SloAware];
}

/// One dispatch unit: requests sharing a tenant, served under one
/// splice of that tenant's adapter.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tenant: TenantId,
    pub requests: Vec<Request>,
}

impl Batch {
    pub fn tokens(&self) -> usize {
        self.requests.iter().map(|r| r.tokens).sum()
    }
}

/// Offline planner: the whole queue into batches of at most
/// `batch_size` requests. Every request appears in exactly one batch;
/// within a tenant, input order is preserved under every policy.
/// Requests are moved, never cloned. `SloAware` has no clock to
/// consult offline, so it plans like `SwapAware`.
pub fn plan(requests: Vec<Request>, batch_size: usize,
            policy: Policy) -> Vec<Batch> {
    let cap = batch_size.max(1);
    match policy {
        Policy::Fifo => {
            let mut out: Vec<Batch> = Vec::new();
            for r in requests {
                let start_new = match out.last() {
                    Some(b) => b.tenant != r.tenant
                        || b.requests.len() >= cap,
                    None => true,
                };
                if start_new {
                    out.push(Batch { tenant: r.tenant,
                                     requests: Vec::new() });
                }
                out.last_mut().unwrap().requests.push(r);
            }
            out
        }
        Policy::SwapAware | Policy::SloAware => {
            // Stable grouping by tenant in first-arrival order: a
            // HashMap index instead of the old O(n·t) linear scan over
            // the group list.
            let mut order: Vec<TenantId> = Vec::new();
            let mut groups: HashMap<TenantId, Vec<Request>> =
                HashMap::new();
            for r in requests {
                match groups.entry(r.tenant) {
                    Entry::Occupied(mut e) => e.get_mut().push(r),
                    Entry::Vacant(e) => {
                        order.push(r.tenant);
                        e.insert(vec![r]);
                    }
                }
            }
            let mut out = Vec::new();
            for tenant in order {
                // Chunk by moving: split_off leaves the head chunk in
                // place and hands back the tail, so no request is ever
                // cloned.
                let mut head = groups.remove(&tenant).unwrap();
                while head.len() > cap {
                    let tail = head.split_off(cap);
                    out.push(Batch { tenant, requests: head });
                    head = tail;
                }
                if !head.is_empty() {
                    out.push(Batch { tenant, requests: head });
                }
            }
            out
        }
    }
}

/// Adapter splices needed to serve the plan starting from the bare
/// base: 1 for the first batch plus 1 per adjacent tenant change
/// (consecutive same-tenant batches reuse the live splice).
pub fn swap_count(batches: &[Batch]) -> usize {
    let mut swaps = 0;
    let mut current: Option<TenantId> = None;
    for b in batches {
        if current != Some(b.tenant) {
            swaps += 1;
            current = Some(b.tenant);
        }
    }
    swaps
}

/// One tenant's pending FIFO plus a monotonic deque over absolute
/// deadlines, so the tightest deadline of the queue is O(1) per
/// dispatch instead of a scan of the whole backlog (which would make
/// slo-aware dispatch quadratic exactly in the overload regime it
/// exists for).
#[derive(Debug, Default)]
struct PendingQueue {
    q: VecDeque<(u64, Request)>,
    /// Non-decreasing absolute deadlines of the requests in `q`;
    /// front is the queue's minimum.
    min_deadline: VecDeque<f64>,
}

impl PendingQueue {
    fn push(&mut self, seq: u64, r: Request) {
        let d = r.absolute_deadline();
        while self.min_deadline.back().is_some_and(|&b| b > d) {
            self.min_deadline.pop_back();
        }
        self.min_deadline.push_back(d);
        self.q.push_back((seq, r));
    }

    fn pop(&mut self) -> Option<(u64, Request)> {
        let (seq, r) = self.q.pop_front()?;
        // Bitwise-identical value: it came from this request's push.
        if self.min_deadline.front() == Some(&r.absolute_deadline()) {
            self.min_deadline.pop_front();
        }
        Some((seq, r))
    }

    fn front_seq(&self) -> Option<u64> {
        self.q.front().map(|(seq, _)| *seq)
    }

    /// Tightest absolute deadline among queued requests.
    fn earliest_deadline(&self) -> Option<f64> {
        self.min_deadline.front().copied()
    }
}

/// The online continuous-batching scheduler.
///
/// Owns the not-yet-arrived tail of the trace plus per-tenant pending
/// queues of admitted requests. The engine's step loop drives it:
/// `admit(clock)` → `dispatch(live_tenant, clock)` → serve → repeat,
/// jumping the virtual clock to `next_arrival()` when idle. Admission
/// order is tracked with a per-request sequence number so FIFO
/// head-of-line decisions are exact.
pub struct OnlineScheduler {
    policy: Policy,
    cap: usize,
    /// Not-yet-admitted requests, ascending `arrival_s` — stored
    /// reversed so `pop()` yields the next arrival.
    future: Vec<Request>,
    /// Per-tenant pending queues, indexed by `TenantId`.
    pending: Vec<PendingQueue>,
    pending_count: usize,
    next_seq: u64,
    /// Seconds of slack the slo-aware policy charges a tenant switch —
    /// the scheduling price of an adapter swap. The engine's
    /// `serve_online` loop keeps this calibrated to the active clock
    /// model (analytic swap cost, or the measured running average);
    /// set it manually only when driving the scheduler directly.
    pub swap_penalty_s: f64,
}

impl OnlineScheduler {
    /// `n_tenants` bounds the dense `TenantId` space (usually
    /// `pool.len()`). Requests are stably sorted by arrival, so ties
    /// keep their input order.
    pub fn new(mut requests: Vec<Request>, n_tenants: usize,
               batch_size: usize, policy: Policy) -> OnlineScheduler {
        requests.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        for r in &requests {
            assert!(r.tenant.index() < n_tenants,
                    "tenant id {} outside pool of {n_tenants}",
                    r.tenant.0);
        }
        requests.reverse();
        OnlineScheduler {
            policy,
            cap: batch_size.max(1),
            future: requests,
            pending: (0..n_tenants)
                .map(|_| PendingQueue::default()).collect(),
            pending_count: 0,
            next_seq: 0,
            swap_penalty_s: 0.0,
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Admit every request whose arrival has passed; returns how many
    /// were admitted.
    pub fn admit(&mut self, clock: f64) -> usize {
        let mut n = 0;
        while self.future.last()
            .is_some_and(|r| r.arrival_s <= clock)
        {
            let r = self.future.pop().unwrap();
            let seq = self.next_seq;
            self.next_seq += 1;
            self.pending[r.tenant.index()].push(seq, r);
            self.pending_count += 1;
            n += 1;
        }
        n
    }

    /// Arrival time of the next not-yet-admitted request.
    pub fn next_arrival(&self) -> Option<f64> {
        self.future.last().map(|r| r.arrival_s)
    }

    /// Admitted-but-undispatched requests.
    pub fn pending_len(&self) -> usize {
        self.pending_count
    }

    /// True when nothing is pending and nothing is still to arrive.
    pub fn is_done(&self) -> bool {
        self.pending_count == 0 && self.future.is_empty()
    }

    /// Tenant of the earliest-admitted pending request.
    fn head_of_line(&self) -> Option<TenantId> {
        self.pending.iter().enumerate()
            .filter_map(|(t, q)| {
                q.front_seq().map(|seq| (seq, TenantId(t as u32)))
            })
            .min_by_key(|(seq, _)| *seq)
            .map(|(_, t)| t)
    }

    fn front_seq(&self, t: TenantId) -> Option<u64> {
        self.pending[t.index()].front_seq()
    }

    /// Slo-aware tenant choice: earliest-deadline-first on each
    /// tenant's tightest slack, where switching away from the live
    /// tenant pays `swap_penalty_s` of extra slack — so a swap only
    /// happens when another tenant's deadline pressure exceeds the
    /// swap cost. Ties prefer the live tenant, then earliest
    /// admission.
    fn pick_slo(&self, live: Option<TenantId>,
                clock: f64) -> Option<TenantId> {
        let mut best: Option<(f64, bool, u64, TenantId)> = None;
        for (i, q) in self.pending.iter().enumerate() {
            let front = match q.front_seq() {
                Some(seq) => seq,
                None => continue,
            };
            let t = TenantId(i as u32);
            // O(1): the per-queue monotonic deque tracks the minimum.
            let slack = q.earliest_deadline()
                .unwrap_or(f64::INFINITY) - clock;
            let is_switch = live != Some(t);
            let score = if is_switch {
                slack + self.swap_penalty_s
            } else {
                slack
            };
            // Serve the tenant whose penalized slack is SMALLEST,
            // preferring the live tenant, then FIFO.
            let key = (score, is_switch, front, t);
            let better = match &best {
                None => true,
                Some((bs, bsw, bf, _)) => {
                    match score.total_cmp(bs) {
                        std::cmp::Ordering::Less => true,
                        std::cmp::Ordering::Greater => false,
                        std::cmp::Ordering::Equal => {
                            (is_switch, front) < (*bsw, *bf)
                        }
                    }
                }
            };
            if better {
                best = Some(key);
            }
        }
        best.map(|(_, _, _, t)| t)
    }

    /// Pop up to `cap` requests from `t`'s queue, in admission order.
    fn take(&mut self, t: TenantId) -> Batch {
        let mut requests = Vec::new();
        while requests.len() < self.cap {
            match self.pending[t.index()].pop() {
                Some((_, r)) => {
                    self.pending_count -= 1;
                    requests.push(r);
                }
                None => break,
            }
        }
        Batch { tenant: t, requests }
    }

    /// One incremental dispatch decision. `live` is the tenant whose
    /// adapter is currently spliced into the base (None = bare base);
    /// `clock` is the virtual now. Returns None when nothing is
    /// pending (the caller should jump the clock to `next_arrival`).
    pub fn dispatch(&mut self, live: Option<TenantId>,
                    clock: f64) -> Option<Batch> {
        if self.pending_count == 0 {
            return None;
        }
        match self.policy {
            Policy::Fifo => {
                // The batch is the maximal same-tenant *run* in global
                // admission order, capped at `cap` — exactly the
                // offline FIFO batch boundary.
                let t = self.head_of_line()?;
                let mut requests = Vec::new();
                while requests.len() < self.cap
                    && self.head_of_line() == Some(t)
                {
                    let (_, r) =
                        self.pending[t.index()].pop().unwrap();
                    self.pending_count -= 1;
                    requests.push(r);
                }
                Some(Batch { tenant: t, requests })
            }
            Policy::SwapAware => {
                // Continuous batching: stay on the live tenant while
                // it has pending work (new same-tenant arrivals join
                // here), else move to the earliest-admitted tenant.
                let t = match live {
                    Some(t) if self.front_seq(t).is_some() => t,
                    _ => self.head_of_line()?,
                };
                Some(self.take(t))
            }
            Policy::SloAware => {
                let t = self.pick_slo(live, clock)?;
                Some(self.take(t))
            }
        }
    }

    /// Drain the scheduler as if every request had already arrived
    /// (admission at +inf) — the fully-arrived dispatch sequence the
    /// offline planner anchors against.
    pub fn drain_fully_arrived(&mut self) -> Vec<Batch> {
        self.admit(f64::INFINITY);
        let mut out: Vec<Batch> = Vec::new();
        let mut live = None;
        while let Some(b) = self.dispatch(live, 0.0) {
            live = Some(b.tenant);
            out.push(b);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool_of(n: usize) -> TenantPool {
        let mut p = TenantPool::new();
        for i in 0..n {
            p.intern(&format!("t{i}"));
        }
        p
    }

    fn req(id: u64, tenant: u32) -> Request {
        Request { id, tenant: TenantId(tenant), tokens: 16,
                  arrival_s: id as f64 * 0.01,
                  deadline_s: f64::INFINITY }
    }

    fn mixed() -> Vec<Request> {
        // Interleaved tenants — the worst case for FIFO.
        [0u32, 1, 0, 2, 1, 0, 2, 1, 0, 1].iter().enumerate()
            .map(|(i, &t)| req(i as u64, t)).collect()
    }

    fn ids(batches: &[Batch]) -> Vec<u64> {
        let mut v: Vec<u64> = batches.iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn tenant_pool_interns_densely() {
        let mut p = TenantPool::new();
        let a = p.intern("a");
        let b = p.intern("b");
        assert_eq!(p.intern("a"), a, "re-intern must be stable");
        assert_eq!(a, TenantId(0));
        assert_eq!(b, TenantId(1));
        assert_eq!(p.name(a), "a");
        assert_eq!(p.get("b"), Some(b));
        assert_eq!(p.get("zz"), None);
        assert_eq!(p.len(), 2);
        assert_eq!(p.names(), &["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn all_policies_preserve_all_requests() {
        for policy in Policy::ALL {
            let batches = plan(mixed(), 4, policy);
            assert_eq!(ids(&batches), (0..10).collect::<Vec<_>>(),
                       "{policy:?}");
            for b in &batches {
                assert!(b.requests.len() <= 4);
                assert!(b.requests.iter().all(|r| r.tenant == b.tenant));
            }
        }
    }

    #[test]
    fn swap_aware_beats_fifo_on_interleaved_tenants() {
        let fifo = swap_count(&plan(mixed(), 4, Policy::Fifo));
        let aware = swap_count(&plan(mixed(), 4, Policy::SwapAware));
        assert_eq!(aware, 3, "one swap per distinct tenant");
        assert!(fifo > aware, "fifo {fifo} !> swap-aware {aware}");
    }

    #[test]
    fn fifo_preserves_arrival_order() {
        let batches = plan(mixed(), 4, Policy::Fifo);
        let flat: Vec<u64> = batches.iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        assert_eq!(flat, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn swap_aware_keeps_per_tenant_order_and_chunks() {
        let reqs: Vec<Request> = (0..9).map(|i| req(i, 0)).collect();
        let batches = plan(reqs, 4, Policy::SwapAware);
        assert_eq!(batches.len(), 3); // 4 + 4 + 1
        assert_eq!(batches[2].requests.len(), 1);
        assert_eq!(swap_count(&batches), 1);
        let flat: Vec<u64> = batches.iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        assert_eq!(flat, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("fifo").unwrap(), Policy::Fifo);
        assert_eq!(Policy::parse("swap-aware").unwrap(),
                   Policy::SwapAware);
        assert_eq!(Policy::parse("slo-aware").unwrap(),
                   Policy::SloAware);
        assert!(Policy::parse("lifo").is_err());
    }

    #[test]
    fn empty_queue_plans_empty() {
        assert!(plan(Vec::new(), 8, Policy::Fifo).is_empty());
        assert_eq!(swap_count(&[]), 0);
        let mut s = OnlineScheduler::new(Vec::new(), 0, 8,
                                         Policy::Fifo);
        assert!(s.is_done());
        assert!(s.dispatch(None, 0.0).is_none());
        assert!(s.next_arrival().is_none());
    }

    #[test]
    fn online_admits_by_arrival_time() {
        let pool = pool_of(3);
        let reqs = mixed(); // arrivals at id * 0.01
        let mut s = OnlineScheduler::new(reqs, pool.len(), 4,
                                         Policy::Fifo);
        assert_eq!(s.admit(-1.0), 0, "nothing has arrived yet");
        assert_eq!(s.pending_len(), 0);
        assert_eq!(s.next_arrival(), Some(0.0));
        assert_eq!(s.admit(0.035), 4, "ids 0..=3 have arrived");
        assert_eq!(s.pending_len(), 4);
        assert_eq!(s.next_arrival(), Some(0.04));
        assert_eq!(s.admit(10.0), 6, "the rest");
        assert!(s.next_arrival().is_none());
        assert!(!s.is_done(), "still pending");
    }

    #[test]
    fn online_fully_arrived_matches_offline_plan() {
        // The refactor's correctness anchor, at unit scale: on a
        // fully-arrived queue the online dispatch sequence IS the
        // offline plan, batch for batch, for fifo and swap-aware.
        for policy in [Policy::Fifo, Policy::SwapAware] {
            let offline = plan(mixed(), 4, policy);
            let mut s = OnlineScheduler::new(mixed(), 3, 4, policy);
            let online = s.drain_fully_arrived();
            assert_eq!(online.len(), offline.len(), "{policy:?}");
            for (a, b) in online.iter().zip(&offline) {
                assert_eq!(a.tenant, b.tenant, "{policy:?}");
                let ia: Vec<u64> =
                    a.requests.iter().map(|r| r.id).collect();
                let ib: Vec<u64> =
                    b.requests.iter().map(|r| r.id).collect();
                assert_eq!(ia, ib, "{policy:?}");
            }
            assert_eq!(swap_count(&online), swap_count(&offline));
        }
    }

    #[test]
    fn continuous_batching_joins_live_tenant() {
        // A new same-tenant arrival admitted mid-service joins the
        // next dispatch instead of waiting behind other tenants.
        let mut reqs = vec![req(0, 0), req(1, 0), req(2, 1)];
        reqs.push(Request { id: 3, tenant: TenantId(0), tokens: 16,
                            arrival_s: 0.5,
                            deadline_s: f64::INFINITY });
        let mut s = OnlineScheduler::new(reqs, 2, 1,
                                         Policy::SwapAware);
        s.admit(0.1); // ids 0, 1, 2
        let b0 = s.dispatch(None, 0.1).unwrap();
        assert_eq!(b0.requests[0].id, 0);
        // id 3 (tenant 0) arrives while tenant 0 is live.
        s.admit(0.6);
        let b1 = s.dispatch(Some(TenantId(0)), 0.6).unwrap();
        assert_eq!(b1.tenant, TenantId(0));
        assert_eq!(b1.requests[0].id, 1);
        let b2 = s.dispatch(Some(TenantId(0)), 0.7).unwrap();
        assert_eq!(b2.tenant, TenantId(0),
                   "late arrival keeps the live tenant dispatching");
        assert_eq!(b2.requests[0].id, 3);
        let b3 = s.dispatch(Some(TenantId(0)), 0.8).unwrap();
        assert_eq!(b3.tenant, TenantId(1), "then the queued tenant");
        assert_eq!(b3.requests[0].id, 2);
        assert!(s.is_done());
    }

    #[test]
    fn slo_aware_serves_urgent_tenant_first() {
        // Tenant 1's deadline is much tighter; slo-aware jumps to it
        // even though tenant 0 arrived first.
        let mk = |id, tenant, deadline_s| Request {
            id, tenant: TenantId(tenant), tokens: 8, arrival_s: 0.0,
            deadline_s,
        };
        let reqs = vec![mk(0, 0, 10.0), mk(1, 1, 0.05)];
        let mut s = OnlineScheduler::new(reqs, 2, 4, Policy::SloAware);
        s.admit(0.0);
        let b = s.dispatch(None, 0.0).unwrap();
        assert_eq!(b.tenant, TenantId(1), "tighter deadline first");
        // FIFO on the same queue would serve tenant 0 first.
        let reqs = vec![mk(0, 0, 10.0), mk(1, 1, 0.05)];
        let mut s = OnlineScheduler::new(reqs, 2, 4, Policy::Fifo);
        s.admit(0.0);
        assert_eq!(s.dispatch(None, 0.0).unwrap().tenant, TenantId(0));
    }

    #[test]
    fn slo_aware_swap_penalty_keeps_live_tenant() {
        // Tenant 1 is slightly more urgent than live tenant 0, but by
        // less than the swap penalty — the scheduler stays put. With
        // the penalty at zero it would switch immediately.
        let mk = |id, tenant, deadline_s| Request {
            id, tenant: TenantId(tenant), tokens: 8, arrival_s: 0.0,
            deadline_s,
        };
        let reqs = || vec![mk(0, 0, 0.50), mk(1, 0, 0.50),
                           mk(2, 1, 0.45)];
        let mut s = OnlineScheduler::new(reqs(), 2, 1,
                                         Policy::SloAware);
        s.swap_penalty_s = 0.2;
        s.admit(0.0);
        let order: Vec<TenantId> = std::iter::successors(
            s.dispatch(Some(TenantId(0)), 0.0),
            |prev| s.dispatch(Some(prev.tenant), 0.0))
            .map(|b| b.tenant).collect();
        assert_eq!(order, vec![TenantId(0), TenantId(0), TenantId(1)],
                   "0.05 of extra urgency does not buy a 0.2 swap");
        // Same queue, no penalty: the urgent tenant preempts at once.
        let mut s = OnlineScheduler::new(reqs(), 2, 1,
                                         Policy::SloAware);
        s.admit(0.0);
        assert_eq!(s.dispatch(Some(TenantId(0)), 0.0).unwrap().tenant,
                   TenantId(1));
    }

    #[test]
    fn online_preserves_every_request_exactly_once() {
        for policy in Policy::ALL {
            let mut s = OnlineScheduler::new(mixed(), 3, 4, policy);
            let batches = s.drain_fully_arrived();
            assert_eq!(ids(&batches), (0..10).collect::<Vec<_>>(),
                       "{policy:?}");
            assert!(s.is_done());
        }
    }
}
