//! Step-level event tracing for the serving stack: a typed
//! [`EngineEvent`] stream emitted by the engine, scheduler, KV pool,
//! prefix cache and adapter registry, behind a zero-cost-when-disabled
//! [`Events`] handle.
//!
//! Three consumers ride on the stream:
//!   * exporters — [`to_jsonl`] (one JSON object per line) and
//!     [`to_chrome_trace`] (Chrome trace-event format: open the file
//!     in Perfetto / `chrome://tracing` and every serve run becomes a
//!     timeline with one track per tenant and one per engine slot);
//!   * the span reconstructor — [`build_spans`] / [`span_latencies`]
//!     rebuild each request's lifecycle (queueing → prefill → decode →
//!     preempt/resume cycles → completion) from events alone and
//!     re-derive the queueing/TTFT/TPOT/service/e2e samples the engine
//!     records directly, so the two accountings can be cross-checked
//!     bit-for-bit;
//!   * the online [`EventAuditor`] — an always-on runtime detector for
//!     the causal invariants the property/fuzz suites check post-hoc:
//!     no dispatch before arrival, exactly-once completion, paired
//!     splice/un-splice, a balanced KV alloc/free ledger that never
//!     over-commits, and (Arrival aside) a non-decreasing virtual
//!     clock.
//!
//! Disabled (the default `Events::off()` handle) every `emit` is a
//! single `Option` check and no event is ever constructed, so
//! analytic-clock benches and the reduction anchors stay bit-identical
//! with tracing off.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::metrics::LatencyRecorder;
use crate::util::json::Json;

/// Every kind the serving stack emits. `a`/`b` payload meanings are
/// per-kind (see `docs/events.md`); 0 when unused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Request enters the system. Stamped with the ORIGINAL arrival
    /// time — the one kind whose timestamp may precede earlier events.
    /// a = prompt tokens, b = decode tokens.
    Arrival,
    /// Admission: the virtual clock passed the arrival time and the
    /// request joined its tenant's pending queue. a/b as Arrival.
    Admit,
    /// The admission gate deferred the head request this attempt
    /// (step-token budget or KV capacity). a = token charge,
    /// b = blocks needed.
    Reject,
    /// Scheduler handed the request to the engine. a = prompt tokens,
    /// b = decode tokens AT THIS DISPATCH (the first dispatch carries
    /// the original decode length; re-dispatches after preemption
    /// carry the remainder).
    Dispatch,
    /// Tenant adapter spliced into the shared base. tenant set.
    SpliceIn,
    /// Tenant adapter un-spliced (bit-exact restore). tenant set.
    SpliceOut,
    /// Slot seated and its prefill charged. a = prefill tokens
    /// actually computed, b = prefix-cache hit tokens skipped.
    PrefillStart,
    /// Prefill step finished. a = 1 if the first output token was
    /// emitted here (0 on a resume recompute), b = prefill tokens.
    PrefillEnd,
    /// One decode token produced. a = 1, b = decode tokens remaining.
    DecodeStep,
    /// Prefix-cache hit on seat. a = hit tokens, b = blocks attached.
    PrefixHit,
    /// Completed/preempted sequence donated its prefix blocks.
    /// a = blocks donated, b = donated chain length.
    Donate,
    /// LRU reclaim freed cache-only blocks. a = blocks freed,
    /// b = blocks needed.
    Reclaim,
    /// A tenant's cached subtree was dropped as stale. a = blocks
    /// dropped, b = cumulative invalidations.
    Invalidate,
    /// Copy-on-write fork of a shared partially-filled tail block.
    /// a = old block id, b = new block id.
    CowFork,
    /// One pool block went live. a = 1, b = used blocks after.
    KvAlloc,
    /// One pool block was freed. a = 1, b = used blocks after.
    KvFree,
    /// Tokens accepted beyond capacity by the clamp path.
    /// a = overflow tokens this clamp, b = cumulative overflow.
    Overflow,
    /// A decoding slot was evicted. a = 1 under memory pressure, 0 for
    /// a deadline rescue; b = decode tokens remaining.
    Preempt,
    /// A previously preempted request was re-seated (recompute
    /// prefill follows). a = tokens to recompute.
    Resume,
    /// Request finished. a = total output tokens emitted.
    Complete,
    /// Registry loaded an adapter from disk (name not carried — the
    /// registry keys by tenant name, not interned id). a = cumulative
    /// loads, b = resident adapters after.
    AdapterLoad,
    /// Registry evicted a resident adapter. a = tenant generation
    /// after the bump, b = resident adapters after.
    AdapterEvict,
    /// One chunk of a chunked prefill was computed this step (the
    /// final chunk is the one whose `b` reaches 0; `PrefillEnd`
    /// follows it in the same instant). a = chunk tokens computed,
    /// b = prefill tokens still owed after this chunk.
    PrefillChunk,
    /// One speculative prefix-prefetch step: idle step budget warmed a
    /// cold tenant's shared system prompt. Carries NO request — a
    /// prefetch never emits output tokens. a = prefix tokens computed
    /// this step, b = prefix tokens still to warm.
    Prefetch,
    /// A completed speculative prefetch donated its blocks to the
    /// radix cache. Carries NO request. a = blocks donated,
    /// b = prefix tokens warmed.
    PrefetchDonate,
    /// A deadlined request settled and charged its tenant's rolling
    /// SLO error budget. Emitted at completion, before `Complete`,
    /// only for requests that carried a finite deadline. a = 1 on a
    /// deadline miss, 0 on an on-time completion; b = lateness in
    /// whole microseconds (0 when on time).
    SloBurn,
}

impl EventKind {
    pub const ALL: [EventKind; 26] = [
        EventKind::Arrival, EventKind::Admit, EventKind::Reject,
        EventKind::Dispatch, EventKind::SpliceIn, EventKind::SpliceOut,
        EventKind::PrefillStart, EventKind::PrefillEnd,
        EventKind::DecodeStep, EventKind::PrefixHit, EventKind::Donate,
        EventKind::Reclaim, EventKind::Invalidate, EventKind::CowFork,
        EventKind::KvAlloc, EventKind::KvFree, EventKind::Overflow,
        EventKind::Preempt, EventKind::Resume, EventKind::Complete,
        EventKind::AdapterLoad, EventKind::AdapterEvict,
        EventKind::PrefillChunk, EventKind::Prefetch,
        EventKind::PrefetchDonate, EventKind::SloBurn,
    ];
    pub const COUNT: usize = Self::ALL.len();

    pub fn name(self) -> &'static str {
        match self {
            EventKind::Arrival => "arrival",
            EventKind::Admit => "admit",
            EventKind::Reject => "reject",
            EventKind::Dispatch => "dispatch",
            EventKind::SpliceIn => "splice_in",
            EventKind::SpliceOut => "splice_out",
            EventKind::PrefillStart => "prefill_start",
            EventKind::PrefillEnd => "prefill_end",
            EventKind::DecodeStep => "decode_step",
            EventKind::PrefixHit => "prefix_hit",
            EventKind::Donate => "donate",
            EventKind::Reclaim => "reclaim",
            EventKind::Invalidate => "invalidate",
            EventKind::CowFork => "cow_fork",
            EventKind::KvAlloc => "kv_alloc",
            EventKind::KvFree => "kv_free",
            EventKind::Overflow => "overflow",
            EventKind::Preempt => "preempt",
            EventKind::Resume => "resume",
            EventKind::Complete => "complete",
            EventKind::AdapterLoad => "adapter_load",
            EventKind::AdapterEvict => "adapter_evict",
            EventKind::PrefillChunk => "prefill_chunk",
            EventKind::Prefetch => "prefetch",
            EventKind::PrefetchDonate => "prefetch_donate",
            EventKind::SloBurn => "slo_burn",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

/// One traced event. Timestamps are the engine's VIRTUAL clock
/// (seconds); `step` is the number of engine steps completed at
/// emission. Tenant ids are the interned `TenantId` values (raw u32 to
/// keep this module dependency-free); request ids are trace ids.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineEvent {
    pub t_s: f64,
    pub step: u64,
    pub kind: EventKind,
    pub tenant: Option<u32>,
    pub request: Option<u64>,
    pub a: u64,
    pub b: u64,
}

impl EngineEvent {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("t_s".into(), Json::Num(self.t_s));
        m.insert("step".into(), Json::Num(self.step as f64));
        m.insert("kind".into(), Json::Str(self.kind.name().into()));
        if let Some(t) = self.tenant {
            m.insert("tenant".into(), Json::Num(t as f64));
        }
        if let Some(r) = self.request {
            m.insert("request".into(), Json::Num(r as f64));
        }
        m.insert("a".into(), Json::Num(self.a as f64));
        m.insert("b".into(), Json::Num(self.b as f64));
        Json::Obj(m)
    }
}

/// An event consumer. The bus drives every registered sink through
/// this; [`NullSink`] is the do-nothing default proving the interface
/// costs nothing beyond the virtual call when tracing is on. `Debug`
/// is a supertrait so buses carrying boxed sinks stay debuggable.
pub trait EventSink: std::fmt::Debug {
    fn on_event(&mut self, ev: &EngineEvent);
    /// End of run — flush/verify accumulated state.
    fn finalize(&mut self) {}
}

/// Discards everything.
#[derive(Debug, Default)]
pub struct NullSink;

impl EventSink for NullSink {
    fn on_event(&mut self, _ev: &EngineEvent) {}
}

/// Buffers the stream in memory for export / span reconstruction —
/// the in-memory [`EventSink`] impl. Unbounded by default; under a
/// `--trace-buffer-events` bound it keeps the FIRST `cap` events and
/// counts everything past the bound in `dropped` (never silent — the
/// count surfaces in the report and the `metrics` JSON section). The
/// streaming file sink is unaffected by the bound: the full stream is
/// always on disk.
#[derive(Debug, Default)]
pub struct Recorder {
    pub events: Vec<EngineEvent>,
    cap: usize,
    dropped: u64,
}

impl EventSink for Recorder {
    fn on_event(&mut self, ev: &EngineEvent) {
        if self.cap > 0 && self.events.len() >= self.cap {
            self.dropped += 1;
        } else {
            self.events.push(*ev);
        }
    }
}

/// Per-request lifecycle state the auditor tracks.
#[derive(Debug, Default)]
struct ReqAudit {
    arrival_s: f64,
    admitted: bool,
    seated: bool,
    completed: bool,
    /// Preempted and not yet re-dispatched.
    awaiting_resume: bool,
    /// Emitted its first output token (the unique `PrefillEnd` with
    /// a == 1).
    first_token: bool,
    dispatches: u64,
    /// Chunked-prefill ledger: prefill tokens still owed, opened at
    /// `PrefillStart` (a) and drained by in-order `PrefillChunk`s.
    prefill_left: u64,
    /// This seat's prefill is chunked (a `PrefillChunk` was seen), so
    /// `PrefillEnd` must find the ledger drained to exactly 0.
    chunked: bool,
}

/// The online invariant auditor: consumes the stream DURING the run
/// and records a violation string for every causal invariant broken.
/// A clean run ends with `violations == 0` after [`EventSink::
/// finalize`].
#[derive(Debug, Default)]
pub struct EventAuditor {
    /// Pool bound in blocks; 0 = unbounded (no capacity check).
    kv_capacity: u64,
    /// Running KV ledger: +a per KvAlloc, −a per KvFree; must equal
    /// each event's reported `b`, stay in [0, capacity], end at 0.
    kv_used: i64,
    /// Tenant currently spliced into the shared base, if any.
    live_splice: Option<u32>,
    last_t: f64,
    req: BTreeMap<u64, ReqAudit>,
    violations: Vec<String>,
    violation_count: u64,
}

/// Keep the report readable when something is badly wrong.
const MAX_RECORDED_VIOLATIONS: usize = 32;

impl EventAuditor {
    pub fn with_kv_capacity(blocks: u64) -> EventAuditor {
        EventAuditor { kv_capacity: blocks, ..Default::default() }
    }

    pub fn violation_count(&self) -> u64 {
        self.violation_count
    }

    pub fn violations(&self) -> &[String] {
        &self.violations
    }

    fn violate(&mut self, msg: String) {
        self.violation_count += 1;
        if self.violations.len() < MAX_RECORDED_VIOLATIONS {
            self.violations.push(msg);
        }
    }

    fn check(&mut self, ev: &EngineEvent) {
        use EventKind::*;
        // Arrival carries the original arrival time and is the one
        // kind allowed to point backwards; everything else rides the
        // engine's monotone virtual clock.
        if ev.kind != Arrival {
            if ev.t_s < self.last_t {
                self.violate(format!(
                    "{} at t={:.6} before prior event t={:.6}",
                    ev.kind.name(), ev.t_s, self.last_t));
            }
            self.last_t = self.last_t.max(ev.t_s);
        }
        match ev.kind {
            Arrival => {
                let id = ev.request.unwrap_or(u64::MAX);
                if self.req.contains_key(&id) {
                    self.violate(format!("request {id}: second arrival"));
                } else {
                    self.req.insert(id, ReqAudit {
                        arrival_s: ev.t_s, ..Default::default()
                    });
                }
            }
            Admit => self.req_check(ev, |r| {
                if r.admitted {
                    return Some("admitted twice".into());
                }
                r.admitted = true;
                None
            }),
            Reject => self.req_check(ev, |r| {
                if !r.admitted || r.seated || r.completed {
                    return Some("rejected outside pending".into());
                }
                None
            }),
            Dispatch => {
                let t = ev.t_s;
                self.req_check(ev, |r| {
                    if !r.admitted {
                        return Some("dispatched before admission"
                                    .into());
                    }
                    if t < r.arrival_s {
                        return Some(format!(
                            "dispatched at {t:.6} before arrival {:.6}",
                            r.arrival_s));
                    }
                    if r.seated {
                        return Some("dispatched while seated".into());
                    }
                    if r.completed {
                        return Some("dispatched after completion"
                                    .into());
                    }
                    r.seated = true;
                    r.dispatches += 1;
                    None
                });
            }
            Resume => self.req_check(ev, |r| {
                if !r.awaiting_resume {
                    return Some("resume without preemption".into());
                }
                if !r.seated {
                    return Some("resume outside a seat".into());
                }
                r.awaiting_resume = false;
                None
            }),
            PrefillStart => {
                let owed = ev.a;
                self.req_check(ev, |r| {
                    if !r.seated {
                        return Some("prefill outside a seat".into());
                    }
                    r.prefill_left = owed;
                    r.chunked = false;
                    None
                });
            }
            PrefillChunk => {
                let (chunk, left) = (ev.a, ev.b);
                self.req_check(ev, |r| {
                    if !r.seated {
                        return Some("chunk outside a seat".into());
                    }
                    if chunk == 0 {
                        return Some("empty prefill chunk".into());
                    }
                    if chunk > r.prefill_left {
                        return Some(format!(
                            "chunk of {chunk} exceeds {} owed",
                            r.prefill_left));
                    }
                    if left != r.prefill_left - chunk {
                        return Some(format!(
                            "chunk ledger drift: reported {left} \
                             left vs running {}",
                            r.prefill_left - chunk));
                    }
                    r.prefill_left = left;
                    r.chunked = true;
                    None
                });
            }
            PrefillEnd => {
                let first = ev.a == 1;
                self.req_check(ev, |r| {
                    if !r.seated {
                        return Some("prefill-end outside a seat"
                                    .into());
                    }
                    if r.chunked && r.prefill_left != 0 {
                        return Some(format!(
                            "prefill-end with {} chunk tokens \
                             still owed", r.prefill_left));
                    }
                    r.chunked = false;
                    if first {
                        if r.first_token {
                            return Some("second first-token".into());
                        }
                        r.first_token = true;
                    }
                    None
                });
            }
            DecodeStep => self.req_check(ev, |r| {
                if !r.seated {
                    return Some("decode outside a seat".into());
                }
                None
            }),
            Preempt => self.req_check(ev, |r| {
                if !r.seated {
                    return Some("preempted outside a seat".into());
                }
                r.seated = false;
                r.awaiting_resume = true;
                // A mid-prompt eviction abandons its chunk ledger;
                // the re-seat's PrefillStart opens a fresh one.
                r.chunked = false;
                r.prefill_left = 0;
                None
            }),
            Complete => self.req_check(ev, |r| {
                if r.completed {
                    return Some("completed twice".into());
                }
                if !r.seated {
                    return Some("completed outside a seat".into());
                }
                r.completed = true;
                r.seated = false;
                None
            }),
            SpliceIn => {
                if let Some(live) = self.live_splice {
                    self.violate(format!(
                        "splice-in of tenant {:?} over live tenant \
                         {live}", ev.tenant));
                }
                self.live_splice = ev.tenant;
            }
            SpliceOut => {
                if self.live_splice != ev.tenant {
                    self.violate(format!(
                        "splice-out of tenant {:?} but live is {:?}",
                        ev.tenant, self.live_splice));
                }
                self.live_splice = None;
            }
            KvAlloc => {
                self.kv_used += ev.a as i64;
                self.kv_ledger_check(ev);
                if self.kv_capacity > 0
                    && self.kv_used > self.kv_capacity as i64
                {
                    self.violate(format!(
                        "kv over-commit: {} used > {} capacity",
                        self.kv_used, self.kv_capacity));
                }
            }
            KvFree => {
                self.kv_used -= ev.a as i64;
                if self.kv_used < 0 {
                    self.violate("kv free of an unallocated block"
                                 .into());
                }
                self.kv_ledger_check(ev);
            }
            // SLO settlement happens at completion, while the slot is
            // still live — after `Complete` (or before a seat) it is
            // a bookkeeping bug.
            SloBurn => self.req_check(ev, |r| {
                if !r.seated || r.completed {
                    return Some("slo burn outside a live seat".into());
                }
                None
            }),
            // Speculation is engine-scoped: a prefetch that claims a
            // request would mean speculative work emitted tokens.
            Prefetch | PrefetchDonate => {
                if ev.request.is_some() {
                    self.violate(format!(
                        "{} tied to request {:?} — prefetch never \
                         emits request tokens",
                        ev.kind.name(), ev.request));
                }
            }
            // Pure counters: no causal state to check.
            PrefixHit | Donate | Reclaim | Invalidate | CowFork
                | Overflow | AdapterLoad | AdapterEvict => {}
        }
    }

    /// The event's reported post-op occupancy must agree with the
    /// running ledger — a lost or doubled alloc/free anywhere shows
    /// up immediately.
    fn kv_ledger_check(&mut self, ev: &EngineEvent) {
        if self.kv_used != ev.b as i64 {
            self.violate(format!(
                "kv ledger drift at {}: running {} vs reported {}",
                ev.kind.name(), self.kv_used, ev.b));
        }
    }

    fn req_check(&mut self, ev: &EngineEvent,
                 f: impl FnOnce(&mut ReqAudit) -> Option<String>) {
        let id = ev.request.unwrap_or(u64::MAX);
        let msg = match self.req.get_mut(&id) {
            Some(r) => f(r),
            None => Some("event before arrival".into()),
        };
        if let Some(m) = msg {
            self.violate(format!("request {id}: {} — {m}",
                                 ev.kind.name()));
        }
    }
}

impl EventSink for EventAuditor {
    fn on_event(&mut self, ev: &EngineEvent) {
        self.check(ev);
    }

    fn finalize(&mut self) {
        let mut incomplete = 0usize;
        let mut stranded = 0usize;
        for r in self.req.values() {
            if !r.completed {
                incomplete += 1;
            }
            if r.awaiting_resume {
                stranded += 1;
            }
        }
        if incomplete > 0 {
            self.violate(format!(
                "{incomplete} arrived requests never completed"));
        }
        if stranded > 0 {
            self.violate(format!(
                "{stranded} preempted requests never resumed"));
        }
        if let Some(t) = self.live_splice {
            self.violate(format!(
                "tenant {t} still spliced at finish"));
        }
        if self.kv_used != 0 {
            self.violate(format!(
                "kv ledger nonzero at finish: {} blocks",
                self.kv_used));
        }
    }
}

/// The shared bus behind an enabled [`Events`] handle: stamps events
/// with the current virtual clock/step and fans them out to every
/// registered sink — the in-memory recorder and online auditor
/// always, plus the optional live-telemetry sinks (streaming JSONL
/// file, metrics feeder, SLO burn tracker) and any boxed extras, all
/// in one fixed order so a traced run is deterministic regardless of
/// which consumers are attached.
#[derive(Debug, Default)]
pub struct EventBus {
    recorder: Recorder,
    auditor: EventAuditor,
    slo: crate::serve::telemetry::SloBurnTracker,
    stream: Option<crate::serve::telemetry::JsonlStreamSink>,
    metrics: Option<crate::serve::telemetry::MetricsFeeder>,
    extra: Vec<Box<dyn EventSink>>,
    counts: [u64; EventKind::COUNT],
    total: u64,
    now: f64,
    step: u64,
}

impl EventBus {
    fn dispatch(&mut self, ev: EngineEvent) {
        self.counts[ev.kind.index()] += 1;
        self.total += 1;
        // Through the trait, like any other sink.
        EventSink::on_event(&mut self.recorder, &ev);
        EventSink::on_event(&mut self.auditor, &ev);
        EventSink::on_event(&mut self.slo, &ev);
        if let Some(s) = &mut self.stream {
            EventSink::on_event(s, &ev);
        }
        if let Some(m) = &mut self.metrics {
            EventSink::on_event(m, &ev);
        }
        for s in &mut self.extra {
            s.on_event(&ev);
        }
    }
}

/// The handle every serve-layer struct holds. `Events::off()` (the
/// `Default`) is a `None` — emitting is a single branch and nothing
/// is allocated, so disabled tracing is provably inert. Clones share
/// one bus, which is how the engine, scheduler, KV pool, prefix cache
/// and registry all write one totally-ordered stream.
#[derive(Debug, Clone, Default)]
pub struct Events(Option<Rc<RefCell<EventBus>>>);

impl Events {
    /// Tracing disabled: every emit is a no-op.
    pub fn off() -> Events {
        Events(None)
    }

    /// Tracing enabled: record + audit every event.
    pub fn recording() -> Events {
        Events(Some(Rc::new(RefCell::new(EventBus::default()))))
    }

    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Tell the auditor the pool bound so it can flag over-commits
    /// (0 = unbounded).
    pub fn set_kv_capacity(&self, blocks: u64) {
        if let Some(bus) = &self.0 {
            bus.borrow_mut().auditor.kv_capacity = blocks;
        }
    }

    /// Advance the stamp clock (the engine calls this at every
    /// virtual-clock change, before the emissions of that moment).
    pub fn set_now(&self, t_s: f64) {
        if let Some(bus) = &self.0 {
            bus.borrow_mut().now = t_s;
        }
    }

    /// Advance the stamp step counter.
    pub fn set_step(&self, step: u64) {
        if let Some(bus) = &self.0 {
            bus.borrow_mut().step = step;
        }
    }

    /// Emit at the current stamp clock.
    pub fn emit(&self, kind: EventKind, tenant: Option<u32>,
                request: Option<u64>, a: u64, b: u64) {
        if let Some(bus) = &self.0 {
            let mut bus = bus.borrow_mut();
            let ev = EngineEvent { t_s: bus.now, step: bus.step, kind,
                                   tenant, request, a, b };
            bus.dispatch(ev);
        }
    }

    /// Emit at an explicit time (Arrival's original timestamp;
    /// admission instants).
    pub fn emit_at(&self, t_s: f64, kind: EventKind,
                   tenant: Option<u32>, request: Option<u64>, a: u64,
                   b: u64) {
        if let Some(bus) = &self.0 {
            let mut bus = bus.borrow_mut();
            let ev = EngineEvent { t_s, step: bus.step, kind, tenant,
                                   request, a, b };
            bus.dispatch(ev);
        }
    }

    /// Run every sink's end-of-run hook: the auditor's invariant
    /// checks, the streaming sink's final flush, the metrics feeder's
    /// closing scrape (engine `finish()` calls this after the final
    /// un-splice and cache flush).
    pub fn finalize(&self) {
        if let Some(bus) = &self.0 {
            let mut bus = bus.borrow_mut();
            EventSink::finalize(&mut bus.auditor);
            if let Some(s) = &mut bus.stream {
                EventSink::finalize(s);
            }
            if let Some(m) = &mut bus.metrics {
                EventSink::finalize(m);
            }
            for s in &mut bus.extra {
                s.finalize();
            }
        }
    }

    /// Install the incremental JSONL file sink: every event appends
    /// to its ring and the ring flushes to disk each time it fills,
    /// so the trace file grows DURING the run instead of at export.
    pub fn stream_to(&self,
                     sink: crate::serve::telemetry::JsonlStreamSink) {
        if let Some(bus) = &self.0 {
            bus.borrow_mut().stream = Some(sink);
        }
    }

    /// Bound the in-memory recorder to `cap` events (keep-first;
    /// 0 = unbounded). Emissions past the bound are counted, never
    /// silently lost — see [`Events::events_dropped`].
    pub fn bound_recorder(&self, cap: usize) {
        if let Some(bus) = &self.0 {
            bus.borrow_mut().recorder.cap = cap;
        }
    }

    /// Events the bounded in-memory recorder did not retain.
    pub fn events_dropped(&self) -> u64 {
        self.0.as_ref().map_or(0, |b| b.borrow().recorder.dropped)
    }

    /// Lines the streaming sink has flushed to disk so far (0 when no
    /// stream sink is installed).
    pub fn stream_written(&self) -> u64 {
        self.0.as_ref().map_or(0, |b| {
            b.borrow().stream.as_ref().map_or(0, |s| s.written())
        })
    }

    /// First I/O error the streaming sink hit, if any (sinks cannot
    /// surface `Result` mid-dispatch, so errors latch here).
    pub fn stream_error(&self) -> Option<String> {
        self.0.as_ref().and_then(|b| {
            b.borrow().stream.as_ref().and_then(|s| s.error())
        })
    }

    /// Copy of the streamed bytes when the sink writes to memory
    /// (tests compare them against the buffered exporter); None for
    /// file-backed sinks.
    pub fn stream_body(&self) -> Option<Vec<u8>> {
        self.0.as_ref().and_then(|b| {
            b.borrow().stream.as_ref()
                .and_then(|s| s.mem().map(<[u8]>::to_vec))
        })
    }

    /// Install the event-fed metrics feeder (counters/gauges/
    /// histograms + periodic Prometheus-text scrapes).
    pub fn configure_metrics(
        &self, feeder: crate::serve::telemetry::MetricsFeeder)
    {
        if let Some(bus) = &self.0 {
            bus.borrow_mut().metrics = Some(feeder);
        }
    }

    /// Clone of the feeder's current registry (`None` when no feeder
    /// is installed).
    pub fn metrics_registry(&self)
        -> Option<crate::serve::telemetry::MetricsRegistry>
    {
        self.0.as_ref().and_then(|b| {
            b.borrow().metrics.as_ref().map(|m| m.registry().clone())
        })
    }

    /// Scrape blocks the feeder has rendered so far.
    pub fn metrics_scrapes(&self) -> u64 {
        self.0.as_ref().map_or(0, |b| {
            b.borrow().metrics.as_ref().map_or(0, |m| m.scrapes())
        })
    }

    /// First I/O error the metrics feeder hit, if any.
    pub fn metrics_error(&self) -> Option<String> {
        self.0.as_ref().and_then(|b| {
            b.borrow().metrics.as_ref().and_then(|m| m.error())
        })
    }

    /// Per-tenant rolling SLO burn rows (empty until a deadlined
    /// request settles), sorted by tenant id.
    pub fn slo_summary(&self)
        -> Vec<crate::serve::telemetry::SloTenant>
    {
        self.0.as_ref().map_or_else(Vec::new, |b| {
            b.borrow().slo.summary()
        })
    }

    /// Register an arbitrary extra sink (driven after the built-in
    /// ones, in registration order).
    pub fn add_sink(&self, sink: Box<dyn EventSink>) {
        if let Some(bus) = &self.0 {
            bus.borrow_mut().extra.push(sink);
        }
    }

    pub fn total(&self) -> u64 {
        self.0.as_ref().map_or(0, |b| b.borrow().total)
    }

    /// (kind name, count) for every kind seen at least once.
    pub fn counts(&self) -> Vec<(&'static str, u64)> {
        let Some(bus) = &self.0 else { return Vec::new() };
        let bus = bus.borrow();
        EventKind::ALL.iter()
            .map(|k| (k.name(), bus.counts[k.index()]))
            .filter(|(_, n)| *n > 0)
            .collect()
    }

    pub fn violation_count(&self) -> u64 {
        self.0.as_ref()
            .map_or(0, |b| b.borrow().auditor.violation_count())
    }

    pub fn violations(&self) -> Vec<String> {
        self.0.as_ref().map_or_else(Vec::new, |b| {
            b.borrow().auditor.violations().to_vec()
        })
    }

    /// Copy of the full recorded stream.
    pub fn snapshot(&self) -> Vec<EngineEvent> {
        self.0.as_ref().map_or_else(Vec::new, |b| {
            b.borrow().recorder.events.clone()
        })
    }

    /// Cluster failover, killed-replica side: forget the request's
    /// audit state on THIS bus. The id migrated to a survivor, so
    /// this stream legitimately ends mid-lifecycle for it — and any
    /// LATER event for the id here trips the auditor's
    /// "event before arrival" check, which is exactly the
    /// nothing-after-migration rule.
    pub fn migrate_out(&self, id: u64) {
        if let Some(bus) = &self.0 {
            bus.borrow_mut().auditor.req.remove(&id);
        }
    }

    /// Cluster failover, survivor side: adopt a migrated request's
    /// audit state as if its arrival and admission had happened here.
    /// `awaiting_resume` marks ids with a live recompute-on-resume
    /// entry (evacuated seats, and earlier preemptions still
    /// pending); `first_token` marks ids whose unique first-token
    /// emission already happened on the dead replica — a duplicate
    /// `PrefillEnd` (a = 1) on the survivor then trips
    /// "second first-token" ONLINE, the exactly-once half of the
    /// failover contract.
    pub fn adopt(&self, id: u64, arrival_s: f64,
                 awaiting_resume: bool, first_token: bool) {
        if let Some(bus) = &self.0 {
            bus.borrow_mut().auditor.req.insert(id, ReqAudit {
                arrival_s,
                admitted: true,
                seated: false,
                completed: false,
                awaiting_resume,
                first_token,
                dispatches: 0,
                prefill_left: 0,
                chunked: false,
            });
        }
    }
}

// ---------------------------------------------------------------- spans

/// One request's lifecycle, reconstructed purely from the stream.
#[derive(Debug, Clone, Default)]
pub struct RequestSpan {
    pub tenant: Option<u32>,
    pub arrival_s: Option<f64>,
    pub first_dispatch_s: Option<f64>,
    pub last_dispatch_s: Option<f64>,
    /// Clock of the unique first-token `PrefillEnd` (a == 1).
    pub first_token_s: Option<f64>,
    pub complete_s: Option<f64>,
    /// Decode length of the FIRST dispatch — the original request,
    /// before any preemption rewrote the remainder.
    pub orig_decode: u64,
    pub dispatches: u64,
    pub preempts: u64,
    pub decode_steps: u64,
}

impl RequestSpan {
    /// First seat minus arrival, clamped at 0 — the engine's queueing
    /// sample arithmetic exactly.
    pub fn queueing_s(&self) -> Option<f64> {
        Some((self.first_dispatch_s? - self.arrival_s?).max(0.0))
    }

    pub fn ttft_s(&self) -> Option<f64> {
        Some((self.first_token_s? - self.arrival_s?).max(0.0))
    }

    /// Final residency only (the engine restarts its service clock on
    /// re-dispatch after preemption).
    pub fn service_s(&self) -> Option<f64> {
        Some((self.complete_s? - self.last_dispatch_s?).max(0.0))
    }

    pub fn e2e_s(&self) -> Option<f64> {
        Some((self.complete_s? - self.arrival_s?).max(0.0))
    }

    /// Mean time per output token after the first, over the ORIGINAL
    /// decode length (recompute replays don't re-count).
    pub fn tpot_s(&self) -> Option<f64> {
        if self.orig_decode == 0 {
            return None;
        }
        Some((self.complete_s? - self.first_token_s?).max(0.0)
             / self.orig_decode as f64)
    }
}

/// Fold the stream into per-request spans.
pub fn build_spans(events: &[EngineEvent])
                   -> BTreeMap<u64, RequestSpan> {
    let mut spans: BTreeMap<u64, RequestSpan> = BTreeMap::new();
    for ev in events {
        let Some(id) = ev.request else { continue };
        let s = spans.entry(id).or_default();
        if ev.tenant.is_some() {
            s.tenant = ev.tenant;
        }
        match ev.kind {
            EventKind::Arrival => s.arrival_s = Some(ev.t_s),
            EventKind::Dispatch => {
                if s.first_dispatch_s.is_none() {
                    s.first_dispatch_s = Some(ev.t_s);
                    s.orig_decode = ev.b;
                }
                s.last_dispatch_s = Some(ev.t_s);
                s.dispatches += 1;
            }
            EventKind::PrefillEnd if ev.a == 1 => {
                s.first_token_s = Some(ev.t_s);
            }
            EventKind::DecodeStep => s.decode_steps += 1,
            EventKind::Preempt => s.preempts += 1,
            EventKind::Complete => s.complete_s = Some(ev.t_s),
            _ => {}
        }
    }
    spans
}

/// The engine's latency recorders, re-derived from events alone. Keys
/// mirror the engine's: the tenant name (via `tenant_names`, indexed
/// by interned id) and the `"(all)"` aggregate.
pub struct SpanLatencies {
    pub queueing: LatencyRecorder,
    pub service: LatencyRecorder,
    pub e2e: LatencyRecorder,
    pub ttft: LatencyRecorder,
    pub tpot: LatencyRecorder,
}

pub fn span_latencies(events: &[EngineEvent],
                      tenant_names: &[String]) -> SpanLatencies {
    let mut out = SpanLatencies {
        queueing: LatencyRecorder::default(),
        service: LatencyRecorder::default(),
        e2e: LatencyRecorder::default(),
        ttft: LatencyRecorder::default(),
        tpot: LatencyRecorder::default(),
    };
    let name = |t: Option<u32>| -> String {
        t.and_then(|i| tenant_names.get(i as usize).cloned())
            .unwrap_or_else(|| format!("t{}", t.unwrap_or(0)))
    };
    for span in build_spans(events).values() {
        let key = name(span.tenant);
        let mut rec = |r: &mut LatencyRecorder, v: Option<f64>| {
            if let Some(v) = v {
                r.record(&key, v);
                r.record("(all)", v);
            }
        };
        rec(&mut out.queueing, span.queueing_s());
        rec(&mut out.service, span.service_s());
        rec(&mut out.e2e, span.e2e_s());
        rec(&mut out.ttft, span.ttft_s());
        rec(&mut out.tpot, span.tpot_s());
    }
    out
}

// ------------------------------------------------------------ exporters

/// One JSON object per line — greppable, streamable, and the format
/// the CI smoke parses line-by-line.
pub fn to_jsonl(events: &[EngineEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&ev.to_json().to_string());
        out.push('\n');
    }
    out
}

/// A closed [start, end] interval on some track.
struct Interval {
    name: String,
    start: f64,
    end: f64,
    request: u64,
    tenant: Option<u32>,
}

/// Chrome trace-event format (the JSON-object flavour Perfetto and
/// `chrome://tracing` open directly): pid 1 carries one thread per
/// TENANT with that tenant's request residencies; pid 2 re-lays the
/// same residencies onto engine SLOT lanes (greedy interval
/// packing), so batch occupancy is visible at a glance; pid 0 carries
/// the splice intervals plus instantaneous pool/cache markers.
/// Timestamps are µs of virtual time.
pub fn to_chrome_trace(events: &[EngineEvent],
                       tenant_names: &[String]) -> Json {
    let us = |t: f64| (t * 1e6).max(0.0);
    let name_of = |t: Option<u32>| -> String {
        t.and_then(|i| tenant_names.get(i as usize).cloned())
            .unwrap_or_else(|| format!("t{}", t.unwrap_or(0)))
    };
    let mut trace: Vec<Json> = Vec::new();
    let mut meta = |pid: f64, tid: Option<f64>, name: &str| {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(
            if tid.is_some() { "thread_name" } else { "process_name" }
                .into()));
        m.insert("ph".into(), Json::Str("M".into()));
        m.insert("pid".into(), Json::Num(pid));
        if let Some(t) = tid {
            m.insert("tid".into(), Json::Num(t));
        }
        let mut args = BTreeMap::new();
        args.insert("name".into(), Json::Str(name.into()));
        m.insert("args".into(), Json::Obj(args));
        trace.push(Json::Obj(m));
    };
    meta(0.0, None, "engine");
    meta(1.0, None, "tenants");
    meta(2.0, None, "slots");
    for (i, n) in tenant_names.iter().enumerate() {
        meta(1.0, Some(i as f64), n);
    }

    let complete = |name: &str, pid: f64, tid: f64, start: f64,
                    end: f64, args: BTreeMap<String, Json>| -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(name.into()));
        m.insert("ph".into(), Json::Str("X".into()));
        m.insert("pid".into(), Json::Num(pid));
        m.insert("tid".into(), Json::Num(tid));
        m.insert("ts".into(), Json::Num(us(start)));
        m.insert("dur".into(),
                 Json::Num((us(end) - us(start)).max(0.0)));
        if !args.is_empty() {
            m.insert("args".into(), Json::Obj(args));
        }
        Json::Obj(m)
    };
    let instant = |name: &str, pid: f64, tid: f64, t: f64| -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(name.into()));
        m.insert("ph".into(), Json::Str("i".into()));
        m.insert("s".into(), Json::Str("t".into()));
        m.insert("pid".into(), Json::Num(pid));
        m.insert("tid".into(), Json::Num(tid));
        m.insert("ts".into(), Json::Num(us(t)));
        Json::Obj(m)
    };

    // Per-tenant aggregation track: a counter series on the tenants
    // process sampling each tenant's in-flight residency count at
    // every dispatch/preempt/complete transition, so cross-tenant
    // load reads as stacked area without opening individual lanes.
    let mut inflight: BTreeMap<u32, i64> = BTreeMap::new();
    let mut counter = |trace: &mut Vec<Json>, t: f64, tenant: u32,
                       n: i64| {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str("inflight".into()));
        m.insert("ph".into(), Json::Str("C".into()));
        m.insert("pid".into(), Json::Num(1.0));
        m.insert("ts".into(), Json::Num(us(t)));
        let mut args = BTreeMap::new();
        args.insert(name_of(Some(tenant)),
                    Json::Num(n.max(0) as f64));
        m.insert("args".into(), Json::Obj(args));
        trace.push(Json::Obj(m));
    };

    // Request residencies: Dispatch opens, Preempt/Complete closes.
    let mut open: BTreeMap<u64, (f64, Option<u32>)> = BTreeMap::new();
    let mut resid: Vec<Interval> = Vec::new();
    let mut splice_open: Option<(f64, Option<u32>)> = None;
    let mut last_t = 0.0f64;
    for ev in events {
        last_t = last_t.max(ev.t_s);
        match ev.kind {
            EventKind::Dispatch => {
                if let Some(id) = ev.request {
                    open.insert(id, (ev.t_s, ev.tenant));
                    if let Some(t) = ev.tenant {
                        let n = inflight.entry(t).or_insert(0);
                        *n += 1;
                        counter(&mut trace, ev.t_s, t, *n);
                    }
                }
            }
            EventKind::Preempt | EventKind::Complete => {
                if let Some(id) = ev.request {
                    if let Some(t) = ev.tenant {
                        let n = inflight.entry(t).or_insert(0);
                        *n -= 1;
                        counter(&mut trace, ev.t_s, t, *n);
                    }
                    if let Some((start, tenant)) = open.remove(&id) {
                        let tag = if ev.kind == EventKind::Preempt {
                            format!("req {id} (preempted)")
                        } else {
                            format!("req {id}")
                        };
                        resid.push(Interval {
                            name: tag, start, end: ev.t_s,
                            request: id, tenant,
                        });
                    }
                }
            }
            EventKind::SpliceIn => {
                splice_open = Some((ev.t_s, ev.tenant));
            }
            EventKind::SpliceOut => {
                if let Some((start, tenant)) = splice_open.take() {
                    let mut args = BTreeMap::new();
                    args.insert("tenant".into(),
                                Json::Str(name_of(tenant)));
                    trace.push(complete(
                        &format!("splice {}", name_of(tenant)),
                        0.0, 0.0, start, ev.t_s, args));
                }
            }
            EventKind::Reject => {
                trace.push(instant(
                    "reject", 1.0,
                    ev.tenant.map_or(0.0, |t| t as f64), ev.t_s));
            }
            EventKind::PrefixHit => {
                trace.push(instant(
                    &format!("prefix hit ({} tok)", ev.a), 1.0,
                    ev.tenant.map_or(0.0, |t| t as f64), ev.t_s));
            }
            EventKind::CowFork => {
                trace.push(instant("cow fork", 0.0, 1.0, ev.t_s));
            }
            EventKind::Reclaim => {
                trace.push(instant(
                    &format!("reclaim ({} blk)", ev.a), 0.0, 1.0,
                    ev.t_s));
            }
            EventKind::Overflow => {
                trace.push(instant("kv overflow", 0.0, 1.0, ev.t_s));
            }
            _ => {}
        }
    }
    // Anything still seated at the end of the stream closes there.
    for (id, (start, tenant)) in open {
        resid.push(Interval { name: format!("req {id} (open)"), start,
                              end: last_t, request: id, tenant });
    }
    if let Some((start, tenant)) = splice_open {
        let mut args = BTreeMap::new();
        args.insert("tenant".into(), Json::Str(name_of(tenant)));
        trace.push(complete(&format!("splice {}", name_of(tenant)),
                            0.0, 0.0, start, last_t, args));
    }

    // Tenant tracks, then slot lanes via greedy interval packing.
    resid.sort_by(|x, y| x.start.partial_cmp(&y.start).unwrap());
    let mut lane_end: Vec<f64> = Vec::new();
    for iv in &resid {
        let mut args = BTreeMap::new();
        args.insert("request".into(), Json::Num(iv.request as f64));
        args.insert("tenant".into(), Json::Str(name_of(iv.tenant)));
        trace.push(complete(&iv.name, 1.0,
                            iv.tenant.map_or(0.0, |t| t as f64),
                            iv.start, iv.end, args.clone()));
        let lane = match lane_end.iter()
            .position(|&end| end <= iv.start)
        {
            Some(i) => i,
            None => {
                lane_end.push(0.0);
                lane_end.len() - 1
            }
        };
        lane_end[lane] = iv.end;
        trace.push(complete(&iv.name, 2.0, lane as f64, iv.start,
                            iv.end, args));
    }

    let mut root = BTreeMap::new();
    root.insert("traceEvents".into(), Json::Arr(trace));
    root.insert("displayTimeUnit".into(), Json::Str("ms".into()));
    Json::Obj(root)
}

// ------------------------------------------------------------- cluster

/// Merge per-replica event streams into one globally-ordered stream
/// of `(replica, event)` pairs. Each stream is keyed by its RUNNING
/// MAX of `t_s` (Arrival is the one kind allowed to point backwards,
/// and it never advances a stream's clock), so per-replica emission
/// order is preserved exactly and the merged non-Arrival clock is
/// non-decreasing. Ties break by replica id, then per-replica index —
/// fully deterministic.
pub fn merge_replica_streams(streams: &[Vec<EngineEvent>])
                             -> Vec<(u32, EngineEvent)> {
    let mut keyed: Vec<(f64, u32, usize, EngineEvent)> = Vec::new();
    for (rid, evs) in streams.iter().enumerate() {
        let mut key = 0.0f64;
        for (i, ev) in evs.iter().enumerate() {
            key = key.max(ev.t_s);
            keyed.push((key, rid as u32, i, *ev));
        }
    }
    keyed.sort_by(|x, y| {
        x.0.total_cmp(&y.0)
            .then(x.1.cmp(&y.1))
            .then(x.2.cmp(&y.2))
    });
    keyed.into_iter().map(|(_, r, _, ev)| (r, ev)).collect()
}

/// Cross-replica invariant auditor for the merged cluster stream.
/// Each replica's own [`EventAuditor`] already enforces the
/// single-engine causal rules online; this pass re-audits the MERGED
/// interleaving for the properties that only exist across replicas:
///
///   * every request arrives once and is admitted once, cluster-wide
///     (failover re-dispatch rides `requeue`, which re-emits
///     neither);
///   * a request is resident on at most ONE replica at a time —
///     `Dispatch` claims residency, `Preempt`/`Complete` release it,
///     and every mid-flight event must come from the owner;
///   * first-token emission (`PrefillEnd` with a == 1) and
///     `Complete` each happen exactly once globally — the
///     exactly-once failover contract;
///   * the merged non-Arrival clock is non-decreasing (the merge is
///     a real single-timeline interleaving, not N clocks glued
///     together);
///   * at finalize, every arrived request completed and no residency
///     is left behind.
#[derive(Debug, Default)]
pub struct ClusterAuditor {
    /// Per-request cluster-wide ledger: (admits, first-tokens,
    /// completions) seen so far.
    req: BTreeMap<u64, (u64, u64, u64)>,
    /// Owner replica of each currently-seated request.
    resident: BTreeMap<u64, u32>,
    last_t: f64,
    violations: Vec<String>,
    violation_count: u64,
}

impl ClusterAuditor {
    /// Audit a full merged stream (convenience for tests/reports).
    pub fn audit(merged: &[(u32, EngineEvent)]) -> ClusterAuditor {
        let mut a = ClusterAuditor::default();
        for (replica, ev) in merged {
            a.check(*replica, ev);
        }
        a.finalize();
        a
    }

    pub fn violation_count(&self) -> u64 {
        self.violation_count
    }

    pub fn violations(&self) -> &[String] {
        &self.violations
    }

    fn violate(&mut self, msg: String) {
        self.violation_count += 1;
        if self.violations.len() < MAX_RECORDED_VIOLATIONS {
            self.violations.push(msg);
        }
    }

    /// Residency gate: the event must come from the request's owner.
    fn owner_check(&mut self, replica: u32, ev: &EngineEvent)
                   -> bool {
        let id = ev.request.unwrap_or(u64::MAX);
        match self.resident.get(&id) {
            Some(&r) if r == replica => true,
            Some(&r) => {
                self.violate(format!(
                    "request {id}: {} on replica {replica} while \
                     resident on replica {r}", ev.kind.name()));
                false
            }
            None => {
                self.violate(format!(
                    "request {id}: {} on replica {replica} while \
                     resident nowhere", ev.kind.name()));
                false
            }
        }
    }

    pub fn check(&mut self, replica: u32, ev: &EngineEvent) {
        use EventKind::*;
        if ev.kind != Arrival {
            if ev.t_s < self.last_t {
                self.violate(format!(
                    "merged clock: {} on replica {replica} at \
                     t={:.6} before prior event t={:.6}",
                    ev.kind.name(), ev.t_s, self.last_t));
            }
            self.last_t = self.last_t.max(ev.t_s);
        }
        let id = ev.request.unwrap_or(u64::MAX);
        match ev.kind {
            Arrival => {
                if self.req.contains_key(&id) {
                    self.violate(format!(
                        "request {id}: second cluster-wide arrival \
                         (replica {replica})"));
                } else {
                    self.req.insert(id, (0, 0, 0));
                }
            }
            Admit => match self.req.get_mut(&id) {
                Some(r) => {
                    r.0 += 1;
                    if r.0 > 1 {
                        self.violate(format!(
                            "request {id}: admitted on two replicas"));
                    }
                }
                None => self.violate(format!(
                    "request {id}: admit before arrival \
                     (replica {replica})")),
            },
            Dispatch => {
                if let Some(&r) = self.resident.get(&id) {
                    self.violate(format!(
                        "request {id}: dispatched on replica \
                         {replica} while resident on replica {r}"));
                } else if self.req.get(&id).is_some_and(|r| r.2 > 0) {
                    self.violate(format!(
                        "request {id}: dispatched after completion \
                         (replica {replica})"));
                } else {
                    self.resident.insert(id, replica);
                }
            }
            Preempt => {
                if self.owner_check(replica, ev) {
                    self.resident.remove(&id);
                }
            }
            Complete => {
                if self.owner_check(replica, ev) {
                    self.resident.remove(&id);
                }
                if let Some(r) = self.req.get_mut(&id) {
                    r.2 += 1;
                    if r.2 > 1 {
                        self.violate(format!(
                            "request {id}: second cluster-wide \
                             completion (replica {replica})"));
                    }
                }
            }
            PrefillEnd => {
                self.owner_check(replica, ev);
                if ev.a == 1 {
                    if let Some(r) = self.req.get_mut(&id) {
                        r.1 += 1;
                        if r.1 > 1 {
                            self.violate(format!(
                                "request {id}: second cluster-wide \
                                 first token (replica {replica})"));
                        }
                    }
                }
            }
            PrefillStart | PrefillChunk | DecodeStep | Resume
                | SloBurn => {
                self.owner_check(replica, ev);
            }
            // Reject concerns a pending (non-resident) request;
            // everything else is replica-local state with no
            // cross-replica claim to check.
            _ => {}
        }
    }

    pub fn finalize(&mut self) {
        let incomplete = self.req.values()
            .filter(|r| r.2 == 0).count();
        if incomplete > 0 {
            self.violate(format!(
                "{incomplete} arrived requests never completed \
                 cluster-wide"));
        }
        if !self.resident.is_empty() {
            self.violate(format!(
                "{} requests still resident at finish",
                self.resident.len()));
        }
    }
}

/// One JSON object per line WITH a `replica` field — the
/// `--replicas > 1` flavour of [`to_jsonl`]. Single-engine runs keep
/// using [`to_jsonl`], so their trace files stay byte-identical to
/// pre-cluster builds.
pub fn to_jsonl_cluster(merged: &[(u32, EngineEvent)]) -> String {
    let mut out = String::new();
    for (replica, ev) in merged {
        let mut j = ev.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("replica".into(), Json::Num(*replica as f64));
        }
        out.push_str(&j.to_string());
        out.push('\n');
    }
    out
}

/// Cluster flavour of [`to_chrome_trace`]: each replica's stream is
/// laid out by the single-engine exporter, then shifted into the
/// replica's own process-id block — pids 3R, 3R+1, 3R+2 carry
/// replica R's engine/tenants/slots groups, with process names
/// prefixed `rR` — so N replicas render side-by-side in one
/// Perfetto view.
pub fn to_chrome_trace_cluster(streams: &[Vec<EngineEvent>],
                               tenant_names: &[String]) -> Json {
    let mut all: Vec<Json> = Vec::new();
    for (rid, evs) in streams.iter().enumerate() {
        let base = (rid * 3) as f64;
        let Json::Obj(mut root) = to_chrome_trace(evs, tenant_names)
        else {
            unreachable!("to_chrome_trace returns an object");
        };
        let Some(Json::Arr(trace)) = root.remove("traceEvents")
        else {
            unreachable!("trace root carries traceEvents");
        };
        for mut e in trace {
            if let Json::Obj(m) = &mut e {
                if let Some(Json::Num(p)) = m.get_mut("pid") {
                    *p += base;
                }
                let is_pname = m.get("name").and_then(Json::as_str)
                    == Some("process_name");
                if is_pname {
                    if let Some(Json::Obj(args)) = m.get_mut("args") {
                        if let Some(Json::Str(n)) =
                            args.get_mut("name")
                        {
                            *n = format!("r{rid} {n}");
                        }
                    }
                }
            }
            all.push(e);
        }
    }
    let mut root = BTreeMap::new();
    root.insert("traceEvents".into(), Json::Arr(all));
    root.insert("displayTimeUnit".into(), Json::Str("ms".into()));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: f64, kind: EventKind, tenant: u32, req: u64, a: u64,
          b: u64) -> EngineEvent {
        EngineEvent { t_s: t, step: 0, kind, tenant: Some(tenant),
                      request: Some(req), a, b }
    }

    /// A minimal clean lifecycle: arrive, admit, dispatch, prefill,
    /// decode, complete, with a balanced KV ledger and paired splice.
    fn clean_run(events: &Events) {
        use EventKind::*;
        events.emit_at(0.0, Arrival, Some(0), Some(1), 4, 2);
        events.set_now(0.5);
        events.emit(Admit, Some(0), Some(1), 4, 2);
        events.emit(Dispatch, Some(0), Some(1), 4, 2);
        events.emit(SpliceIn, Some(0), None, 0, 0);
        events.emit(KvAlloc, None, None, 1, 1);
        events.emit(PrefillStart, Some(0), Some(1), 4, 0);
        events.set_now(0.6);
        events.emit(PrefillEnd, Some(0), Some(1), 1, 4);
        events.set_now(0.7);
        events.emit(DecodeStep, Some(0), Some(1), 1, 1);
        events.set_now(0.8);
        events.emit(DecodeStep, Some(0), Some(1), 1, 0);
        events.emit(Complete, Some(0), Some(1), 3, 0);
        events.emit(KvFree, None, None, 1, 0);
        events.emit(SpliceOut, Some(0), None, 0, 0);
        events.finalize();
    }

    #[test]
    fn off_handle_is_inert() {
        let events = Events::off();
        assert!(!events.enabled());
        clean_run(&events); // all no-ops
        assert_eq!(events.total(), 0);
        assert!(events.snapshot().is_empty());
        assert_eq!(events.violation_count(), 0);
        assert!(events.counts().is_empty());
    }

    #[test]
    fn recording_handle_counts_and_audits_clean() {
        let events = Events::recording();
        assert!(events.enabled());
        clean_run(&events);
        assert_eq!(events.total(), 12);
        assert_eq!(events.violation_count(), 0,
                   "{:?}", events.violations());
        let counts: BTreeMap<_, _> =
            events.counts().into_iter().collect();
        assert_eq!(counts["arrival"], 1);
        assert_eq!(counts["decode_step"], 2);
        assert_eq!(counts["complete"], 1);
        // Clones share the bus.
        let alias = events.clone();
        alias.emit(EventKind::Overflow, None, None, 3, 0);
        assert_eq!(events.total(), 13);
    }

    #[test]
    fn null_sink_satisfies_the_trait() {
        let mut sink: Box<dyn EventSink> = Box::<NullSink>::default();
        sink.on_event(&ev(0.0, EventKind::Arrival, 0, 0, 0, 0));
        sink.finalize();
    }

    #[test]
    fn auditor_flags_causal_violations() {
        use EventKind::*;
        let catches = |emit: &dyn Fn(&Events)| -> u64 {
            let events = Events::recording();
            emit(&events);
            events.finalize();
            events.violation_count()
        };
        // Dispatch before arrival.
        assert!(catches(&|e| {
            e.emit(Dispatch, Some(0), Some(9), 1, 0);
            // Keep finalize quiet about the incompleteness:
            e.emit(Complete, Some(0), Some(9), 1, 0);
        }) > 0);
        // Dispatch before the arrival TIME.
        assert!(catches(&|e| {
            e.emit_at(5.0, Arrival, Some(0), Some(1), 1, 0);
            e.set_now(1.0);
            e.emit(Admit, Some(0), Some(1), 1, 0);
            e.emit(Dispatch, Some(0), Some(1), 1, 0);
            e.set_now(6.0);
            e.emit(Complete, Some(0), Some(1), 1, 0);
        }) > 0);
        // Double completion.
        assert!(catches(&|e| {
            e.emit_at(0.0, Arrival, Some(0), Some(1), 1, 0);
            e.emit(Admit, Some(0), Some(1), 1, 0);
            e.emit(Dispatch, Some(0), Some(1), 1, 0);
            e.emit(Complete, Some(0), Some(1), 1, 0);
            e.emit(Complete, Some(0), Some(1), 1, 0);
        }) > 0);
        // Unpaired splice (still live at finish).
        assert!(catches(&|e| {
            e.emit(SpliceIn, Some(3), None, 0, 0);
        }) > 0);
        // Splice-in over a live tenant.
        assert!(catches(&|e| {
            e.emit(SpliceIn, Some(1), None, 0, 0);
            e.emit(SpliceIn, Some(2), None, 0, 0);
            e.emit(SpliceOut, Some(2), None, 0, 0);
        }) > 0);
        // KV ledger: free without alloc.
        assert!(catches(&|e| {
            e.emit(KvFree, None, None, 1, 0);
        }) > 0);
        // KV ledger drift (reported b disagrees).
        assert!(catches(&|e| {
            e.emit(KvAlloc, None, None, 1, 7);
            e.emit(KvFree, None, None, 1, 0);
        }) > 0);
        // KV over-commit against a declared bound.
        let events = Events::recording();
        events.set_kv_capacity(1);
        events.emit(KvAlloc, None, None, 1, 1);
        events.emit(KvAlloc, None, None, 1, 2);
        assert!(events.violation_count() > 0);
        // Non-arrival clock regression.
        assert!(catches(&|e| {
            e.set_now(2.0);
            e.emit(Overflow, None, None, 1, 0);
            e.set_now(1.0);
            e.emit(Overflow, None, None, 1, 0);
        }) > 0);
        // Arrival IS allowed to point backwards.
        assert_eq!(catches(&|e| {
            e.set_now(2.0);
            e.emit(Overflow, None, None, 1, 0);
            e.emit_at(0.5, Arrival, Some(0), Some(1), 1, 0);
            e.emit(Admit, Some(0), Some(1), 1, 0);
            e.emit(Dispatch, Some(0), Some(1), 1, 0);
            e.emit(Complete, Some(0), Some(1), 1, 0);
        }), 0);
    }

    #[test]
    fn auditor_accepts_preempt_resume_cycles() {
        use EventKind::*;
        let e = Events::recording();
        e.emit_at(0.0, Arrival, Some(0), Some(1), 4, 6);
        e.set_now(0.1);
        e.emit(Admit, Some(0), Some(1), 4, 6);
        e.emit(Dispatch, Some(0), Some(1), 4, 6);
        e.emit(PrefillStart, Some(0), Some(1), 4, 0);
        e.set_now(0.2);
        e.emit(PrefillEnd, Some(0), Some(1), 1, 4);
        e.set_now(0.3);
        e.emit(Preempt, Some(0), Some(1), 1, 5);
        e.set_now(0.9);
        e.emit(Dispatch, Some(0), Some(1), 5, 5);
        e.emit(Resume, Some(0), Some(1), 5, 0);
        e.emit(PrefillStart, Some(0), Some(1), 5, 0);
        e.set_now(1.0);
        e.emit(PrefillEnd, Some(0), Some(1), 0, 5); // recompute: a=0
        e.set_now(1.6);
        e.emit(Complete, Some(0), Some(1), 6, 0);
        e.finalize();
        assert_eq!(e.violation_count(), 0, "{:?}", e.violations());
        // Resume without a preceding preempt is flagged.
        let e = Events::recording();
        e.emit_at(0.0, Arrival, Some(0), Some(1), 1, 0);
        e.emit(Admit, Some(0), Some(1), 1, 0);
        e.emit(Dispatch, Some(0), Some(1), 1, 0);
        e.emit(Resume, Some(0), Some(1), 1, 0);
        assert!(e.violation_count() > 0);
    }

    #[test]
    fn auditor_enforces_chunk_and_prefetch_rules() {
        use EventKind::*;
        let catches = |emit: &dyn Fn(&Events)| -> u64 {
            let events = Events::recording();
            emit(&events);
            events.violation_count()
        };
        // A clean chunked prefill: 10 tokens in 4 + 4 + 2, then the
        // exactly-once final PrefillEnd.
        let e = Events::recording();
        e.emit_at(0.0, Arrival, Some(0), Some(1), 10, 1);
        e.emit(Admit, Some(0), Some(1), 10, 1);
        e.emit(Dispatch, Some(0), Some(1), 10, 1);
        e.emit(PrefillStart, Some(0), Some(1), 10, 0);
        e.set_now(0.1);
        e.emit(PrefillChunk, Some(0), Some(1), 4, 6);
        e.set_now(0.2);
        e.emit(PrefillChunk, Some(0), Some(1), 4, 2);
        e.set_now(0.3);
        e.emit(PrefillChunk, Some(0), Some(1), 2, 0);
        e.emit(PrefillEnd, Some(0), Some(1), 1, 10);
        e.set_now(0.4);
        e.emit(DecodeStep, Some(0), Some(1), 1, 0);
        e.emit(Complete, Some(0), Some(1), 2, 0);
        e.finalize();
        assert_eq!(e.violation_count(), 0, "{:?}", e.violations());
        // PrefillEnd before the last chunk (2 tokens still owed).
        assert!(catches(&|e| {
            e.emit_at(0.0, Arrival, Some(0), Some(1), 10, 0);
            e.emit(Admit, Some(0), Some(1), 10, 0);
            e.emit(Dispatch, Some(0), Some(1), 10, 0);
            e.emit(PrefillStart, Some(0), Some(1), 10, 0);
            e.emit(PrefillChunk, Some(0), Some(1), 8, 2);
            e.emit(PrefillEnd, Some(0), Some(1), 1, 10);
        }) > 0);
        // Out-of-order / over-sized chunk: 8 owed, chunk of 12.
        assert!(catches(&|e| {
            e.emit_at(0.0, Arrival, Some(0), Some(1), 8, 0);
            e.emit(Admit, Some(0), Some(1), 8, 0);
            e.emit(Dispatch, Some(0), Some(1), 8, 0);
            e.emit(PrefillStart, Some(0), Some(1), 8, 0);
            e.emit(PrefillChunk, Some(0), Some(1), 12, 0);
        }) > 0);
        // Chunk ledger drift: reported remainder disagrees.
        assert!(catches(&|e| {
            e.emit_at(0.0, Arrival, Some(0), Some(1), 8, 0);
            e.emit(Admit, Some(0), Some(1), 8, 0);
            e.emit(Dispatch, Some(0), Some(1), 8, 0);
            e.emit(PrefillStart, Some(0), Some(1), 8, 0);
            e.emit(PrefillChunk, Some(0), Some(1), 4, 3);
        }) > 0);
        // Chunk outside a seat.
        assert!(catches(&|e| {
            e.emit_at(0.0, Arrival, Some(0), Some(1), 8, 0);
            e.emit(Admit, Some(0), Some(1), 8, 0);
            e.emit(PrefillChunk, Some(0), Some(1), 4, 4);
        }) > 0);
        // A mid-prompt preempt abandons the ledger; the re-seat opens
        // a fresh one and must still drain it.
        let e = Events::recording();
        e.emit_at(0.0, Arrival, Some(0), Some(1), 10, 0);
        e.emit(Admit, Some(0), Some(1), 10, 0);
        e.emit(Dispatch, Some(0), Some(1), 10, 0);
        e.emit(PrefillStart, Some(0), Some(1), 10, 0);
        e.set_now(0.1);
        e.emit(PrefillChunk, Some(0), Some(1), 4, 6);
        e.emit(Preempt, Some(0), Some(1), 1, 0);
        e.set_now(0.5);
        e.emit(Dispatch, Some(0), Some(1), 10, 0);
        e.emit(Resume, Some(0), Some(1), 10, 0);
        e.emit(PrefillStart, Some(0), Some(1), 10, 0);
        e.set_now(0.6);
        e.emit(PrefillChunk, Some(0), Some(1), 10, 0);
        e.emit(PrefillEnd, Some(0), Some(1), 1, 10);
        e.emit(Complete, Some(0), Some(1), 1, 0);
        e.finalize();
        assert_eq!(e.violation_count(), 0, "{:?}", e.violations());
        // Prefetch is engine-scoped: tying it to a request is the
        // "speculation emitted tokens" violation.
        let e = Events::recording();
        e.emit(Prefetch, Some(2), None, 16, 32);
        e.emit(PrefetchDonate, Some(2), None, 3, 48);
        assert_eq!(e.violation_count(), 0, "{:?}", e.violations());
        assert!(catches(&|e| {
            e.emit(Prefetch, Some(2), Some(7), 16, 32);
        }) > 0);
        assert!(catches(&|e| {
            e.emit(PrefetchDonate, Some(2), Some(7), 3, 48);
        }) > 0);
    }

    #[test]
    fn spans_rebuild_the_lifecycle() {
        let e = Events::recording();
        clean_run(&e);
        let spans = build_spans(&e.snapshot());
        assert_eq!(spans.len(), 1);
        let s = &spans[&1];
        assert_eq!(s.arrival_s, Some(0.0));
        assert_eq!(s.first_dispatch_s, Some(0.5));
        assert_eq!(s.first_token_s, Some(0.6));
        assert_eq!(s.complete_s, Some(0.8));
        assert_eq!(s.orig_decode, 2);
        assert_eq!(s.decode_steps, 2);
        assert_eq!(s.queueing_s(), Some(0.5));
        assert_eq!(s.ttft_s(), Some(0.6));
        assert_eq!(s.e2e_s(), Some(0.8));
        assert_eq!(s.service_s(), Some(0.8 - 0.5));
        assert_eq!(s.tpot_s(), Some((0.8 - 0.6) / 2.0));
        let lat = span_latencies(&e.snapshot(),
                                 &["tenant-00".to_string()]);
        assert_eq!(lat.e2e.count("tenant-00"), 1);
        assert_eq!(lat.e2e.count("(all)"), 1);
        assert_eq!(lat.tpot.percentile("(all)", 0.5),
                   Some((0.8 - 0.6) / 2.0));
    }

    #[test]
    fn jsonl_lines_all_parse() {
        let e = Events::recording();
        clean_run(&e);
        let text = to_jsonl(&e.snapshot());
        assert_eq!(text.lines().count(), 12);
        for line in text.lines() {
            let j = Json::parse(line).unwrap();
            assert!(j.get("kind").and_then(Json::as_str).is_some());
            assert!(j.get("t_s").and_then(Json::as_f64).is_some());
            assert!(j.get("step").is_some());
        }
        // Round-trip: values survive serialization exactly.
        let first = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("kind").and_then(Json::as_str),
                   Some("arrival"));
        assert_eq!(first.get("request").and_then(Json::as_usize),
                   Some(1));
    }

    #[test]
    fn chrome_trace_is_wellformed() {
        let e = Events::recording();
        clean_run(&e);
        let j = to_chrome_trace(&e.snapshot(),
                                &["tenant-00".to_string()]);
        // Self round-trip through the serializer.
        let back = Json::parse(&j.to_string()).unwrap();
        let arr = back.get("traceEvents").and_then(Json::as_arr)
            .expect("traceEvents array");
        assert!(!arr.is_empty());
        let phases: Vec<&str> = arr.iter()
            .filter_map(|v| v.get("ph").and_then(Json::as_str))
            .collect();
        assert!(phases.contains(&"M"), "metadata events");
        assert!(phases.contains(&"X"), "complete events");
        // The residency appears on both the tenant and slot tracks.
        let xs: Vec<&Json> = arr.iter()
            .filter(|v| v.get("ph").and_then(Json::as_str)
                    == Some("X"))
            .collect();
        let pids: Vec<i64> = xs.iter()
            .filter_map(|v| v.get("pid").and_then(Json::as_i64))
            .collect();
        assert!(pids.contains(&1) && pids.contains(&2));
        for x in xs {
            let dur = x.get("dur").and_then(Json::as_f64).unwrap();
            assert!(dur >= 0.0);
        }
    }
}
