//! Analytic serving-cost model — the simulator's forward-only
//! extension (A100 / Gaudi2 profiles) behind `paca bench --exp serve`
//! and the projection block of `paca serve`.
//!
//! The systems argument, at serving time: a PaCA adapter merges into
//! the frozen base, so the serving forward is EXACTLY the base model's
//! (zero extra kernels, zero extra latency — paper §2). LoRA-family
//! multi-adapter serving cannot merge (each tenant would need a full
//! weight copy), so it runs the adapters unmerged and pays the
//! serialized extra-kernel path per target on every request ("LoRA Is
//! Slower Than You Think"). PaCA's cost instead moves to the per-batch
//! adapter *swap* — O(r·d_out) row traffic per target — which
//! swap-aware batching amortizes.

use crate::manifest::ModelInfo;
use crate::simulator::{bw_time, gemm_time, DeviceProfile, A100_80G,
                       GAUDI2};

/// How adapters are applied at serving time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServePath {
    /// PaCA (or any merged method): the base IS the effective model.
    Merged,
    /// LoRA kept unmerged for multi-tenant sharing: two extra
    /// serialized GEMMs + framework overhead per target.
    LoraAdapters,
}

impl ServePath {
    pub fn name(self) -> &'static str {
        match self {
            ServePath::Merged => "paca-merged",
            ServePath::LoraAdapters => "lora-unmerged",
        }
    }
}

/// Built-in paper-scale profile so serving projections work on a fresh
/// checkout (no artifacts/manifest required).
pub fn llama3_8b() -> ModelInfo {
    ModelInfo { name: "llama3-8b".into(), vocab: 128256, d_model: 4096,
                n_layers: 32, n_heads: 32, d_ff: 14336, max_seq: 8192,
                profile_only: true }
}

/// Forward (prefill) wall time for one batch of `batch` sequences of
/// length `seq` — the compute-bound phase: big token×weight GEMMs
/// that amortize every weight read over `batch·seq` rows. Also the
/// analytic TTFT (the first output token drops when prefill ends).
pub fn forward_time(dev: &DeviceProfile, m: &ModelInfo, path: ServePath,
                    rank: usize, batch: usize, seq: usize) -> f64 {
    let t = (batch * seq) as f64;
    let d = m.d_model as f64;
    let s = seq as f64;
    let r = rank as f64;
    let b = batch as f64;
    let h = m.n_heads as f64;
    let hd = d / h;

    let mut fwd = 0.0;
    for _ in 0..m.n_layers {
        for (_, din, dout) in m.linear_shapes() {
            let (din, dout) = (din as f64, dout as f64);
            fwd += gemm_time(dev, t, din, dout);
            if path == ServePath::LoraAdapters {
                // The serialized adapter pair after every frozen GEMM.
                fwd += gemm_time(dev, t, din, r)
                    + gemm_time(dev, t, r, dout)
                    + dev.adapter_overhead_s;
            }
        }
        // Attention + elementwise traffic (method-independent).
        fwd += gemm_time(dev, b * h * s, hd, s)
            + gemm_time(dev, b * h * s, s, hd)
            + bw_time(dev, t * d * 12.0);
    }
    fwd + gemm_time(dev, t, d, m.vocab as f64)
}

/// One decode iteration for `batch` in-flight sequences at context
/// length `ctx` — the OTHER arithmetic-intensity regime: each step
/// computes one token per sequence, so every target weight is
/// re-streamed for a `batch`-row GEMM (bandwidth-bound at serving
/// batch sizes) and the KV cache is read once per layer. The unmerged
/// LoRA path pays its serialized adapter pair + framework overhead
/// PER STEP, i.e. per output token — the latency tax "LoRA Is Slower
/// Than You Think" measures, and the reason iteration-level serving
/// of merged PaCA adapters is the favourable regime.
pub fn decode_step_time(dev: &DeviceProfile, m: &ModelInfo,
                        path: ServePath, rank: usize, batch: usize,
                        ctx: usize) -> f64 {
    let b = batch.max(1) as f64;
    let d = m.d_model as f64;
    let r = rank as f64;
    // Per-layer KV bytes per context token — the ONE derivation of
    // the KV footprint (ModelInfo::kv_bytes_per_token), shared with
    // the paged allocator's capacity ledger in serve::kv so the time
    // model and the memory manager can never disagree on what a
    // resident token costs.
    let kv_layer_bytes =
        m.kv_bytes_per_token() as f64 / m.n_layers as f64;
    let mut step = 0.0;
    for _ in 0..m.n_layers {
        for (_, din, dout) in m.linear_shapes() {
            let (din, dout) = (din as f64, dout as f64);
            step += gemm_time(dev, b, din, dout);
            if path == ServePath::LoraAdapters {
                step += gemm_time(dev, b, din, r)
                    + gemm_time(dev, b, r, dout)
                    + dev.adapter_overhead_s;
            }
        }
        // KV-cache streaming (bf16 K and V over the whole context)
        // plus the per-token elementwise traffic.
        step += bw_time(dev, b * ctx as f64 * kv_layer_bytes)
            + bw_time(dev, b * d * 12.0);
    }
    step + gemm_time(dev, b, d, m.vocab as f64)
}

/// Analytic time-per-output-token at steady decode: one decode step
/// serves every in-flight sequence one token, so TPOT is simply the
/// step period.
pub fn tpot_s(dev: &DeviceProfile, m: &ModelInfo, path: ServePath,
              rank: usize, batch: usize, ctx: usize) -> f64 {
    decode_step_time(dev, m, path, rank, batch, ctx)
}

/// Aggregate decode throughput, output tokens/s across the batch.
pub fn decode_tok_per_s(dev: &DeviceProfile, m: &ModelInfo,
                        path: ServePath, rank: usize, batch: usize,
                        ctx: usize) -> f64 {
    batch.max(1) as f64
        / decode_step_time(dev, m, path, rank, batch, ctx)
}

/// Prefill wall time when a fraction `hit` of the prompt is served
/// from the prefix cache: only the uncached `(1−hit)·seq` suffix is
/// computed (attention still spans the full context, but at serving
/// batch sizes the target GEMMs dominate — the same modelling level
/// as `forward_time`). `hit = 0` reduces exactly to `forward_time`;
/// the engine enforces ≥ 1 computed token, mirrored by the `.max(1)`
/// floor. This is the analytic TTFT with a warm cache.
pub fn prefill_time_cached(dev: &DeviceProfile, m: &ModelInfo,
                           path: ServePath, rank: usize, batch: usize,
                           seq: usize, hit: f64) -> f64 {
    let hit = hit.clamp(0.0, 1.0);
    let suffix = ((seq as f64 * (1.0 - hit)).ceil() as usize).max(1);
    forward_time(dev, m, path, rank, batch, suffix)
}

/// Prefix-cache projection: analytic TTFT vs cache hit rate for the
/// merged path on both device profiles — what a given steady-state
/// hit rate (the engine reports the measured one) buys at paper
/// scale. The `speedup` column is against the cold (hit 0) prefill.
pub fn prefix_hit_table(m: &ModelInfo, rank: usize, batch: usize,
                        seq: usize) -> String {
    use crate::metrics::Table;
    let mut out = String::new();
    for dev in [&A100_80G, &GAUDI2] {
        let cold = prefill_time_cached(dev, m, ServePath::Merged,
                                       rank, batch, seq, 0.0);
        let mut t = Table::new(&["hit rate", "TTFT ms", "speedup"]);
        for hit in [0.0, 0.25, 0.5, 0.75, 0.9] {
            let warm = prefill_time_cached(
                dev, m, ServePath::Merged, rank, batch, seq, hit);
            t.row(&[format!("{:.0}%", hit * 100.0),
                    format!("{:.1}", warm * 1e3),
                    format!("{:.2}x", cold / warm)]);
        }
        out.push_str(&format!(
            "\n{} — {} prefix-cache hit-rate projection, rank \
             {rank}, batch {batch}, prompt {seq} (TTFT = prefill of \
             the uncached suffix; hit rate is the cached fraction of \
             the prompt):\n\n", dev.name, m.name));
        out.push_str(&t.render());
    }
    out
}

/// Prefill chunks a prompt splits into under `--prefill-chunk-tokens`
/// — the shared arithmetic of the chunked projections. Chunk 0 means
/// unchunked (the whole prompt is one "chunk"), matching the engine's
/// convention.
pub fn prefill_chunks(prompt: usize, chunk: usize) -> usize {
    if chunk == 0 || prompt == 0 {
        1
    } else {
        prompt.div_ceil(chunk)
    }
}

/// Worst-case stall a DECODING slot suffers in one step while a long
/// prompt prefills alongside it: the step cannot complete until the
/// co-scheduled prefill work does, so unchunked (chunk 0) the decoder
/// waits out the WHOLE prompt's forward — the batch-of-one pathology —
/// while chunked it waits only one chunk's worth. This is the analytic
/// decode-TPOT-tail term the chunked engine flattens.
pub fn prefill_stall_s(dev: &DeviceProfile, m: &ModelInfo,
                       path: ServePath, rank: usize, prompt: usize,
                       chunk: usize) -> f64 {
    let per_step = if chunk == 0 { prompt } else { chunk.min(prompt) };
    forward_time(dev, m, path, rank, 1, per_step.max(1))
}

/// Analytic TTFT of the long prompt itself under chunking: its own
/// prefill compute is conserved (the chunks sum to the prompt), but
/// every chunk after the first rides a later step that also serves
/// the co-resident decoders — so the prompt's first token pays
/// `interleave_s` (one decode step of the sharing batch, see
/// [`decode_step_time`]) per extra chunk. Chunk 0 reduces exactly to
/// `forward_time`: the stall/TTFT trade is the whole projection.
pub fn chunked_ttft_s(dev: &DeviceProfile, m: &ModelInfo,
                      path: ServePath, rank: usize, prompt: usize,
                      chunk: usize, interleave_s: f64) -> f64 {
    let chunks = prefill_chunks(prompt, chunk);
    forward_time(dev, m, path, rank, 1, prompt.max(1))
        + (chunks - 1) as f64 * interleave_s
}

/// Chunked-prefill projection: the decode-stall vs long-prompt-TTFT
/// trade as the chunk size sweeps, merged path, both devices — what a
/// given `--prefill-chunk-tokens` buys the decoding slots and costs
/// the long prompt. The chunk-0 row is the unchunked anchor.
pub fn chunked_prefill_table(m: &ModelInfo, rank: usize, prompt: usize,
                             batch: usize, ctx: usize) -> String {
    use crate::metrics::Table;
    let mut out = String::new();
    for dev in [&A100_80G, &GAUDI2] {
        let base = prefill_stall_s(dev, m, ServePath::Merged, rank,
                                   prompt, 0);
        let step = decode_step_time(dev, m, ServePath::Merged, rank,
                                    batch, ctx);
        let mut t = Table::new(&["chunk", "chunks", "decode stall ms",
                                 "stall cut", "long-prompt TTFT ms"]);
        for chunk in [0usize, 512, 256, 128, 64] {
            let stall = prefill_stall_s(dev, m, ServePath::Merged,
                                        rank, prompt, chunk);
            let ttft = chunked_ttft_s(dev, m, ServePath::Merged, rank,
                                      prompt, chunk, step);
            let label = if chunk == 0 { "off".to_string() }
                        else { chunk.to_string() };
            t.row(&[label,
                    prefill_chunks(prompt, chunk).to_string(),
                    format!("{:.1}", stall * 1e3),
                    format!("{:.1}x", base / stall),
                    format!("{:.1}", ttft * 1e3)]);
        }
        out.push_str(&format!(
            "\n{} — {} chunked prefill, rank {rank}, prompt {prompt}, \
             {batch} decoding slots at ctx {ctx} (stall = the longest \
             wait chunked prefill injects into one decode step; TTFT \
             = the long prompt's own first token):\n\n",
            dev.name, m.name));
        out.push_str(&t.render());
    }
    out
}

/// Device cost of one PaCA adapter swap on the merged path: per target
/// per layer, save r·d_out displaced rows and write r·d_out adapter
/// rows (bf16), plus a dispatch per target.
pub fn adapter_swap_time(dev: &DeviceProfile, m: &ModelInfo,
                         rank: usize) -> f64 {
    let r = rank as f64;
    let mut bytes = 0.0;
    let mut launches = 0.0;
    for _ in 0..m.n_layers {
        for (_, _din, dout) in m.linear_shapes() {
            bytes += 2.0 * r * dout as f64 * 2.0;
            launches += 1.0;
        }
    }
    bytes / dev.mem_bw + launches * dev.launch_s
}

/// Steady-state serving throughput in requests/s, including the
/// per-batch swap on the merged path (one swap per `batch` requests —
/// the swap-aware scheduler's amortization unit). The unmerged LoRA
/// path needs no swaps but pays its overhead on every forward.
pub fn serve_throughput_req_per_s(dev: &DeviceProfile, m: &ModelInfo,
                                  path: ServePath, rank: usize,
                                  batch: usize, seq: usize) -> f64 {
    let per_batch = match path {
        ServePath::Merged => {
            forward_time(dev, m, path, rank, batch, seq)
                + adapter_swap_time(dev, m, rank)
        }
        ServePath::LoraAdapters => {
            forward_time(dev, m, path, rank, batch, seq)
        }
    };
    batch as f64 / per_batch
}

pub fn serve_throughput_tok_per_s(dev: &DeviceProfile, m: &ModelInfo,
                                  path: ServePath, rank: usize,
                                  batch: usize, seq: usize) -> f64 {
    serve_throughput_req_per_s(dev, m, path, rank, batch, seq)
        * seq as f64
}

/// Mean per-request service time at the given batch geometry,
/// including the amortized per-batch adapter swap on the merged path.
pub fn service_time_per_req_s(dev: &DeviceProfile, m: &ModelInfo,
                              path: ServePath, rank: usize,
                              batch: usize, seq: usize) -> f64 {
    let per_batch = match path {
        ServePath::Merged => {
            forward_time(dev, m, path, rank, batch, seq)
                + adapter_swap_time(dev, m, rank)
        }
        ServePath::LoraAdapters => {
            forward_time(dev, m, path, rank, batch, seq)
        }
    };
    per_batch / batch.max(1) as f64
}

/// Mean queueing delay at offered load `req_per_s` — the analytic
/// online term the continuous-batching pipeline made observable.
/// Modeled as M/D/1 (Poisson arrivals, near-deterministic batch
/// service): Wq = ρ·s / (2·(1−ρ)) with s the per-request service time
/// and ρ = λ·s the utilization. Returns +inf at or beyond saturation:
/// the queue grows without bound, which is exactly what the measured
/// pipeline shows when an overloaded policy's virtual clock falls
/// behind arrivals.
pub fn queueing_delay_s(dev: &DeviceProfile, m: &ModelInfo,
                        path: ServePath, rank: usize, batch: usize,
                        seq: usize, req_per_s: f64) -> f64 {
    let s = service_time_per_req_s(dev, m, path, rank, batch, seq);
    let rho = req_per_s * s;
    if rho >= 1.0 {
        return f64::INFINITY;
    }
    rho * s / (2.0 * (1.0 - rho))
}

/// Mean end-to-end request latency at offered load: queueing delay
/// plus the full batch residence time (a request completes with its
/// batch).
pub fn total_latency_s(dev: &DeviceProfile, m: &ModelInfo,
                       path: ServePath, rank: usize, batch: usize,
                       seq: usize, req_per_s: f64) -> f64 {
    let per_batch = service_time_per_req_s(dev, m, path, rank, batch,
                                           seq) * batch.max(1) as f64;
    queueing_delay_s(dev, m, path, rank, batch, seq, req_per_s)
        + per_batch
}

/// Latency-vs-load projection: queueing + total latency for both
/// serving paths as offered load sweeps toward the merged path's
/// saturation point. The unmerged-LoRA column saturates first (its
/// service time is longer) — the queueing-theoretic restatement of
/// "LoRA Is Slower Than You Think" under live traffic.
pub fn latency_table(m: &ModelInfo, rank: usize, batch: usize,
                     seq: usize) -> String {
    use crate::metrics::Table;
    let fmt_ms = |v: f64| if v.is_finite() {
        format!("{:.1}ms", v * 1e3)
    } else {
        "saturated".to_string()
    };
    let mut out = String::new();
    for dev in [&A100_80G, &GAUDI2] {
        let cap = 1.0 / service_time_per_req_s(
            dev, m, ServePath::Merged, rank, batch, seq);
        let mut t = Table::new(&["load req/s", "utilization",
                                 "PaCA queue", "PaCA e2e",
                                 "LoRA queue", "LoRA e2e"]);
        for frac in [0.2, 0.5, 0.8, 0.95] {
            let load = frac * cap;
            t.row(&[format!("{load:.1}"),
                    format!("{:.0}%", frac * 100.0),
                    fmt_ms(queueing_delay_s(
                        dev, m, ServePath::Merged, rank, batch, seq,
                        load)),
                    fmt_ms(total_latency_s(
                        dev, m, ServePath::Merged, rank, batch, seq,
                        load)),
                    fmt_ms(queueing_delay_s(
                        dev, m, ServePath::LoraAdapters, rank, batch,
                        seq, load)),
                    fmt_ms(total_latency_s(
                        dev, m, ServePath::LoraAdapters, rank, batch,
                        seq, load))]);
        }
        out.push_str(&format!(
            "\n{} — {} latency vs load, rank {rank}, batch {batch}, \
             seq {seq} (loads are fractions of PaCA-merged \
             capacity):\n\n", dev.name, m.name));
        out.push_str(&t.render());
    }
    out
}

/// Mean queueing delay of one replica in an N-replica cluster at
/// cluster-wide offered load `req_per_s`, when the router hands this
/// replica `share` of the traffic (share 1/N = perfectly balanced;
/// share 2/N = a hash-sharded hot tenant doubling up on its home).
/// Each replica is an independent M/D/1 server — the cluster's merged
/// virtual-clock loop preserves exactly this independence, which is
/// why the analytic term stays per-replica.
pub fn replica_queueing_delay_s(dev: &DeviceProfile, m: &ModelInfo,
                                path: ServePath, rank: usize,
                                batch: usize, seq: usize,
                                req_per_s: f64, share: f64) -> f64 {
    queueing_delay_s(dev, m, path, rank, batch, seq,
                     req_per_s * share.max(0.0))
}

/// Cluster queueing projection for `paca serve --replicas N`: at each
/// offered load (fractions of ONE replica's capacity, so >100% rows
/// exist only because the cluster has N servers), the M/D/1 queueing
/// delay of (a) a single replica eating the whole stream, (b) one
/// replica of a perfectly balanced N-way split — what least-loaded /
/// warmth routing approaches, and what `--router shard` achieves when
/// tenant popularity is uniform — and (c) the hot home shard under a
/// Zipf-skewed tenant mix that receives twice its fair share — the
/// pathology the router's overflow spill exists to cut. Merged path
/// throughout: every replica serves every tenant from one spliced
/// base, so the split is pure load balancing with no placement
/// constraint.
pub fn cluster_queueing_table(m: &ModelInfo, rank: usize, batch: usize,
                              seq: usize, replicas: usize) -> String {
    use crate::metrics::Table;
    let n = replicas.max(2);
    let fmt_ms = |v: f64| if v.is_finite() {
        format!("{:.1}ms", v * 1e3)
    } else {
        "saturated".to_string()
    };
    let mut out = String::new();
    for dev in [&A100_80G, &GAUDI2] {
        let cap = 1.0 / service_time_per_req_s(
            dev, m, ServePath::Merged, rank, batch, seq);
        let mut t = Table::new(&["load req/s", "of 1-replica cap",
                                 "1 replica",
                                 &format!("{n} balanced"),
                                 &format!("{n} hot shard 2x")]);
        for frac in [0.5, 0.8, 1.2, 0.8 * n as f64] {
            let load = frac * cap;
            let q = |share| replica_queueing_delay_s(
                dev, m, ServePath::Merged, rank, batch, seq, load,
                share);
            t.row(&[format!("{load:.1}"),
                    format!("{:.0}%", frac * 100.0),
                    fmt_ms(q(1.0)),
                    fmt_ms(q(1.0 / n as f64)),
                    fmt_ms(q(2.0 / n as f64))]);
        }
        out.push_str(&format!(
            "\n{} — {} cluster queueing, {n} replicas, rank {rank}, \
             batch {batch}, seq {seq} (per-replica M/D/1; 'balanced' \
             = the fair 1/{n} split least-loaded routing approaches, \
             'hot shard' = a hash home receiving twice its share — \
             the skew overflow spill cuts):\n\n",
            dev.name, m.name));
        out.push_str(&t.render());
    }
    out
}

/// Iteration-level serving projection: TTFT (prefill) and TPOT
/// (decode-step period) for merged PaCA vs unmerged LoRA across batch
/// sizes. Decode is where unmerged adapters hurt most: the serialized
/// adapter pair is paid per output token against a bandwidth-bound
/// base step, so the relative tax is far above the prefill tax.
pub fn decode_table(m: &ModelInfo, rank: usize, prompt: usize,
                    ctx: usize) -> String {
    use crate::metrics::Table;
    let mut out = String::new();
    for dev in [&A100_80G, &GAUDI2] {
        let mut t = Table::new(&["Batch", "TTFT ms", "PaCA TPOT ms",
                                 "LoRA TPOT ms", "LoRA decode tax",
                                 "PaCA decode tok/s"]);
        for batch in [1usize, 4, 8, 16, 32] {
            let ttft = forward_time(dev, m, ServePath::Merged, rank,
                                    batch, prompt);
            let paca = tpot_s(dev, m, ServePath::Merged, rank, batch,
                              ctx);
            let lora = tpot_s(dev, m, ServePath::LoraAdapters, rank,
                              batch, ctx);
            t.row(&[batch.to_string(),
                    format!("{:.1}", ttft * 1e3),
                    format!("{:.2}", paca * 1e3),
                    format!("{:.2}", lora * 1e3),
                    format!("{:+.0}%", (lora / paca - 1.0) * 100.0),
                    format!("{:.0}", decode_tok_per_s(
                        dev, m, ServePath::Merged, rank, batch,
                        ctx))]);
        }
        out.push_str(&format!(
            "\n{} — {} iteration-level decode, rank {rank}, prompt \
             {prompt}, context {ctx} (TPOT = decode-step period; the \
             unmerged path pays its adapter kernels per output \
             token):\n\n", dev.name, m.name));
        out.push_str(&t.render());
    }
    out
}

/// Shared frozen base resident at serving time, bf16.
pub fn base_weight_bytes(m: &ModelInfo) -> f64 {
    m.n_params() as f64 * 2.0
}

/// Resident bytes of ONE unmerged LoRA adapter (bf16 A and B per
/// target per layer). Multi-tenant unmerged serving must keep an
/// adapter resident per in-flight tenant — worst case (every
/// concurrent sequence a distinct tenant, the Zipf tail) one per
/// sequence — while merged PaCA splices `(idx, P)` INTO the base and
/// keeps zero extra bytes resident.
pub fn lora_adapter_bytes(m: &ModelInfo, rank: usize) -> f64 {
    let r = rank as f64;
    let per_layer: f64 = m.linear_shapes().iter()
        .map(|(_, din, dout)| r * (*din + *dout) as f64 * 2.0)
        .sum();
    per_layer * m.n_layers as f64
}

/// Resident bytes ONE in-flight sequence pins at context length
/// `ctx`: its KV cache (the shared `kv_bytes_per_token` arithmetic)
/// plus, on the unmerged path, its tenant's resident adapter — the
/// per-sequence footprint the paged allocator's capacity axis
/// measures.
pub fn serve_bytes_per_seq(m: &ModelInfo, path: ServePath,
                           rank: usize, ctx: usize) -> f64 {
    ctx as f64 * m.kv_bytes_per_token() as f64
        + match path {
            ServePath::Merged => 0.0,
            ServePath::LoraAdapters => lora_adapter_bytes(m, rank),
        }
}

/// How many sequences of context `ctx` fit in the device's HBM after
/// the frozen base — the capacity ceiling `--kv-blocks` expresses in
/// the real engine.
pub fn max_concurrent_seqs(dev: &DeviceProfile, m: &ModelInfo,
                           path: ServePath, rank: usize,
                           ctx: usize) -> usize {
    let free = dev.capacity - base_weight_bytes(m);
    if free <= 0.0 {
        return 0;
    }
    (free / serve_bytes_per_seq(m, path, rank, ctx)) as usize
}

/// Longest context `batch` concurrent sequences can hold in HBM after
/// the frozen base (and, unmerged, their resident adapters) — the
/// serving restatement of the paper's "23% longer sequences" claim:
/// capacity not spent on per-sequence method overhead is capacity
/// spent on tokens.
pub fn max_context_len(dev: &DeviceProfile, m: &ModelInfo,
                       path: ServePath, rank: usize,
                       batch: usize) -> usize {
    let b = batch.max(1) as f64;
    let overhead = match path {
        ServePath::Merged => 0.0,
        ServePath::LoraAdapters => lora_adapter_bytes(m, rank),
    };
    let free = dev.capacity - base_weight_bytes(m) - b * overhead;
    if free <= 0.0 {
        return 0;
    }
    ((free / b) / m.kv_bytes_per_token() as f64) as usize
}

/// KV-capacity projection: max concurrent sequences (at a fixed
/// context) and max context (at a fixed batch) for merged PaCA vs
/// unmerged LoRA on both device profiles — the memory axis of the
/// serving comparison. PaCA's spliced adapters pin nothing beyond the
/// base, so every byte the unmerged path spends on resident adapters
/// comes straight out of KV capacity.
pub fn kv_capacity_table(m: &ModelInfo, rank: usize, ctx: usize,
                         batch: usize) -> String {
    use crate::metrics::Table;
    let mut out = String::new();
    for dev in [&A100_80G, &GAUDI2] {
        let mut t = Table::new(&["method", "resident/seq",
                                 "max seqs", "max context",
                                 "vs unmerged"]);
        let seqs = |p| max_concurrent_seqs(dev, m, p, rank, ctx);
        let ctxs = |p| max_context_len(dev, m, p, rank, batch);
        let paca_ctx = ctxs(ServePath::Merged);
        let lora_ctx = ctxs(ServePath::LoraAdapters).max(1);
        for path in [ServePath::Merged, ServePath::LoraAdapters] {
            let gain = match path {
                ServePath::Merged => format!(
                    "{:+.1}% context",
                    100.0 * (paca_ctx as f64 / lora_ctx as f64
                             - 1.0)),
                ServePath::LoraAdapters => "-".to_string(),
            };
            t.row(&[path.name().to_string(),
                    format!("{:.1}MB", serve_bytes_per_seq(
                        m, path, rank, ctx) / 1e6),
                    seqs(path).to_string(),
                    ctxs(path).to_string(),
                    gain]);
        }
        out.push_str(&format!(
            "\n{} — {} KV capacity, rank {rank} (max seqs at ctx \
             {ctx}; max context at batch {batch}; {:.1}GB frozen \
             base):\n\n", dev.name, m.name,
            base_weight_bytes(m) / 1e9));
        out.push_str(&t.render());
    }
    out
}

/// The `paca bench --exp serve` / `paca serve` projection block:
/// merged-PaCA vs unmerged-LoRA serving throughput across batch sizes
/// on both device profiles, plus the swap-amortization curve.
pub fn comparison_table(m: &ModelInfo, rank: usize, seq: usize) -> String {
    use crate::metrics::Table;
    let mut out = String::new();
    for dev in [&A100_80G, &GAUDI2] {
        let mut t = Table::new(&["Batch", "PaCA-merged req/s",
                                 "LoRA-unmerged req/s", "PaCA gain",
                                 "swap cost share"]);
        for batch in [1usize, 2, 4, 8, 16, 32] {
            let paca = serve_throughput_req_per_s(
                dev, m, ServePath::Merged, rank, batch, seq);
            let lora = serve_throughput_req_per_s(
                dev, m, ServePath::LoraAdapters, rank, batch, seq);
            let swap = adapter_swap_time(dev, m, rank);
            let fwd = forward_time(dev, m, ServePath::Merged, rank,
                                   batch, seq);
            t.row(&[batch.to_string(),
                    format!("{paca:.2}"),
                    format!("{lora:.2}"),
                    format!("{:+.1}%", (paca / lora - 1.0) * 100.0),
                    format!("{:.2}%", 100.0 * swap / (fwd + swap))]);
        }
        out.push_str(&format!(
            "\n{} — {} serving, rank {rank}, seq {seq} (one adapter \
             swap per batch on the merged path):\n\n",
            dev.name, m.name));
        out.push_str(&t.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merged_serving_beats_unmerged_lora() {
        // The serving restatement of paper Fig 2: even paying one swap
        // per batch, merged PaCA out-serves unmerged LoRA.
        let m = llama3_8b();
        for dev in [&A100_80G, &GAUDI2] {
            for batch in [1, 8, 32] {
                let p = serve_throughput_req_per_s(
                    dev, &m, ServePath::Merged, 64, batch, 512);
                let l = serve_throughput_req_per_s(
                    dev, &m, ServePath::LoraAdapters, 64, batch, 512);
                assert!(p > l, "{} b{batch}: paca {p} !> lora {l}",
                        dev.name);
            }
        }
    }

    #[test]
    fn lora_overhead_is_significant_but_bounded() {
        // At small batch the serialized adapter path dominates (the
        // "LoRA Is Slower Than You Think" regime); at large batch it
        // amortizes but never disappears.
        let m = llama3_8b();
        let ratio = |batch| {
            forward_time(&A100_80G, &m, ServePath::LoraAdapters, 64,
                         batch, 512)
                / forward_time(&A100_80G, &m, ServePath::Merged, 64,
                               batch, 512)
        };
        let r1 = ratio(1);
        let r32 = ratio(32);
        assert!(r1 > 1.2 && r1 < 2.5, "batch-1 ratio {r1}");
        assert!(r32 > 1.0, "overhead never disappears: {r32}");
        assert!(r32 < r1, "large batches amortize the adapter path");
    }

    #[test]
    fn swap_is_cheap_relative_to_forward() {
        // The premise of swap-aware batching: a swap costs much less
        // than a batch forward, so one swap per batch is amortizable.
        let m = llama3_8b();
        let swap = adapter_swap_time(&A100_80G, &m, 64);
        let fwd = forward_time(&A100_80G, &m, ServePath::Merged, 64,
                               8, 512);
        assert!(swap > 0.0);
        assert!(swap < 0.25 * fwd, "swap {swap} vs fwd {fwd}");
    }

    #[test]
    fn swap_share_shrinks_with_batch_size() {
        // Swap-aware batching's amortization: the swap's share of batch
        // time falls as same-tenant batches grow, and per-request
        // throughput rises.
        let m = llama3_8b();
        let share = |b| {
            let swap = adapter_swap_time(&A100_80G, &m, 64);
            let fwd = forward_time(&A100_80G, &m, ServePath::Merged,
                                   64, b, 512);
            swap / (fwd + swap)
        };
        assert!(share(32) < share(1) / 4.0,
                "share(32)={} share(1)={}", share(32), share(1));
        let t1 = serve_throughput_req_per_s(
            &A100_80G, &m, ServePath::Merged, 64, 1, 512);
        let t32 = serve_throughput_req_per_s(
            &A100_80G, &m, ServePath::Merged, 64, 32, 512);
        assert!(t32 > t1);
    }

    #[test]
    fn decode_tax_exceeds_prefill_tax() {
        // The iteration-level motivation: unmerged LoRA's serialized
        // adapter kernels are a fixed per-step cost, so against a
        // bandwidth-bound decode step they tax FAR more (relatively)
        // than against a compute-bound prefill.
        let m = llama3_8b();
        for dev in [&A100_80G, &GAUDI2] {
            for batch in [1usize, 8] {
                let decode_ratio =
                    tpot_s(dev, &m, ServePath::LoraAdapters, 64,
                           batch, 512)
                    / tpot_s(dev, &m, ServePath::Merged, 64, batch,
                             512);
                let prefill_ratio =
                    forward_time(dev, &m, ServePath::LoraAdapters, 64,
                                 batch, 512)
                    / forward_time(dev, &m, ServePath::Merged, 64,
                                   batch, 512);
                assert!(decode_ratio > 2.0,
                        "{} b{batch}: decode tax only {decode_ratio}",
                        dev.name);
                assert!(decode_ratio > prefill_ratio,
                        "{} b{batch}: decode {decode_ratio} !> \
                         prefill {prefill_ratio}", dev.name);
            }
        }
    }

    #[test]
    fn decode_batching_amortizes_weight_reads() {
        // A decode step is weight-bandwidth-bound, so its period
        // barely grows with batch — aggregate decode tok/s scales
        // nearly linearly until compute binds.
        let m = llama3_8b();
        let t1 = decode_tok_per_s(&A100_80G, &m, ServePath::Merged,
                                  64, 1, 512);
        let t32 = decode_tok_per_s(&A100_80G, &m, ServePath::Merged,
                                   64, 32, 512);
        assert!(t32 > 4.0 * t1, "tok/s {t1} -> {t32}");
        // Longer context = more KV traffic = slower steps.
        let short = decode_step_time(&A100_80G, &m, ServePath::Merged,
                                     64, 8, 128);
        let long = decode_step_time(&A100_80G, &m, ServePath::Merged,
                                    64, 8, 8192);
        assert!(long > short);
        // And a decode step is far cheaper than a 512-token prefill —
        // the two phases genuinely sit on different rooflines.
        let prefill = forward_time(&A100_80G, &m, ServePath::Merged,
                                   64, 8, 512);
        let step = decode_step_time(&A100_80G, &m, ServePath::Merged,
                                    64, 8, 512);
        assert!(step < 0.25 * prefill, "step {step} vs prefill \
                                        {prefill}");
    }

    #[test]
    fn prefill_hit_rate_term_is_monotone_and_anchored() {
        let m = llama3_8b();
        for dev in [&A100_80G, &GAUDI2] {
            let t = |hit| prefill_time_cached(
                dev, &m, ServePath::Merged, 64, 8, 512, hit);
            // hit 0 IS forward_time — the reduction anchor of the
            // analytic term.
            assert_eq!(t(0.0), forward_time(
                dev, &m, ServePath::Merged, 64, 8, 512));
            // Strictly monotone: more cache, less prefill.
            assert!(t(0.25) < t(0.0), "{}", dev.name);
            assert!(t(0.5) < t(0.25));
            assert!(t(0.9) < t(0.5));
            // Never free: the first output token still needs a
            // forward, even fully cached (and out-of-range hit rates
            // clamp instead of exploding).
            assert!(t(1.0) > 0.0);
            assert_eq!(t(7.0), t(1.0));
            assert_eq!(t(-3.0), t(0.0));
        }
    }

    #[test]
    fn chunked_prefill_trades_stall_for_ttft_and_anchors_at_zero() {
        let m = llama3_8b();
        for dev in [&A100_80G, &GAUDI2] {
            let stall = |c| prefill_stall_s(
                dev, &m, ServePath::Merged, 64, 4096, c);
            let step = decode_step_time(dev, &m, ServePath::Merged,
                                        64, 8, 512);
            let ttft = |c| chunked_ttft_s(
                dev, &m, ServePath::Merged, 64, 4096, c, step);
            // Chunk 0 IS the unchunked engine: the stall is the whole
            // prompt's forward and the TTFT is plain forward_time —
            // the reduction anchor of the analytic term.
            assert_eq!(stall(0), forward_time(
                dev, &m, ServePath::Merged, 64, 1, 4096));
            assert_eq!(ttft(0), forward_time(
                dev, &m, ServePath::Merged, 64, 1, 4096));
            // A chunk at least the prompt changes nothing.
            assert_eq!(stall(4096), stall(0));
            assert_eq!(ttft(8192), ttft(0));
            // Smaller chunks: strictly less stall injected per decode
            // step, strictly more interleaved steps before the long
            // prompt's own first token.
            assert!(stall(256) < stall(1024));
            assert!(stall(64) < stall(256));
            assert!(stall(64) < 0.1 * stall(0),
                    "{}: a 64-token chunk must cut the 4096-token \
                     stall by well over 10x", dev.name);
            assert!(ttft(256) > ttft(1024));
            assert!(ttft(64) > ttft(256));
            assert!(ttft(64) > ttft(0));
        }
        assert_eq!(prefill_chunks(4096, 0), 1);
        assert_eq!(prefill_chunks(4096, 100), 41);
        assert_eq!(prefill_chunks(0, 64), 1);
    }

    #[test]
    fn chunked_prefill_table_renders() {
        let m = llama3_8b();
        let s = chunked_prefill_table(&m, 64, 4096, 8, 512);
        assert!(s.contains("decode stall ms"));
        assert!(s.contains("long-prompt TTFT ms"));
        assert!(s.contains("off"), "the chunk-0 anchor row");
        assert!(s.contains("1.0x"), "the anchor's stall cut is 1x");
        assert!(s.contains("A100-80GB") && s.contains("Gaudi2"));
    }

    #[test]
    fn prefix_hit_table_renders() {
        let m = llama3_8b();
        let s = prefix_hit_table(&m, 64, 8, 512);
        assert!(s.contains("hit rate"));
        assert!(s.contains("speedup"));
        assert!(s.contains("1.00x"), "hit 0 row is the 1x anchor");
        assert!(s.contains("A100-80GB") && s.contains("Gaudi2"));
    }

    #[test]
    fn decode_table_renders() {
        let m = llama3_8b();
        let s = decode_table(&m, 64, 512, 512);
        assert!(s.contains("TTFT ms"));
        assert!(s.contains("LoRA decode tax"));
        assert!(s.contains("A100-80GB") && s.contains("Gaudi2"));
    }

    #[test]
    fn gaudi2_serves_faster() {
        let m = llama3_8b();
        let a = serve_throughput_req_per_s(
            &A100_80G, &m, ServePath::Merged, 64, 8, 512);
        let g = serve_throughput_req_per_s(
            &GAUDI2, &m, ServePath::Merged, 64, 8, 512);
        assert!(g > a);
    }

    #[test]
    fn comparison_table_renders() {
        let m = llama3_8b();
        let s = comparison_table(&m, 64, 512);
        assert!(s.contains("A100-80GB"));
        assert!(s.contains("Gaudi2"));
        assert!(s.contains("PaCA-merged"));
    }

    #[test]
    fn merged_serving_fits_more_sequences_and_longer_context() {
        // The paper's longer-sequence framing at serving time: with
        // zero resident adapter overhead, merged PaCA turns the bytes
        // unmerged LoRA pins into KV capacity — more concurrent
        // sequences at fixed context, longer context at fixed batch.
        let m = llama3_8b();
        for dev in [&A100_80G, &GAUDI2] {
            let ps = max_concurrent_seqs(dev, &m, ServePath::Merged,
                                         64, 4096);
            let ls = max_concurrent_seqs(dev, &m,
                                         ServePath::LoraAdapters, 64,
                                         4096);
            assert!(ps > ls, "{}: paca {ps} !> lora {ls} seqs",
                    dev.name);
            let pc = max_context_len(dev, &m, ServePath::Merged, 64,
                                     8);
            let lc = max_context_len(dev, &m, ServePath::LoraAdapters,
                                     64, 8);
            assert!(pc > lc, "{}: paca ctx {pc} !> lora {lc}",
                    dev.name);
            // The relative context gain at batch 8 is material (the
            // adapter set is ~6% of a rank-64 llama3-8b's KV at 4k).
            assert!(pc as f64 / lc as f64 > 1.02,
                    "{}: gain too small {pc}/{lc}", dev.name);
        }
    }

    #[test]
    fn per_seq_footprint_decomposes() {
        let m = llama3_8b();
        let kv_only = serve_bytes_per_seq(&m, ServePath::Merged, 64,
                                          4096);
        assert_eq!(kv_only, 4096.0 * m.kv_bytes_per_token() as f64,
                   "merged = pure KV (the shared arithmetic)");
        let with_adapter = serve_bytes_per_seq(
            &m, ServePath::LoraAdapters, 64, 4096);
        assert_eq!(with_adapter - kv_only, lora_adapter_bytes(&m, 64));
        assert!(lora_adapter_bytes(&m, 64) > 0.0);
        // Longer context ⇒ strictly larger footprint; the adapter tax
        // is context-independent.
        assert!(serve_bytes_per_seq(&m, ServePath::Merged, 64, 8192)
                > kv_only);
    }

    #[test]
    fn kv_capacity_table_renders() {
        let m = llama3_8b();
        let s = kv_capacity_table(&m, 64, 4096, 8);
        assert!(s.contains("max seqs"));
        assert!(s.contains("max context"));
        assert!(s.contains("paca-merged"));
        assert!(s.contains("lora-unmerged"));
        assert!(s.contains("A100-80GB") && s.contains("Gaudi2"));
        assert!(s.contains("% context"));
    }

    #[test]
    fn queueing_delay_grows_with_load_and_saturates() {
        let m = llama3_8b();
        let cap = 1.0 / service_time_per_req_s(
            &A100_80G, &m, ServePath::Merged, 64, 8, 512);
        let q = |frac: f64| queueing_delay_s(
            &A100_80G, &m, ServePath::Merged, 64, 8, 512, frac * cap);
        assert!(q(0.2) > 0.0);
        assert!(q(0.5) > q(0.2), "delay must grow with load");
        assert!(q(0.95) > 4.0 * q(0.5),
                "near saturation the queue blows up: {} vs {}",
                q(0.95), q(0.5));
        assert!(q(1.0).is_infinite(), "at saturation the queue \
                                       diverges");
        assert!(q(1.5).is_infinite());
        // Vanishing load ⇒ vanishing queueing.
        assert!(q(1e-6) < 1e-3 * q(0.5));
    }

    #[test]
    fn merged_path_queues_less_at_equal_load() {
        // Same offered load, shorter service time ⇒ lower utilization
        // ⇒ less queueing AND lower end-to-end latency — and the
        // unmerged path saturates while merged still has headroom.
        let m = llama3_8b();
        let cap_merged = 1.0 / service_time_per_req_s(
            &A100_80G, &m, ServePath::Merged, 64, 8, 512);
        let load = 0.7 * cap_merged;
        let qm = queueing_delay_s(&A100_80G, &m, ServePath::Merged,
                                  64, 8, 512, load);
        let ql = queueing_delay_s(&A100_80G, &m,
                                  ServePath::LoraAdapters, 64, 8, 512,
                                  load);
        assert!(qm.is_finite());
        assert!(ql > qm, "lora queue {ql} !> paca queue {qm}");
        assert!(total_latency_s(&A100_80G, &m, ServePath::Merged, 64,
                                8, 512, load)
                < total_latency_s(&A100_80G, &m,
                                  ServePath::LoraAdapters, 64, 8, 512,
                                  load));
        // 0.95·merged-capacity load saturates the slower lora path on
        // A100 (its service time is >5% longer at batch 8).
        let near = 0.95 * cap_merged;
        assert!(queueing_delay_s(&A100_80G, &m,
                                 ServePath::LoraAdapters, 64, 8, 512,
                                 near).is_infinite());
    }

    #[test]
    fn latency_table_renders_with_saturation() {
        let m = llama3_8b();
        let s = latency_table(&m, 64, 8, 512);
        assert!(s.contains("PaCA queue"));
        assert!(s.contains("saturated"),
                "the lora column must hit saturation at 95% of \
                 merged capacity");
    }

    #[test]
    fn replica_share_splits_the_queue() {
        // The router's whole value proposition in one inequality
        // chain: at the same cluster-wide offered load, a balanced
        // 1/N share queues less than a 2/N hot shard, which queues
        // less than one replica eating the entire stream.
        let m = llama3_8b();
        let cap = 1.0 / service_time_per_req_s(
            &A100_80G, &m, ServePath::Merged, 64, 8, 512);
        let load = 0.8 * cap;
        let q = |share| replica_queueing_delay_s(
            &A100_80G, &m, ServePath::Merged, 64, 8, 512, load,
            share);
        assert!(q(0.25) > 0.0);
        assert!(q(0.25) < q(0.5), "balanced {} !< hot {}",
                q(0.25), q(0.5));
        assert!(q(0.5) < q(1.0), "hot {} !< single {}",
                q(0.5), q(1.0));
        // share 1.0 IS the single-queue term — the reduction anchor.
        assert_eq!(q(1.0), queueing_delay_s(
            &A100_80G, &m, ServePath::Merged, 64, 8, 512, load));
        // Past one replica's capacity, only the split survives: the
        // single server saturates, the balanced 4-way split does not.
        let over = 1.2 * cap;
        assert!(replica_queueing_delay_s(
            &A100_80G, &m, ServePath::Merged, 64, 8, 512, over, 1.0)
            .is_infinite());
        assert!(replica_queueing_delay_s(
            &A100_80G, &m, ServePath::Merged, 64, 8, 512, over, 0.25)
            .is_finite());
    }

    #[test]
    fn cluster_queueing_table_renders() {
        let m = llama3_8b();
        let s = cluster_queueing_table(&m, 64, 8, 512, 4);
        assert!(s.contains("4 balanced"));
        assert!(s.contains("4 hot shard 2x"));
        assert!(s.contains("1 replica"));
        // The 320% row: one replica is saturated, the balanced
        // split is not — the table must show both regimes.
        assert!(s.contains("saturated"));
        assert!(s.contains("320%"));
        assert!(s.contains("A100-80GB") && s.contains("Gaudi2"));
    }
}
