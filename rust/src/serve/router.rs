//! Cluster ingress routing: which replica serves a request.
//!
//! PaCA makes every replica equally CAPABLE of serving every tenant —
//! adapters hot-splice into the shared frozen base in O(r·d_out) and
//! pin zero resident bytes, so there is no adapter-placement
//! constraint to solve. What replicas DO differ in is observable
//! load state: queue depth, free KV blocks, and radix-prefix warmth.
//! The [`Router`] picks replicas from exactly those three advertised
//! signals (a [`LoadSnapshot`] per replica, taken at the request's
//! arrival instant on the merged virtual clock), under one of three
//! policies:
//!
//!   * `shard` — pure tenant-shard hash affinity: FNV-1a of the
//!     tenant name modulo N. Deterministic, perfectly cache-warm per
//!     tenant, and blind to load — the round-robin-by-tenant
//!     baseline the bench's flash-crowd section beats.
//!   * `least-loaded` — global minimum queue depth (pending +
//!     in-flight), ties to the most free KV blocks, then lowest
//!     replica id. Maximal load spreading, warmth-blind.
//!   * `warmth` — follow the tenant's warm radix chain when any
//!     replica has one (argmax warm tokens); otherwise shard
//!     affinity, with an overflow spill to the least-loaded replica
//!     when the home shard is congested (depth at least twice the
//!     batch margin AND strictly above the cluster minimum — a
//!     loaded-but-balanced cluster does not spill).
//!
//! A dead home shard always re-routes to the least-loaded survivor
//! (the `failover` counter), under every policy.

use crate::serve::engine::LoadSnapshot;
use crate::util::rng::fnv1a;

/// Replica-selection policy for cluster ingress.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Tenant-shard hash affinity (FNV-1a(name) mod N).
    Shard,
    /// Minimum queue depth, ties to free KV blocks.
    LeastLoaded,
    /// Warm-chain affinity with shard fallback and overflow spill.
    Warmth,
}

impl RouterPolicy {
    pub const ALL: [RouterPolicy; 3] = [
        RouterPolicy::Shard,
        RouterPolicy::LeastLoaded,
        RouterPolicy::Warmth,
    ];

    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "shard" => Some(RouterPolicy::Shard),
            "least-loaded" => Some(RouterPolicy::LeastLoaded),
            "warmth" => Some(RouterPolicy::Warmth),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RouterPolicy::Shard => "shard",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::Warmth => "warmth",
        }
    }
}

/// Where routed requests went, by decision kind. One increment per
/// routed request; `failover` additionally counts each killed
/// replica's evacuated/re-routed requests at the cluster layer.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct RouterStats {
    /// Routed to the tenant's home shard.
    pub home: u64,
    /// Warmth policy followed a warm chain away from home.
    pub warm: u64,
    /// Least-loaded policy picked a non-home replica.
    pub steal: u64,
    /// Warmth policy spilled a congested home to least-loaded.
    pub spill: u64,
    /// Home shard dead at routing time — re-routed to a survivor.
    pub failover: u64,
}

/// The cluster's ingress router. Pure over its inputs: a decision is
/// a function of (tenant name, advertised loads) only, so identical
/// traces route identically — the property tests replay on this.
#[derive(Debug)]
pub struct Router {
    policy: RouterPolicy,
    /// Congestion margin for the warmth policy's spill rule — the
    /// cluster passes the per-replica batch size, so "congested"
    /// means two-plus full batches deep.
    margin: usize,
    pub stats: RouterStats,
}

/// Queue depth a replica advertises: everything admitted or seated.
fn depth(l: &LoadSnapshot) -> usize {
    l.pending + l.in_flight
}

impl Router {
    pub fn new(policy: RouterPolicy, margin: usize) -> Router {
        Router { policy, margin: margin.max(1),
                 stats: RouterStats::default() }
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// The tenant's home shard by name hash — stable across runs,
    /// replica counts permitting, and independent of tenant-id
    /// assignment order.
    pub fn home_shard(&self, tenant_name: &str, n: usize) -> usize {
        (fnv1a(tenant_name.as_bytes()) % n as u64) as usize
    }

    /// Least-loaded ALIVE replica: minimum queue depth, ties to the
    /// most free KV blocks, then lowest id. Panics if no replica is
    /// alive (the cluster never routes after the last kill — kills
    /// are rejected by validation when they would empty the
    /// cluster).
    pub fn least_loaded(loads: &[Option<LoadSnapshot>]) -> usize {
        loads.iter().enumerate()
            .filter_map(|(i, l)| l.as_ref().map(|l| (i, l)))
            .min_by_key(|(i, l)| {
                (depth(l), std::cmp::Reverse(l.free_blocks), *i)
            })
            .map(|(i, _)| i)
            .expect("route with no alive replica")
    }

    /// Pick a replica for a request of tenant `tenant_id` named
    /// `tenant_name`, given each replica's advertised load (`None` =
    /// dead). Increments exactly one stats counter per call.
    pub fn route(&mut self, tenant_name: &str, tenant_id: u32,
                 loads: &[Option<LoadSnapshot>]) -> usize {
        let home = self.home_shard(tenant_name, loads.len());
        let Some(home_load) = &loads[home] else {
            self.stats.failover += 1;
            return Self::least_loaded(loads);
        };
        match self.policy {
            RouterPolicy::Shard => {
                self.stats.home += 1;
                home
            }
            RouterPolicy::LeastLoaded => {
                let pick = Self::least_loaded(loads);
                if pick == home {
                    self.stats.home += 1;
                } else {
                    self.stats.steal += 1;
                }
                pick
            }
            RouterPolicy::Warmth => {
                // Follow the warmest radix chain for this tenant —
                // highest advertised warm tokens, ties to lowest id.
                let (best_w, best_i) = loads.iter().enumerate()
                    .filter_map(|(i, l)| l.as_ref().map(|l| (i, l)))
                    .map(|(i, l)| {
                        let w = l.warm_tokens
                            .get(tenant_id as usize)
                            .copied().unwrap_or(0);
                        (w, i)
                    })
                    .max_by_key(|&(w, i)| (w, std::cmp::Reverse(i)))
                    .expect("route with no alive replica");
                if best_w > 0 {
                    if best_i == home {
                        self.stats.home += 1;
                    } else {
                        self.stats.warm += 1;
                    }
                    return best_i;
                }
                // No warm chain anywhere: shard affinity, unless the
                // home is congested — then overflow-spill to the
                // least-loaded replica (this is what the flash-crowd
                // bench measures).
                let home_depth = depth(home_load);
                let min_depth = loads.iter().flatten()
                    .map(depth).min().unwrap_or(0);
                if home_depth >= 2 * self.margin
                    && home_depth > min_depth
                {
                    self.stats.spill += 1;
                    Self::least_loaded(loads)
                } else {
                    self.stats.home += 1;
                    home
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(pending: usize, in_flight: usize, free: usize,
            warm: &[usize]) -> Option<LoadSnapshot> {
        Some(LoadSnapshot { pending, in_flight, free_blocks: free,
                            warm_tokens: warm.to_vec() })
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in RouterPolicy::ALL {
            assert_eq!(RouterPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(RouterPolicy::parse("nope"), None);
    }

    #[test]
    fn shard_is_pure_name_hash() {
        let mut r = Router::new(RouterPolicy::Shard, 8);
        let loads = vec![load(9, 9, 0, &[]), load(0, 0, 64, &[])];
        let home = r.home_shard("tenant-a", 2);
        // Load-blind: the congested home still wins.
        assert_eq!(r.route("tenant-a", 0, &loads), home);
        assert_eq!(r.route("tenant-a", 0, &loads), home);
        assert_eq!(r.stats.home, 2);
        assert_eq!(r.stats.steal + r.stats.spill + r.stats.warm, 0);
    }

    #[test]
    fn least_loaded_breaks_ties_on_free_blocks_then_id() {
        let mut r = Router::new(RouterPolicy::LeastLoaded, 8);
        // Equal depth: more free KV blocks wins.
        let loads = vec![load(1, 1, 4, &[]), load(2, 0, 16, &[]),
                         load(2, 1, 64, &[])];
        assert_eq!(r.route("t", 0, &loads), 1);
        // Fully equal: lowest id wins.
        let loads = vec![load(1, 0, 8, &[]), load(1, 0, 8, &[])];
        assert_eq!(r.route("t", 0, &loads), 0);
    }

    #[test]
    fn warmth_follows_the_warm_chain() {
        let mut r = Router::new(RouterPolicy::Warmth, 8);
        let home = r.home_shard("t0", 3);
        // Replica 2 holds t0's warm prefix: it wins regardless of
        // shard affinity or load.
        let loads = vec![load(5, 5, 0, &[0, 64]),
                         load(0, 0, 64, &[0, 0]),
                         load(3, 3, 8, &[48, 0])];
        assert_eq!(r.route("t0", 0, &loads), 2);
        if home == 2 {
            assert_eq!(r.stats.home, 1);
        } else {
            assert_eq!(r.stats.warm, 1);
        }
        // Tenant 1's warmth lives on replica 0.
        assert_eq!(r.route("t1", 1, &loads), 0);
    }

    #[test]
    fn warmth_cold_spills_only_congested_unbalanced_home() {
        let mut r = Router::new(RouterPolicy::Warmth, 2);
        let n = 2;
        let home = r.home_shard("t0", n);
        let other = 1 - home;
        // Cold everywhere, home shallow: stays home.
        let mut loads = vec![load(0, 0, 64, &[0]), load(0, 0, 64, &[0])];
        assert_eq!(r.route("t0", 0, &loads), home);
        assert_eq!(r.stats.spill, 0);
        // Home at 2×margin with an emptier peer: spills least-loaded.
        loads[home] = load(3, 1, 64, &[0]);
        assert_eq!(r.route("t0", 0, &loads), other);
        assert_eq!(r.stats.spill, 1);
        // Equally deep everywhere: congested but balanced, no spill.
        loads[other] = load(2, 2, 64, &[0]);
        assert_eq!(r.route("t0", 0, &loads), home);
        assert_eq!(r.stats.spill, 1);
    }

    #[test]
    fn dead_home_fails_over_to_least_loaded_survivor() {
        for policy in RouterPolicy::ALL {
            let mut r = Router::new(policy, 8);
            let home = r.home_shard("t0", 2);
            let mut loads = vec![load(0, 0, 64, &[0]),
                                 load(0, 0, 64, &[0])];
            loads[home] = None;
            assert_eq!(r.route("t0", 0, &loads), 1 - home,
                       "{}", policy.name());
            assert_eq!(r.stats.failover, 1, "{}", policy.name());
        }
    }
}
