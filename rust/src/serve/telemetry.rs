//! Live telemetry for the serving stack: the consumers that turn the
//! event bus into something you can watch DURING a run instead of
//! only after it drains.
//!
//! Four pieces, all riding the existing [`Events`] handle so they are
//! zero-cost when off and provably inert when on (the reduction
//! anchors compare scrubbed engine stats bit for bit):
//!
//!   * [`JsonlStreamSink`] — the buffered-JSONL [`EventSink`] impl: a
//!     bounded ring that flushes to disk every time it fills, so the
//!     `--trace-events` file grows incrementally instead of being
//!     written at export. The in-memory impl is the recorder's
//!     bounded ring ([`Events::bound_recorder`]); whatever the bound
//!     drops there is counted, never silent.
//!   * [`MetricsRegistry`] / [`MetricsFeeder`] — counters, gauges and
//!     log-bucketed histograms with `tenant`/`replica`/`policy`
//!     labels, fed purely from the event stream (zero new emission
//!     sites in engine code) and scraped to Prometheus text every
//!     `--metrics-interval` virtual seconds.
//!   * [`StepProfiler`] — per-phase decomposition of the engine step
//!     loop (admission / dispatch / prefill / decode / kv-grow /
//!     prefix / router) with paired begin/end stamps: virtual-clock
//!     attribution always, wall-clock dual stamps under
//!     `--clock measured`. Phase times partition each step's service
//!     time exactly — no unattributed remainder — and export as a
//!     report table plus folded stacks for flamegraph tooling.
//!   * [`SloBurnTracker`] — per-tenant rolling deadline-miss budget
//!     fed by `SloBurn` events, making the slo-aware scheduler's
//!     rescue behaviour observable rather than inferred.
//!
//! [`Events`]: crate::serve::events::Events
//! [`Events::bound_recorder`]: crate::serve::events::Events::bound_recorder

use std::collections::{BTreeMap, VecDeque};
use std::io::Write;
use std::path::Path;

use crate::metrics::{nearest_rank_index, Table};
use crate::serve::events::{EngineEvent, EventKind, EventSink};
use crate::util::json::Json;

// ------------------------------------------------------------- output

/// Where a telemetry sink writes: a buffered file in production, an
/// in-memory byte vector under test. I/O errors cannot surface as
/// `Result` from inside event dispatch, so the first one latches on
/// the owning sink and the CLI checks it after the run.
#[derive(Debug)]
pub enum TelemetryOut {
    File(std::io::BufWriter<std::fs::File>),
    Mem(Vec<u8>),
}

impl TelemetryOut {
    pub fn create(path: &Path) -> std::io::Result<TelemetryOut> {
        Ok(TelemetryOut::File(std::io::BufWriter::new(
            std::fs::File::create(path)?)))
    }

    pub fn memory() -> TelemetryOut {
        TelemetryOut::Mem(Vec::new())
    }

    /// Write + flush through to the OS, so readers see the bytes
    /// while the run is still going.
    pub(crate) fn put(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        match self {
            TelemetryOut::File(w) => {
                w.write_all(bytes)?;
                w.flush()
            }
            TelemetryOut::Mem(v) => {
                v.extend_from_slice(bytes);
                Ok(())
            }
        }
    }

    /// In-memory bytes (None for the file flavour) — test hook.
    pub fn mem(&self) -> Option<&[u8]> {
        match self {
            TelemetryOut::Mem(v) => Some(v),
            TelemetryOut::File(_) => None,
        }
    }
}

// -------------------------------------------------------- stream sink

/// The buffered-JSONL [`EventSink`]: events land in a bounded ring
/// and the ring flushes to the output every time it reaches its
/// bound (and once more at finalize), so the trace file is non-empty
/// long before the run finishes. Nothing is ever dropped here — the
/// ring is a flush granularity, not a loss bound; the lossy bound
/// lives on the in-memory recorder, where drops are explicitly
/// counted.
#[derive(Debug)]
pub struct JsonlStreamSink {
    out: TelemetryOut,
    ring: Vec<EngineEvent>,
    cap: usize,
    written: u64,
    flushes: u64,
    error: Option<String>,
}

impl JsonlStreamSink {
    pub fn new(out: TelemetryOut, cap: usize) -> JsonlStreamSink {
        JsonlStreamSink {
            out,
            ring: Vec::with_capacity(cap.max(1)),
            cap: cap.max(1),
            written: 0,
            flushes: 0,
            error: None,
        }
    }

    pub fn create(path: &Path, cap: usize)
                  -> std::io::Result<JsonlStreamSink> {
        Ok(JsonlStreamSink::new(TelemetryOut::create(path)?, cap))
    }

    /// Lines flushed to the output so far.
    pub fn written(&self) -> u64 {
        self.written
    }

    /// Ring flushes performed so far.
    pub fn flushes(&self) -> u64 {
        self.flushes
    }

    pub fn error(&self) -> Option<String> {
        self.error.clone()
    }

    /// In-memory output bytes — test hook.
    pub fn mem(&self) -> Option<&[u8]> {
        self.out.mem()
    }

    fn flush_ring(&mut self) {
        if self.ring.is_empty() {
            return;
        }
        let mut body = String::new();
        for ev in &self.ring {
            body.push_str(&ev.to_json().to_string());
            body.push('\n');
        }
        let n = self.ring.len() as u64;
        self.ring.clear();
        self.flushes += 1;
        if let Err(e) = self.out.put(body.as_bytes()) {
            if self.error.is_none() {
                self.error = Some(e.to_string());
            }
            return;
        }
        self.written += n;
    }
}

impl EventSink for JsonlStreamSink {
    fn on_event(&mut self, ev: &EngineEvent) {
        self.ring.push(*ev);
        if self.ring.len() >= self.cap {
            self.flush_ring();
        }
    }

    fn finalize(&mut self) {
        self.flush_ring();
    }
}

// ---------------------------------------------------------- histogram

/// Doubling log-bucket edges: bucket 0 is everything at or below
/// [`HIST_LOWEST`] (including 0), bucket `i` (1 ≤ i ≤ [`HIST_TOP`])
/// covers `(LOWEST·2^(i−1), LOWEST·2^i]`, and the final bucket is the
/// `+Inf` overflow.
pub const HIST_LOWEST: f64 = 1e-6;
pub const HIST_TOP: usize = 40;
pub const HIST_BUCKETS: usize = HIST_TOP + 2;

/// Upper edge of bucket `i` (`+Inf` for the overflow bucket).
pub fn bucket_le(i: usize) -> f64 {
    if i > HIST_TOP {
        return f64::INFINITY;
    }
    HIST_LOWEST * (i as f64).exp2()
}

/// Bucket index for a sample. Non-positive samples (and anything not
/// above the lowest edge) land in bucket 0; anything above the
/// largest finite edge lands in the overflow bucket.
pub fn bucket_index(v: f64) -> usize {
    if !(v > HIST_LOWEST) {
        return 0;
    }
    let mut edge = HIST_LOWEST;
    for i in 0..=HIST_TOP {
        if v <= edge {
            return i;
        }
        edge *= 2.0;
    }
    HIST_BUCKETS - 1
}

/// A log-bucketed histogram that also remembers each bucket's MAX
/// sample as its representative. The bucket walk reuses the
/// recorders' shared [`nearest_rank_index`] rule, so whenever every
/// occupied bucket holds one distinct sample the histogram's
/// percentiles agree with `LatencyRecorder` **bitwise** — the unit
/// suite pins that down.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    counts: Vec<u64>,
    reps: Vec<f64>,
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            counts: vec![0; HIST_BUCKETS],
            reps: vec![0.0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Histogram {
    pub fn observe(&mut self, v: f64) {
        if !v.is_finite() {
            debug_assert!(false, "non-finite histogram sample {v}");
            return;
        }
        let i = bucket_index(v);
        self.counts[i] += 1;
        if self.counts[i] == 1 || v > self.reps[i] {
            self.reps[i] = v;
        }
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Merge: counts add, representatives take the max, extrema
    /// combine — associative and commutative, so replica registries
    /// merge in any order to the same result.
    pub fn merge(&mut self, other: &Histogram) {
        for i in 0..HIST_BUCKETS {
            if other.counts[i] == 0 {
                continue;
            }
            if self.counts[i] == 0 || other.reps[i] > self.reps[i] {
                self.reps[i] = other.reps[i];
            }
            self.counts[i] += other.counts[i];
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn bucket_count(&self, i: usize) -> u64 {
        self.counts[i]
    }

    /// Nearest-rank percentile via the bucket walk: find the bucket
    /// holding the target order statistic, return its representative.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let target = nearest_rank_index(self.count as usize, q);
        let mut cum = 0usize;
        for i in 0..HIST_BUCKETS {
            cum += self.counts[i] as usize;
            if cum > target {
                return Some(self.reps[i]);
            }
        }
        Some(self.reps[HIST_BUCKETS - 1])
    }

    pub fn mean(&self) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        Some(self.sum / self.count as f64)
    }
}

// ----------------------------------------------------------- registry

/// One metric series: a name plus its series-specific labels (base
/// labels like `policy`/`replica` are stamped by the registry at
/// render time). Labels are kept sorted so equal label sets compare
/// equal.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Series {
    pub name: String,
    pub labels: Vec<(String, String)>,
}

impl Series {
    fn new(name: &str, labels: &[(&str, &str)]) -> Series {
        let mut labels: Vec<(String, String)> = labels.iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        labels.sort();
        Series { name: name.to_string(), labels }
    }
}

/// Prometheus label-value escaping (`\`, `"`, newline).
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"")
        .replace('\n', "\\n")
}

/// Deterministic sample-value formatting: integers render without a
/// fraction, everything else through Rust's shortest round-trip
/// float display. Never NaN — observe paths reject non-finite
/// samples.
fn fmt_value(v: f64) -> String {
    if v == v.trunc() && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// The metrics registry: counters, gauges and [`Histogram`]s keyed by
/// [`Series`], with registry-wide base labels (`policy="..."`,
/// `replica="..."`) stamped onto every rendered line. Merging two
/// registries is a plain union — cluster mode gives each replica's
/// registry a distinct `replica` base label, so merged series never
/// collide and the merge is associative.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    base: Vec<(String, String)>,
    counters: BTreeMap<Series, f64>,
    gauges: BTreeMap<Series, f64>,
    hists: BTreeMap<Series, Histogram>,
}

impl MetricsRegistry {
    pub fn with_base(labels: &[(&str, &str)]) -> MetricsRegistry {
        let mut base: Vec<(String, String)> = labels.iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        base.sort();
        MetricsRegistry { base, ..Default::default() }
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty()
            && self.hists.is_empty()
    }

    pub fn inc(&mut self, name: &str, labels: &[(&str, &str)],
               v: f64) {
        debug_assert!(v >= 0.0, "counters only go up");
        *self.counters.entry(Series::new(name, labels))
            .or_insert(0.0) += v;
    }

    pub fn set_gauge(&mut self, name: &str, labels: &[(&str, &str)],
                     v: f64) {
        self.gauges.insert(Series::new(name, labels), v);
    }

    pub fn observe(&mut self, name: &str, labels: &[(&str, &str)],
                   v: f64) {
        self.hists.entry(Series::new(name, labels))
            .or_default().observe(v);
    }

    pub fn counter(&self, name: &str, labels: &[(&str, &str)])
                   -> f64 {
        self.counters.get(&Series::new(name, labels)).copied()
            .unwrap_or(0.0)
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)])
                 -> Option<f64> {
        self.gauges.get(&Series::new(name, labels)).copied()
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)])
                     -> Option<&Histogram> {
        self.hists.get(&Series::new(name, labels))
    }

    /// Union-merge `other` into `self`: counters and gauges add,
    /// histograms [`Histogram::merge`]. Series are compared on their
    /// FULL label set including base labels, so replica-labeled
    /// registries union without collisions.
    pub fn merge(&mut self, other: &MetricsRegistry) {
        let relabel = |s: &Series, base: &[(String, String)]| {
            let mut labels = s.labels.clone();
            for (k, v) in base {
                if !labels.iter().any(|(lk, _)| lk == k) {
                    labels.push((k.clone(), v.clone()));
                }
            }
            labels.sort();
            Series { name: s.name.clone(), labels }
        };
        // Fold the two base-label sets into the series themselves;
        // the merged registry keeps only the base labels common to
        // both sides.
        let self_base = self.base.clone();
        let common: Vec<(String, String)> = self_base.iter()
            .filter(|kv| other.base.contains(kv)).cloned().collect();
        if self.base != common {
            let fold: Vec<(String, String)> = self_base.iter()
                .filter(|kv| !common.contains(kv)).cloned().collect();
            self.counters = std::mem::take(&mut self.counters)
                .into_iter()
                .map(|(s, v)| (relabel(&s, &fold), v)).collect();
            self.gauges = std::mem::take(&mut self.gauges)
                .into_iter()
                .map(|(s, v)| (relabel(&s, &fold), v)).collect();
            self.hists = std::mem::take(&mut self.hists)
                .into_iter()
                .map(|(s, v)| (relabel(&s, &fold), v)).collect();
            self.base = common.clone();
        }
        let fold: Vec<(String, String)> = other.base.iter()
            .filter(|kv| !common.contains(kv)).cloned().collect();
        for (s, v) in &other.counters {
            *self.counters.entry(relabel(s, &fold)).or_insert(0.0)
                += v;
        }
        for (s, v) in &other.gauges {
            *self.gauges.entry(relabel(s, &fold)).or_insert(0.0) += v;
        }
        for (s, h) in &other.hists {
            self.hists.entry(relabel(s, &fold)).or_default().merge(h);
        }
    }

    fn label_str(&self, extra: &[(String, String)]) -> String {
        let mut all: Vec<(&str, &str)> = self.base.iter()
            .chain(extra.iter())
            .map(|(k, v)| (k.as_str(), v.as_str()))
            .collect();
        all.sort();
        if all.is_empty() {
            return String::new();
        }
        let body: Vec<String> = all.iter()
            .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
            .collect();
        format!("{{{}}}", body.join(","))
    }

    fn label_str_le(&self, extra: &[(String, String)], le: f64)
                    -> String {
        let le = if le.is_finite() {
            format!("{le}")
        } else {
            "+Inf".to_string()
        };
        let mut extra = extra.to_vec();
        extra.push(("le".to_string(), le));
        self.label_str(&extra)
    }

    /// Render one Prometheus-text scrape: `# TYPE` headers, one
    /// sample line per series, histograms as cumulative `_bucket`
    /// lines (occupied buckets plus `+Inf`) with `_sum`/`_count`.
    /// An empty registry renders to an empty string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut last_name = String::new();
        for (s, v) in &self.counters {
            if s.name != last_name {
                out.push_str(&format!("# TYPE {} counter\n", s.name));
                last_name = s.name.clone();
            }
            out.push_str(&format!("{}{} {}\n", s.name,
                                  self.label_str(&s.labels),
                                  fmt_value(*v)));
        }
        last_name.clear();
        for (s, v) in &self.gauges {
            if s.name != last_name {
                out.push_str(&format!("# TYPE {} gauge\n", s.name));
                last_name = s.name.clone();
            }
            out.push_str(&format!("{}{} {}\n", s.name,
                                  self.label_str(&s.labels),
                                  fmt_value(*v)));
        }
        last_name.clear();
        for (s, h) in &self.hists {
            if s.name != last_name {
                out.push_str(&format!("# TYPE {} histogram\n",
                                      s.name));
                last_name = s.name.clone();
            }
            let mut cum = 0u64;
            for i in 0..HIST_BUCKETS - 1 {
                if h.bucket_count(i) == 0 {
                    continue;
                }
                cum += h.bucket_count(i);
                out.push_str(&format!(
                    "{}_bucket{} {}\n", s.name,
                    self.label_str_le(&s.labels, bucket_le(i)), cum));
            }
            out.push_str(&format!(
                "{}_bucket{} {}\n", s.name,
                self.label_str_le(&s.labels, f64::INFINITY),
                h.count));
            out.push_str(&format!("{}_sum{} {}\n", s.name,
                                  self.label_str(&s.labels),
                                  fmt_value(h.sum)));
            out.push_str(&format!("{}_count{} {}\n", s.name,
                                  self.label_str(&s.labels),
                                  h.count));
        }
        out
    }

    /// JSON snapshot for the report's `metrics` section: counters and
    /// gauges keyed by their rendered series signature, histograms
    /// summarized as count/sum/p50/p99.
    pub fn snapshot_json(&self) -> Json {
        let mut root = BTreeMap::new();
        let mut counters = BTreeMap::new();
        for (s, v) in &self.counters {
            counters.insert(format!("{}{}", s.name,
                                    self.label_str(&s.labels)),
                            Json::Num(*v));
        }
        root.insert("counters".to_string(), Json::Obj(counters));
        let mut gauges = BTreeMap::new();
        for (s, v) in &self.gauges {
            gauges.insert(format!("{}{}", s.name,
                                  self.label_str(&s.labels)),
                          Json::Num(*v));
        }
        root.insert("gauges".to_string(), Json::Obj(gauges));
        let mut hists = BTreeMap::new();
        for (s, h) in &self.hists {
            let mut m = BTreeMap::new();
            m.insert("count".to_string(), Json::Num(h.count as f64));
            m.insert("sum".to_string(), Json::Num(h.sum));
            if let Some(p) = h.percentile(0.50) {
                m.insert("p50".to_string(), Json::Num(p));
            }
            if let Some(p) = h.percentile(0.99) {
                m.insert("p99".to_string(), Json::Num(p));
            }
            hists.insert(format!("{}{}", s.name,
                                 self.label_str(&s.labels)),
                         Json::Obj(m));
        }
        root.insert("histograms".to_string(), Json::Obj(hists));
        Json::Obj(root)
    }
}

// ------------------------------------------------------------- feeder

/// The event-fed registry driver: consumes the bus stream, maintains
/// a [`MetricsRegistry`], and appends a Prometheus-text scrape block
/// to its output every time the virtual clock crosses an interval
/// boundary (collapsing multi-interval idle jumps to one scrape).
/// Engine code gains zero new emission sites — everything derives
/// from events that already exist.
#[derive(Debug)]
pub struct MetricsFeeder {
    reg: MetricsRegistry,
    tenants: Vec<String>,
    interval_s: f64,
    now: f64,
    next_scrape_s: f64,
    scrapes: u64,
    out: Option<TelemetryOut>,
    error: Option<String>,
    /// request id → arrival time, for TTFT / e2e histograms.
    arrivals: BTreeMap<u64, f64>,
}

impl MetricsFeeder {
    /// `out = None` accumulates the registry without writing scrapes
    /// (cluster mode: the cluster scrapes the MERGED registries on
    /// the shared clock).
    pub fn new(base: &[(&str, &str)], tenants: &[String],
               interval_s: f64, out: Option<TelemetryOut>)
               -> MetricsFeeder {
        assert!(interval_s > 0.0, "metrics interval must be positive");
        MetricsFeeder {
            reg: MetricsRegistry::with_base(base),
            tenants: tenants.to_vec(),
            interval_s,
            now: 0.0,
            next_scrape_s: interval_s,
            scrapes: 0,
            out,
            error: None,
            arrivals: BTreeMap::new(),
        }
    }

    pub fn registry(&self) -> &MetricsRegistry {
        &self.reg
    }

    pub fn scrapes(&self) -> u64 {
        self.scrapes
    }

    pub fn error(&self) -> Option<String> {
        self.error.clone()
    }

    /// In-memory output bytes — test hook.
    pub fn mem(&self) -> Option<&[u8]> {
        self.out.as_ref().and_then(TelemetryOut::mem)
    }

    fn tenant_label(&self, t: Option<u32>) -> Option<String> {
        let t = t?;
        Some(self.tenants.get(t as usize).cloned()
             .unwrap_or_else(|| format!("t{t}")))
    }

    fn scrape(&mut self, t_s: f64) {
        let Some(out) = &mut self.out else { return };
        self.scrapes += 1;
        let body = format!("# scrape {} t_s {t_s:.6}\n{}\n",
                           self.scrapes, self.reg.render());
        if let Err(e) = out.put(body.as_bytes()) {
            if self.error.is_none() {
                self.error = Some(e.to_string());
            }
        }
    }

    fn advance(&mut self, t_s: f64) {
        self.now = self.now.max(t_s);
        if self.now >= self.next_scrape_s {
            let at = self.next_scrape_s;
            self.scrape(at);
            // Collapse multi-interval jumps: one scrape per crossing.
            let k = (self.now / self.interval_s).floor() + 1.0;
            self.next_scrape_s = k * self.interval_s;
        }
    }
}

impl EventSink for MetricsFeeder {
    fn on_event(&mut self, ev: &EngineEvent) {
        use EventKind::*;
        // Scrape boundaries ride the running-max clock (Arrival is
        // allowed to point backwards and never advances it).
        if ev.kind != Arrival {
            self.advance(ev.t_s);
        }
        let tenant = self.tenant_label(ev.tenant);
        let tl: Vec<(&str, &str)> = match &tenant {
            Some(name) => vec![("tenant", name.as_str())],
            None => Vec::new(),
        };
        self.reg.inc("paca_events_total",
                     &[("kind", ev.kind.name())], 1.0);
        match ev.kind {
            Arrival => {
                if let Some(id) = ev.request {
                    self.arrivals.insert(id, ev.t_s);
                }
                self.reg.inc("paca_requests_arrived_total", &tl, 1.0);
            }
            Complete => {
                self.reg.inc("paca_requests_completed_total", &tl,
                             1.0);
                if let Some(t0) = ev.request
                    .and_then(|id| self.arrivals.remove(&id))
                {
                    self.reg.observe("paca_e2e_seconds", &tl,
                                     (ev.t_s - t0).max(0.0));
                }
            }
            PrefillEnd if ev.a == 1 => {
                if let Some(t0) = ev.request
                    .and_then(|id| self.arrivals.get(&id).copied())
                {
                    self.reg.observe("paca_ttft_seconds", &tl,
                                     (ev.t_s - t0).max(0.0));
                }
            }
            DecodeStep => {
                self.reg.inc("paca_tokens_decoded_total", &tl, 1.0);
            }
            Preempt => {
                // a = 1 under memory pressure, 0 for a deadline
                // rescue (events.rs kind doc).
                let cause = if ev.a == 1 { "memory" } else {
                    "rescue"
                };
                self.reg.inc("paca_preemptions_total",
                             &[("cause", cause)], 1.0);
            }
            PrefixHit => {
                self.reg.inc("paca_prefix_hit_tokens_total", &tl,
                             ev.a as f64);
            }
            KvAlloc | KvFree => {
                self.reg.set_gauge("paca_kv_used_blocks", &[],
                                   ev.b as f64);
            }
            Overflow => {
                self.reg.inc("paca_kv_overflow_tokens_total", &[],
                             ev.a as f64);
            }
            SpliceIn => {
                self.reg.inc("paca_adapter_splices_total", &tl, 1.0);
            }
            SloBurn => {
                self.reg.inc("paca_slo_completions_total", &tl, 1.0);
                if ev.a == 1 {
                    self.reg.inc("paca_slo_misses_total", &tl, 1.0);
                }
            }
            _ => {}
        }
    }

    /// Closing scrape: whatever the final registry says, stamped at
    /// the last clock the feeder saw.
    fn finalize(&mut self) {
        let at = self.now;
        self.scrape(at);
    }
}

// ---------------------------------------------------------- slo burn

/// Rolling deadline-miss window per tenant (last [`SLO_WINDOW`]
/// deadlined completions).
pub const SLO_WINDOW: usize = 32;

#[derive(Debug, Default)]
struct SloTenantState {
    total: u64,
    missed: u64,
    max_lateness_us: u64,
    window: VecDeque<bool>,
}

/// One tenant's burn row for the report.
#[derive(Debug, Clone, PartialEq)]
pub struct SloTenant {
    pub tenant: u32,
    /// Deadlined completions settled, all-time.
    pub total: u64,
    /// Deadline misses, all-time.
    pub missed: u64,
    /// Size of the rolling window currently held (≤ [`SLO_WINDOW`]).
    pub window_len: usize,
    /// Misses inside the rolling window.
    pub window_missed: usize,
    /// Worst lateness seen, µs.
    pub max_lateness_us: u64,
}

impl SloTenant {
    /// Fraction of the rolling window burned (0 when empty).
    pub fn burn_rate(&self) -> f64 {
        if self.window_len == 0 {
            return 0.0;
        }
        self.window_missed as f64 / self.window_len as f64
    }
}

/// Always-on bus sink: folds `SloBurn` events into per-tenant rolling
/// budgets. Costs one kind check per event when no deadlines exist.
#[derive(Debug, Default)]
pub struct SloBurnTracker {
    tenants: BTreeMap<u32, SloTenantState>,
}

impl SloBurnTracker {
    pub fn summary(&self) -> Vec<SloTenant> {
        self.tenants.iter()
            .map(|(t, s)| SloTenant {
                tenant: *t,
                total: s.total,
                missed: s.missed,
                window_len: s.window.len(),
                window_missed: s.window.iter()
                    .filter(|m| **m).count(),
                max_lateness_us: s.max_lateness_us,
            })
            .collect()
    }
}

impl EventSink for SloBurnTracker {
    fn on_event(&mut self, ev: &EngineEvent) {
        if ev.kind != EventKind::SloBurn {
            return;
        }
        let s = self.tenants.entry(ev.tenant.unwrap_or(u32::MAX))
            .or_default();
        let missed = ev.a == 1;
        s.total += 1;
        if missed {
            s.missed += 1;
            s.max_lateness_us = s.max_lateness_us.max(ev.b);
        }
        s.window.push_back(missed);
        if s.window.len() > SLO_WINDOW {
            s.window.pop_front();
        }
    }
}

// ----------------------------------------------------------- profiler

/// The engine step loop's phases. `Router` is cluster-scoped (the
/// routing decision at arrival delivery); everything else is one
/// engine step's anatomy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Admission,
    Dispatch,
    Prefill,
    Decode,
    KvGrow,
    Prefix,
    Router,
}

impl Phase {
    pub const ALL: [Phase; 7] = [
        Phase::Admission, Phase::Dispatch, Phase::Prefill,
        Phase::Decode, Phase::KvGrow, Phase::Prefix, Phase::Router,
    ];
    pub const COUNT: usize = Self::ALL.len();

    pub fn name(self) -> &'static str {
        match self {
            Phase::Admission => "admission",
            Phase::Dispatch => "dispatch",
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
            Phase::KvGrow => "kv_grow",
            Phase::Prefix => "prefix",
            Phase::Router => "router",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

/// Accumulated per-phase time: virtual attribution always, wall time
/// only when dual stamps are armed.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseAgg {
    pub virtual_s: f64,
    pub wall_s: f64,
    pub count: u64,
}

/// Per-phase decomposition of the step loop. Virtual attribution
/// partitions each step's service time exactly (the analytic clock's
/// `batch_s + token_s·tokens + swap_s·swapped` terms map one-to-one
/// onto dispatch/prefill/decode), so `Σ phase.virtual_s` equals
/// `step_virtual_s` to f64 tolerance — the no-unattributed-time
/// property. Wall-clock dual stamps (`wall = true`, armed under
/// `--clock measured`) wrap the same begin/end pairs with `Instant`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StepProfiler {
    agg: [PhaseAgg; Phase::COUNT],
    /// Σ of the step service times attributed so far (the
    /// reconciliation total for the partition property).
    pub step_virtual_s: f64,
    pub steps: u64,
    /// Arm wall-clock dual stamps.
    pub wall: bool,
}

impl StepProfiler {
    pub fn new(wall: bool) -> StepProfiler {
        StepProfiler { wall, ..Default::default() }
    }

    /// Begin stamp of a begin/end pair: `Some(Instant)` only when
    /// wall stamps are armed, so analytic-clock runs never touch the
    /// OS clock.
    pub fn begin(&self) -> Option<std::time::Instant> {
        if self.wall {
            Some(std::time::Instant::now())
        } else {
            None
        }
    }

    /// End stamp: attribute `virtual_s` to `phase`, plus the wall
    /// time since `begin` when armed.
    pub fn end(&mut self, phase: Phase,
               begin: Option<std::time::Instant>, virtual_s: f64) {
        let wall_s = begin.map_or(0.0,
                                  |t| t.elapsed().as_secs_f64());
        self.add(phase, virtual_s, wall_s);
    }

    /// Direct attribution for phases whose wall time is already
    /// measured elsewhere (the forward step measures its own wall
    /// time regardless of profiling — no second stamp needed).
    pub fn add(&mut self, phase: Phase, virtual_s: f64, wall_s: f64) {
        let a = &mut self.agg[phase.index()];
        a.virtual_s += virtual_s;
        if self.wall {
            a.wall_s += wall_s;
        }
        a.count += 1;
    }

    /// Account one completed step's total service time (what the
    /// phase attributions of that step must sum to).
    pub fn add_step(&mut self, step_s: f64) {
        self.step_virtual_s += step_s;
        self.steps += 1;
    }

    pub fn phase(&self, p: Phase) -> PhaseAgg {
        self.agg[p.index()]
    }

    /// Σ over phases of attributed virtual time.
    pub fn total_virtual(&self) -> f64 {
        self.agg.iter().map(|a| a.virtual_s).sum()
    }

    /// Merge another profiler (cluster: engine profilers + the
    /// router-phase profiler fold into one table).
    pub fn merge(&mut self, other: &StepProfiler) {
        for i in 0..Phase::COUNT {
            self.agg[i].virtual_s += other.agg[i].virtual_s;
            self.agg[i].wall_s += other.agg[i].wall_s;
            self.agg[i].count += other.agg[i].count;
        }
        self.step_virtual_s += other.step_virtual_s;
        self.steps += other.steps;
        self.wall |= other.wall;
    }

    /// The report's profile table.
    pub fn table(&self) -> Table {
        let mut t = if self.wall {
            Table::new(&["phase", "count", "virtual s", "share",
                         "wall ms"])
        } else {
            Table::new(&["phase", "count", "virtual s", "share"])
        };
        let total = self.total_virtual().max(f64::MIN_POSITIVE);
        for p in Phase::ALL {
            let a = self.phase(p);
            if a.count == 0 {
                continue;
            }
            let mut row = vec![
                p.name().to_string(),
                format!("{}", a.count),
                format!("{:.6}", a.virtual_s),
                format!("{:.1}%", 100.0 * a.virtual_s / total),
            ];
            if self.wall {
                row.push(format!("{:.3}", a.wall_s * 1e3));
            }
            t.row(&row);
        }
        t
    }

    /// Folded-stacks export (`stack;frames value` lines, values in
    /// whole µs of virtual time) — `flamegraph.pl` and speedscope
    /// ingest this directly. With wall stamps armed a parallel
    /// `paca_serve_wall` root carries the measured times.
    pub fn folded(&self) -> String {
        let mut out = String::new();
        for p in Phase::ALL {
            out.push_str(&format!(
                "paca_serve;step;{} {}\n", p.name(),
                (self.phase(p).virtual_s * 1e6).round() as u64));
        }
        if self.wall {
            for p in Phase::ALL {
                out.push_str(&format!(
                    "paca_serve_wall;step;{} {}\n", p.name(),
                    (self.phase(p).wall_s * 1e6).round() as u64));
            }
        }
        out
    }

    /// Profiler totals for the report's `metrics` JSON section.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("steps".to_string(),
                    Json::Num(self.steps as f64));
        root.insert("step_virtual_s".to_string(),
                    Json::Num(self.step_virtual_s));
        let mut phases = BTreeMap::new();
        for p in Phase::ALL {
            let a = self.phase(p);
            if a.count == 0 {
                continue;
            }
            let mut m = BTreeMap::new();
            m.insert("count".to_string(), Json::Num(a.count as f64));
            m.insert("virtual_s".to_string(),
                     Json::Num(a.virtual_s));
            if self.wall {
                m.insert("wall_s".to_string(), Json::Num(a.wall_s));
            }
            phases.insert(p.name().to_string(), Json::Obj(m));
        }
        root.insert("phases".to_string(), Json::Obj(phases));
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::LatencyRecorder;

    fn ev(t: f64, kind: EventKind, tenant: Option<u32>,
          req: Option<u64>, a: u64, b: u64) -> EngineEvent {
        EngineEvent { t_s: t, step: 0, kind, tenant, request: req,
                      a, b }
    }

    // ------------------------------------------------- histogram

    #[test]
    fn histogram_bucket_boundary_edges() {
        // 0 and anything at or below the lowest edge → bucket 0.
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-1.0), 0);
        assert_eq!(bucket_index(HIST_LOWEST), 0);
        // Just above the lowest edge → bucket 1; exactly at an edge
        // stays in that bucket (le-inclusive).
        assert_eq!(bucket_index(HIST_LOWEST * 1.000001), 1);
        assert_eq!(bucket_index(bucket_le(1)), 1);
        assert_eq!(bucket_index(bucket_le(1) * 1.000001), 2);
        // 1.0 second: smallest i with 1e-6·2^i ≥ 1 is 20.
        assert_eq!(bucket_index(1.0), 20);
        assert!(bucket_le(20) >= 1.0 && bucket_le(19) < 1.0);
        // The largest finite edge holds the top regular bucket; one
        // ulp beyond lands in the overflow bucket.
        let top = bucket_le(HIST_TOP);
        assert_eq!(bucket_index(top), HIST_TOP);
        assert_eq!(bucket_index(top * 1.000001), HIST_BUCKETS - 1);
        assert!(bucket_le(HIST_BUCKETS - 1).is_infinite());
        // Observations land where bucket_index says.
        let mut h = Histogram::default();
        h.observe(0.0);
        h.observe(top * 2.0);
        assert_eq!(h.bucket_count(0), 1);
        assert_eq!(h.bucket_count(HIST_BUCKETS - 1), 1);
        assert_eq!(h.count, 2);
    }

    #[test]
    fn histogram_merge_is_associative() {
        let reg = |seed: u64| {
            let mut r = MetricsRegistry::with_base(
                &[("replica", &format!("{seed}"))]);
            for i in 0..8u64 {
                let v = ((seed * 131 + i * 17) % 97) as f64 * 1e-4;
                r.observe("paca_e2e_seconds",
                          &[("tenant", "t0")], v);
                r.inc("paca_events_total", &[("kind", "admit")],
                      (i % 3) as f64);
            }
            r
        };
        let (a, b, c) = (reg(1), reg(2), reg(3));
        // (a ⊕ b) ⊕ c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a ⊕ (b ⊕ c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right);
        // Replica base labels folded into every series.
        assert!(left.render().contains("replica=\"2\""));
    }

    #[test]
    fn empty_registry_renders_empty() {
        let r = MetricsRegistry::with_base(&[("policy", "fifo")]);
        assert!(r.is_empty());
        assert_eq!(r.render(), "");
        let j = r.snapshot_json();
        assert_eq!(j.get("counters").map(|c| c.to_string()),
                   Some("{}".to_string()));
    }

    #[test]
    fn histogram_percentiles_match_latency_recorder_bitwise() {
        // One distinct sample per bucket: the bucket walk must pick
        // the same f64 the recorder's nearest-rank rule picks.
        let samples: Vec<f64> = (0..12)
            .map(|k| 1.5e-6 * (k as f64).exp2())
            .collect();
        let mut h = Histogram::default();
        let mut rec = LatencyRecorder::default();
        for v in &samples {
            h.observe(*v);
            rec.record("x", *v);
        }
        // Sanity: every occupied bucket holds exactly one sample.
        assert_eq!((0..HIST_BUCKETS)
                   .filter(|i| h.bucket_count(*i) == 1).count(),
                   samples.len());
        for q in [0.0, 0.25, 0.50, 0.75, 0.90, 0.99, 1.0] {
            let want = rec.percentile("x", q).unwrap();
            let got = h.percentile(q).unwrap();
            assert_eq!(got.to_bits(), want.to_bits(),
                       "q={q}: {got} vs {want}");
        }
        let want_sum: f64 = samples.iter().sum();
        assert!((h.mean().unwrap()
                 - want_sum / samples.len() as f64).abs() < 1e-18);
    }

    #[test]
    fn registry_render_is_valid_prometheus_text() {
        let mut r = MetricsRegistry::with_base(
            &[("policy", "slo-aware"), ("replica", "0")]);
        r.inc("paca_events_total", &[("kind", "admit")], 3.0);
        r.inc("paca_events_total", &[("kind", "complete")], 2.0);
        r.set_gauge("paca_kv_used_blocks", &[], 7.0);
        r.observe("paca_ttft_seconds", &[("tenant", "tenant-000")],
                  0.25);
        let text = r.render();
        assert!(text.contains("# TYPE paca_events_total counter"));
        assert!(text.contains(
            "paca_events_total{kind=\"admit\",policy=\"slo-aware\",\
             replica=\"0\"} 3"));
        assert!(text.contains("# TYPE paca_kv_used_blocks gauge"));
        assert!(text.contains("# TYPE paca_ttft_seconds histogram"));
        assert!(text.contains("paca_ttft_seconds_bucket"));
        assert!(text.contains("le=\"+Inf\""));
        assert!(text.contains("paca_ttft_seconds_count"));
        assert!(!text.contains("NaN"));
        // The TYPE header appears once per metric name.
        assert_eq!(text.matches("# TYPE paca_events_total").count(),
                   1);
    }

    // ----------------------------------------------- stream sink

    #[test]
    fn stream_sink_flushes_on_ring_capacity() {
        let mut s = JsonlStreamSink::new(TelemetryOut::memory(), 4);
        let mk = |i: u64| ev(i as f64 * 0.1, EventKind::Overflow,
                             None, None, i, 0);
        for i in 0..10 {
            s.on_event(&mk(i));
        }
        // Two full rings flushed, two events still pending.
        assert_eq!(s.written(), 8);
        assert_eq!(s.flushes(), 2);
        let mid = String::from_utf8(s.mem().unwrap().to_vec())
            .unwrap();
        assert_eq!(mid.lines().count(), 8, "incremental, not final");
        s.finalize();
        assert_eq!(s.written(), 10);
        let body = String::from_utf8(s.mem().unwrap().to_vec())
            .unwrap();
        // Order identical to the buffered exporter over the same
        // events.
        let all: Vec<EngineEvent> = (0..10).map(mk).collect();
        assert_eq!(body,
                   crate::serve::events::to_jsonl(&all));
        assert!(s.error().is_none());
    }

    // ---------------------------------------------------- feeder

    #[test]
    fn feeder_scrapes_on_interval_boundaries_and_finalize() {
        let mut f = MetricsFeeder::new(
            &[("policy", "fifo")], &["tenant-000".to_string()], 1.0,
            Some(TelemetryOut::memory()));
        f.on_event(&ev(0.0, EventKind::Arrival, Some(0), Some(1),
                       4, 2));
        f.on_event(&ev(0.4, EventKind::Admit, Some(0), Some(1),
                       4, 2));
        assert_eq!(f.scrapes(), 0, "no boundary crossed yet");
        f.on_event(&ev(1.2, EventKind::Dispatch, Some(0), Some(1),
                       4, 2));
        assert_eq!(f.scrapes(), 1, "crossed t=1.0");
        // A long idle jump across many boundaries collapses to ONE
        // scrape.
        f.on_event(&ev(7.5, EventKind::PrefillEnd, Some(0), Some(1),
                       1, 4));
        assert_eq!(f.scrapes(), 2);
        f.on_event(&ev(8.1, EventKind::Complete, Some(0), Some(1),
                       3, 0));
        assert_eq!(f.scrapes(), 3);
        f.finalize();
        assert_eq!(f.scrapes(), 4, "closing scrape");
        let text = String::from_utf8(f.mem().unwrap().to_vec())
            .unwrap();
        assert_eq!(text.matches("# scrape").count(), 4);
        // Counters are monotone across successive scrape blocks.
        let events_totals: Vec<u64> = text.lines()
            .filter(|l| l.starts_with(
                "paca_events_total{kind=\"arrival\""))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert!(events_totals.windows(2).all(|w| w[0] <= w[1]),
                "{events_totals:?}");
        // TTFT / e2e derived from the arrival ledger.
        let reg = f.registry();
        let ttft = reg.histogram("paca_ttft_seconds",
                                 &[("tenant", "tenant-000")])
            .expect("ttft recorded");
        assert_eq!(ttft.count, 1);
        assert!((ttft.sum - 7.5).abs() < 1e-12);
        let e2e = reg.histogram("paca_e2e_seconds",
                                &[("tenant", "tenant-000")])
            .expect("e2e recorded");
        assert!((e2e.sum - 8.1).abs() < 1e-12);
        assert_eq!(
            reg.counter("paca_events_total", &[("kind", "admit")]),
            1.0);
    }

    // ------------------------------------------------------- slo

    #[test]
    fn slo_tracker_rolls_a_bounded_window() {
        let mut t = SloBurnTracker::default();
        // 40 settlements for tenant 3: the first 10 miss, the rest
        // are on time — the 32-wide window forgets 2 of the misses.
        for i in 0..40u64 {
            let missed = i < 10;
            t.on_event(&ev(i as f64, EventKind::SloBurn, Some(3),
                           Some(i), missed as u64,
                           if missed { 1500 } else { 0 }));
        }
        let rows = t.summary();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!((r.tenant, r.total, r.missed), (3, 40, 10));
        assert_eq!(r.window_len, SLO_WINDOW);
        assert_eq!(r.window_missed, 2);
        assert!((r.burn_rate() - 2.0 / 32.0).abs() < 1e-12);
        assert_eq!(r.max_lateness_us, 1500);
        // Non-SloBurn kinds are ignored.
        t.on_event(&ev(99.0, EventKind::Complete, Some(3), Some(99),
                       1, 0));
        assert_eq!(t.summary()[0].total, 40);
    }

    // -------------------------------------------------- profiler

    #[test]
    fn profiler_phases_partition_analytic_step_time() {
        // Mirror the engine's analytic attribution for a batch of
        // steps and check the no-unattributed-time property.
        let (swap_s, batch_s, token_s) = (2e-3, 5e-4, 2e-5);
        let mut p = StepProfiler::new(false);
        let mut want_total = 0.0;
        for step in 0..200u64 {
            let swapped = step % 3 == 0;
            let prefill_tok = (step % 7) * 5;
            let decode_tok = 1 + step % 4;
            let tok = (prefill_tok + decode_tok) as f64;
            let step_s = batch_s + token_s * tok
                + if swapped { swap_s } else { 0.0 };
            let b = p.begin();
            p.end(Phase::Admission, b, 0.0);
            let sw = if swapped { swap_s } else { 0.0 };
            p.end(Phase::Dispatch, None, batch_s + sw);
            let tok_part = token_s * tok;
            p.end(Phase::Prefill, None,
                  tok_part * prefill_tok as f64 / tok);
            p.end(Phase::Decode, None,
                  tok_part * decode_tok as f64 / tok);
            p.end(Phase::KvGrow, None, 0.0);
            p.add_step(step_s);
            want_total += step_s;
        }
        let got = p.total_virtual();
        assert!((got - p.step_virtual_s).abs()
                <= 1e-9 * p.step_virtual_s.max(1.0),
                "unattributed time: {} vs {}", got,
                p.step_virtual_s);
        assert!((p.step_virtual_s - want_total).abs() < 1e-12);
        // No wall stamps on the analytic path.
        assert_eq!(p.phase(Phase::Admission).wall_s, 0.0);
        assert!(p.begin().is_none());
    }

    #[test]
    fn profiler_folded_stacks_and_merge() {
        let mut a = StepProfiler::new(false);
        a.end(Phase::Prefill, None, 0.5);
        a.end(Phase::Decode, None, 0.25);
        a.add_step(0.75);
        let mut b = StepProfiler::new(false);
        b.end(Phase::Router, None, 0.0);
        b.end(Phase::Decode, None, 0.25);
        b.add_step(0.25);
        a.merge(&b);
        assert_eq!(a.phase(Phase::Decode).virtual_s, 0.5);
        assert_eq!(a.phase(Phase::Router).count, 1);
        assert!((a.step_virtual_s - 1.0).abs() < 1e-12);
        let folded = a.folded();
        assert_eq!(folded.lines().count(), Phase::COUNT);
        for line in folded.lines() {
            let (stack, value) = line.rsplit_once(' ').unwrap();
            assert_eq!(stack.split(';').count(), 3, "{line}");
            let _: u64 = value.parse().unwrap();
        }
        assert!(folded.contains("paca_serve;step;prefill 500000\n"));
        let table = a.table().render();
        assert!(table.contains("decode"));
        assert!(table.contains("50.0%") || table.contains("decode"),
                "{table}");
        // Wall-armed profilers gain the dual-stamp columns/lines.
        let mut w = StepProfiler::new(true);
        let t0 = w.begin();
        assert!(t0.is_some());
        w.end(Phase::Admission, t0, 0.0);
        w.add_step(0.0);
        assert!(w.folded().contains("paca_serve_wall;step;"));
        assert!(w.table().render().contains("wall ms"));
    }
}
