//! Per-tenant PaCA adapter storage + the hot-splice swap primitive.
//!
//! An adapter is the paper's `(idx, P)` per target linear: `idx` names
//! the r selected input-feature rows, `P` holds their trained values.
//! For a LLaMA-scale target (d_in × d_out) the adapter is r/d_in of the
//! weight — e.g. r=64 on 4096×4096 is 1.6% — so millions of tenants are
//! storable while ONE frozen base serves them all: splicing a tenant in
//! is O(r·d_out) per target (coordinator::merge::splice_rows), and
//! un-splicing restores the shared base bit-exactly.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::checkpoint;
use crate::coordinator::merge;
use crate::manifest::ModelInfo;
use crate::peft::Selection;
use crate::serve::events::{EventKind, Events};
use crate::tensor::{DType, HostTensor};
use crate::util::rng::Rng;

/// The shared frozen base the adapters splice into: target weights
/// keyed by manifest-style names ("blocks/<layer>/<target>/w").
pub type WeightMap = BTreeMap<String, HostTensor>;

/// FNV-1a over names + raw tensor bytes — the bit-exactness witness
/// used to assert the base is untouched after un-merge.
pub fn fingerprint(weights: &WeightMap) -> u64 {
    let mut h = crate::util::rng::FNV_OFFSET;
    for (name, t) in weights {
        h = crate::util::rng::fnv1a_update(h, name.as_bytes());
        h = crate::util::rng::fnv1a_update(h, &t.data);
    }
    h
}

/// One target linear's partial connections.
#[derive(Debug, Clone)]
pub struct AdapterEntry {
    /// Target prefix, e.g. "blocks/0/q" (weight lives at `<target>/w`).
    pub target: String,
    /// Selected input-feature rows (distinct, paper's random default).
    pub idx: Vec<u32>,
    /// Trained rows, shape (idx.len(), d_out).
    pub p: HostTensor,
}

/// A tenant's complete adapter: one entry per PEFT target per layer.
#[derive(Debug, Clone)]
pub struct PacaAdapter {
    pub tenant: String,
    pub rank: usize,
    pub entries: Vec<AdapterEntry>,
}

/// Displaced base rows from a splice — consumed by `restore` for the
/// exact un-merge.
#[derive(Debug)]
pub struct SpliceGuard {
    pub tenant: String,
    saved: Vec<(String, Vec<u32>, HostTensor)>,
}

impl PacaAdapter {
    /// Deterministic synthetic adapter for a tenant on a model geometry
    /// (stand-in for a PaCA fine-tune output; distinct per tenant).
    /// Index sets come from the paper's default selection strategy
    /// (peft::Selection::Random), streamed per (tenant, target).
    pub fn synthetic(tenant: &str, m: &ModelInfo, rank: usize,
                     seed: u64) -> PacaAdapter {
        let none = BTreeMap::new();
        let mut entries = Vec::new();
        for layer in 0..m.n_layers {
            for (t, d_in, d_out) in m.linear_shapes() {
                let target = format!("blocks/{layer}/{t}");
                let r = rank.min(d_in);
                let idx = Selection::Random
                    .select(seed, &format!("{tenant}/{target}/idx"),
                            d_in, r, &none)
                    .expect("random selection is infallible");
                let mut rng = Rng::for_tag(
                    seed, &format!("{tenant}/{target}/p"));
                let p: Vec<f32> = (0..r * d_out)
                    .map(|_| rng.normal_f32(0.05)).collect();
                entries.push(AdapterEntry {
                    target,
                    idx,
                    p: HostTensor::from_f32(&[r, d_out], p),
                });
            }
        }
        PacaAdapter { tenant: tenant.to_string(), rank, entries }
    }

    /// Extract a serveable adapter from a *trained* PaCA state
    /// (names/tensors as produced by coordinator::checkpoint): for
    /// every `<target>/idx` the partial connections P are exactly the
    /// selected rows of the sibling `<target>/w` — the train→serve
    /// bridge (the trained rows already live inside the weight).
    pub fn from_trained_state(tenant: &str, names: &[String],
                              tensors: &[HostTensor]) -> Result<PacaAdapter> {
        if names.len() != tensors.len() {
            return Err(anyhow!("{} names vs {} tensors", names.len(),
                               tensors.len()));
        }
        let by_name: BTreeMap<&str, &HostTensor> =
            names.iter().map(String::as_str).zip(tensors).collect();
        let mut entries = Vec::new();
        let mut rank = 0;
        for (name, t) in &by_name {
            let target = match name.strip_suffix("/idx") {
                Some(p) => p,
                None => continue,
            };
            let wname = format!("{target}/w");
            let w = by_name.get(wname.as_str()).ok_or_else(|| {
                anyhow!("{name} has no sibling {wname}")
            })?;
            if w.shape.len() != 2 {
                return Err(anyhow!("{wname}: expected a 2-D weight, \
                                    got shape {:?}", w.shape));
            }
            let idx: Vec<u32> = t.as_i32().iter()
                .map(|&i| i as u32).collect();
            if let Some(&bad) = idx.iter()
                .find(|&&i| (i as usize) >= w.shape[0])
            {
                return Err(anyhow!("{name}: row {bad} out of range \
                                    (rows {})", w.shape[0]));
            }
            let p = w.extract_rows(&idx);
            rank = rank.max(idx.len());
            entries.push(AdapterEntry { target: target.to_string(),
                                        idx, p });
        }
        if entries.is_empty() {
            return Err(anyhow!(
                "state has no <target>/idx tensors — not a PaCA-trained \
                 artifact"));
        }
        Ok(PacaAdapter { tenant: tenant.to_string(), rank, entries })
    }

    /// `from_trained_state` over a training checkpoint file (the
    /// output of `paca train -o checkpoint=...`).
    pub fn from_checkpoint(path: &Path, tenant: &str) -> Result<PacaAdapter> {
        let (names, tensors) = checkpoint::load(path)?;
        Self::from_trained_state(tenant, &names, &tensors)
    }

    /// Compact on-disk size (the multi-tenant scaling argument).
    pub fn bytes(&self) -> usize {
        self.entries.iter()
            .map(|e| e.idx.len() * 4 + e.p.bytes())
            .sum()
    }

    /// Persist as a PACA checkpoint (`<target>/idx` + `<target>/p`).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        for e in &self.entries {
            names.push(format!("{}/idx", e.target));
            tensors.push(HostTensor::from_i32(
                &[e.idx.len()],
                e.idx.iter().map(|&i| i as i32).collect()));
            names.push(format!("{}/p", e.target));
            tensors.push(e.p.clone());
        }
        checkpoint::save(path, &names, &tensors)
            .with_context(|| format!("saving adapter {}", self.tenant))
    }

    pub fn load(path: &Path, tenant: &str) -> Result<PacaAdapter> {
        let (names, tensors) = checkpoint::load(path)
            .with_context(|| format!("loading adapter {tenant}"))?;
        let mut by_target: BTreeMap<String, (Option<Vec<u32>>,
                                             Option<HostTensor>)> =
            BTreeMap::new();
        for (name, t) in names.iter().zip(tensors) {
            if let Some(target) = name.strip_suffix("/idx") {
                if t.dtype != DType::I32 {
                    return Err(anyhow!("{name}: idx must be i32"));
                }
                by_target.entry(target.to_string()).or_default().0 =
                    Some(t.as_i32().iter().map(|&i| i as u32).collect());
            } else if let Some(target) = name.strip_suffix("/p") {
                by_target.entry(target.to_string()).or_default().1 =
                    Some(t);
            } else {
                return Err(anyhow!("unexpected adapter tensor {name}"));
            }
        }
        let mut entries = Vec::new();
        let mut rank = 0;
        for (target, (idx, p)) in by_target {
            let idx = idx
                .ok_or_else(|| anyhow!("{target}: missing idx"))?;
            let p = p.ok_or_else(|| anyhow!("{target}: missing p"))?;
            if p.shape.len() != 2 || p.shape[0] != idx.len() {
                return Err(anyhow!(
                    "{target}: p shape {:?} does not match {} indices",
                    p.shape, idx.len()));
            }
            rank = rank.max(idx.len());
            entries.push(AdapterEntry { target, idx, p });
        }
        if entries.is_empty() {
            return Err(anyhow!("adapter {tenant} has no entries"));
        }
        Ok(PacaAdapter { tenant: tenant.to_string(), rank, entries })
    }

    /// Hot-merge this adapter into the shared base. On any failure the
    /// already-spliced entries are rolled back, leaving the base
    /// untouched. Returns the guard needed for the exact un-merge.
    pub fn splice(&self, weights: &mut WeightMap) -> Result<SpliceGuard> {
        let mut saved: Vec<(String, Vec<u32>, HostTensor)> = Vec::new();
        for e in &self.entries {
            let wname = format!("{}/w", e.target);
            let r = match weights.get_mut(&wname) {
                Some(w) => merge::splice_rows(w, &e.idx, &e.p),
                None => Err(anyhow!("base has no target {wname}")),
            };
            match r {
                Ok(displaced) => {
                    saved.push((e.target.clone(), e.idx.clone(),
                                displaced));
                }
                Err(err) => {
                    // Roll back to keep the shared base consistent.
                    let guard = SpliceGuard {
                        tenant: self.tenant.clone(), saved,
                    };
                    guard.restore(weights).ok();
                    return Err(err.context(format!(
                        "splicing tenant {}", self.tenant)));
                }
            }
        }
        Ok(SpliceGuard { tenant: self.tenant.clone(), saved })
    }
}

impl SpliceGuard {
    /// Exact un-merge: put the displaced base rows back (bit-exact —
    /// byte-level restore via coordinator::merge::unsplice_rows).
    pub fn restore(self, weights: &mut WeightMap) -> Result<()> {
        // Reverse order so nested/overlapping splices unwind correctly.
        for (target, idx, displaced) in self.saved.into_iter().rev() {
            let wname = format!("{target}/w");
            let w = weights.get_mut(&wname)
                .ok_or_else(|| anyhow!("base lost target {wname}"))?;
            merge::unsplice_rows(w, &idx, &displaced)?;
        }
        Ok(())
    }
}

#[derive(Debug, Default, Clone, Copy)]
pub struct RegistryStats {
    pub hits: u64,
    pub misses: u64,
    pub loads: u64,
    pub evictions: u64,
}

/// LRU-bounded in-memory adapter cache, optionally backed by a
/// directory of `<tenant>.paca` files (cold tenants are loaded on
/// demand; over-capacity tenants are evicted least-recently-used).
pub struct AdapterRegistry {
    dir: Option<PathBuf>,
    capacity: usize,
    clock: u64,
    map: HashMap<String, (u64, PacaAdapter)>,
    /// Per-tenant adapter GENERATION: bumped whenever the tenant's
    /// resident adapter is evicted or replaced, i.e. whenever the
    /// weights a future splice will produce may differ from what an
    /// earlier splice produced. Anything derived from a tenant's
    /// spliced base — the serving stack's cached prefix KV above all
    /// — is only valid for the generation it was computed under.
    /// (Entries outlive eviction on purpose: a re-load after an
    /// eviction must present a NEW generation.)
    gen: HashMap<String, u64>,
    /// Event-stream handle (off by default). Adapter events carry no
    /// tenant id — the registry is keyed by tenant NAME; the timeline
    /// still shows when loads/evictions happened relative to steps.
    events: Events,
    pub stats: RegistryStats,
}

impl AdapterRegistry {
    pub fn new(capacity: usize) -> AdapterRegistry {
        AdapterRegistry { dir: None, capacity: capacity.max(1),
                          clock: 0, map: HashMap::new(),
                          gen: HashMap::new(),
                          events: Events::off(),
                          stats: RegistryStats::default() }
    }

    /// Install an event-stream handle. Off by default.
    pub fn set_events(&mut self, events: Events) {
        self.events = events;
    }

    pub fn with_dir(dir: &Path, capacity: usize) -> AdapterRegistry {
        let mut r = Self::new(capacity);
        r.dir = Some(dir.to_path_buf());
        r
    }

    pub fn adapter_path(dir: &Path, tenant: &str) -> PathBuf {
        dir.join(format!("{tenant}.paca"))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn contains(&self, tenant: &str) -> bool {
        self.map.contains_key(tenant)
    }

    pub fn tenants(&self) -> Vec<String> {
        let mut t: Vec<String> = self.map.keys().cloned().collect();
        t.sort();
        t
    }

    /// The tenant's current adapter generation (0 until its resident
    /// adapter is first evicted or replaced). Consumers holding
    /// generation-stamped derived state — the prefix cache's per-
    /// tenant KV subtrees — compare against this and drop anything
    /// stale.
    pub fn generation(&self, tenant: &str) -> u64 {
        self.gen.get(tenant).copied().unwrap_or(0)
    }

    fn bump_generation(&mut self, tenant: &str) {
        *self.gen.entry(tenant.to_string()).or_insert(0) += 1;
    }

    /// Insert (or replace), evicting LRU entries over capacity.
    /// Replacing a RESIDENT adapter bumps the tenant's generation —
    /// the new weights may differ, so derived state is stale.
    pub fn insert(&mut self, adapter: PacaAdapter) {
        self.clock += 1;
        if self.map.contains_key(&adapter.tenant) {
            self.bump_generation(&adapter.tenant);
        }
        self.map.insert(adapter.tenant.clone(), (self.clock, adapter));
        while self.map.len() > self.capacity {
            self.evict_lru();
        }
    }

    /// Explicitly evict a tenant (generation bumps: a later reload is
    /// a NEW generation even if the file is unchanged — the registry
    /// cannot know, so it must assume staleness).
    pub fn evict(&mut self, tenant: &str) -> Option<PacaAdapter> {
        let out = self.map.remove(tenant).map(|(_, a)| a);
        if out.is_some() {
            self.bump_generation(tenant);
            self.events.emit(EventKind::AdapterEvict, None, None,
                             self.generation(tenant),
                             self.map.len() as u64);
        }
        out
    }

    fn evict_lru(&mut self) {
        if let Some(t) = self.map.iter()
            .min_by_key(|(_, (used, _))| *used)
            .map(|(t, _)| t.clone())
        {
            self.map.remove(&t);
            self.bump_generation(&t);
            self.stats.evictions += 1;
            self.events.emit(EventKind::AdapterEvict, None, None,
                             self.generation(&t),
                             self.map.len() as u64);
        }
    }

    /// Fetch a tenant's adapter, loading from the backing directory on
    /// miss (and evicting LRU if that overflows the bound).
    pub fn fetch(&mut self, tenant: &str) -> Result<&PacaAdapter> {
        if self.map.contains_key(tenant) {
            self.stats.hits += 1;
        } else {
            self.stats.misses += 1;
            let dir = self.dir.clone().ok_or_else(|| {
                anyhow!("tenant {tenant} not in registry (no backing \
                         adapter directory configured)")
            })?;
            let path = Self::adapter_path(&dir, tenant);
            let adapter = PacaAdapter::load(&path, tenant)
                .with_context(|| format!("{}", path.display()))?;
            self.stats.loads += 1;
            self.insert(adapter);
            self.events.emit(EventKind::AdapterLoad, None, None,
                             self.stats.loads,
                             self.map.len() as u64);
        }
        self.clock += 1;
        let clock = self.clock;
        let slot = self.map.get_mut(tenant).unwrap();
        slot.0 = clock;
        Ok(&slot.1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelInfo {
        ModelInfo { name: "serve-tiny".into(), vocab: 512, d_model: 16,
                    n_layers: 2, n_heads: 4, d_ff: 24, max_seq: 128,
                    profile_only: false }
    }

    fn base(m: &ModelInfo) -> WeightMap {
        let mut w = WeightMap::new();
        for layer in 0..m.n_layers {
            for (t, d_in, d_out) in m.linear_shapes() {
                let mut rng = Rng::for_tag(7, &format!("{layer}/{t}"));
                let vals: Vec<f32> = (0..d_in * d_out)
                    .map(|_| rng.normal_f32(0.02)).collect();
                w.insert(format!("blocks/{layer}/{t}/w"),
                         HostTensor::from_f32(&[d_in, d_out], vals));
            }
        }
        w
    }

    #[test]
    fn splice_restore_roundtrips_base() {
        let m = tiny();
        let mut w = base(&m);
        let fp0 = fingerprint(&w);
        let a = PacaAdapter::synthetic("t0", &m, 4, 1);
        let guard = a.splice(&mut w).unwrap();
        assert_ne!(fingerprint(&w), fp0, "splice must change the base");
        guard.restore(&mut w).unwrap();
        assert_eq!(fingerprint(&w), fp0, "un-merge must be bit-exact");
    }

    #[test]
    fn sequential_tenants_are_isolated() {
        let m = tiny();
        let mut w = base(&m);
        let a = PacaAdapter::synthetic("a", &m, 4, 1);
        let b = PacaAdapter::synthetic("b", &m, 4, 2);
        // b spliced onto a pristine base…
        let mut w_direct = w.clone();
        let g = b.splice(&mut w_direct).unwrap();
        let fp_b = fingerprint(&w_direct);
        g.restore(&mut w_direct).unwrap();
        // …equals b spliced after an a-splice/un-splice cycle.
        let ga = a.splice(&mut w).unwrap();
        ga.restore(&mut w).unwrap();
        let gb = b.splice(&mut w).unwrap();
        assert_eq!(fingerprint(&w), fp_b,
                   "tenant a must leave no trace in tenant b's weights");
        gb.restore(&mut w).unwrap();
    }

    #[test]
    fn save_load_roundtrip() {
        let m = tiny();
        let a = PacaAdapter::synthetic("t9", &m, 4, 3);
        let path = std::env::temp_dir().join(format!(
            "paca-adapter-{}.paca", std::process::id()));
        a.save(&path).unwrap();
        let b = PacaAdapter::load(&path, "t9").unwrap();
        assert_eq!(b.entries.len(), a.entries.len());
        assert_eq!(b.rank, 4);
        let ea: &AdapterEntry = &a.entries[0];
        let eb = b.entries.iter().find(|e| e.target == ea.target)
            .unwrap();
        assert_eq!(ea.idx, eb.idx);
        assert_eq!(ea.p.data, eb.p.data);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn trained_state_exports_a_serveable_adapter() {
        // A "trained" PaCA state: idx selects rows of w whose values
        // are the trained partial connections.
        let names = vec!["blocks/0/q/w".to_string(),
                         "blocks/0/q/idx".to_string(),
                         "opt/step".to_string()];
        let w = HostTensor::from_f32(
            &[4, 2], vec![0., 0., 10., 11., 0., 0., 20., 21.]);
        let tensors = vec![w, HostTensor::from_i32(&[2], vec![3, 1]),
                           HostTensor::scalar_i32(5)];
        let a = PacaAdapter::from_trained_state("t", &names, &tensors)
            .unwrap();
        assert_eq!(a.entries.len(), 1);
        assert_eq!(a.rank, 2);
        assert_eq!(a.entries[0].idx, vec![3, 1]);
        assert_eq!(a.entries[0].p.as_f32(), vec![20., 21., 10., 11.]);
        // Spliced onto a fresh base, the trained rows land exactly.
        let mut base = WeightMap::new();
        base.insert("blocks/0/q/w".into(),
                    HostTensor::from_f32(&[4, 2], vec![1.; 8]));
        let g = a.splice(&mut base).unwrap();
        let v = base["blocks/0/q/w"].as_f32();
        assert_eq!(v, vec![1., 1., 10., 11., 1., 1., 20., 21.]);
        g.restore(&mut base).unwrap();
        assert!(base["blocks/0/q/w"].as_f32().iter()
                .all(|&x| x == 1.0));
        // Non-PaCA states (no idx tensors) are rejected.
        assert!(PacaAdapter::from_trained_state(
            "t", &names[..1].to_vec(), &tensors[..1].to_vec())
                .is_err());
        // A malformed (non-2-D) weight sibling is an error, not a
        // panic.
        let bad_names = vec!["blocks/0/q/w".to_string(),
                             "blocks/0/q/idx".to_string()];
        let bad = vec![HostTensor::from_f32(&[8], vec![0.; 8]),
                       HostTensor::from_i32(&[1], vec![0])];
        assert!(PacaAdapter::from_trained_state("t", &bad_names, &bad)
                .is_err());
    }

    #[test]
    fn registry_lru_bound_and_disk_reload() {
        let m = tiny();
        let dir = std::env::temp_dir().join(format!(
            "paca-reg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for t in ["t0", "t1", "t2"] {
            PacaAdapter::synthetic(t, &m, 2, 5)
                .save(&AdapterRegistry::adapter_path(&dir, t)).unwrap();
        }
        let mut reg = AdapterRegistry::with_dir(&dir, 2);
        reg.fetch("t0").unwrap();
        reg.fetch("t1").unwrap();
        reg.fetch("t2").unwrap(); // evicts t0
        assert_eq!(reg.len(), 2);
        assert!(!reg.contains("t0"));
        assert_eq!(reg.stats.evictions, 1);
        // t0 reloads from disk on demand.
        reg.fetch("t0").unwrap();
        assert_eq!(reg.stats.loads, 4);
        assert!(reg.contains("t0"));
        // LRU: t1 was the least recently used.
        assert!(!reg.contains("t1"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn generations_bump_on_evict_and_replace_only() {
        let m = tiny();
        let mut reg = AdapterRegistry::new(2);
        assert_eq!(reg.generation("t0"), 0);
        reg.insert(PacaAdapter::synthetic("t0", &m, 2, 5));
        assert_eq!(reg.generation("t0"), 0,
                   "first insert is not a replacement");
        reg.insert(PacaAdapter::synthetic("t0", &m, 2, 6));
        assert_eq!(reg.generation("t0"), 1, "hot replace bumps");
        assert!(reg.evict("t0").is_some());
        assert_eq!(reg.generation("t0"), 2, "evict bumps");
        assert!(reg.evict("t0").is_none());
        assert_eq!(reg.generation("t0"), 2,
                   "evicting an absent tenant is a no-op");
        // LRU eviction bumps the victim, not the newcomer.
        reg.insert(PacaAdapter::synthetic("a", &m, 2, 5));
        reg.insert(PacaAdapter::synthetic("b", &m, 2, 5));
        reg.insert(PacaAdapter::synthetic("c", &m, 2, 5)); // evicts a
        assert_eq!(reg.generation("a"), 1);
        assert_eq!(reg.generation("b"), 0);
        assert_eq!(reg.generation("c"), 0);
    }

    #[test]
    fn fetch_unknown_without_dir_errors() {
        let mut reg = AdapterRegistry::new(4);
        assert!(reg.fetch("ghost").is_err());
    }

    #[test]
    fn adapter_is_compact() {
        let m = tiny();
        let a = PacaAdapter::synthetic("t", &m, 2, 1);
        let base_bytes: usize = base(&m).values().map(|t| t.bytes()).sum();
        assert!(a.bytes() < base_bytes / 3,
                "adapter {} vs base {base_bytes}", a.bytes());
    }
}
