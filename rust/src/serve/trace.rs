//! Synthetic multi-tenant request traces + JSONL persistence.
//!
//! Tenant popularity is Zipfian (a few hot tenants, a long cold tail —
//! the observed shape of multi-adapter serving fleets), arrivals are a
//! Poisson process (exponential inter-arrival times) optionally
//! modulated into bursts, prompt lengths are uniform around a mean,
//! and each request can carry a per-tenant SLO deadline. Fully
//! deterministic from the seed, like every other substrate in the
//! crate.
//!
//! A [`Trace`] owns both the requests and the [`TenantPool`] that
//! interns their tenant names — ids are dense handles, names only
//! exist at the JSONL boundary and in reports.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::serve::scheduler::{Request, TenantPool};
use crate::util::json::Json;
use crate::util::rng::{Rng, Zipf};

/// Mean burst length (requests) when `burstiness > 1`.
const BURST_LEN: f64 = 8.0;

/// Mean lognormal stretch, in multiples of `mean_tokens`, applied to a
/// prompt selected by `prompt_tail`.
const TAIL_STRETCH: f64 = 4.0;

/// Hard cap on a tail-stretched prompt, in multiples of `mean_tokens`
/// — keeps the lognormal's far tail from synthesizing prompts no pool
/// configuration could ever seat.
const TAIL_CAP: usize = 64;

/// Mean think time (seconds) between consecutive turns of a chat
/// session when `chat_turns ≥ 2`.
const CHAT_THINK_S: f64 = 0.25;

/// Instantaneous-rate multiplier inside a flash-crowd window.
const FLASH_FACTOR: f64 = 8.0;

/// A flash window spans this fraction of the trace's nominal length.
const FLASH_WINDOW_FRAC: f64 = 1.0 / 8.0;

/// Peak-to-mean swing of the diurnal sinusoid (rate varies in
/// [1 − swing, 1 + swing] · λ across one nominal-span period).
const DIURNAL_SWING: f64 = 0.75;

/// Long-horizon shape of the arrival rate (`--arrival-pattern`).
/// Orthogonal to `burstiness`, which models short-range clumping;
/// these modulate the MEAN rate over the whole trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ArrivalPattern {
    /// Constant mean rate — the historical generator, bit for bit.
    #[default]
    Steady,
    /// One sinusoidal period over the trace's nominal span: a slow
    /// peak-and-trough load curve.
    Diurnal,
    /// An 8× rate spike in one window ~1/8 of the nominal span wide,
    /// centered at a point drawn (from the pattern's own rng stream)
    /// uniformly in the middle half of the trace — the load shape
    /// that separates load-aware routing from pure shard hashing.
    Flash,
}

impl ArrivalPattern {
    pub fn parse(s: &str) -> Option<ArrivalPattern> {
        match s {
            "steady" => Some(ArrivalPattern::Steady),
            "diurnal" => Some(ArrivalPattern::Diurnal),
            "flash" => Some(ArrivalPattern::Flash),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ArrivalPattern::Steady => "steady",
            ArrivalPattern::Diurnal => "diurnal",
            ArrivalPattern::Flash => "flash",
        }
    }
}

#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub n_requests: usize,
    pub n_tenants: usize,
    /// Mean prompt length (tokens); lengths are uniform in
    /// [mean/2, 3·mean/2).
    pub mean_tokens: usize,
    /// Zipf exponent of tenant popularity.
    pub zipf_s: f64,
    /// Mean arrival rate, requests/second.
    pub req_per_s: f64,
    /// Arrival burstiness b ≥ 1. At 1 arrivals are pure Poisson; above
    /// 1 they alternate between fast intra-burst spacing (rate b·λ,
    /// bursts of ~BURST_LEN requests) and stretched inter-burst gaps
    /// (rate λ/b) — same requests, much spikier instantaneous load.
    pub burstiness: f64,
    /// Mean per-request deadline in milliseconds after arrival
    /// (jittered ±25% per request); 0 = no deadlines.
    pub deadline_ms: f64,
    /// Mean decode length — output tokens generated after the first,
    /// each one decode iteration in the iteration-level engine
    /// (jittered like `mean_tokens`); 0 = prefill-only requests, the
    /// shape every pre-decode trace has.
    pub decode_tokens: usize,
    /// Per-tenant system-prompt length: every request's prompt is
    /// PREPENDED with its tenant's shared prefix of this many tokens
    /// (so `tokens` = shared prefix + the unique draw), and carries
    /// `shared_prefix_tokens` so the serving stack's prefix cache can
    /// reuse the prefix KV across same-tenant requests. 0 = fully
    /// unique prompts, the shape every pre-prefix trace has.
    pub shared_prefix_tokens: usize,
    /// Probability in [0, 1) that a request's prompt is stretched by a
    /// lognormal multiplier (median ~`TAIL_STRETCH`·mean extra tokens,
    /// capped at `TAIL_CAP`·mean) — the RAG-sized heavy tail that
    /// exposes prefill stalls. 0 = the historical uniform lengths.
    /// Drawn from its own tagged stream, so tail on/off yields the
    /// SAME arrivals, tenants, deadlines and decode lengths.
    pub prompt_tail: f64,
    /// Turns per chat session. At ≥ 2 every synthesized request
    /// becomes the opening turn of a session: each follow-up turn
    /// re-sends the WHOLE previous context (previous prompt + its
    /// decoded reply) as `shared_prefix_tokens` plus a fresh user
    /// message, arriving an exponential think time later — a
    /// conversation re-hitting its own growing prefix. 0 or 1 = the
    /// historical single-turn shape, bit-for-bit.
    pub chat_turns: usize,
    /// Long-horizon arrival-rate shape. `Steady` draws nothing from
    /// the pattern stream and reproduces old seeds bit-for-bit;
    /// `Diurnal`/`Flash` retime the SAME requests (tenants, prompts,
    /// deadlines and decode lengths are untouched — only arrival
    /// instants move).
    pub arrival_pattern: ArrivalPattern,
    pub seed: u64,
}

impl Default for TraceSpec {
    fn default() -> TraceSpec {
        TraceSpec { n_requests: 256, n_tenants: 8, mean_tokens: 64,
                    zipf_s: 1.1, req_per_s: 200.0, burstiness: 1.0,
                    deadline_ms: 0.0, decode_tokens: 0,
                    shared_prefix_tokens: 0, prompt_tail: 0.0,
                    chat_turns: 0,
                    arrival_pattern: ArrivalPattern::Steady,
                    seed: 42 }
    }
}

pub fn tenant_name(i: usize) -> String {
    format!("tenant-{i:03}")
}

/// A request trace plus the tenant-name interner its ids live in.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub pool: TenantPool,
    pub requests: Vec<Request>,
}

impl Trace {
    /// Distinct tenant names appearing in the trace, sorted.
    pub fn tenant_names(&self) -> Vec<String> {
        let mut t: Vec<String> = self.pool.names().to_vec();
        t.sort();
        t
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Trace span in seconds (last arrival).
    pub fn span_s(&self) -> f64 {
        self.requests.iter().map(|r| r.arrival_s)
            .fold(0.0, f64::max)
    }
}

pub fn synthesize(spec: &TraceSpec) -> Trace {
    assert!(spec.n_tenants > 0 && spec.mean_tokens >= 2);
    let mut rng = Rng::for_tag(spec.seed, "serve/trace");
    // Decode lengths come from their OWN tagged stream so that (a)
    // prefill-only specs consume exactly the pre-decode stream —
    // existing seeds reproduce their old traces bit-for-bit — and (b)
    // the same seed with decode on/off yields IDENTICAL arrivals,
    // tenants and prompts, differing only in decode lengths.
    let mut dec_rng = Rng::for_tag(spec.seed, "serve/trace/decode");
    // Heavy-tail stretches likewise get their own stream: tail on/off
    // differs ONLY in the stretched lengths, and tail-0 specs draw
    // nothing from it, reproducing old traces bit-for-bit.
    let mut tail_rng = Rng::for_tag(spec.seed, "serve/trace/tail");
    // Pattern parameters (the flash window's center) draw from their
    // own stream: `Steady` consumes nothing from it and modulates
    // nothing, so existing seeds reproduce their old traces
    // bit-for-bit, and flash/diurnal leave every non-time draw of
    // the main/decode/tail streams untouched.
    let mut pat_rng = Rng::for_tag(spec.seed, "serve/trace/pattern");
    let zipf = Zipf::new(spec.n_tenants, spec.zipf_s);
    let mut pool = TenantPool::new();
    let rate = spec.req_per_s.max(1e-9);
    let b = spec.burstiness.max(1.0);
    // The shape is laid out over the trace's NOMINAL span (expected
    // length at the unmodulated mean rate) — the real span is only
    // known after generation.
    let nominal_span = spec.n_requests as f64 / rate;
    let flash_center = match spec.arrival_pattern {
        ArrivalPattern::Flash => {
            nominal_span * (0.25 + 0.5 * pat_rng.next_f64())
        }
        _ => 0.0,
    };
    let shape = |t: f64| -> f64 {
        match spec.arrival_pattern {
            ArrivalPattern::Steady => 1.0,
            ArrivalPattern::Diurnal => {
                let phase = 2.0 * std::f64::consts::PI * t
                    / nominal_span.max(1e-9);
                1.0 + DIURNAL_SWING * phase.sin()
            }
            ArrivalPattern::Flash => {
                let half = nominal_span * FLASH_WINDOW_FRAC / 2.0;
                if (t - flash_center).abs() <= half {
                    FLASH_FACTOR
                } else {
                    1.0
                }
            }
        }
    };
    let mut t = 0.0f64;
    let requests = (0..spec.n_requests as u64).map(|id| {
        // Exponential inter-arrival at the (possibly burst-modulated)
        // instantaneous rate. The b == 1 path draws exactly the same
        // stream as the pre-burstiness generator, so existing seeds
        // reproduce their old traces.
        let lambda = if b > 1.0 {
            if rng.next_f64() < 1.0 / BURST_LEN {
                rate / b // inter-burst gap
            } else {
                rate * b // intra-burst spacing
            }
        } else {
            rate
        } * shape(t);
        let u = rng.next_f64().max(1e-12);
        t += -u.ln() / lambda;
        let tenant = pool.intern(&tenant_name(zipf.sample(&mut rng)));
        let mut tokens = spec.mean_tokens / 2
            + rng.below(spec.mean_tokens.max(2));
        // Lognormal heavy tail: a `prompt_tail` fraction of prompts
        // gain exp(N(0,1)) · TAIL_STRETCH · mean extra tokens — most
        // stretched prompts are a few× the mean, a few are huge.
        if spec.prompt_tail > 0.0
            && tail_rng.next_f64() < spec.prompt_tail
        {
            let extra = (spec.mean_tokens as f64 * TAIL_STRETCH
                         * tail_rng.normal().exp()) as usize;
            tokens += extra.min(spec.mean_tokens * TAIL_CAP);
        }
        let deadline_s = if spec.deadline_ms > 0.0 {
            spec.deadline_ms * 1e-3 * (0.75 + 0.5 * rng.next_f64())
        } else {
            f64::INFINITY
        };
        // The floor of 1 keeps `--decode-tokens 1` from degenerating
        // into a prefill-only trace (only d = 1 is affected: d/2 ≥ 1
        // beyond).
        let decode_tokens = if spec.decode_tokens > 0 {
            (spec.decode_tokens / 2).max(1)
                + dec_rng.below(spec.decode_tokens)
        } else {
            0
        };
        // The tenant's system prompt rides in front of the unique
        // draw. No rng is consumed, so prefix on/off yields the SAME
        // arrivals, tenants, unique lengths and decode lengths — and
        // prefix-0 specs reproduce old traces bit-for-bit.
        let shared = spec.shared_prefix_tokens;
        Request { id, tenant, tokens: shared + tokens, decode_tokens,
                  shared_prefix_tokens: shared, arrival_s: t,
                  deadline_s }
    }).collect();
    let requests = expand_chat_sessions(spec, requests);
    Trace { pool, requests }
}

/// Expand every request into a `chat_turns`-turn session (no-op below
/// 2 turns — single-turn specs reproduce their old traces bit-for-bit,
/// drawing nothing from the chat stream). Turn k + 1 carries turn k's
/// whole context (prompt + decoded reply) as its shared prefix plus a
/// fresh user message, and arrives an exponential think time after
/// turn k. The merged trace is re-sorted by arrival and re-numbered so
/// downstream invariants (strictly increasing arrivals, dense ids)
/// hold regardless of how sessions interleave.
fn expand_chat_sessions(spec: &TraceSpec, base: Vec<Request>)
                        -> Vec<Request> {
    if spec.chat_turns < 2 {
        return base;
    }
    let mut chat_rng = Rng::for_tag(spec.seed, "serve/trace/chat");
    let mut all = Vec::with_capacity(base.len() * spec.chat_turns);
    for first in base {
        let mut prev = first.clone();
        all.push(first);
        for _ in 1..spec.chat_turns {
            // The whole conversation so far becomes the next turn's
            // shared prefix: a cache that retained the previous turn
            // serves everything but the fresh user message.
            let context = prev.tokens + prev.decode_tokens;
            let fresh = spec.mean_tokens / 2
                + chat_rng.below(spec.mean_tokens.max(2));
            let u = chat_rng.next_f64().max(1e-12);
            let arrival_s = prev.arrival_s - u.ln() * CHAT_THINK_S;
            let decode_tokens = if spec.decode_tokens > 0 {
                (spec.decode_tokens / 2).max(1)
                    + chat_rng.below(spec.decode_tokens)
            } else {
                0
            };
            let deadline_s = if spec.deadline_ms > 0.0 {
                spec.deadline_ms * 1e-3
                    * (0.75 + 0.5 * chat_rng.next_f64())
            } else {
                f64::INFINITY
            };
            let turn = Request { id: 0, tenant: prev.tenant,
                                 tokens: context + fresh,
                                 decode_tokens,
                                 shared_prefix_tokens: context,
                                 arrival_s, deadline_s };
            prev = turn.clone();
            all.push(turn);
        }
    }
    // Stable sort keeps each session's turns in order; the epsilon
    // bump restores the strictly-increasing-arrivals invariant when
    // interleaved sessions collide.
    all.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    let mut last = f64::NEG_INFINITY;
    for (id, r) in all.iter_mut().enumerate() {
        if r.arrival_s <= last {
            r.arrival_s = last + 1e-9;
        }
        last = r.arrival_s;
        r.id = id as u64;
    }
    all
}

pub fn write_jsonl(path: &Path, trace: &Trace) -> Result<()> {
    let mut out = String::new();
    for r in &trace.requests {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("id".to_string(), Json::Num(r.id as f64));
        obj.insert("tenant".to_string(),
                   Json::Str(trace.pool.name(r.tenant).to_string()));
        obj.insert("tokens".to_string(), Json::Num(r.tokens as f64));
        obj.insert("arrival_s".to_string(), Json::Num(r.arrival_s));
        // No-deadline / prefill-only requests simply omit the fields,
        // so traces without SLOs or decode phases stay readable by
        // (and byte-identical to) the older formats.
        if r.deadline_s.is_finite() {
            obj.insert("deadline_s".to_string(),
                       Json::Num(r.deadline_s));
        }
        if r.decode_tokens > 0 {
            obj.insert("decode_tokens".to_string(),
                       Json::Num(r.decode_tokens as f64));
        }
        if r.shared_prefix_tokens > 0 {
            obj.insert("shared_prefix_tokens".to_string(),
                       Json::Num(r.shared_prefix_tokens as f64));
        }
        out.push_str(&Json::Obj(obj).to_string());
        out.push('\n');
    }
    std::fs::write(path, out)
        .with_context(|| format!("writing {}", path.display()))
}

pub fn read_jsonl(path: &Path) -> Result<Trace> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut trace = Trace::default();
    for (ln, line) in src.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| {
            anyhow!("{}:{}: {e}", path.display(), ln + 1)
        })?;
        let num_field = |k: &str| -> Result<f64> {
            j.get(k).and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!(
                    "{}:{}: missing {k}", path.display(), ln + 1))
        };
        let name = j.get("tenant").and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!(
                "{}:{}: missing tenant", path.display(), ln + 1))?;
        let tenant = trace.pool.intern(name);
        trace.requests.push(Request {
            id: num_field("id")? as u64,
            tenant,
            tokens: num_field("tokens")? as usize,
            // Older traces predate the decode field: absent means
            // prefill-only.
            decode_tokens: j.get("decode_tokens")
                .and_then(|v| v.as_usize()).unwrap_or(0),
            // Older traces predate the prefix field: absent means a
            // fully unique prompt.
            shared_prefix_tokens: j.get("shared_prefix_tokens")
                .and_then(|v| v.as_usize()).unwrap_or(0),
            arrival_s: num_field("arrival_s")?,
            // Older traces predate the SLO field: absent means no
            // deadline, not deadline-zero.
            deadline_s: j.get("deadline_s").and_then(|v| v.as_f64())
                .unwrap_or(f64::INFINITY),
        });
    }
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_well_formed() {
        let spec = TraceSpec { n_requests: 100, n_tenants: 5,
                               ..Default::default() };
        let a = synthesize(&spec);
        let b = synthesize(&spec);
        assert_eq!(a.len(), 100);
        assert_eq!(a.requests, b.requests,
                   "trace must be seed-deterministic");
        assert!(a.tenant_names().len() >= 2,
                "multi-tenant by construction");
        for w in a.requests.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s,
                    "arrivals must be increasing");
        }
        for r in &a.requests {
            assert!(r.tokens >= spec.mean_tokens / 2);
            assert!(r.tokens < 2 * spec.mean_tokens);
            assert!(r.deadline_s.is_infinite(),
                    "no deadlines unless requested");
            assert_eq!(r.decode_tokens, 0,
                       "prefill-only unless requested");
        }
        assert!(a.span_s() > 0.0);
    }

    #[test]
    fn decode_lengths_are_jittered_around_the_mean() {
        let spec = TraceSpec { n_requests: 300, decode_tokens: 32,
                               ..Default::default() };
        let trace = synthesize(&spec);
        let mut distinct = std::collections::BTreeSet::new();
        for r in &trace.requests {
            assert!(r.decode_tokens >= 16 && r.decode_tokens < 48,
                    "decode {} outside [16, 48)", r.decode_tokens);
            assert_eq!(r.total_tokens(), r.tokens + r.decode_tokens);
            distinct.insert(r.decode_tokens);
        }
        assert!(distinct.len() > 8, "lengths must actually vary");
        // Adding decode lengths must not perturb the rest of the
        // stream: same seed, decode on/off, identical arrivals and
        // prompts.
        let plain = synthesize(&TraceSpec { decode_tokens: 0,
                                            n_requests: 300,
                                            ..Default::default() });
        for (a, b) in trace.requests.iter().zip(&plain.requests) {
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.tenant, b.tenant);
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-12);
        }
        // And the d = 1 edge: asking for decode must never silently
        // synthesize a prefill-only trace.
        let one = synthesize(&TraceSpec { n_requests: 50,
                                          decode_tokens: 1,
                                          ..Default::default() });
        for r in &one.requests {
            assert_eq!(r.decode_tokens, 1,
                       "--decode-tokens 1 degenerated to 0");
        }
    }

    #[test]
    fn shared_prefix_rides_in_front_without_perturbing_the_stream() {
        let spec = TraceSpec { n_requests: 200, decode_tokens: 8,
                               shared_prefix_tokens: 48,
                               ..Default::default() };
        let with = synthesize(&spec);
        let without = synthesize(&TraceSpec {
            shared_prefix_tokens: 0, ..spec.clone() });
        for (a, b) in with.requests.iter().zip(&without.requests) {
            assert_eq!(a.shared_prefix_tokens, 48);
            assert_eq!(b.shared_prefix_tokens, 0);
            // Same unique draw, same everything else: the prefix is
            // prepended, not drawn.
            assert_eq!(a.tokens, b.tokens + 48);
            assert!(a.tokens > a.shared_prefix_tokens,
                    "a prompt is its prefix plus a nonempty tail");
            assert_eq!(a.tenant, b.tenant);
            assert_eq!(a.decode_tokens, b.decode_tokens);
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-12);
        }
        // And the prefix field round-trips through JSONL only when
        // nonzero (PR-4-era shape stays byte-stable — see the
        // pr2-era test).
        let path = std::env::temp_dir().join(format!(
            "paca-trace-prefix-{}.jsonl", std::process::id()));
        write_jsonl(&path, &without).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.contains("shared_prefix_tokens"),
                "prefix-0 traces must omit the field");
        write_jsonl(&path, &with).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("shared_prefix_tokens"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn prompt_tail_stretches_only_the_selected_prompts() {
        let spec = TraceSpec { n_requests: 400, decode_tokens: 8,
                               deadline_ms: 50.0, prompt_tail: 0.2,
                               ..Default::default() };
        let tailed = synthesize(&spec);
        let plain = synthesize(&TraceSpec { prompt_tail: 0.0,
                                            ..spec.clone() });
        let mut stretched = 0;
        for (a, b) in tailed.requests.iter().zip(&plain.requests) {
            // The tail stream is independent: arrivals, tenants,
            // deadlines and decode lengths are untouched, and a
            // non-selected prompt keeps its exact uniform draw.
            assert_eq!(a.tenant, b.tenant);
            assert_eq!(a.decode_tokens, b.decode_tokens);
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-12);
            assert!((a.deadline_s - b.deadline_s).abs() < 1e-12);
            assert!(a.tokens >= b.tokens);
            if a.tokens > b.tokens {
                stretched += 1;
                assert!(a.tokens <= b.tokens
                        + spec.mean_tokens * TAIL_CAP);
            }
        }
        // ~20% of 400 prompts selected, far outside the binomial
        // noise band; and the tail must actually exceed the uniform
        // generator's hard 2×mean ceiling.
        assert!((40..160).contains(&stretched),
                "{stretched} stretched prompts");
        let max = tailed.requests.iter().map(|r| r.tokens)
            .max().unwrap();
        assert!(max >= 2 * spec.mean_tokens,
                "heavy tail must break the uniform cap (max {max})");
        // tail-0 ≡ the historical generator, bit-for-bit.
        assert_eq!(plain.requests, synthesize(&TraceSpec {
            decode_tokens: 8, deadline_ms: 50.0, n_requests: 400,
            ..Default::default() }).requests);
    }

    #[test]
    fn chat_sessions_regrow_their_own_prefix() {
        let spec = TraceSpec { n_requests: 12, n_tenants: 3,
                               decode_tokens: 8, chat_turns: 3,
                               req_per_s: 50.0,
                               ..Default::default() };
        let trace = synthesize(&spec);
        assert_eq!(trace.len(), 12 * 3,
                   "every request opens a 3-turn session");
        let mut follow_ups = 0;
        for (i, r) in trace.requests.iter().enumerate() {
            assert_eq!(r.id, i as u64, "ids re-numbered densely");
            if r.shared_prefix_tokens > 0 {
                follow_ups += 1;
                // A follow-up turn = the whole previous context plus
                // a fresh uniform user message.
                let fresh = r.tokens - r.shared_prefix_tokens;
                assert!(fresh >= spec.mean_tokens / 2
                        && fresh < 2 * spec.mean_tokens);
                // Context grew past one opening turn's worth, so the
                // prefix a cache can reuse GROWS turn over turn.
                assert!(r.shared_prefix_tokens
                        >= spec.mean_tokens / 2 + 1);
            }
        }
        assert_eq!(follow_ups, 12 * 2,
                   "two follow-up turns per session");
        for w in trace.requests.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s,
                    "arrivals stay strictly increasing");
        }
        // chat off (0 or 1) ≡ the historical generator, bit-for-bit.
        let base = synthesize(&TraceSpec { chat_turns: 0,
                                           ..spec.clone() });
        let one = synthesize(&TraceSpec { chat_turns: 1,
                                          ..spec.clone() });
        assert_eq!(base.requests.len(), 12);
        assert_eq!(base.requests, one.requests);
    }

    #[test]
    fn chat_and_tail_traces_roundtrip_through_jsonl() {
        // The new shapes introduce NO new JSONL fields: a chat/tail
        // trace round-trips through the existing schema untouched.
        let spec = TraceSpec { n_requests: 16, n_tenants: 2,
                               decode_tokens: 6, chat_turns: 2,
                               prompt_tail: 0.3,
                               ..Default::default() };
        let trace = synthesize(&spec);
        let path = std::env::temp_dir().join(format!(
            "paca-trace-chat-{}.jsonl", std::process::id()));
        write_jsonl(&path, &trace).unwrap();
        let back = read_jsonl(&path).unwrap();
        assert_eq!(back.requests.len(), trace.requests.len());
        for (a, b) in trace.requests.iter().zip(&back.requests) {
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.shared_prefix_tokens, b.shared_prefix_tokens);
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-9);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn arrival_patterns_retime_without_perturbing_the_draws() {
        let spec = TraceSpec { n_requests: 400, decode_tokens: 8,
                               deadline_ms: 50.0,
                               ..Default::default() };
        let steady = synthesize(&spec);
        for pattern in [ArrivalPattern::Diurnal,
                        ArrivalPattern::Flash] {
            let shaped = synthesize(&TraceSpec {
                arrival_pattern: pattern, ..spec.clone() });
            let mut moved = 0;
            for (a, b) in shaped.requests.iter()
                .zip(&steady.requests)
            {
                // Only the clock moves: same tenants, prompts,
                // deadline widths and decode lengths in the same
                // order.
                assert_eq!(a.tenant, b.tenant, "{}", pattern.name());
                assert_eq!(a.tokens, b.tokens);
                assert_eq!(a.decode_tokens, b.decode_tokens);
                assert!((a.deadline_s - b.deadline_s).abs() < 1e-12);
                if (a.arrival_s - b.arrival_s).abs() > 1e-9 {
                    moved += 1;
                }
            }
            assert!(moved > 100,
                    "{}: only {moved} arrivals moved", pattern.name());
        }
        // steady ≡ the historical generator, bit-for-bit (it draws
        // nothing from the pattern stream).
        assert_eq!(steady.requests, synthesize(&spec).requests);
        assert_eq!(ArrivalPattern::default(), ArrivalPattern::Steady);
    }

    #[test]
    fn flash_crowd_concentrates_arrivals_into_one_window() {
        let spec = TraceSpec { n_requests: 800,
                               ..Default::default() };
        let steady = synthesize(&spec);
        let flash = synthesize(&TraceSpec {
            arrival_pattern: ArrivalPattern::Flash,
            ..spec.clone() });
        // Peak occupancy of a sliding nominal-span/8 window: the
        // flash trace must pack several× more arrivals into its
        // hottest window than the steady one ever does.
        let window = (spec.n_requests as f64 / spec.req_per_s)
            * FLASH_WINDOW_FRAC;
        let peak = |t: &Trace| {
            let a: Vec<f64> = t.requests.iter().map(|r| r.arrival_s)
                .collect();
            let mut best = 0;
            let mut lo = 0;
            for hi in 0..a.len() {
                while a[hi] - a[lo] > window {
                    lo += 1;
                }
                best = best.max(hi - lo + 1);
            }
            best
        };
        let (ps, pf) = (peak(&steady), peak(&flash));
        assert!(pf as f64 >= 2.5 * ps as f64,
                "flash peak {pf} vs steady peak {ps}");
        // And it is a retiming, not a rewrite: same request count,
        // arrivals still strictly increasing.
        assert_eq!(flash.len(), steady.len());
        for w in flash.requests.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
    }

    #[test]
    fn arrival_pattern_parse_roundtrip() {
        for p in [ArrivalPattern::Steady, ArrivalPattern::Diurnal,
                  ArrivalPattern::Flash] {
            assert_eq!(ArrivalPattern::parse(p.name()), Some(p));
        }
        assert_eq!(ArrivalPattern::parse("tidal"), None);
    }

    #[test]
    fn zipf_popularity_is_head_heavy() {
        let spec = TraceSpec { n_requests: 2000, n_tenants: 16,
                               ..Default::default() };
        let trace = synthesize(&spec);
        let hot = trace.pool.get(&tenant_name(0)).unwrap();
        let head = trace.requests.iter()
            .filter(|r| r.tenant == hot).count();
        assert!(head > 2000 / 16, "tenant-000 should be hot ({head})");
    }

    #[test]
    fn burstiness_raises_interarrival_variance() {
        let smooth = synthesize(&TraceSpec {
            n_requests: 1000, ..Default::default() });
        let bursty = synthesize(&TraceSpec {
            n_requests: 1000, burstiness: 4.0, ..Default::default() });
        let cv2 = |t: &Trace| {
            let gaps: Vec<f64> = t.requests.windows(2)
                .map(|w| w[1].arrival_s - w[0].arrival_s).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var = gaps.iter().map(|g| (g - mean) * (g - mean))
                .sum::<f64>() / gaps.len() as f64;
            var / (mean * mean)
        };
        // Poisson inter-arrivals have CV² ≈ 1; the burst mixture is
        // markedly overdispersed.
        assert!(cv2(&smooth) < 2.0, "smooth CV² {}", cv2(&smooth));
        assert!(cv2(&bursty) > 2.0 * cv2(&smooth),
                "bursty CV² {} vs smooth {}", cv2(&bursty),
                cv2(&smooth));
    }

    #[test]
    fn deadlines_are_jittered_around_the_mean() {
        let spec = TraceSpec { n_requests: 200, deadline_ms: 80.0,
                               ..Default::default() };
        let trace = synthesize(&spec);
        for r in &trace.requests {
            assert!(r.deadline_s >= 0.75 * 0.080
                    && r.deadline_s < 1.25 * 0.080,
                    "deadline {} outside jitter band", r.deadline_s);
            assert!(r.absolute_deadline() > r.arrival_s);
        }
    }

    #[test]
    fn jsonl_roundtrip_preserves_everything_in_order() {
        let spec = TraceSpec { n_requests: 32, n_tenants: 4,
                               deadline_ms: 50.0, decode_tokens: 24,
                               shared_prefix_tokens: 48,
                               ..Default::default() };
        let trace = synthesize(&spec);
        let path = std::env::temp_dir().join(format!(
            "paca-trace-{}.jsonl", std::process::id()));
        write_jsonl(&path, &trace).unwrap();
        let back = read_jsonl(&path).unwrap();
        assert_eq!(back.len(), trace.len());
        for (a, b) in trace.requests.iter().zip(&back.requests) {
            assert_eq!(a.id, b.id);
            assert_eq!(trace.pool.name(a.tenant),
                       back.pool.name(b.tenant));
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.decode_tokens, b.decode_tokens);
            assert_eq!(a.shared_prefix_tokens, b.shared_prefix_tokens);
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-9);
            assert!((a.deadline_s - b.deadline_s).abs() < 1e-9);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pr2_era_trace_loads_with_defaults_and_roundtrips_bitwise() {
        // A trace written before `decode_tokens` (and, line 1, before
        // `deadline_s`) existed: absent fields must read back as
        // prefill-only / no-deadline, and save(load(file)) must
        // reproduce the file BYTE-identically — old archives stay
        // stable under a load/save cycle.
        let old = concat!(
            "{\"arrival_s\":0.25,\"id\":0,\"tenant\":\"tenant-000\",",
            "\"tokens\":32}\n",
            "{\"arrival_s\":0.5,\"deadline_s\":0.075,\"id\":1,",
            "\"tenant\":\"tenant-001\",\"tokens\":16}\n");
        let path = std::env::temp_dir().join(format!(
            "paca-trace-pr2-{}.jsonl", std::process::id()));
        std::fs::write(&path, old).unwrap();
        let trace = read_jsonl(&path).unwrap();
        assert_eq!(trace.len(), 2);
        for r in &trace.requests {
            assert_eq!(r.decode_tokens, 0, "old trace = prefill-only");
            assert_eq!(r.shared_prefix_tokens, 0,
                       "old trace = fully unique prompts");
            assert_eq!(r.total_tokens(), r.tokens);
        }
        assert!(trace.requests[0].deadline_s.is_infinite());
        assert!((trace.requests[1].deadline_s - 0.075).abs() < 1e-12);
        write_jsonl(&path, &trace).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(back, old, "load→save must be byte-identical");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_deadline_field_defaults_to_no_deadline() {
        // A trace written before the SLO field existed must read back
        // with deadline_s = INFINITY, not 0 (which would mean "already
        // missed").
        let path = std::env::temp_dir().join(format!(
            "paca-trace-old-{}.jsonl", std::process::id()));
        std::fs::write(&path, concat!(
            "{\"arrival_s\":0.25,\"id\":0,\"tenant\":\"tenant-000\",",
            "\"tokens\":32}\n",
            "{\"arrival_s\":0.5,\"deadline_s\":0.075,\"id\":1,",
            "\"tenant\":\"tenant-001\",\"tokens\":16}\n")).unwrap();
        let trace = read_jsonl(&path).unwrap();
        assert_eq!(trace.len(), 2);
        assert!(trace.requests[0].deadline_s.is_infinite());
        assert!((trace.requests[1].deadline_s - 0.075).abs() < 1e-12);
        // And a no-deadline trace round-trips back WITHOUT the field.
        write_jsonl(&path, &trace).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.lines().next().unwrap().contains("deadline_s"));
        assert!(text.lines().nth(1).unwrap().contains("deadline_s"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn interning_is_stable_across_write_read() {
        let spec = TraceSpec { n_requests: 64, n_tenants: 6,
                               ..Default::default() };
        let trace = synthesize(&spec);
        let path = std::env::temp_dir().join(format!(
            "paca-trace-intern-{}.jsonl", std::process::id()));
        write_jsonl(&path, &trace).unwrap();
        let back = read_jsonl(&path).unwrap();
        // Names survive; first-appearance order makes ids line up too.
        assert_eq!(trace.pool.names(), back.pool.names());
        for (a, b) in trace.requests.iter().zip(&back.requests) {
            assert_eq!(a.tenant, b.tenant);
        }
        std::fs::remove_file(&path).ok();
    }
}
