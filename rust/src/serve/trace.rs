//! Synthetic multi-tenant request traces + JSONL persistence.
//!
//! Tenant popularity is Zipfian (a few hot tenants, a long cold tail —
//! the observed shape of multi-adapter serving fleets), arrivals are a
//! Poisson process (exponential inter-arrival times), and prompt
//! lengths are uniform around a mean. Fully deterministic from the
//! seed, like every other substrate in the crate.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::serve::scheduler::Request;
use crate::util::json::Json;
use crate::util::rng::{Rng, Zipf};

#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub n_requests: usize,
    pub n_tenants: usize,
    /// Mean prompt length (tokens); lengths are uniform in
    /// [mean/2, 3·mean/2).
    pub mean_tokens: usize,
    /// Zipf exponent of tenant popularity.
    pub zipf_s: f64,
    /// Mean arrival rate, requests/second.
    pub req_per_s: f64,
    pub seed: u64,
}

impl Default for TraceSpec {
    fn default() -> TraceSpec {
        TraceSpec { n_requests: 256, n_tenants: 8, mean_tokens: 64,
                    zipf_s: 1.1, req_per_s: 200.0, seed: 42 }
    }
}

pub fn tenant_name(i: usize) -> String {
    format!("tenant-{i:03}")
}

pub fn synthesize(spec: &TraceSpec) -> Vec<Request> {
    assert!(spec.n_tenants > 0 && spec.mean_tokens >= 2);
    let mut rng = Rng::for_tag(spec.seed, "serve/trace");
    let zipf = Zipf::new(spec.n_tenants, spec.zipf_s);
    let mut t = 0.0f64;
    (0..spec.n_requests as u64).map(|id| {
        // Exponential inter-arrival at the target rate.
        let u = rng.next_f64().max(1e-12);
        t += -u.ln() / spec.req_per_s.max(1e-9);
        Request {
            id,
            tenant: tenant_name(zipf.sample(&mut rng)),
            tokens: spec.mean_tokens / 2
                + rng.below(spec.mean_tokens.max(2)),
            arrival_s: t,
        }
    }).collect()
}

/// Distinct tenants appearing in a trace, sorted.
pub fn tenants(reqs: &[Request]) -> Vec<String> {
    let mut t: Vec<String> = reqs.iter().map(|r| r.tenant.clone())
        .collect();
    t.sort();
    t.dedup();
    t
}

pub fn write_jsonl(path: &Path, reqs: &[Request]) -> Result<()> {
    let mut out = String::new();
    for r in reqs {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("id".to_string(), Json::Num(r.id as f64));
        obj.insert("tenant".to_string(), Json::Str(r.tenant.clone()));
        obj.insert("tokens".to_string(), Json::Num(r.tokens as f64));
        obj.insert("arrival_s".to_string(), Json::Num(r.arrival_s));
        out.push_str(&Json::Obj(obj).to_string());
        out.push('\n');
    }
    std::fs::write(path, out)
        .with_context(|| format!("writing {}", path.display()))
}

pub fn read_jsonl(path: &Path) -> Result<Vec<Request>> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut reqs = Vec::new();
    for (ln, line) in src.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| {
            anyhow!("{}:{}: {e}", path.display(), ln + 1)
        })?;
        let str_field = |k: &str| -> Result<String> {
            j.get(k).and_then(|v| v.as_str()).map(String::from)
                .ok_or_else(|| anyhow!(
                    "{}:{}: missing {k}", path.display(), ln + 1))
        };
        let num_field = |k: &str| -> Result<f64> {
            j.get(k).and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!(
                    "{}:{}: missing {k}", path.display(), ln + 1))
        };
        reqs.push(Request {
            id: num_field("id")? as u64,
            tenant: str_field("tenant")?,
            tokens: num_field("tokens")? as usize,
            arrival_s: num_field("arrival_s")?,
        });
    }
    Ok(reqs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_well_formed() {
        let spec = TraceSpec { n_requests: 100, n_tenants: 5,
                               ..Default::default() };
        let a = synthesize(&spec);
        let b = synthesize(&spec);
        assert_eq!(a.len(), 100);
        assert_eq!(a, b, "trace must be seed-deterministic");
        assert!(tenants(&a).len() >= 2, "multi-tenant by construction");
        for w in a.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s,
                    "arrivals must be increasing");
        }
        for r in &a {
            assert!(r.tokens >= spec.mean_tokens / 2);
            assert!(r.tokens < 2 * spec.mean_tokens);
        }
    }

    #[test]
    fn zipf_popularity_is_head_heavy() {
        let spec = TraceSpec { n_requests: 2000, n_tenants: 16,
                               ..Default::default() };
        let reqs = synthesize(&spec);
        let head = reqs.iter()
            .filter(|r| r.tenant == tenant_name(0)).count();
        assert!(head > 2000 / 16, "tenant-000 should be hot ({head})");
    }

    #[test]
    fn jsonl_roundtrip() {
        let spec = TraceSpec { n_requests: 32, n_tenants: 4,
                               ..Default::default() };
        let reqs = synthesize(&spec);
        let path = std::env::temp_dir().join(format!(
            "paca-trace-{}.jsonl", std::process::id()));
        write_jsonl(&path, &reqs).unwrap();
        let back = read_jsonl(&path).unwrap();
        assert_eq!(back.len(), reqs.len());
        for (a, b) in reqs.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tenant, b.tenant);
            assert_eq!(a.tokens, b.tokens);
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-9);
        }
        std::fs::remove_file(&path).ok();
    }
}
