//! Host-side tensors: typed, shape-carrying byte buffers that convert
//! to/from `xla::Literal` at the PJRT boundary. The coordinator keeps
//! the training state as `HostTensor`s (checkpointable, inspectable)
//! and materializes literals per dispatch.

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I8,
}

impl DType {
    pub fn from_manifest(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "i8" => DType::I8,
            other => bail!("unknown dtype {other:?}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }

    pub fn element_type(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::I8 => xla::ElementType::S8,
        }
    }
}

#[derive(Debug, Clone)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub data: Vec<u8>,
}

impl HostTensor {
    pub fn zeros(shape: &[usize], dtype: DType) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor { shape: shape.to_vec(), dtype,
                     data: vec![0u8; n * dtype.size()] }
    }

    pub fn from_f32(shape: &[usize], vals: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { shape: shape.to_vec(), dtype: DType::F32, data }
    }

    pub fn from_i32(shape: &[usize], vals: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { shape: shape.to_vec(), dtype: DType::I32, data }
    }

    pub fn from_i8(shape: &[usize], vals: Vec<i8>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        HostTensor { shape: shape.to_vec(), dtype: DType::I8,
                     data: vals.into_iter().map(|v| v as u8).collect() }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::from_f32(&[], vec![v])
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::from_i32(&[], vec![v])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    pub fn as_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, DType::F32);
        self.data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn as_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, DType::I32);
        self.data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn f32_at(&self, i: usize) -> f32 {
        assert_eq!(self.dtype, DType::F32);
        let b = &self.data[i * 4..i * 4 + 4];
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }

    pub fn set_f32(&mut self, i: usize, v: f32) {
        assert_eq!(self.dtype, DType::F32);
        self.data[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
    }

    /// Row `i` of a 2-D f32 tensor.
    pub fn row_f32(&self, i: usize) -> Vec<f32> {
        assert_eq!(self.dtype, DType::F32);
        assert_eq!(self.shape.len(), 2);
        let cols = self.shape[1];
        (0..cols).map(|j| self.f32_at(i * cols + j)).collect()
    }

    /// Byte length of one row of a 2-D tensor.
    fn row_stride(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "row ops need a 2-D tensor");
        self.shape[1] * self.dtype.size()
    }

    /// Gather rows `idx` into a new (idx.len(), cols) tensor. Pure byte
    /// copy — bit-exact for any dtype (the substrate of the serving
    /// registry's hot-splice save/restore, coordinator::merge).
    pub fn extract_rows(&self, idx: &[u32]) -> HostTensor {
        let stride = self.row_stride();
        let mut data = Vec::with_capacity(idx.len() * stride);
        for &i in idx {
            let i = i as usize;
            assert!(i < self.shape[0],
                    "row {i} out of range (rows {})", self.shape[0]);
            data.extend_from_slice(&self.data[i * stride..(i + 1) * stride]);
        }
        HostTensor { shape: vec![idx.len(), self.shape[1]],
                     dtype: self.dtype, data }
    }

    /// Scatter `rows` (an (idx.len(), cols) tensor) into rows `idx`,
    /// overwriting in place — the exact inverse of `extract_rows` over
    /// the same index set.
    pub fn write_rows(&mut self, idx: &[u32], rows: &HostTensor) {
        let stride = self.row_stride();
        assert_eq!(rows.dtype, self.dtype, "dtype mismatch");
        assert_eq!(rows.shape, vec![idx.len(), self.shape[1]],
                   "rows shape mismatch");
        for (k, &i) in idx.iter().enumerate() {
            let i = i as usize;
            assert!(i < self.shape[0],
                    "row {i} out of range (rows {})", self.shape[0]);
            self.data[i * stride..(i + 1) * stride]
                .copy_from_slice(&rows.data[k * stride..(k + 1) * stride]);
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        xla::Literal::create_from_shape_and_untyped_data(
            self.dtype.element_type(), &self.shape, &self.data)
            .context("literal from host tensor")
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize)
            .collect();
        let dtype = match shape.ty() {
            xla::ElementType::F32 => DType::F32,
            xla::ElementType::S32 => DType::I32,
            xla::ElementType::S8 => DType::I8,
            other => bail!("unsupported literal element type {other:?}"),
        };
        let n: usize = dims.iter().product();
        let out;
        match dtype {
            DType::F32 => {
                let mut buf = vec![0f32; n];
                lit.copy_raw_to::<f32>(&mut buf)?;
                out = HostTensor::from_f32(&dims, buf);
            }
            DType::I32 => {
                let mut buf = vec![0i32; n];
                lit.copy_raw_to::<i32>(&mut buf)?;
                out = HostTensor::from_i32(&dims, buf);
            }
            DType::I8 => {
                let mut buf = vec![0i8; n];
                lit.copy_raw_to::<i8>(&mut buf)?;
                out = HostTensor::from_i8(&dims, buf);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_bytes() {
        let t = HostTensor::from_f32(&[2, 2], vec![1.0, -2.5, 3.25, 0.0]);
        assert_eq!(t.as_f32(), vec![1.0, -2.5, 3.25, 0.0]);
        assert_eq!(t.f32_at(2), 3.25);
        assert_eq!(t.bytes(), 16);
    }

    #[test]
    fn rows() {
        let t = HostTensor::from_f32(&[2, 3],
                                     vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row_f32(1), vec![4., 5., 6.]);
    }

    #[test]
    fn set_get() {
        let mut t = HostTensor::zeros(&[4], DType::F32);
        t.set_f32(3, 9.5);
        assert_eq!(t.f32_at(3), 9.5);
        assert_eq!(t.f32_at(0), 0.0);
    }

    #[test]
    fn extract_write_rows_roundtrip() {
        let w = HostTensor::from_f32(&[4, 2],
                                     vec![0., 1., 2., 3., 4., 5., 6., 7.]);
        let rows = w.extract_rows(&[3, 1]);
        assert_eq!(rows.shape, vec![2, 2]);
        assert_eq!(rows.as_f32(), vec![6., 7., 2., 3.]);
        let mut w2 = w.clone();
        w2.write_rows(&[0, 2], &rows);
        assert_eq!(w2.as_f32(), vec![6., 7., 2., 3., 2., 3., 6., 7.]);
        // writing back what was extracted restores bit-exactly
        let saved = w2.extract_rows(&[0, 2]);
        w2.write_rows(&[0, 2], &saved);
        assert_eq!(w2.as_f32(), vec![6., 7., 2., 3., 2., 3., 6., 7.]);
    }

    #[test]
    fn scalar_shapes() {
        assert_eq!(HostTensor::scalar_f32(1.0).shape, Vec::<usize>::new());
        assert_eq!(HostTensor::scalar_i32(7).as_i32(), vec![7]);
    }
}
