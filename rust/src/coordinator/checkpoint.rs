//! Checkpointing: the full training state (params + optimizer moments +
//! step counter) as a single self-describing binary file.
//!
//! Format (little-endian):
//!   magic "PACA" | u32 version | u64 n_tensors
//!   per tensor: u32 name_len | name bytes | u8 dtype | u32 ndim |
//!               u64 dims… | u64 data_len | raw bytes
//! A trailing u64 FNV-1a checksum over everything before it guards
//! against truncation.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::{DType, HostTensor};
use crate::util::rng::fnv1a;

const MAGIC: &[u8; 4] = b"PACA";
const VERSION: u32 = 1;

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::I32 => 1,
        DType::I8 => 2,
    }
}

fn dtype_from(code: u8) -> Result<DType> {
    Ok(match code {
        0 => DType::F32,
        1 => DType::I32,
        2 => DType::I8,
        other => bail!("bad dtype code {other}"),
    })
}

pub fn save(path: &Path, names: &[String],
            tensors: &[HostTensor]) -> Result<()> {
    assert_eq!(names.len(), tensors.len());
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(names.len() as u64).to_le_bytes());
    for (name, t) in names.iter().zip(tensors) {
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        buf.push(dtype_code(t.dtype));
        buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for d in &t.shape {
            buf.extend_from_slice(&(*d as u64).to_le_bytes());
        }
        buf.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
        buf.extend_from_slice(&t.data);
    }
    let sum = fnv1a(&buf);
    buf.extend_from_slice(&sum.to_le_bytes());
    let tmp = path.with_extension("tmp");
    std::fs::File::create(&tmp)
        .and_then(|mut f| f.write_all(&buf))
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path).context("atomic checkpoint rename")?;
    Ok(())
}

pub fn load(path: &Path) -> Result<(Vec<String>, Vec<HostTensor>)> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .and_then(|mut f| f.read_to_end(&mut buf))
        .with_context(|| format!("reading {}", path.display()))?;
    if buf.len() < 24 || &buf[..4] != MAGIC {
        bail!("not a PACA checkpoint: {}", path.display());
    }
    let body_len = buf.len() - 8;
    let stored = u64::from_le_bytes(buf[body_len..].try_into().unwrap());
    if fnv1a(&buf[..body_len]) != stored {
        bail!("checkpoint checksum mismatch (truncated?): {}",
              path.display());
    }
    let mut i = 4;
    let rd_u32 = |i: &mut usize| -> u32 {
        let v = u32::from_le_bytes(buf[*i..*i + 4].try_into().unwrap());
        *i += 4;
        v
    };
    let rd_u64 = |i: &mut usize| -> u64 {
        let v = u64::from_le_bytes(buf[*i..*i + 8].try_into().unwrap());
        *i += 8;
        v
    };
    let version = rd_u32(&mut i);
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let n = rd_u64(&mut i) as usize;
    let mut names = Vec::with_capacity(n);
    let mut tensors = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = rd_u32(&mut i) as usize;
        let name = String::from_utf8(buf[i..i + name_len].to_vec())
            .map_err(|_| anyhow!("bad tensor name"))?;
        i += name_len;
        let dtype = dtype_from(buf[i])?;
        i += 1;
        let ndim = rd_u32(&mut i) as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(rd_u64(&mut i) as usize);
        }
        let data_len = rd_u64(&mut i) as usize;
        let data = buf[i..i + data_len].to_vec();
        i += data_len;
        let expect: usize = shape.iter().product::<usize>()
            * dtype.size();
        if data.len() != expect {
            bail!("tensor {name}: {} bytes, expected {expect}",
                  data.len());
        }
        names.push(name);
        tensors.push(HostTensor { shape, dtype, data });
    }
    Ok((names, tensors))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("paca-ckpt-test-{}-{}", std::process::id(), name));
        p
    }

    #[test]
    fn roundtrip() {
        let names = vec!["a/w".to_string(), "opt/step".to_string()];
        let tensors = vec![
            HostTensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]),
            HostTensor::scalar_i32(41),
        ];
        let p = tmpfile("roundtrip");
        save(&p, &names, &tensors).unwrap();
        let (n2, t2) = load(&p).unwrap();
        assert_eq!(n2, names);
        assert_eq!(t2[0].as_f32(), tensors[0].as_f32());
        assert_eq!(t2[1].as_i32(), vec![41]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_corruption() {
        let p = tmpfile("corrupt");
        save(&p, &["x".into()],
             &[HostTensor::from_f32(&[2], vec![1., 2.])]).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_non_checkpoint() {
        let p = tmpfile("garbage");
        std::fs::write(&p, b"hello world, definitely not a ckpt")
            .unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
