//! Inference-time weight merging — the paper's §2 observation made
//! executable: every PEFT method's effective weights can be merged into
//! the pretrained matrices *for inference* (LoRA's headline property),
//! so a single method-agnostic eval graph (lowered with full-shape
//! weights) serves all seven methods. The coordinator merges host-side
//! before each evaluation:
//!
//!   full/paca : W is already the effective weight.
//!   lora      : W + (α/r)·A·B
//!   moslora   : W + (α/r)·A·M·B
//!   dora      : mag ⊙ (W + (α/r)·A·B) / ‖·‖_col
//!   qlora     : dequant(codes, scales) + (α/r)·A·B
//!   qpaca     : dequant(codes, scales) with rows[idx] ← P

use anyhow::{anyhow, Result};

use crate::manifest::ArtifactInfo;
use crate::nf4;
use crate::tensor::HostTensor;

/// (M, K) @ (K, N) row-major f32 matmul (host-side; adapter matrices
/// are small: d×r and r×d).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize,
              n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for l in 0..k {
            let av = a[i * k + l];
            if av == 0.0 {
                continue;
            }
            let brow = &b[l * n..(l + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// Hot-splice: overwrite rows `idx` of the base weight `w` with the
/// rows of `p` ((r, d_out) — PaCA's trained partial connections),
/// returning the displaced base rows. O(r·d_out) byte copies per
/// target, independent of d_in — the paper's §2 zero-overhead merged
/// inference made executable, and the serving registry's swap
/// primitive (serve::registry).
///
/// `idx` must be duplicate-free (PaCA selections are drawn without
/// replacement); this is checked because exact un-merge depends on it.
pub fn splice_rows(w: &mut HostTensor, idx: &[u32],
                   p: &HostTensor) -> Result<HostTensor> {
    if w.shape.len() != 2 || p.shape.len() != 2 {
        return Err(anyhow!("splice: need 2-D tensors, got W {:?} P {:?}",
                           w.shape, p.shape));
    }
    if p.shape[1] != w.shape[1] || p.shape[0] != idx.len()
        || p.dtype != w.dtype
    {
        return Err(anyhow!(
            "splice: P {:?} does not fit W {:?} with {} indices",
            p.shape, w.shape, idx.len()));
    }
    if let Some(&bad) = idx.iter().find(|&&i| (i as usize) >= w.shape[0]) {
        return Err(anyhow!("splice: row {bad} out of range (rows {})",
                           w.shape[0]));
    }
    let mut sorted = idx.to_vec();
    sorted.sort_unstable();
    if sorted.windows(2).any(|pair| pair[0] == pair[1]) {
        return Err(anyhow!("splice: duplicate row index (un-merge \
                            would not be exact)"));
    }
    let saved = w.extract_rows(idx);
    w.write_rows(idx, p);
    Ok(saved)
}

/// Exact un-merge: restore the rows displaced by a previous
/// `splice_rows` call with the same `idx`. Byte-level restore, so the
/// base weight is recovered bit-exactly.
pub fn unsplice_rows(w: &mut HostTensor, idx: &[u32],
                     saved: &HostTensor) -> Result<()> {
    if saved.shape.len() != 2 || saved.shape[0] != idx.len()
        || w.shape.len() != 2 || saved.shape[1] != w.shape[1]
        || saved.dtype != w.dtype
    {
        return Err(anyhow!(
            "unsplice: saved rows {:?} do not fit W {:?} with {} indices",
            saved.shape, w.shape, idx.len()));
    }
    if let Some(&bad) = idx.iter().find(|&&i| (i as usize) >= w.shape[0]) {
        return Err(anyhow!("unsplice: row {bad} out of range (rows {})",
                           w.shape[0]));
    }
    w.write_rows(idx, saved);
    Ok(())
}

/// Merge one target linear's effective weight from the method-specific
/// parameters. `get` fetches a sibling tensor ("a", "b", "idx", …).
pub fn merge_linear(
    info: &ArtifactInfo, prefix: &str,
    get: &dyn Fn(&str) -> Result<HostTensor>) -> Result<HostTensor> {
    let method = info.method.as_str();
    let scaling = if info.rank > 0 {
        (info.alpha / info.rank as f64) as f32
    } else {
        1.0
    };
    let g = |s: &str| get(&format!("{prefix}/{s}"));

    match method {
        "full" | "paca" => g("w"),
        "lora" | "moslora" | "dora" => {
            let w = g("w")?;
            let a = g("a")?;
            let b = g("b")?;
            let (d_in, r) = (a.shape[0], a.shape[1]);
            let d_out = b.shape[1];
            let ab = if method == "moslora" {
                let mix = g("mix")?;
                let am = matmul(&a.as_f32(), &mix.as_f32(), d_in, r, r);
                matmul(&am, &b.as_f32(), d_in, r, d_out)
            } else {
                matmul(&a.as_f32(), &b.as_f32(), d_in, r, d_out)
            };
            let mut w_eff = w.as_f32();
            for (we, d) in w_eff.iter_mut().zip(&ab) {
                *we += scaling * d;
            }
            if method == "dora" {
                let mag = g("mag")?.as_f32();
                // column norms over the d_in axis
                let mut norms = vec![0f32; d_out];
                for i in 0..d_in {
                    for j in 0..d_out {
                        let v = w_eff[i * d_out + j];
                        norms[j] += v * v;
                    }
                }
                for n in norms.iter_mut() {
                    *n = n.sqrt() + 1e-6;
                }
                for i in 0..d_in {
                    for j in 0..d_out {
                        w_eff[i * d_out + j] *= mag[j] / norms[j];
                    }
                }
            }
            Ok(HostTensor::from_f32(&[d_in, d_out], w_eff))
        }
        "qlora" | "qpaca" => {
            let codes_t = g("codes")?;
            let scales_t = g("scales")?;
            let codes: Vec<i8> = codes_t.data.iter()
                .map(|&b| b as i8).collect();
            let block = codes_t.shape[1];
            let mut w = nf4::dequantize(&codes, &scales_t.as_f32(),
                                        block);
            if method == "qlora" {
                let a = g("a")?;
                let b = g("b")?;
                let (d_in, r) = (a.shape[0], a.shape[1]);
                let d_out = b.shape[1];
                let ab = matmul(&a.as_f32(), &b.as_f32(), d_in, r,
                                d_out);
                for (we, d) in w.iter_mut().zip(&ab) {
                    *we += scaling * d;
                }
                Ok(HostTensor::from_f32(&[d_in, d_out], w))
            } else {
                let p = g("p")?;
                let idx = g("idx")?;
                let d_out = p.shape[1];
                let d_in = w.len() / d_out;
                let idx: Vec<u32> = idx.as_i32().iter()
                    .map(|&i| i as u32).collect();
                let mut wt = HostTensor::from_f32(&[d_in, d_out], w);
                splice_rows(&mut wt, &idx, &p)?;
                Ok(wt)
            }
        }
        other => Err(anyhow!("merge: unknown method {other:?}")),
    }
}

/// Build the full merged parameter list matching `eval_entries` order
/// (the eval artifact's full-shape layout).
pub fn merged_state(
    train_info: &ArtifactInfo,
    eval_entries: &[crate::manifest::EntrySpec],
    get: &dyn Fn(&str) -> Result<HostTensor>) -> Result<Vec<HostTensor>> {
    let mut out = Vec::with_capacity(eval_entries.len());
    for e in eval_entries {
        // Target linears live under blocks/<i>/<t>/w and need merging;
        // everything else (embed, norms, head) passes through.
        let is_target = e.name.starts_with("blocks/")
            && e.name.ends_with("/w");
        let t = if is_target {
            let prefix = e.name.strip_suffix("/w").unwrap();
            merge_linear(train_info, prefix, get)?
        } else {
            get(&e.name)?
        };
        if t.shape != e.shape || t.dtype != e.dtype {
            return Err(anyhow!(
                "merged {} has shape {:?}, eval graph wants {:?}",
                e.name, t.shape, e.shape));
        }
        out.push(t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let c = matmul(&[1., 2., 3., 4.], &[5., 6., 7., 8.], 2, 2, 2);
        assert_eq!(c, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_rect() {
        // (1x3) @ (3x2)
        let c = matmul(&[1., 0., 2.], &[1., 2., 3., 4., 5., 6.],
                       1, 3, 2);
        assert_eq!(c, vec![11., 14.]);
    }

    fn info(method: &str, rank: usize, alpha: f64) -> ArtifactInfo {
        ArtifactInfo {
            name: "t".into(), file: String::new(), kind: "train_step"
                .into(), model: "tiny-lm".into(),
            method: method.into(), rank, alpha, batch: 1, seq: 1,
            use_pallas: false, trainable_params: 0, state: vec![],
            batch_inputs: vec![], extra_inputs: vec![],
            outputs: vec![],
        }
    }

    #[test]
    fn lora_merge_adds_scaled_ab() {
        let inf = info("lora", 2, 4.0); // scaling = 2
        let get = |name: &str| -> Result<HostTensor> {
            Ok(match name {
                "l/w" => HostTensor::from_f32(&[2, 2],
                                              vec![1., 0., 0., 1.]),
                "l/a" => HostTensor::from_f32(&[2, 2],
                                              vec![1., 0., 0., 1.]),
                "l/b" => HostTensor::from_f32(&[2, 2],
                                              vec![0.5, 0., 0., 0.5]),
                other => return Err(anyhow!("{other}")),
            })
        };
        let m = merge_linear(&inf, "l", &get).unwrap();
        // W + 2·(I·0.5I) = I + I = 2I
        assert_eq!(m.as_f32(), vec![2., 0., 0., 2.]);
    }

    #[test]
    fn splice_unsplice_is_bit_exact() {
        let mut w = HostTensor::from_f32(
            &[4, 3], (0..12).map(|i| i as f32 * 0.25).collect());
        let orig = w.data.clone();
        let p = HostTensor::from_f32(&[2, 3], vec![9.; 6]);
        let saved = splice_rows(&mut w, &[2, 0], &p).unwrap();
        assert_eq!(w.row_f32(0), vec![9., 9., 9.]);
        assert_eq!(w.row_f32(2), vec![9., 9., 9.]);
        assert_eq!(w.row_f32(1), vec![0.75, 1.0, 1.25]); // untouched
        unsplice_rows(&mut w, &[2, 0], &saved).unwrap();
        assert_eq!(w.data, orig);
    }

    #[test]
    fn splice_rejects_bad_inputs() {
        let mut w = HostTensor::from_f32(&[4, 2], vec![0.; 8]);
        let p = HostTensor::from_f32(&[2, 2], vec![1.; 4]);
        assert!(splice_rows(&mut w, &[0, 0], &p).is_err()); // dup idx
        assert!(splice_rows(&mut w, &[0, 9], &p).is_err()); // oob
        assert!(splice_rows(&mut w, &[0], &p).is_err());    // len mismatch
        let bad = HostTensor::from_f32(&[2, 3], vec![1.; 6]);
        assert!(splice_rows(&mut w, &[0, 1], &bad).is_err()); // cols
    }

    #[test]
    fn qpaca_merge_overwrites_selected_rows() {
        let inf = info("qpaca", 1, 1.0);
        let w = vec![0.5f32; 64]; // one quant block, scale 0.5
        let (codes, scales) = nf4::quantize(&w, 64);
        let get = move |name: &str| -> Result<HostTensor> {
            Ok(match name {
                "l/codes" => HostTensor::from_i8(&[1, 64],
                                                 codes.clone()),
                "l/scales" => HostTensor::from_f32(&[1],
                                                   scales.clone()),
                "l/p" => HostTensor::from_f32(&[1, 8], vec![9.0; 8]),
                "l/idx" => HostTensor::from_i32(&[1], vec![3]),
                other => return Err(anyhow!("{other}")),
            })
        };
        let m = merge_linear(&inf, "l", &get).unwrap();
        let v = m.as_f32();
        assert_eq!(m.shape, vec![8, 8]);
        assert!(v[3 * 8..4 * 8].iter().all(|&x| x == 9.0));
        assert!((v[0] - 0.5).abs() < 0.05); // dequantized base row
    }
}
