//! Learning-rate schedules, computed host-side and fed to the lowered
//! train-step graph as a runtime scalar (one artifact serves any
//! schedule). Paper setups: cosine + 100-step warmup for MMLU (Table 9),
//! linear + 10% warmup ratio for Oasst1 (Tables 10–11).

use crate::config::SchedKind;

#[derive(Debug, Clone)]
pub struct Schedule {
    pub kind: SchedKind,
    pub peak_lr: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
}

impl Schedule {
    pub fn new(kind: SchedKind, peak_lr: f64, warmup_steps: usize,
               total_steps: usize) -> Schedule {
        Schedule { kind, peak_lr, warmup_steps, total_steps }
    }

    /// LR for 0-based step index.
    pub fn lr(&self, step: usize) -> f64 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.peak_lr * (step + 1) as f64
                / self.warmup_steps as f64;
        }
        let decay_steps =
            self.total_steps.saturating_sub(self.warmup_steps).max(1);
        let t = (step - self.warmup_steps.min(step)) as f64
            / decay_steps as f64;
        let t = t.min(1.0);
        match self.kind {
            SchedKind::Constant => self.peak_lr,
            SchedKind::Linear => self.peak_lr * (1.0 - t),
            SchedKind::Cosine => {
                self.peak_lr * 0.5
                    * (1.0 + (std::f64::consts::PI * t).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::new(SchedKind::Cosine, 1.0, 10, 100);
        assert!((s.lr(0) - 0.1).abs() < 1e-12);
        assert!((s.lr(4) - 0.5).abs() < 1e-12);
        assert!((s.lr(9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_decays_to_zero() {
        let s = Schedule::new(SchedKind::Cosine, 1.0, 0, 100);
        assert!((s.lr(0) - 1.0).abs() < 1e-9);
        assert!((s.lr(50) - 0.5).abs() < 1e-9);
        assert!(s.lr(100) < 1e-9);
        // never increases after warmup
        for i in 1..=100 {
            assert!(s.lr(i) <= s.lr(i - 1) + 1e-12);
        }
    }

    #[test]
    fn linear_decays_to_zero() {
        let s = Schedule::new(SchedKind::Linear, 2.0, 0, 10);
        assert!((s.lr(5) - 1.0).abs() < 1e-12);
        assert!(s.lr(10) == 0.0);
    }

    #[test]
    fn constant_holds() {
        let s = Schedule::new(SchedKind::Constant, 0.5, 2, 10);
        assert_eq!(s.lr(5), 0.5);
        assert_eq!(s.lr(500), 0.5);
    }

    #[test]
    fn past_total_is_clamped() {
        let s = Schedule::new(SchedKind::Cosine, 1.0, 0, 10);
        assert!(s.lr(10_000) >= 0.0);
        assert!(s.lr(10_000) < 1e-9);
    }
}
