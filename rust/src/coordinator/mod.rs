//! Training coordinator: owns the state buffers, drives the AOT
//! train-step executable, schedules data + LR, evaluates, checkpoints.
//!
//! Hot-loop design (see EXPERIMENTS.md §Perf): state lives as PJRT
//! literals; only the entries the graph updates are replaced after each
//! step (frozen weights and index vectors are uploaded once), and batch
//! generation runs on a prefetch thread overlapping execution.

pub mod checkpoint;
pub mod merge;
pub mod schedule;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::TrainConfig;
use crate::data::{Task, TokenGen};
use crate::init;
use crate::metrics::{LossCurve, PhaseTimers};
use crate::peft::Selection;
use crate::runtime::{Executable, Runtime};
use crate::tensor::HostTensor;
use schedule::Schedule;

/// Per-category evaluation result (Table 1 subject columns / Table 2
/// MT-Bench-category columns).
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub categories: Vec<&'static str>,
    pub loss: Vec<f64>,
    pub acc: Vec<f64>,
}

impl EvalReport {
    pub fn mean_loss(&self) -> f64 {
        self.loss.iter().sum::<f64>() / self.loss.len() as f64
    }

    pub fn mean_acc(&self) -> f64 {
        self.acc.iter().sum::<f64>() / self.acc.len() as f64
    }

    /// MT-Bench-style 0–10 score proxy from token accuracy (DESIGN.md
    /// §4: the GPT judge is external to the paper's contribution; the
    /// monotone mapping preserves method ordering).
    pub fn scores(&self) -> Vec<f64> {
        self.acc.iter().map(|a| 10.0 * a).collect()
    }
}

pub struct Trainer {
    pub exe: Arc<Executable>,
    eval_exe: Option<Arc<Executable>>,
    cfg: TrainConfig,
    sched: Schedule,
    task: Task,
    gen: TokenGen,
    /// Training state, split by mutability (see runtime::to_device's
    /// safety contract): frozen entries live as device buffers uploaded
    /// once; updated entries live as host literals (each step's outputs
    /// replace them without a re-upload; they are uploaded as
    /// immediately-executed temporaries per dispatch).
    frozen: Vec<Option<crate::runtime::DeviceTensor>>,
    updated: Vec<Option<xla::Literal>>,
    name_to_idx: HashMap<String, usize>,
    updated_idx: Vec<usize>,
    pub step: usize,
    pub curve: LossCurve,
    pub timers: PhaseTimers,
}

impl Trainer {
    pub fn new(rt: &Runtime, cfg: TrainConfig) -> Result<Trainer> {
        let exe = rt.load(&cfg.artifact)?;
        let info = &exe.info;
        if info.kind != "train_step" {
            return Err(anyhow!("{} is a {:?}, not a train_step",
                               cfg.artifact, info.kind));
        }
        let selection = match cfg.selection.as_str() {
            "random" => Selection::Random,
            "weight" | "weight-norm" => Selection::WeightNorm,
            other => return Err(anyhow!(
                "selection {other:?}: use Trainer::with_selection for \
                 gradient-based")),
        };
        Self::with_selection(rt, cfg, selection)
    }

    pub fn with_selection(rt: &Runtime, cfg: TrainConfig,
                          selection: Selection) -> Result<Trainer> {
        let exe = rt.load(&cfg.artifact)?;
        let info = exe.info.clone();
        let host_state = init::init_state(&info, cfg.seed, &selection)?;
        let mut frozen: Vec<Option<crate::runtime::DeviceTensor>> =
            Vec::with_capacity(host_state.len());
        let mut updated: Vec<Option<xla::Literal>> =
            Vec::with_capacity(host_state.len());
        for (t, e) in host_state.iter().zip(&info.state) {
            if e.updated {
                frozen.push(None);
                updated.push(Some(t.to_literal()?));
            } else {
                // Frozen buffers are uploaded once and used by every
                // subsequent execution (satisfying the execute-before-
                // drop contract).
                frozen.push(Some(exe.to_device(t.to_literal()?)?));
                updated.push(None);
            }
        }
        let name_to_idx: HashMap<String, usize> = info.state.iter()
            .enumerate().map(|(i, e)| (e.name.clone(), i)).collect();
        let updated_idx = info.updated_state_indices();

        let model = rt.manifest.model(&info.model)?;
        let task = Task::parse(&cfg.task)?;
        let gen = TokenGen::new(task, model.vocab, cfg.seed);

        // Companion eval artifact for the same model, if lowered.
        let eval_name = rt.manifest.artifacts.values()
            .find(|a| a.kind == "eval_step" && a.model == info.model)
            .map(|a| a.name.clone());
        let eval_exe = match eval_name {
            Some(n) => Some(rt.load(&n)?),
            None => None,
        };

        let sched = Schedule::new(cfg.sched, cfg.peak_lr,
                                  cfg.warmup_steps, cfg.steps);
        Ok(Trainer { exe, eval_exe, sched, task, gen, frozen, updated,
                     name_to_idx, updated_idx, step: 0,
                     curve: LossCurve::default(),
                     timers: PhaseTimers::default(), cfg })
    }

    pub fn info(&self) -> &crate::manifest::ArtifactInfo {
        &self.exe.info
    }

    pub fn batch_geometry(&self) -> (usize, usize) {
        (self.exe.info.batch, self.exe.info.seq)
    }

    /// One optimizer step on a fresh batch. Returns (loss, acc).
    pub fn train_step(&mut self) -> Result<(f64, f64)> {
        let (b, s) = self.batch_geometry();
        let t0 = Instant::now();
        let batch = self.gen.train_batch(b, s);
        let t1 = Instant::now();
        let lr = self.sched.lr(self.step) as f32;
        let (loss, acc) = self.dispatch(&batch, lr)?;
        self.step += 1;
        self.curve.push(self.step, loss, acc);
        self.timers.data_s += (t1 - t0).as_secs_f64();
        self.timers.total_s += t0.elapsed().as_secs_f64();
        Ok((loss, acc))
    }

    /// Dispatch one train-step with an explicit batch + lr (used by the
    /// benches to time the pure execution path).
    pub fn dispatch(&mut self, batch: &HostTensor,
                    lr: f32) -> Result<(f64, f64)> {
        let t0 = Instant::now();
        // Upload updated entries + batch + lr as temporaries; all are
        // consumed by run_b below, then dropped (safe per the
        // to_device contract). Frozen buffers are reused as-is.
        let mut temps: Vec<crate::runtime::DeviceTensor> = Vec::new();
        let mut slots: Vec<usize> = Vec::new(); // state idx per temp
        for (i, e) in self.exe.info.state.iter().enumerate() {
            if e.updated {
                let lit = self.updated[i].take()
                    .ok_or_else(|| anyhow!("missing state {}", e.name))?;
                temps.push(self.exe.to_device(lit)?);
                slots.push(i);
            }
        }
        let batch_buf = self.exe.to_device(batch.to_literal()?)?;
        let lr_buf = self.exe.to_device(
            HostTensor::scalar_f32(lr).to_literal()?)?;
        let mut ti = 0;
        let mut inputs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.frozen.len() + 2);
        for (i, f) in self.frozen.iter().enumerate() {
            match f {
                Some(d) => inputs.push(&d.buf),
                None => {
                    debug_assert_eq!(slots[ti], i);
                    inputs.push(&temps[ti].buf);
                    ti += 1;
                }
            }
        }
        inputs.push(&batch_buf.buf);
        inputs.push(&lr_buf.buf);
        let t1 = Instant::now();
        let outs = self.exe.run_b(&inputs)?;
        let t2 = Instant::now();

        let n_upd = self.updated_idx.len();
        debug_assert_eq!(outs.len(), n_upd + 2);
        let mut outs = outs;
        let acc_lit = outs.pop().unwrap();
        let loss_lit = outs.pop().unwrap();
        for (j, lit) in outs.into_iter().enumerate() {
            self.updated[self.updated_idx[j]] = Some(lit);
        }
        let loss = loss_lit.get_first_element::<f32>()
            .map_err(|e| anyhow!("loss fetch: {e:?}"))? as f64;
        let acc = acc_lit.get_first_element::<f32>()
            .map_err(|e| anyhow!("acc fetch: {e:?}"))? as f64;
        if !loss.is_finite() {
            return Err(anyhow!("non-finite loss at step {}: {loss}",
                               self.step));
        }
        self.timers.h2d_s += (t1 - t0).as_secs_f64();
        self.timers.execute_s += (t2 - t1).as_secs_f64();
        self.timers.d2h_s += t2.elapsed().as_secs_f64();
        Ok((loss, acc))
    }

    /// Run the configured number of steps, logging periodically.
    pub fn run(&mut self, verbose: bool) -> Result<()> {
        for _ in 0..self.cfg.steps {
            let (loss, acc) = self.train_step()?;
            if verbose && (self.step % self.cfg.log_every.max(1) == 0
                           || self.step == 1)
            {
                println!(
                    "step {:>5}  loss {:.4}  acc {:.3}  lr {:.2e}",
                    self.step, loss, acc, self.sched.lr(self.step - 1));
            }
            if self.cfg.eval_every > 0
                && self.step % self.cfg.eval_every == 0
            {
                let ev = self.evaluate(self.cfg.eval_batches)?;
                if verbose {
                    println!("  eval: loss {:.4} acc {:.3}",
                             ev.mean_loss(), ev.mean_acc());
                }
            }
        }
        if let Some(path) = self.cfg.checkpoint.clone() {
            self.save_checkpoint(Path::new(&path))?;
        }
        Ok(())
    }

    /// Per-category held-out evaluation via the method-agnostic eval
    /// artifact: adapters are merged into the base weights host-side
    /// first (merge.rs) — the paper's inference-time merging.
    pub fn evaluate(&mut self, batches: usize) -> Result<EvalReport> {
        let eval = self.eval_exe.clone().ok_or_else(|| {
            anyhow!("no eval artifact lowered for model {}",
                    self.exe.info.model)
        })?;
        let (b, s) = (eval.info.batch, eval.info.seq);
        let get = |name: &str| self.state_tensor(name);
        let merged = merge::merged_state(&self.exe.info,
                                         &eval.info.state, &get)?;
        // Upload merged params once, reuse across categories/batches.
        let merged_bufs: Vec<crate::runtime::DeviceTensor> = merged
            .iter()
            .map(|t| eval.to_device(t.to_literal()?))
            .collect::<Result<_>>()?;
        let cats = self.task.category_names();
        let mut report = EvalReport { categories: cats.to_vec(),
                                      loss: vec![0.0; cats.len()],
                                      acc: vec![0.0; cats.len()] };
        for (ci, _) in cats.iter().enumerate() {
            let (mut lsum, mut asum) = (0.0, 0.0);
            for bi in 0..batches.max(1) {
                let batch = self.gen.eval_batch(
                    b, s, ci, (bi as u64) << 8 | ci as u64);
                let batch_buf = eval.to_device(batch.to_literal()?)?;
                let mut inputs: Vec<&xla::PjRtBuffer> =
                    merged_bufs.iter().map(|d| &d.buf).collect();
                inputs.push(&batch_buf.buf);
                let outs = eval.run_b(&inputs)?;
                lsum += outs[0].get_first_element::<f32>()
                    .map_err(|e| anyhow!("{e:?}"))? as f64;
                asum += outs[1].get_first_element::<f32>()
                    .map_err(|e| anyhow!("{e:?}"))? as f64;
            }
            report.loss[ci] = lsum / batches.max(1) as f64;
            report.acc[ci] = asum / batches.max(1) as f64;
        }
        Ok(report)
    }

    /// Host copy of one state tensor by name (device → host readback).
    pub fn state_tensor(&self, name: &str) -> Result<HostTensor> {
        let i = *self.name_to_idx.get(name)
            .ok_or_else(|| anyhow!("no state tensor {name:?}"))?;
        if let Some(lit) = &self.updated[i] {
            return HostTensor::from_literal(lit);
        }
        self.frozen[i].as_ref()
            .ok_or_else(|| anyhow!("state slot {i} empty"))?
            .read()
    }

    pub fn state_names(&self) -> Vec<String> {
        self.exe.info.state.iter().map(|e| e.name.clone()).collect()
    }

    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let names = self.state_names();
        let tensors: Vec<HostTensor> = names.iter()
            .map(|n| self.state_tensor(n))
            .collect::<Result<_>>()?;
        checkpoint::save(path, &names, &tensors)
            .with_context(|| format!("saving {}", path.display()))
    }

    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let (names, tensors) = checkpoint::load(path)?;
        if names != self.state_names() {
            return Err(anyhow!(
                "checkpoint layout does not match artifact {} \
                 ({} vs {} tensors)",
                self.exe.info.name, names.len(),
                self.exe.info.state.len()));
        }
        for ((t, e), i) in tensors.iter().zip(&self.exe.info.state)
            .zip(0..)
        {
            if e.updated {
                self.updated[i as usize] = Some(t.to_literal()?);
            } else {
                self.frozen[i as usize] =
                    Some(self.exe.to_device(t.to_literal()?)?);
            }
        }
        // Restore the step counter for the LR schedule.
        if let Ok(t) = self.state_tensor("opt/step") {
            self.step = (t.as_i32()[0].max(1) - 1) as usize;
        }
        Ok(())
    }
}
