//! Minimal recursive-descent JSON parser (substrate — the offline build
//! has no serde_json). Full RFC 8259 value model with the subset of
//! escape handling the AOT manifest needs; numbers parse as f64 with an
//! exact-i64 fast path.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors (None on type mismatch) --
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) }
                               else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (used by the metrics/report writers).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.into(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte 0x{:02x}", c))),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()
                        .ok_or_else(|| self.err("eof in escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let n = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            s.push(char::from_u32(n)
                                   .unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Re-decode multi-byte utf8 starting at i-1.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    let chunk = std::str::from_utf8(
                        &self.b[start..start + len])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": "x"}], "c": {"d": false}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_bool(),
                   Some(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(),
                   Json::Str("A".into()));
        assert_eq!(Json::parse("\"héllo\"").unwrap(),
                   Json::Str("héllo".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m": {"x": [1,2.5,"s",null,true]}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }
}
