//! Deterministic RNG substrate (no `rand` crate offline): SplitMix64
//! for streams + xoshiro256** for bulk, Box–Muller normals, Fisher–Yates
//! partial shuffles, and Zipf sampling for the synthetic corpus.
//!
//! Streams are derived from (seed, tag-string) so every tensor / shard /
//! worker gets an independent, reproducible stream regardless of the
//! order in which they are initialized.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// FNV-1a offset basis — seed for incremental hashing via
/// `fnv1a_update`.
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// Fold `bytes` into a running FNV-1a state (start from FNV_OFFSET).
/// The single FNV implementation in the crate — tag streams,
/// checkpoint checksums, and the serving base fingerprint all go
/// through here.
pub fn fnv1a_update(mut h: u64, bytes: &[u8]) -> u64 {
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// One-shot FNV-1a over a byte buffer.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_update(FNV_OFFSET, bytes)
}

/// FNV-1a over the tag, mixed into the stream seed.
pub fn tag_hash(tag: &str) -> u64 {
    fnv1a(tag.as_bytes())
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm),
                  splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Independent stream for (seed, tag) — used per tensor name.
    pub fn for_tag(seed: u64, tag: &str) -> Rng {
        Rng::new(seed ^ tag_hash(tag))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Rejection-free 128-bit multiply method (Lemire).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// `r` distinct indices from [0, n) — partial Fisher–Yates.
    pub fn choice(&mut self, n: usize, r: usize) -> Vec<u32> {
        assert!(r <= n);
        let mut pool: Vec<u32> = (0..n as u32).collect();
        for i in 0..r {
            let j = self.range(i, n);
            pool.swap(i, j);
        }
        pool.truncate(r);
        pool
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Zipf(s) sampler over [0, n) via precomputed CDF inversion — the
/// token-frequency model of the synthetic corpus.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Zipf {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_tag() {
        let a: Vec<u64> = {
            let mut r = Rng::for_tag(7, "blocks/0/q/w");
            (0..4).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::for_tag(7, "blocks/0/q/w");
            (0..4).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = Rng::for_tag(7, "blocks/1/q/w");
            (0..4).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(42);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={}", mean);
        assert!((var - 1.0).abs() < 0.02, "var={}", var);
    }

    #[test]
    fn choice_distinct_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let idx = r.choice(64, 16);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 16);
            assert!(idx.iter().all(|&i| i < 64));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{:?}", counts);
        }
    }

    #[test]
    fn zipf_is_head_heavy_and_in_range() {
        let z = Zipf::new(100, 1.2);
        let mut r = Rng::new(5);
        let mut head = 0;
        for _ in 0..10_000 {
            let k = z.sample(&mut r);
            assert!(k < 100);
            if k < 10 {
                head += 1;
            }
        }
        assert!(head > 5_000, "head={}", head);
    }
}
