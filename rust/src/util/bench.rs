//! Micro-benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with mean / p50 / p95 / throughput reporting, used
//! by `cargo bench` targets in benches/.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    pub fn report(&self) {
        println!(
            "{:<44} {:>8} iters  mean {:>10.3?}  p50 {:>10.3?}  \
             p95 {:>10.3?}  min {:>10.3?}",
            self.name, self.iters, self.mean, self.p50, self.p95,
            self.min);
    }
}

/// Time `f` with `warmup` untimed runs and up to `iters` timed runs
/// (capped at `budget` wall-clock).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         budget: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if start.elapsed() > budget {
            break;
        }
    }
    summarize(name, samples)
}

pub fn summarize(name: &str, mut samples: Vec<Duration>) -> BenchResult {
    assert!(!samples.is_empty());
    samples.sort_unstable();
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        min: samples[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let r = summarize("t", vec![Duration::from_millis(1),
                                    Duration::from_millis(2),
                                    Duration::from_millis(30)]);
        assert_eq!(r.min, Duration::from_millis(1));
        assert_eq!(r.p50, Duration::from_millis(2));
        assert!(r.p95 >= r.p50);
        assert_eq!(r.iters, 3);
    }

    #[test]
    fn bench_runs_and_caps() {
        let mut count = 0;
        let r = bench("noop", 2, 1000, Duration::from_millis(50), || {
            count += 1;
            std::thread::sleep(Duration::from_micros(200));
        });
        assert!(r.iters >= 1);
        assert_eq!(count, r.iters + 2);
    }
}
