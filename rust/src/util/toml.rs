//! Minimal TOML-subset parser for run configs (substrate — no `toml`
//! crate offline). Supports: `[section]` / `[section.sub]` tables,
//! `key = value` with strings, integers, floats, booleans, and flat
//! arrays, plus `#` comments. Keys flatten to `section.sub.key`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Flat `section.key -> value` document.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
        let mut entries = BTreeMap::new();
        let mut prefix = String::new();
        for (ln, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(TomlError {
                    line: ln + 1,
                    msg: "unterminated table header".into(),
                })?;
                prefix = name.trim().to_string();
                continue;
            }
            let eq = line.find('=').ok_or(TomlError {
                line: ln + 1,
                msg: "expected key = value".into(),
            })?;
            let key = line[..eq].trim();
            let val = parse_value(line[eq + 1..].trim(), ln + 1)?;
            let full = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{}.{}", prefix, key)
            };
            entries.insert(full, val);
        }
        Ok(TomlDoc { entries })
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }
    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings must not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<TomlValue, TomlError> {
    let err = |msg: &str| TomlError { line, msg: msg.into() };
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.rfind('"').ok_or_else(|| err("unterminated string"))?;
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']')
            .ok_or_else(|| err("unterminated array"))?;
        let mut out = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for item in trimmed.split(',') {
                let item = item.trim();
                if item.is_empty() {
                    continue; // trailing comma
                }
                out.push(parse_value(item, line)?);
            }
        }
        return Ok(TomlValue::Arr(out));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(&format!("cannot parse value: {}", s)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typed_values() {
        let doc = TomlDoc::parse(
            "a = 1\nb = 2.5\nc = \"hi\"\nd = true\ne = [1, 2, 3]\n",
        )
        .unwrap();
        assert_eq!(doc.i64_or("a", 0), 1);
        assert_eq!(doc.f64_or("b", 0.0), 2.5);
        assert_eq!(doc.str_or("c", ""), "hi");
        assert!(doc.bool_or("d", false));
        assert_eq!(
            doc.get("e").unwrap(),
            &TomlValue::Arr(vec![TomlValue::Int(1), TomlValue::Int(2),
                                 TomlValue::Int(3)])
        );
    }

    #[test]
    fn sections_flatten() {
        let doc = TomlDoc::parse(
            "[train]\nlr = 3e-4 # peak\n[train.sched]\nwarmup = 100\n",
        )
        .unwrap();
        assert_eq!(doc.f64_or("train.lr", 0.0), 3e-4);
        assert_eq!(doc.i64_or("train.sched.warmup", 0), 100);
    }

    #[test]
    fn comments_and_underscores() {
        let doc = TomlDoc::parse("# header\nn = 1_000_000\ns = \"a # b\"\n")
            .unwrap();
        assert_eq!(doc.i64_or("n", 0), 1_000_000);
        assert_eq!(doc.str_or("s", ""), "a # b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(e.line, 2);
    }
}
