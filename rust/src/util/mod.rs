//! Zero-dependency substrates: JSON + TOML-subset parsers, deterministic
//! RNG, and the micro-bench / property-test harnesses (the offline build
//! vendors only the `xla` crate and its deps — no serde/clap/criterion).

pub mod bench;
pub mod json;
pub mod rng;
pub mod toml;
