"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: each Pallas kernel is asserted
allclose against its oracle in `python/tests/` over hypothesis-driven
shape/dtype sweeps, and the L2 graphs can be lowered against either
implementation (`PeftConfig.use_pallas`) with identical numerics.
"""

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# PaCA partial-connection gradient:  ∇P = (ᵖX_in)ᵀ · ∇X_out   (paper Eq. 9)
# Convention: activations are (T, d) row-major with y = x @ W, W: (d_in,
# d_out); the paper's "columns of W ∈ R^{d_out×d_in}" are our W *rows*,
# i.e. input-feature slices. idx selects r input features.
# ---------------------------------------------------------------------------


def paca_grad_ref(xp: jnp.ndarray, dy: jnp.ndarray) -> jnp.ndarray:
    """∇P from pre-gathered partial activations. xp: (T, r), dy: (T, d_out)
    -> (r, d_out)."""
    return xp.T @ dy


def paca_grad_fused_ref(x: jnp.ndarray, idx: jnp.ndarray,
                        dy: jnp.ndarray) -> jnp.ndarray:
    """Fused gather+grad: x: (T, d_in), idx: (r,) int32, dy: (T, d_out)."""
    return jnp.take(x, idx, axis=1).T @ dy


def gather_cols_ref(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """ᵖX_in = x[:, idx]. x: (T, d_in), idx: (r,) -> (T, r)."""
    return jnp.take(x, idx, axis=1)


def scatter_rows_ref(w: jnp.ndarray, idx: jnp.ndarray,
                     p: jnp.ndarray) -> jnp.ndarray:
    """Write the fine-tuned rows back into W: w[idx, :] = p."""
    return w.at[idx, :].set(p)


def scatter_add_rows_ref(w: jnp.ndarray, idx: jnp.ndarray,
                         dp: jnp.ndarray) -> jnp.ndarray:
    return w.at[idx, :].add(dp)


# ---------------------------------------------------------------------------
# LoRA adapter forward: y = x @ W + scaling * (x @ A) @ B
# Two separate GEMMs — the serialized-adapter structure the paper measures.
# A: (d_in, r), B: (r, d_out).
# ---------------------------------------------------------------------------


def lora_fwd_ref(x, w, a, b, scaling):
    return x @ w + scaling * ((x @ a) @ b)


def lora_adapter_ref(x, a, b, scaling):
    return scaling * ((x @ a) @ b)


# ---------------------------------------------------------------------------
# RMSNorm: x * rsqrt(mean(x^2) + eps) * g
# ---------------------------------------------------------------------------


def rmsnorm_ref(x, g, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


# ---------------------------------------------------------------------------
# NF4 (4-bit NormalFloat, QLoRA §3). 16-value codebook = quantiles of
# N(0,1) normalized to [-1, 1]; per-block absmax scaling.
# ---------------------------------------------------------------------------

# The exact NF4 codebook from Dettmers et al. 2023 (bitsandbytes
# functional.py); index 7 is exactly 0.
NF4_CODEBOOK = jnp.array([
    -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
    -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
    0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
    0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
    0.7229568362236023, 1.0,
], dtype=jnp.float32)


def nf4_quantize_ref(w: jnp.ndarray, block: int = 64):
    """w: any shape with size % block == 0 -> (codes int8 (nblocks, block),
    scales f32 (nblocks,)). Nearest-codebook-entry rounding."""
    flat = w.reshape(-1, block)
    scales = jnp.max(jnp.abs(flat), axis=1, keepdims=True)
    # Avoid 0/0 on all-zero blocks.
    normed = flat / jnp.where(scales == 0.0, 1.0, scales)
    # (nblocks, block, 16) distance to each code.
    dist = jnp.abs(normed[..., None] - NF4_CODEBOOK[None, None, :])
    codes = jnp.argmin(dist, axis=-1).astype(jnp.int8)
    return codes, scales[:, 0]


def nf4_dequantize_ref(codes: jnp.ndarray, scales: jnp.ndarray,
                       shape, block: int = 64) -> jnp.ndarray:
    """codes: (nblocks, block) int8, scales: (nblocks,) -> f32 `shape`."""
    vals = NF4_CODEBOOK[codes.astype(jnp.int32)] * scales[:, None]
    return vals.reshape(shape)


# ---------------------------------------------------------------------------
# Softmax cross-entropy with integer targets (LM head loss).
# ---------------------------------------------------------------------------


def softmax_xent_ref(logits: jnp.ndarray, targets: jnp.ndarray):
    """logits: (T, V), targets: (T,) int32 -> (loss_per_tok (T,), ncorrect)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    loss = logz - gold
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == targets)
                      .astype(jnp.float32))
    return loss, correct
