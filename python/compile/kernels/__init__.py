"""L1: Pallas kernels for PaCA's compute hot-spots + baselines.

- paca_grad:  ∇P = (ᵖX_in)ᵀ∇X_out — the paper's only new backward op.
- gather:     partial-activation gather / fine-tuned-row scatter.
- lora:       two-serialized-GEMM adapter baseline.
- nf4:        4-bit NormalFloat dequant (QPaCA/QLoRA path).
- rmsnorm:    substrate norm kernel.
- ref:        pure-jnp oracles for all of the above.
"""

from . import gather, lora, nf4, paca_grad, ref, rmsnorm  # noqa: F401
