"""Pallas kernels for the LoRA adapter path (the paper's baseline).

The adapter is *deliberately* two separate pallas_calls — `x @ A` then
`(xA) @ B` — mirroring the two serialized GPU kernels whose launch +
sync latency is the overhead the paper measures in Fig. 2. Keeping the
structure lets the lowered HLO exhibit the same non-fusable two-pass
shape on TPU (two grid invocations over HBM).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 128
BLOCK_IN = 128
BLOCK_OUT = 128


def _pad(x, axis, mult):
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _matmul_kernel(x_ref, w_ref, o_ref):
    """Grid = (T/bT, N/bN, K/bK), accumulating over K."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(x: jnp.ndarray, w: jnp.ndarray,
           interpret: bool = True) -> jnp.ndarray:
    """Tiled (T, K) @ (K, N) Pallas matmul."""
    t, k = x.shape
    k2, n = w.shape
    assert k == k2
    bt = min(BLOCK_T, max(8, t))
    bk = min(BLOCK_IN, max(8, k))
    bn = min(BLOCK_OUT, max(8, n))
    x_p = _pad(_pad(x, 0, bt), 1, bk)
    w_p = _pad(_pad(w, 0, bk), 1, bn)
    tp, kp = x_p.shape
    np_ = w_p.shape[1]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(tp // bt, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((tp, np_), jnp.float32),
        interpret=interpret,
    )(x_p.astype(jnp.float32), w_p.astype(jnp.float32))
    return out[:t, :n]


def lora_adapter(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                 scaling: float, interpret: bool = True) -> jnp.ndarray:
    """scaling * (x @ A) @ B as two serialized kernel invocations."""
    x_mid = matmul(x, a, interpret=interpret)
    return scaling * matmul(x_mid, b, interpret=interpret)


def lora_fwd(x, w, a, b, scaling, interpret: bool = True):
    """Full LoRA forward: frozen GEMM + serialized adapter GEMMs."""
    return matmul(x, w, interpret=interpret) + lora_adapter(
        x, a, b, scaling, interpret=interpret)
