"""Pallas RMSNorm kernel (LLaMA-style pre-normalization).

Row-parallel: each grid step normalizes a block of token rows entirely
in VMEM (one HBM read + one write per element, VPU-only). Included both
as a substrate kernel for the L2 model and as a simple single-pass
baseline for the kernel test-suite.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 256


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(var + eps) * g_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret", "eps"))
def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6,
            interpret: bool = True) -> jnp.ndarray:
    """x: (T, d), g: (d,) -> (T, d)."""
    t, d = x.shape
    bt = min(BLOCK_T, max(8, t))
    rem = (-t) % bt
    x_p = jnp.pad(x, ((0, rem), (0, 0))) if rem else x
    tp = x_p.shape[0]
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(tp // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, d), jnp.float32),
        interpret=interpret,
    )(x_p.astype(jnp.float32), g.astype(jnp.float32))
    return out[:t]
