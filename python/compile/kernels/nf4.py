"""Pallas NF4 (4-bit NormalFloat) dequantize kernel — the QPaCA hot path.

QLoRA/QPaCA store frozen weights as 4-bit codebook indices plus a per-
block absmax scale and dequantize on the fly in every forward/backward.
Quantization happens once at load time, so only the *dequant* needs a
kernel; quantize stays a jnp reference (ref.nf4_quantize_ref).

TPU mapping: dequant is a pure VPU op — a 16-entry table lookup fused
with the scale multiply while the block streams HBM→VMEM. The codebook
lives in registers. Block size 64 matches bitsandbytes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NF4_CODEBOOK

BLOCKS_PER_STEP = 64  # quant blocks handled per grid step


def _dequant_kernel(cb_ref, codes_ref, scales_ref, o_ref):
    codes = codes_ref[...].astype(jnp.int32)          # (bB, block)
    vals = cb_ref[...][codes]                         # table lookup (VPU)
    o_ref[...] = vals * scales_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def nf4_dequantize(codes: jnp.ndarray, scales: jnp.ndarray,
                   interpret: bool = True) -> jnp.ndarray:
    """codes: (nblocks, block) int8, scales: (nblocks,) f32 ->
    (nblocks, block) f32. Caller reshapes to the weight shape."""
    nblocks, block = codes.shape
    bb = min(BLOCKS_PER_STEP, nblocks)
    rem = (-nblocks) % bb
    if rem:
        codes = jnp.pad(codes, ((0, rem), (0, 0)))
        scales = jnp.pad(scales, (0, rem))
    nb_p = codes.shape[0]
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nb_p // bb,),
        in_specs=[
            pl.BlockSpec((16,), lambda i: (0,)),
            pl.BlockSpec((bb, block), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb_p, block), jnp.float32),
        interpret=interpret,
    )(NF4_CODEBOOK, codes, scales.astype(jnp.float32))
    return out[:nblocks]


def dequant_weight(codes: jnp.ndarray, scales: jnp.ndarray, shape,
                   interpret: bool = True) -> jnp.ndarray:
    """Dequantize straight to a weight matrix of `shape`."""
    return nf4_dequantize(codes, scales, interpret=interpret).reshape(shape)
