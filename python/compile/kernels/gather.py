"""Pallas column-gather / row-scatter kernels for PaCA bookkeeping.

`gather_cols` extracts the partial activations ᵖX_in = X_in[:, idx] that
PaCA stores as the *only* backward residual (the activation-memory saving
of the paper). `scatter_rows` writes the fine-tuned rows P back into the
merged weight after the optimizer step.

On TPU both are pure DMA-shaping ops: the gather is a strided HBM→VMEM
read, the scatter a strided VMEM→HBM write; neither touches the MXU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 256


def _gather_kernel(idx_ref, x_ref, o_ref):
    o_ref[...] = jnp.take(x_ref[...], idx_ref[...], axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_cols(x: jnp.ndarray, idx: jnp.ndarray,
                interpret: bool = True) -> jnp.ndarray:
    """x: (T, d_in), idx: (r,) int32 -> (T, r)."""
    t, d_in = x.shape
    (r,) = idx.shape
    bt = min(BLOCK_T, max(8, t))
    rem = (-t) % bt
    x_p = jnp.pad(x, ((0, rem), (0, 0))) if rem else x
    tp = x_p.shape[0]
    out = pl.pallas_call(
        _gather_kernel,
        grid=(tp // bt,),
        in_specs=[
            pl.BlockSpec((r,), lambda i: (0,)),
            pl.BlockSpec((bt, d_in), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, r), x.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), x_p)
    return out[:t]


def _scatter_kernel(idx_ref, p_ref, w_ref, o_ref):
    """One grid step owns the whole matrix (scatter is index-chasing, not
    tileable along the scattered axis without sorting idx)."""
    idx = idx_ref[...]
    o_ref[...] = w_ref[...].at[idx, :].set(p_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_rows(w: jnp.ndarray, idx: jnp.ndarray, p: jnp.ndarray,
                 interpret: bool = True) -> jnp.ndarray:
    """w: (d_in, d_out), idx: (r,), p: (r, d_out) -> w with rows replaced."""
    d_in, d_out = w.shape
    r = idx.shape[0]
    return pl.pallas_call(
        _scatter_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((r,), lambda i: (0,)),
            pl.BlockSpec((r, d_out), lambda i: (0, 0)),
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d_in, d_out), w.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), p, w)
