"""Pallas kernel for the PaCA partial-connection gradient (paper Eq. 9).

    ∇P = (ᵖX_in)ᵀ · ∇X_out        xp: (T, r), dy: (T, d_out) -> (r, d_out)

This is the only *new* computation PaCA adds to backpropagation — the
fwd/bwd matmuls are the frozen model's own kernels — so it is the L1
hot-spot of the paper's contribution.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles (r, d_out)
into MXU-friendly blocks and reduces over T in the innermost grid
dimension with a VMEM accumulator; the fused variant additionally indexes
the r selected features directly out of the full X_in block, so the
column gather rides the HBM→VMEM DMA instead of being a separate pass.

Executed with interpret=True (CPU PJRT cannot run Mosaic custom-calls);
see EXPERIMENTS.md §Perf for the VMEM/MXU estimates of the chosen blocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes chosen for the TPU MXU (128×128 systolic array) and VPU
# 8×128 lanes; on the interpret path they only affect loop structure.
BLOCK_T = 128
BLOCK_R = 128
BLOCK_OUT = 128


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _paca_grad_kernel(xp_ref, dy_ref, o_ref):
    """Grid = (r/bR, d_out/bO, T/bT); accumulate over the T axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (bT, bR)ᵀ @ (bT, bO) -> (bR, bO) partial product on the MXU.
    o_ref[...] += jnp.dot(xp_ref[...].T, dy_ref[...],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paca_grad(xp: jnp.ndarray, dy: jnp.ndarray,
              interpret: bool = True) -> jnp.ndarray:
    """∇P = xpᵀ @ dy with a tiled Pallas matmul.

    xp: (T, r) partial activations, dy: (T, d_out) output gradient.
    Arbitrary T/r/d_out (padded internally to block multiples).
    """
    t, r = xp.shape
    t2, d_out = dy.shape
    assert t == t2, (xp.shape, dy.shape)
    bt = min(BLOCK_T, max(8, t))
    br = min(BLOCK_R, max(8, r))
    bo = min(BLOCK_OUT, max(8, d_out))
    xp_p = _pad_to(_pad_to(xp, 0, bt), 1, br)
    dy_p = _pad_to(_pad_to(dy, 0, bt), 1, bo)
    tp, rp = xp_p.shape
    op = dy_p.shape[1]
    grid = (rp // br, op // bo, tp // bt)
    out = pl.pallas_call(
        _paca_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, br), lambda i, j, k: (k, i)),
            pl.BlockSpec((bt, bo), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((br, bo), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, op), jnp.float32),
        interpret=interpret,
    )(xp_p.astype(jnp.float32), dy_p.astype(jnp.float32))
    return out[:r, :d_out]


def _paca_grad_fused_kernel(idx_ref, x_ref, dy_ref, o_ref):
    """Fused gather+grad: gather the selected features of the X_in block
    in-register, then the same tiled accumulation.

    Grid = (r/bR, d_out/bO, T/bT). x_ref block is (bT, d_in) — the gather
    picks the bR indices owned by grid row i out of the full feature dim,
    which on TPU is expressed as a strided HBM→VMEM DMA.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]  # (bR,) int32 feature indices for this grid row
    xp = jnp.take(x_ref[...], idx, axis=1)  # (bT, bR)
    o_ref[...] += jnp.dot(xp.T, dy_ref[...],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paca_grad_fused(x: jnp.ndarray, idx: jnp.ndarray, dy: jnp.ndarray,
                    interpret: bool = True) -> jnp.ndarray:
    """∇P = x[:, idx]ᵀ @ dy without materializing the gathered matrix in
    HBM. x: (T, d_in), idx: (r,) int32, dy: (T, d_out) -> (r, d_out)."""
    t, d_in = x.shape
    t2, d_out = dy.shape
    assert t == t2
    (r,) = idx.shape
    bt = min(BLOCK_T, max(8, t))
    br = min(BLOCK_R, max(8, r))
    bo = min(BLOCK_OUT, max(8, d_out))
    x_p = _pad_to(x, 0, bt)
    dy_p = _pad_to(_pad_to(dy, 0, bt), 1, bo)
    # Pad idx with repeats of index 0; padded rows are sliced off below.
    rem = (-r) % br
    idx_p = jnp.pad(idx, (0, rem)).astype(jnp.int32)
    tp = x_p.shape[0]
    rp, op = idx_p.shape[0], dy_p.shape[1]
    grid = (rp // br, op // bo, tp // bt)
    out = pl.pallas_call(
        _paca_grad_fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br,), lambda i, j, k: (i,)),
            pl.BlockSpec((bt, d_in), lambda i, j, k: (k, 0)),
            pl.BlockSpec((bt, bo), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((br, bo), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, op), jnp.float32),
        interpret=interpret,
    )(idx_p, x_p.astype(jnp.float32), dy_p.astype(jnp.float32))
    return out[:r, :d_out]


def vmem_bytes(t: int, r: int, d_out: int, d_in: int = 0,
               fused: bool = False) -> int:
    """Estimated per-step VMEM footprint of the kernel (f32)."""
    bt = min(BLOCK_T, max(8, t))
    br = min(BLOCK_R, max(8, r))
    bo = min(BLOCK_OUT, max(8, d_out))
    x_block = bt * (d_in if fused else br)
    return 4 * (x_block + bt * bo + br * bo)


def mxu_flops(t: int, r: int, d_out: int) -> int:
    """MAC-pair FLOPs the MXU performs for one ∇P."""
    return 2 * t * r * d_out
