"""PEFT parameterizations: full | lora | dora | moslora | paca | qlora | qpaca.

Every method is expressed as an `init_linear` (parameter layout + init
spec for the manifest) and an `apply_linear` (forward). PaCA/QPaCA use a
`jax.custom_vjp` so that

  * the forward is the frozen model's single GEMM (no adapter kernels),
  * the backward residual is ONLY the partial activations ᵖX_in — the
    activation-memory claim of the paper, and
  * ∇P is computed by the L1 Pallas kernel when `use_pallas` is set.

Parameter trees are FLAT dicts keyed by '/'-joined paths; a parallel
`Registry` of `ParamSpec`s records role/init/optimizer metadata and is
serialized into artifacts/manifest.json for the rust coordinator.

Roles:
  trainable — AdamW state attached, updated by the optimizer.
  paca_w    — PaCA's merged weight: forward uses it as-is; the optimizer
              updates only the `rank` selected rows (AdamW state is
              (r, d_out)); updated via scatter.
  frozen    — passed through unchanged (pretrained / quantized weights).
  index     — int32 selection indices (PaCA/QPaCA), constant.
"""

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import PeftConfig
from .kernels import gather as gather_k
from .kernels import nf4 as nf4_k
from .kernels import paca_grad as paca_k
from .kernels import ref as kref


# --------------------------------------------------------------------------
# Param spec registry (shared source of truth with the rust layer)
# --------------------------------------------------------------------------


@dataclass
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    dtype: str                     # "f32" | "i32" | "i8"
    role: str                      # trainable | paca_w | frozen | index
    init: Dict[str, Any]
    # Shape of the AdamW moment buffers, if any (differs from `shape` for
    # paca_w, where only the selected rows carry optimizer state).
    adam_shape: Optional[Tuple[int, ...]] = None

    @property
    def updated(self) -> bool:
        return self.role in ("trainable", "paca_w")


class Registry:
    def __init__(self):
        self.specs: List[ParamSpec] = []
        self._names = set()

    def add(self, spec: ParamSpec):
        assert spec.name not in self._names, spec.name
        self._names.add(spec.name)
        self.specs.append(spec)

    def by_role(self, *roles) -> List[ParamSpec]:
        return [s for s in self.specs if s.role in roles]


DTYPES = {"f32": jnp.float32, "i32": jnp.int32, "i8": jnp.int8}


# --------------------------------------------------------------------------
# PaCA dense op with custom VJP
# --------------------------------------------------------------------------
#
# fwd: y = x @ w                        — exactly the frozen model's GEMM.
# bwd: dx = dy @ wᵀ                     — paper Eq. 8
#      dp = (ᵖx)ᵀ dy                    — paper Eq. 9 (Pallas kernel)
#      dw = 0                           — w is only updated through dp.
#
# `p_dummy` carries no value (the current row values live inside w); it
# exists so jax.grad has a leaf to attach ∇P to. The train step gathers
# the current rows out of w, applies AdamW with the (r, d_out) moments,
# and scatters them back — keeping forward a single GEMM, as in the paper.


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def paca_dense(x, w, p_dummy, idx, use_pallas):
    del p_dummy, idx
    return x @ w


def _paca_dense_fwd(x, w, p_dummy, idx, use_pallas):
    del p_dummy
    y = x @ w
    # THE activation-memory saving: residual keeps only the r selected
    # features of x (plus the weight, which is not an activation).
    xp = jnp.take(x, idx, axis=-1)
    return y, (xp, w, idx, x.shape)


def _paca_dense_bwd(use_pallas, res, dy):
    xp, w, idx, x_shape = res
    dx = dy @ w.T
    xp2 = xp.reshape(-1, xp.shape[-1])
    dy2 = dy.reshape(-1, dy.shape[-1])
    if use_pallas:
        dp = paca_k.paca_grad(xp2, dy2, interpret=True)
    else:
        dp = kref.paca_grad_ref(xp2, dy2)
    dw = jnp.zeros_like(w)  # dead; DCE'd since w's grad is never requested
    didx = np.zeros(idx.shape, jax.dtypes.float0)
    return dx, dw, dp, didx


paca_dense.defvjp(_paca_dense_fwd, _paca_dense_bwd)


# QPaCA variant: w_full is reconstructed from NF4 codes each call; the
# trainable rows p are real parameters (they live outside the quantized
# base, as in the paper's 16-bit selected connections).


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def qpaca_dense(x, codes, scales, p, idx, shape_pallas):
    shape, use_pallas = shape_pallas
    w = _dequant(codes, scales, shape, use_pallas)
    w_full = w.at[idx, :].set(p)
    return x @ w_full


def _dequant(codes, scales, shape, use_pallas):
    if use_pallas:
        return nf4_k.dequant_weight(codes, scales, shape, interpret=True)
    return kref.nf4_dequantize_ref(codes, scales, shape)


def _qpaca_dense_fwd(x, codes, scales, p, idx, shape_pallas):
    shape, use_pallas = shape_pallas
    w = _dequant(codes, scales, shape, use_pallas)
    w_full = w.at[idx, :].set(p)
    y = x @ w_full
    xp = jnp.take(x, idx, axis=-1)
    # The dequantized weight is re-materialized in bwd from the 4-bit
    # codes (as in QLoRA) instead of being saved as a residual.
    return y, (xp, codes, scales, p, idx)


def _qpaca_dense_bwd(shape_pallas, res, dy):
    shape, use_pallas = shape_pallas
    xp, codes, scales, p, idx = res
    w_full = _dequant(codes, scales, shape, use_pallas).at[idx, :].set(p)
    dx = dy @ w_full.T
    xp2 = xp.reshape(-1, xp.shape[-1])
    dy2 = dy.reshape(-1, dy.shape[-1])
    if use_pallas:
        dp = paca_k.paca_grad(xp2, dy2, interpret=True)
    else:
        dp = kref.paca_grad_ref(xp2, dy2)
    zero_c = np.zeros(codes.shape, jax.dtypes.float0)
    dscales = jnp.zeros_like(scales)
    didx = np.zeros(idx.shape, jax.dtypes.float0)
    return dx, zero_c, dscales, dp, didx


qpaca_dense.defvjp(_qpaca_dense_fwd, _qpaca_dense_bwd)


# --------------------------------------------------------------------------
# init / apply per method
# --------------------------------------------------------------------------


def _normal(key, shape, std=0.02):
    return jax.random.normal(key, shape, dtype=jnp.float32) * std


def init_linear(key, reg: Registry, name: str, d_in: int, d_out: int,
                pcfg: PeftConfig, seed_tag: int) -> Dict[str, jnp.ndarray]:
    """Create the parameters of one PEFT-target linear layer `name`
    (flat-dict fragment) and register their specs."""
    m, r = pcfg.method, pcfg.rank
    kw, ka, ki = jax.random.split(key, 3)
    params: Dict[str, jnp.ndarray] = {}

    def add(suffix, arr, role, init, adam_shape=None):
        full = f"{name}/{suffix}"
        params[full] = arr
        dt = {"float32": "f32", "int32": "i32", "int8": "i8"}[str(arr.dtype)]
        reg.add(ParamSpec(full, tuple(arr.shape), dt, role, init,
                          adam_shape))

    w = _normal(kw, (d_in, d_out))
    w_init = {"kind": "normal", "std": 0.02}

    if m == "full":
        add("w", w, "trainable", w_init, adam_shape=(d_in, d_out))
        return params

    if m in ("lora", "moslora", "dora"):
        add("w", w, "frozen", w_init)
        a = _normal(ka, (d_in, r), std=1.0 / max(1, d_in) ** 0.5)
        add("a", a, "trainable",
            {"kind": "normal", "std": round(1.0 / max(1, d_in) ** 0.5, 6)},
            adam_shape=(d_in, r))
        add("b", jnp.zeros((r, d_out), jnp.float32), "trainable",
            {"kind": "zeros"}, adam_shape=(r, d_out))
        if m == "moslora":
            add("mix", jnp.eye(r, dtype=jnp.float32), "trainable",
                {"kind": "eye"}, adam_shape=(r, r))
        if m == "dora":
            mag = jnp.linalg.norm(w, axis=0)
            add("mag", mag, "trainable",
                {"kind": "col_norm", "of": f"{name}/w"},
                adam_shape=(d_out,))
        return params

    if m == "paca":
        add("w", w, "paca_w", w_init, adam_shape=(r, d_out))
        idx = jax.random.choice(ki, d_in, (r,), replace=False) \
            .astype(jnp.int32)
        add("idx", idx, "index",
            {"kind": "choice", "n": d_in, "seed_tag": seed_tag})
        return params

    if m in ("qlora", "qpaca"):
        codes, scales = kref.nf4_quantize_ref(w, pcfg.quant_block)
        add("codes", codes, "frozen",
            {"kind": "nf4_codes", "of_shape": [d_in, d_out],
             "std": 0.02, "block": pcfg.quant_block})
        add("scales", scales, "frozen",
            {"kind": "nf4_scales", "of_shape": [d_in, d_out],
             "std": 0.02, "block": pcfg.quant_block})
        if m == "qlora":
            a = _normal(ka, (d_in, r), std=1.0 / max(1, d_in) ** 0.5)
            add("a", a, "trainable",
                {"kind": "normal",
                 "std": round(1.0 / max(1, d_in) ** 0.5, 6)},
                adam_shape=(d_in, r))
            add("b", jnp.zeros((r, d_out), jnp.float32), "trainable",
                {"kind": "zeros"}, adam_shape=(r, d_out))
        else:  # qpaca: 16-bit selected rows, trainable
            idx = jax.random.choice(ki, d_in, (r,), replace=False) \
                .astype(jnp.int32)
            add("idx", idx, "index",
                {"kind": "choice", "n": d_in, "seed_tag": seed_tag})
            add("p", w[idx, :], "trainable",
                {"kind": "rows_of", "of_shape": [d_in, d_out],
                 "std": 0.02, "idx": f"{name}/idx"},
                adam_shape=(r, d_out))
        return params

    raise ValueError(m)


def apply_linear(params: Dict[str, jnp.ndarray], name: str, x, pcfg:
                 PeftConfig, paca_dummies: Optional[Dict] = None):
    """Forward one PEFT-target linear. x: (..., d_in) -> (..., d_out)."""
    m = pcfg.method
    g = lambda s: params[f"{name}/{s}"]  # noqa: E731

    if m == "full":
        return x @ g("w")
    if m == "lora":
        return x @ g("w") + pcfg.scaling * ((x @ g("a")) @ g("b"))
    if m == "moslora":
        return x @ g("w") + pcfg.scaling * (((x @ g("a")) @ g("mix"))
                                            @ g("b"))
    if m == "dora":
        w_dir = g("w") + pcfg.scaling * (g("a") @ g("b"))
        col_norm = jnp.linalg.norm(w_dir, axis=0, keepdims=True)
        w_eff = w_dir * (g("mag")[None, :] / (col_norm + 1e-6))
        return x @ w_eff
    if m == "paca":
        dummy = (paca_dummies or {}).get(
            f"{name}/w",
            jnp.zeros((pcfg.rank, g("w").shape[1]), jnp.float32))
        return paca_dense(x, g("w"), dummy, g("idx"), pcfg.use_pallas)
    if m == "qlora":
        shape = (g("a").shape[0], g("b").shape[1])
        w = _dequant(g("codes"), g("scales"), shape, pcfg.use_pallas)
        return x @ w + pcfg.scaling * ((x @ g("a")) @ g("b"))
    if m == "qpaca":
        p = g("p")
        d_in = g("codes").size // p.shape[1]
        shape = (d_in, p.shape[1])
        return qpaca_dense(x, g("codes"), g("scales"), p, g("idx"),
                           (shape, pcfg.use_pallas))
    raise ValueError(m)


def paca_dummy_tree(reg: Registry) -> Dict[str, jnp.ndarray]:
    """Zero-valued leaves jax.grad differentiates to obtain ∇P
    (one per paca_w spec; keyed by the weight's name)."""
    return {s.name: jnp.zeros(s.adam_shape, jnp.float32)
            for s in reg.specs if s.role == "paca_w"}


def trainable_param_count(reg: Registry) -> int:
    """Number of trainable scalars — the paper's `Param` column.
    For paca_w only the selected rows count."""
    n = 0
    for s in reg.specs:
        if s.role == "trainable":
            n += int(np.prod(s.shape))
        elif s.role == "paca_w":
            n += int(np.prod(s.adam_shape))
    return n
