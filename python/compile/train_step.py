"""Fused train-step / eval-step builders — the L2↔L3 interface.

One lowered HLO module performs: forward → backward → AdamW update →
PaCA row scatter, over a FLAT argument list so the rust coordinator can
drive it with positional PJRT literals:

    inputs  = [state_0 … state_{N-1}, batch…, lr]
    outputs = (updated-state entries in order…, loss, acc)

State entry order: every model ParamSpec (registry order), then one
AdamW `m` and one `v` buffer per optimizer-carrying spec, then the i32
step counter. Only entries with `updated=True` appear in the outputs —
frozen weights and index vectors never round-trip. The full layout is
serialized into artifacts/manifest.json by aot.py.

PaCA specifics (see peft.py): ∇P is pulled out of jax.grad via the
zero-valued dummy leaves; the optimizer gathers the current rows from
the merged weight, applies AdamW with (r, d_out) moments, and scatters
the rows back — forward stays a single GEMM per linear.
"""

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import cnn as cnn_mod
from . import model as lm
from . import vit as vit_mod
from .configs import ModelConfig, PeftConfig
from .optimizer import AdamHP, adamw_update
from .peft import Registry


@dataclass
class StateEntry:
    name: str
    shape: Tuple[int, ...]
    dtype: str
    role: str            # param roles | "opt_m" | "opt_v" | "opt_step"
    init: Dict[str, Any]
    updated: bool


def state_entries(reg: Registry) -> List[StateEntry]:
    entries = [StateEntry(s.name, s.shape, s.dtype, s.role, s.init,
                          s.updated) for s in reg.specs]
    for kind in ("m", "v"):
        for s in reg.specs:
            if s.adam_shape is not None:
                entries.append(StateEntry(
                    f"opt/{kind}/{s.name}", tuple(s.adam_shape), "f32",
                    f"opt_{kind}", {"kind": "zeros"}, True))
    entries.append(StateEntry("opt/step", (), "i32", "opt_step",
                              {"kind": "const_i32", "value": 1}, True))
    return entries


def batch_entries(kind: str, batch: int, seq: int) -> List[StateEntry]:
    if kind == "lm":
        return [StateEntry("batch/tokens", (batch, seq + 1), "i32",
                           "batch", {}, False)]
    assert kind in ("vit", "cnn")
    return [StateEntry("batch/images", (batch, 3, 32, 32), "f32", "batch",
                       {}, False),
            StateEntry("batch/labels", (batch,), "i32", "batch", {},
                       False)]


def build_train_step(cfg: ModelConfig, pcfg: PeftConfig, batch: int,
                     seq: int, kind: str = "lm",
                     hp: AdamHP = AdamHP()):
    """Returns (fn, entries, b_entries, params0, reg). fn takes
    len(entries)+len(b_entries)+1 positional arrays (last one = lr)."""
    key = jax.random.PRNGKey(0)
    if kind == "lm":
        params0, reg = lm.init_lm(key, cfg, pcfg)
    elif kind == "cnn":
        params0, reg = cnn_mod.init_cnn(key, cfg, pcfg)
    else:
        params0, reg = vit_mod.init_vit(key, cfg, pcfg)
    specs = reg.specs
    entries = state_entries(reg)
    b_entries = batch_entries(kind, batch, seq)
    names = [e.name for e in entries]
    diff_names = [s.name for s in specs if s.role == "trainable"]
    paca_specs = [s for s in specs if s.role == "paca_w"]

    def fn(*args):
        n = len(entries)
        arrays = dict(zip(names, args[:n]))
        rest = args[n:]
        params = {s.name: arrays[s.name] for s in specs}
        step = arrays["opt/step"]
        lr = rest[-1]

        diff = {k: params[k] for k in diff_names}
        dummies = {s.name: jnp.zeros(s.adam_shape, jnp.float32)
                   for s in paca_specs}

        def loss_fn(diff_p, dum):
            merged = {**params, **diff_p}
            if kind == "lm":
                loss, acc = lm.loss_and_acc(merged, rest[0], cfg, pcfg,
                                            dum)
            elif kind == "cnn":
                loss, acc = cnn_mod.loss_and_acc(merged, rest[0],
                                                 rest[1], pcfg, dum)
            else:
                loss, acc = vit_mod.loss_and_acc(merged, rest[0], rest[1],
                                                 cfg, pcfg, dum)
            return loss, acc

        (loss, acc), (g_diff, g_dum) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(diff, dummies)

        new_arrays = dict(arrays)
        # Standard trainable leaves: full-shape AdamW.
        for name in diff_names:
            p_new, m_new, v_new = adamw_update(
                params[name], g_diff[name], arrays[f"opt/m/{name}"],
                arrays[f"opt/v/{name}"], step, lr, hp)
            new_arrays[name] = p_new
            new_arrays[f"opt/m/{name}"] = m_new
            new_arrays[f"opt/v/{name}"] = v_new
        # PaCA merged weights: row-sliced AdamW + scatter (paper Eq. 11).
        # (axis 0 is the selected axis for both linears (d_in) and IOHW
        # convs (input channels), so one code path serves both.)
        for s in paca_specs:
            w = params[s.name]
            idx = params[s.name.rsplit("/", 1)[0] + "/idx"]
            p_cur = jnp.take(w, idx, axis=0)
            p_new, m_new, v_new = adamw_update(
                p_cur, g_dum[s.name], arrays[f"opt/m/{s.name}"],
                arrays[f"opt/v/{s.name}"], step, lr, hp)
            new_arrays[s.name] = w.at[idx, :].set(p_new)
            new_arrays[f"opt/m/{s.name}"] = m_new
            new_arrays[f"opt/v/{s.name}"] = v_new
        new_arrays["opt/step"] = step + 1

        outs = [new_arrays[e.name] for e in entries if e.updated]
        return tuple(outs + [loss, acc])

    return fn, entries, b_entries, params0, reg


def build_eval_step(cfg: ModelConfig, pcfg: PeftConfig, batch: int,
                    seq: int, kind: str = "lm"):
    """Eval graph: inputs = [param entries…, batch…] -> (loss, acc)."""
    key = jax.random.PRNGKey(0)
    if kind == "lm":
        params0, reg = lm.init_lm(key, cfg, pcfg)
    elif kind == "cnn":
        params0, reg = cnn_mod.init_cnn(key, cfg, pcfg)
    else:
        params0, reg = vit_mod.init_vit(key, cfg, pcfg)
    specs = reg.specs
    entries = [StateEntry(s.name, s.shape, s.dtype, s.role, s.init, False)
               for s in specs]
    b_entries = batch_entries(kind, batch, seq)

    def fn(*args):
        n = len(entries)
        params = {s.name: a for s, a in zip(specs, args[:n])}
        rest = args[n:]
        if kind == "lm":
            loss, acc = lm.loss_and_acc(params, rest[0], cfg, pcfg, None)
        elif kind == "cnn":
            loss, acc = cnn_mod.loss_and_acc(params, rest[0], rest[1],
                                             pcfg, None)
        else:
            loss, acc = vit_mod.loss_and_acc(params, rest[0], rest[1],
                                             cfg, pcfg, None)
        return loss, acc

    return fn, entries, b_entries, params0, reg


def initial_state(entries: List[StateEntry],
                  params0: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    """Python-side initial state (tests / python-driven runs)."""
    out = []
    for e in entries:
        if e.name in params0:
            out.append(params0[e.name])
        elif e.role in ("opt_m", "opt_v"):
            out.append(jnp.zeros(e.shape, jnp.float32))
        elif e.role == "opt_step":
            out.append(jnp.array(1, jnp.int32))
        else:
            raise KeyError(e.name)
    return out
