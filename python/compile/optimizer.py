"""AdamW, expressed per-leaf so the train step can attach full moments to
`trainable` leaves and row-sliced (r, d_out) moments to PaCA's merged
weights. The learning rate is a *runtime scalar input* of the lowered
graph; warmup/cosine/linear schedules are computed host-side by the rust
coordinator (rust/src/coordinator/schedule.rs), keeping one artifact valid
for any schedule.
"""

from typing import NamedTuple, Tuple

import jax.numpy as jnp


class AdamHP(NamedTuple):
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


def adamw_update(p: jnp.ndarray, g: jnp.ndarray, m: jnp.ndarray,
                 v: jnp.ndarray, step: jnp.ndarray, lr: jnp.ndarray,
                 hp: AdamHP) -> Tuple[jnp.ndarray, jnp.ndarray,
                                      jnp.ndarray]:
    """One AdamW step. `step` is the 1-based iteration count (i32 scalar),
    `lr` a f32 scalar. Returns (p', m', v')."""
    t = step.astype(jnp.float32)
    m_new = hp.beta1 * m + (1.0 - hp.beta1) * g
    v_new = hp.beta2 * v + (1.0 - hp.beta2) * jnp.square(g)
    m_hat = m_new / (1.0 - hp.beta1 ** t)
    v_hat = v_new / (1.0 - hp.beta2 ** t)
    update = m_hat / (jnp.sqrt(v_hat) + hp.eps) + hp.weight_decay * p
    return p - lr * update, m_new, v_new
