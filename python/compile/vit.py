"""Tiny Vision Transformer for the paper's Appendix-B generality study
(Table 6: ViT-B/16 LoRA vs PaCA on four image datasets).

Patch-embeds 32×32×3 images with a 4×4 patch linear, prepends a class
token, runs pre-norm transformer blocks (GELU MLP — ViT, not SwiGLU),
and classifies from the class token. PEFT targets: q,k,v,o,up,down
(fc1/fc2 mapped onto up/down so the PEFT machinery is shared with the LM).
"""

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, PeftConfig
from .model import rmsnorm
from .peft import ParamSpec, Registry, apply_linear, init_linear

IMG = 32
PATCH = 4
N_PATCHES = (IMG // PATCH) ** 2          # 64
N_CLASSES = 10
VIT_TARGETS = ("q", "k", "v", "o", "up", "down")


def init_vit(key, cfg: ModelConfig, pcfg: PeftConfig
             ) -> Tuple[Dict[str, jnp.ndarray], Registry]:
    reg = Registry()
    params: Dict[str, jnp.ndarray] = {}
    full = pcfg.method == "full"
    base_role = "trainable" if full else "frozen"
    d = cfg.d_model

    def add(name, arr, role, init):
        params[name] = arr
        reg.add(ParamSpec(name, tuple(arr.shape), "f32", role, init,
                          tuple(arr.shape) if role == "trainable" else None))

    keys = jax.random.split(key, cfg.n_layers + 4)
    patch_dim = PATCH * PATCH * 3
    add("patch/w", jax.random.normal(keys[0], (patch_dim, d)) * 0.02,
        base_role, {"kind": "normal", "std": 0.02})
    add("cls", jnp.zeros((1, 1, d)), base_role, {"kind": "zeros"})
    add("pos", jax.random.normal(keys[1], (1, N_PATCHES + 1, d)) * 0.02,
        base_role, {"kind": "normal", "std": 0.02})

    shapes = {"q": (d, d), "k": (d, d), "v": (d, d), "o": (d, d),
              "up": (d, cfg.d_ff), "down": (cfg.d_ff, d)}
    for layer in range(cfg.n_layers):
        lkeys = jax.random.split(keys[2 + layer], len(VIT_TARGETS))
        pre = f"blocks/{layer}"
        add(f"{pre}/ln1/g", jnp.ones(d), base_role, {"kind": "ones"})
        add(f"{pre}/ln2/g", jnp.ones(d), base_role, {"kind": "ones"})
        for t_i, tname in enumerate(VIT_TARGETS):
            d_in, d_out = shapes[tname]
            params.update(init_linear(
                lkeys[t_i], reg, f"{pre}/{tname}", d_in, d_out, pcfg,
                seed_tag=layer * 10 + t_i))

    add("lnf/g", jnp.ones(d), base_role, {"kind": "ones"})
    # The classification head is newly initialized and always trainable
    # (standard fine-tuning practice; same for LoRA in the paper's setup).
    params["head/w"] = jax.random.normal(keys[-1], (d, N_CLASSES)) * 0.02
    reg.add(ParamSpec("head/w", (d, N_CLASSES), "f32", "trainable",
                      {"kind": "normal", "std": 0.02}, (d, N_CLASSES)))
    return params, reg


def patchify(images: jnp.ndarray) -> jnp.ndarray:
    """(B, 3, 32, 32) -> (B, 64, 48) patch vectors."""
    b = images.shape[0]
    g = IMG // PATCH
    x = images.reshape(b, 3, g, PATCH, g, PATCH)
    x = x.transpose(0, 2, 4, 3, 5, 1)            # (B, g, g, P, P, 3)
    return x.reshape(b, N_PATCHES, PATCH * PATCH * 3)


def forward(params, images, cfg: ModelConfig, pcfg: PeftConfig,
            paca_dummies: Optional[Dict] = None) -> jnp.ndarray:
    """images: (B, 3, 32, 32) -> logits (B, N_CLASSES)."""
    b = images.shape[0]
    h = patchify(images) @ params["patch/w"]              # (B, 64, d)
    cls = jnp.broadcast_to(params["cls"], (b, 1, cfg.d_model))
    h = jnp.concatenate([cls, h], axis=1) + params["pos"]
    s = h.shape[1]

    def lin(name, x):
        return apply_linear(params, name, x, pcfg, paca_dummies)

    def heads_(x):
        return x.reshape(b, s, cfg.n_heads, cfg.head_dim) \
                .transpose(0, 2, 1, 3)

    for layer in range(cfg.n_layers):
        pre = f"blocks/{layer}"
        xn = rmsnorm(h, params[f"{pre}/ln1/g"])
        q, k, v = heads_(lin(f"{pre}/q", xn)), heads_(lin(f"{pre}/k", xn)), \
            heads_(lin(f"{pre}/v", xn))
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (cfg.head_dim ** 0.5)
        att = jax.nn.softmax(att, axis=-1)      # bidirectional (ViT)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        h = h + lin(f"{pre}/o", ctx)
        xn = rmsnorm(h, params[f"{pre}/ln2/g"])
        h = h + lin(f"{pre}/down", jax.nn.gelu(lin(f"{pre}/up", xn)))

    h = rmsnorm(h, params["lnf/g"])
    return h[:, 0, :] @ params["head/w"]


def loss_and_acc(params, images, labels, cfg, pcfg,
                 paca_dummies: Optional[Dict] = None):
    logits = forward(params, images, cfg, pcfg, paca_dummies)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels)
                   .astype(jnp.float32))
    return loss, acc
