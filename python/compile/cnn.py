"""Small CNN for the paper's Table-7 generality claim (EfficientNetV2
Full-FT vs PaCA): PaCA applies directly to convolution kernels — it
fine-tunes a random subset of *input channels* of each conv — which
LoRA's linear adapters cannot do without un-mergeable adapter layers.

Conv weights use IOHW layout so the selected axis is axis 0, letting the
train-step reuse the same gather/scatter row machinery as the LM
(jnp.take(w, idx, axis=0) / w.at[idx].set(p)).
"""

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig, PeftConfig
from .peft import ParamSpec, Registry

N_CLASSES = 10
# (in_c, out_c, k) per conv stage; stride-2 pooling between stages.
STAGES = [(3, 24, 3), (24, 48, 3), (48, 96, 3)]

DN = jax.lax.conv_dimension_numbers(
    (1, 3, 32, 32), (3, 24, 3, 3),
    ("NCHW", "IOHW", "NCHW"))


def conv(x, w):
    """x: (B, C_in, H, W), w: (C_in, C_out, kh, kw) [IOHW]."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=DN)


# --- PaCA for convolutions -------------------------------------------------
# fwd: y = conv(x, w) — the frozen conv kernel, unchanged.
# bwd: dx via conv transpose with the full kernel; ∇P restricted to the
#      selected input channels, computed from the gathered activations
#      x[:, idx] only (the conv analog of paper Eq. 9).


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def paca_conv(x, w, p_dummy, idx):
    del p_dummy, idx
    return conv(x, w)


def _paca_conv_fwd(x, w, p_dummy, idx):
    del p_dummy
    y = conv(x, w)
    xp = jnp.take(x, idx, axis=1)  # partial input channels only
    return y, (xp, w, idx)


def _paca_conv_bwd(res, dy):
    xp, w, idx, = res
    r = idx.shape[0]
    # dx through the full frozen kernel.
    _, vjp_x = jax.vjp(lambda x_: conv(x_, w),
                       jnp.zeros((dy.shape[0], w.shape[0], dy.shape[2],
                                  dy.shape[3]), dy.dtype))
    (dx,) = vjp_x(dy)
    # ∇P from the gathered channels: weight-grad of conv(xp, wp).
    wp0 = jnp.zeros((r,) + w.shape[1:], w.dtype)
    _, vjp_w = jax.vjp(lambda wp: conv(xp, wp), wp0)
    (dp,) = vjp_w(dy)
    dw = jnp.zeros_like(w)
    didx = np.zeros(idx.shape, jax.dtypes.float0)
    return dx, dw, dp, didx


paca_conv.defvjp(_paca_conv_fwd, _paca_conv_bwd)


def init_cnn(key, cfg: ModelConfig, pcfg: PeftConfig
             ) -> Tuple[Dict[str, jnp.ndarray], Registry]:
    """cfg is unused except for naming symmetry (the CNN is fixed-size);
    pcfg.method must be 'full' or 'paca'."""
    del cfg
    assert pcfg.method in ("full", "paca"), pcfg.method
    reg = Registry()
    params: Dict[str, jnp.ndarray] = {}
    keys = jax.random.split(key, len(STAGES) + 1)

    for i, (cin, cout, k) in enumerate(STAGES):
        name = f"convs/{i}/w"
        fan_in = cin * k * k
        std = float((2.0 / fan_in) ** 0.5)
        w = jax.random.normal(keys[i], (cin, cout, k, k)) * std
        if pcfg.method == "full":
            params[name] = w
            reg.add(ParamSpec(name, tuple(w.shape), "f32", "trainable",
                              {"kind": "normal", "std": round(std, 6)},
                              tuple(w.shape)))
        else:
            r = min(pcfg.rank, cin)
            params[name] = w
            reg.add(ParamSpec(name, tuple(w.shape), "f32", "paca_w",
                              {"kind": "normal", "std": round(std, 6)},
                              (r, cout, k, k)))
            idx = jax.random.permutation(keys[i], cin)[:r] \
                .astype(jnp.int32)
            iname = f"convs/{i}/idx"
            params[iname] = idx
            reg.add(ParamSpec(iname, (r,), "i32", "index",
                              {"kind": "choice", "n": cin}, None))

    head_in = STAGES[-1][1]
    hw = jax.random.normal(keys[-1], (head_in, N_CLASSES)) * 0.02
    params["head/w"] = hw
    reg.add(ParamSpec("head/w", (head_in, N_CLASSES), "f32",
                      "trainable", {"kind": "normal", "std": 0.02},
                      (head_in, N_CLASSES)))
    return params, reg


def pool2(x):
    """2×2 mean pool, NCHW."""
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).mean(axis=(3, 5))


def forward(params, images, pcfg: PeftConfig,
            paca_dummies: Optional[Dict] = None) -> jnp.ndarray:
    h = images
    for i in range(len(STAGES)):
        name = f"convs/{i}/w"
        if pcfg.method == "paca":
            dummy = (paca_dummies or {}).get(
                name, jnp.zeros((params[f"convs/{i}/idx"].shape[0],)
                                + params[name].shape[1:], jnp.float32))
            h = paca_conv(h, params[name], dummy,
                          params[f"convs/{i}/idx"])
        else:
            h = conv(h, params[name])
        h = jax.nn.silu(h)
        h = pool2(h)
    h = h.mean(axis=(2, 3))  # global average pool -> (B, C)
    return h @ params["head/w"]


def loss_and_acc(params, images, labels, pcfg,
                 paca_dummies: Optional[Dict] = None):
    logits = forward(params, images, pcfg, paca_dummies)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels)
                   .astype(jnp.float32))
    return loss, acc
