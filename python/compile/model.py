"""L2: LLaMA-style decoder-only transformer (fwd + loss) with pluggable
PEFT parameterization on the paper's seven target matrices per block
(Q, K, V, O, Gate, Up, Down — Appendix C).

Architecture follows LLaMA: RMSNorm pre-normalization, rotary position
embeddings, SwiGLU MLP, untied LM head. Embedding / norms / head are
frozen under every PEFT method (trainable under `full`), matching the
paper's target-module list.

All functions are pure; parameters are a flat '/'-keyed dict produced by
`init_lm`, with a parallel `Registry` of specs for the AOT manifest.
"""

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, PeftConfig, TARGET_MODULES
from .peft import ParamSpec, Registry, apply_linear, init_linear


def rmsnorm(x, g, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope_tables(seq: int, head_dim: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables (seq, head_dim/2), base 10000 (LLaMA)."""
    half = head_dim // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, H, S, hd); rotate feature pairs."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def init_lm(key, cfg: ModelConfig, pcfg: PeftConfig
            ) -> Tuple[Dict[str, jnp.ndarray], Registry]:
    """Initialize params + spec registry. Embedding/norms/head are
    `trainable` only under full fine-tuning."""
    reg = Registry()
    params: Dict[str, jnp.ndarray] = {}
    full = pcfg.method == "full"
    base_role = "trainable" if full else "frozen"

    def add(name, arr, role, init):
        params[name] = arr
        reg.add(ParamSpec(name, tuple(arr.shape), "f32", role, init,
                          tuple(arr.shape) if role == "trainable" else None))

    keys = jax.random.split(key, cfg.n_layers + 3)
    add("embed/w",
        jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02,
        base_role, {"kind": "normal", "std": 0.02})

    shapes = cfg.linear_shapes()
    for layer in range(cfg.n_layers):
        lkeys = jax.random.split(keys[1 + layer], len(TARGET_MODULES) + 2)
        pre = f"blocks/{layer}"
        add(f"{pre}/ln1/g", jnp.ones(cfg.d_model), base_role,
            {"kind": "ones"})
        add(f"{pre}/ln2/g", jnp.ones(cfg.d_model), base_role,
            {"kind": "ones"})
        for t_i, tname in enumerate(TARGET_MODULES):
            d_in, d_out = shapes[tname]
            params.update(init_linear(
                lkeys[t_i], reg, f"{pre}/{tname}", d_in, d_out, pcfg,
                seed_tag=layer * 10 + t_i))

    add("lnf/g", jnp.ones(cfg.d_model), base_role, {"kind": "ones"})
    add("head/w",
        jax.random.normal(keys[-1], (cfg.d_model, cfg.vocab)) * 0.02,
        base_role, {"kind": "normal", "std": 0.02})
    return params, reg


def forward(params: Dict[str, jnp.ndarray], tokens: jnp.ndarray,
            cfg: ModelConfig, pcfg: PeftConfig,
            paca_dummies: Optional[Dict] = None) -> jnp.ndarray:
    """tokens: (B, S) int32 -> logits (B, S, V)."""
    b, s = tokens.shape
    h = jnp.take(params["embed/w"], tokens, axis=0)  # (B, S, d)
    cos, sin = rope_tables(s, cfg.head_dim)
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))

    def lin(name, x):
        return apply_linear(params, name, x, pcfg, paca_dummies)

    def heads(x):
        return x.reshape(b, s, cfg.n_heads, cfg.head_dim) \
                .transpose(0, 2, 1, 3)

    for layer in range(cfg.n_layers):
        pre = f"blocks/{layer}"
        # --- attention ---
        xn = rmsnorm(h, params[f"{pre}/ln1/g"])
        q = heads(lin(f"{pre}/q", xn))
        k = heads(lin(f"{pre}/k", xn))
        v = heads(lin(f"{pre}/v", xn))
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (cfg.head_dim ** 0.5)
        att = jnp.where(causal[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        h = h + lin(f"{pre}/o", ctx)
        # --- SwiGLU MLP ---
        xn = rmsnorm(h, params[f"{pre}/ln2/g"])
        gate = lin(f"{pre}/gate", xn)
        up = lin(f"{pre}/up", xn)
        h = h + lin(f"{pre}/down", jax.nn.silu(gate) * up)

    h = rmsnorm(h, params["lnf/g"])
    return h @ params["head/w"]


def loss_and_acc(params, tokens_full, cfg: ModelConfig, pcfg: PeftConfig,
                 paca_dummies: Optional[Dict] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens_full: (B, S+1); next-token cross-entropy + token accuracy."""
    inputs = tokens_full[:, :-1]
    targets = tokens_full[:, 1:]
    logits = forward(params, inputs, cfg, pcfg, paca_dummies)
    v = logits.shape[-1]
    flat = logits.reshape(-1, v)
    tflat = targets.reshape(-1)
    logz = jax.nn.logsumexp(flat, axis=-1)
    gold = jnp.take_along_axis(flat, tflat[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(flat, axis=-1) == tflat)
                   .astype(jnp.float32))
    return loss, acc
